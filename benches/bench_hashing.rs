//! Hashing-substrate throughput (supports Table 2's preprocessing numbers
//! and DESIGN.md §Perf).  Reports ns/doc and hashes/s for every hashing
//! method at paper-relevant parameters.
//!
//! Run: `cargo bench --bench bench_hashing`

use bbit_mh::hashing::minwise::{BbitMinHash, MinwiseHasher, PermutationMinwise};
use bbit_mh::hashing::permutation::FeistelPermutation;
use bbit_mh::hashing::rp::RandomProjection;
use bbit_mh::hashing::universal::{UniversalFamily, UniversalHash};
use bbit_mh::hashing::vw::VwHasher;
use bbit_mh::util::bench::{black_box, Bench};
use bbit_mh::util::Rng;

fn main() {
    let mut rng = Rng::new(0xBE7C);
    let d = 1u64 << 30;
    let doc: Vec<u32> = rng
        .sample_distinct(d, 800)
        .into_iter()
        .map(|x| x as u32)
        .collect();
    let mut b = Bench::default();

    // raw 2-universal hash
    let h = UniversalHash::draw(&mut rng);
    b.bench_elems("universal_hash/800_indices", 800, || {
        let mut acc = 0u64;
        for &t in &doc {
            acc ^= h.hash(t, d);
        }
        acc
    });

    // minwise at the paper's k values
    for k in [30usize, 200, 500] {
        let mh = MinwiseHasher::draw(k, d, &mut rng);
        let mut out = vec![0u64; k];
        b.bench_elems(&format!("minwise/k={k}/nnz=800"), (k * 800) as u64, || {
            mh.hash_into(&doc, &mut out);
            out[0]
        });
    }

    // b-bit pack path (hash + truncate + pack)
    let bb = BbitMinHash::draw(200, 8, d, &mut rng);
    let mut scratch = vec![0u64; 200];
    let mut codes = vec![0u16; 200];
    b.bench_elems("bbit_codes/b=8_k=200/nnz=800", 200 * 800, || {
        bb.codes_into(&doc, &mut scratch, &mut codes);
        codes[0]
    });

    // permutation-based minwise (Figure 8 arm) — Feistel costs more per
    // application; this quantifies the gap vs 2-universal
    let perms: Vec<FeistelPermutation> =
        (0..64).map(|_| FeistelPermutation::draw(d, &mut rng)).collect();
    let pm = PermutationMinwise::new(perms);
    let mut out = vec![0u64; 64];
    b.bench_elems("perm_minwise/k=64/nnz=800", 64 * 800, || {
        pm.hash_into(&doc, &mut out);
        out[0]
    });

    // VW hashing at paper bin counts
    for bins in [1024usize, 16384] {
        let vw = VwHasher::draw(bins, &mut rng);
        let mut out = vec![0.0f32; bins];
        b.bench_elems(&format!("vw_hash/bins={bins}/nnz=800"), 800, || {
            out.fill(0.0);
            vw.hash_into(&doc, &mut out);
            black_box(out[0])
        });
    }

    // random projections (much slower per sample — why the paper's world
    // moved to hashing; k small on purpose)
    let rp = RandomProjection::new(16, 1.0, &mut rng);
    b.bench_elems("random_projection/k=16/nnz=800", 16 * 800, || {
        rp.project_set(&doc)[0]
    });

    // packed-codes roundtrip
    let fam = UniversalFamily::draw(200, d, &mut rng);
    let _ = fam;
    let mut pc = bbit_mh::encode::packed::PackedCodes::new(8, 200);
    pc.push_row(&codes).unwrap();
    b.bench_elems("packed_get/row_of_200", 200, || {
        let mut acc = 0u16;
        for j in 0..200 {
            acc ^= pc.get(0, j);
        }
        acc
    });
}
