//! End-to-end pipeline throughput + ablations over the coordinator's
//! tuning knobs (worker count, chunk size, queue depth) — the DESIGN.md
//! §Perf L3 target is that hashing saturates the parse rate — plus the
//! serving path: a resident model server driven over loopback by the
//! crate's load generator (`serve::loadgen`), with the report dumped to
//! `BENCH_serve.json`.
//!
//! Run: `cargo bench --bench bench_pipeline`
//! One scenario group: `cargo bench --bench bench_pipeline -- serve`
//! (any prefix of the scenario names: `pipeline`, `serve`)

use std::time::Duration;

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::{CacheSink, TrainSink};
use bbit_mh::data::expand::{expand_dataset, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::serve::{loadgen, LoadgenConfig, ModelServer, ServeConfig};
use bbit_mh::solver::{LinearModel, SavedModel, SgdConfig, SgdLoss};
use bbit_mh::util::bench::Bench;

fn main() {
    // optional scenario filter (the args cargo passes after `--`)
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let should = |name: &str| match &filter {
        None => true,
        Some(f) => name.starts_with(f.as_str()),
    };
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs: 800,
        vocab: 2500,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 0x9199,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 2500, dim: 1 << 30, three_way_rate: 30, seed: 4 };
    let ds = expand_dataset(&cfg, &base);
    println!("corpus: {} docs, mean nnz {:.0}\n", ds.len(), ds.stats().nnz_mean);
    let job = EncoderSpec::Bbit { b: 8, k: 200, d: 1 << 30, seed: 11 };
    let mut b = Bench::quick();

    if !should("pipeline") {
        if should("serve") {
            run_serve_scenario(&ds);
        }
        return;
    }

    // worker scaling
    for workers in [1usize, 2, 4, bbit_mh::config::available_workers()] {
        let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 128, queue_depth: 4 });
        b.bench_elems(&format!("pipeline/workers={workers}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), &job).unwrap().1.docs
        });
    }

    // chunk-size ablation (scheduling granularity vs channel overhead)
    for chunk in [16usize, 64, 256, 1024] {
        let pipe = Pipeline::new(PipelineConfig {
            workers: bbit_mh::config::available_workers(),
            chunk_size: chunk,
            queue_depth: 4,
        });
        b.bench_elems(&format!("pipeline/chunk={chunk}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, chunk), &job).unwrap().1.docs
        });
    }

    // queue-depth ablation (backpressure head-room)
    for depth in [1usize, 2, 8] {
        let pipe = Pipeline::new(PipelineConfig {
            workers: bbit_mh::config::available_workers(),
            chunk_size: 128,
            queue_depth: depth,
        });
        b.bench_elems(&format!("pipeline/queue_depth={depth}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), &job).unwrap().1.docs
        });
    }

    // sink comparison: same hash job through the three out-of-core sinks
    // (collect = materialize in memory, cache = stream to disk,
    //  train = one-pass SGD), plus the reorder-window high-water mark
    let pipe = Pipeline::new(PipelineConfig {
        workers: bbit_mh::config::available_workers(),
        chunk_size: 128,
        queue_depth: 4,
    });
    let sink_job = EncoderSpec::Bbit { b: 8, k: 64, d: 1 << 30, seed: 11 };
    let mut peaks: Vec<(String, usize)> = Vec::new();

    let mut peak = 0usize;
    b.bench_elems("pipeline/sink=collect", ds.len() as u64, || {
        let (out, report) = pipe.run(dataset_chunks(&ds, 128), &sink_job).unwrap();
        peak = peak.max(report.reorder_peak);
        out.len()
    });
    peaks.push(("collect".into(), peak));

    let cache_path = std::env::temp_dir().join(format!("bbit_bench_{}.cache", std::process::id()));
    let mut peak = 0usize;
    b.bench_elems("pipeline/sink=cache", ds.len() as u64, || {
        let mut sink = CacheSink::create(&cache_path, &sink_job).unwrap();
        let report = pipe.run_sink(dataset_chunks(&ds, 128), &sink_job, &mut sink).unwrap();
        peak = peak.max(report.reorder_peak);
        report.docs
    });
    peaks.push(("cache".into(), peak));
    std::fs::remove_file(&cache_path).ok();

    let sgd = SgdConfig {
        loss: SgdLoss::Logistic,
        lr0: 0.5,
        lambda: 1e-4,
        epochs: 1,
        batch: 256,
    };
    let mut peak = 0usize;
    b.bench_elems("pipeline/sink=train", ds.len() as u64, || {
        let mut sink = TrainSink::new(sgd.clone(), 8, 64);
        let report = pipe.run_sink(dataset_chunks(&ds, 128), &sink_job, &mut sink).unwrap();
        peak = peak.max(report.reorder_peak);
        report.docs
    });
    peaks.push(("train".into(), peak));

    println!("\nreorder-window peaks (chunks; hard bound = 2·(workers+queue_depth)):");
    for (name, peak) in &peaks {
        println!("  sink={name:<8} peak={peak}");
    }

    // encoder throughput: the same corpus through the trait-object worker
    // path for each scheme at comparable storage (bbit/oph: 8 bits × 200;
    // vw: 1024 bins).  OPH's one-pass hashing should dominate bbit's
    // k-pass hashing here — that gap is the scheme's whole point.
    println!();
    let encoder_specs = [
        ("bbit", EncoderSpec::Bbit { b: 8, k: 200, d: 1 << 30, seed: 11 }),
        ("vw", EncoderSpec::Vw { bins: 1024, seed: 11 }),
        ("oph", EncoderSpec::Oph { bins: 200, b: 8, seed: 11 }),
    ];
    for (name, spec) in &encoder_specs {
        b.bench_elems(&format!("pipeline/encoder={name}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), spec).unwrap().1.docs
        });
    }

    if should("serve") {
        run_serve_scenario(&ds);
    }
}

/// The serving path: a resident model behind the micro-batched server,
/// driven over loopback by `serve::loadgen` at two target rates.  The
/// higher-rate report is dumped to `BENCH_serve.json` so the serving path
/// gets the same longitudinal tracking as the hashing scenarios.
fn run_serve_scenario(ds: &bbit_mh::data::SparseDataset) {
    println!();
    let spec = EncoderSpec::Oph { bins: 200, b: 8, seed: 11 };
    let w: Vec<f32> = (0..spec.output_dim()).map(|j| (j as f32 * 0.173).sin()).collect();
    let model = SavedModel::new(spec, LinearModel { w }).unwrap();
    let model_path =
        std::env::temp_dir().join(format!("bbit_bench_{}.bbmh", std::process::id()));
    model.save(&model_path).unwrap();
    let server = ModelServer::start(
        &model_path,
        ServeConfig {
            scorer_workers: 2,
            batch_max: 64,
            batch_wait: Duration::from_micros(100),
            queue_cap: 4096,
            deadline: Duration::from_millis(100),
            ..Default::default()
        },
    )
    .unwrap();
    // score the same expanded documents the hashing scenarios preprocess
    let docs: Vec<String> = (0..ds.len().min(256))
        .map(|i| {
            let mut line = String::from("+1");
            for &t in ds.row(i).0 {
                line.push_str(&format!(" {t}:1"));
            }
            line
        })
        .collect();
    for qps in [1000.0, 4000.0] {
        let report = loadgen::run(
            server.local_addr(),
            &LoadgenConfig {
                qps,
                duration: Duration::from_millis(800),
                connections: 4,
                docs: docs.clone(),
            },
        )
        .unwrap();
        println!("serve/loadgen qps_target={qps}: {}", report.summary());
        if qps == 4000.0 {
            std::fs::write("BENCH_serve.json", report.to_json() + "\n").ok();
        }
    }
    println!("serve/shutdown-report:");
    print!("{}", server.shutdown());
    std::fs::remove_file(&model_path).ok();
}
