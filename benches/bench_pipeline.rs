//! End-to-end pipeline throughput + ablations over the coordinator's
//! tuning knobs (worker count, chunk size, queue depth) — the DESIGN.md
//! §Perf L3 target is that hashing saturates the parse rate — plus the
//! serving path: a resident model server driven over loopback by the
//! crate's load generator (`serve::loadgen`), with the report dumped to
//! `BENCH_serve.json`.
//!
//! Run: `cargo bench --bench bench_pipeline`
//! One scenario group: `cargo bench --bench bench_pipeline -- serve`
//! (any prefix of the scenario names: `pipeline`, `ingest`, `replay`,
//! `serve`, `matrix`)
//!
//! The `matrix` scenario is the standing benchmark matrix: one corpus
//! through train-no-cache / train-from-cache / predict / serve, reporting
//! runtime, rows/s and peak RSS per cell — plus the scalar-vs-unrolled
//! kernel speedup (the train/score inner loops of `bbit_mh::kernels`,
//! A/B'd in-process via `kernels::force_scalar`).  Results land in
//! `BENCH_matrix.json`; CI gates them against
//! `benches/baselines/BENCH_matrix.baseline.json`.
//!
//! The `ingest` scenario times raw-input parsing — the legacy line reader
//! vs. the byte-block parser (1 thread and W workers) vs. raw read
//! throughput — plus end-to-end `preprocess`, whose ratio to raw load
//! time is the paper's Table-2 "preprocessing costs about as much as
//! loading" claim; results land in `BENCH_ingest.json`.
//!
//! The `replay` scenario times cache replay — sequential vs. the
//! N-thread reader pool over the same v3 cache — reporting rows/s and
//! MB/s, and dumps the comparison to `BENCH_replay.json` (the paper's
//! "many cheap training runs over one cache" loop is exactly this read
//! path).

use std::time::Duration;

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::{CacheSink, TrainSink};
use bbit_mh::data::expand::{expand_dataset, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::serve::{loadgen, LoadgenConfig, ModelServer, ServeConfig};
use bbit_mh::solver::{LinearModel, SavedModel, SgdConfig, SgdLoss};
use bbit_mh::util::bench::Bench;

fn main() {
    // optional scenario filter (the args cargo passes after `--`)
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let should = |name: &str| match &filter {
        None => true,
        Some(f) => name.starts_with(f.as_str()),
    };
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs: 800,
        vocab: 2500,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 0x9199,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 2500, dim: 1 << 30, three_way_rate: 30, seed: 4 };
    let ds = expand_dataset(&cfg, &base);
    println!("corpus: {} docs, mean nnz {:.0}\n", ds.len(), ds.stats().nnz_mean);
    let job = EncoderSpec::Bbit { b: 8, k: 200, d: 1 << 30, seed: 11 };
    let mut b = Bench::quick();

    if !should("pipeline") {
        if should("ingest") {
            run_ingest_scenario();
        }
        if should("replay") {
            run_replay_scenario();
        }
        if should("serve") {
            run_serve_scenario(&ds);
        }
        if should("matrix") {
            run_matrix_scenario();
        }
        return;
    }

    // worker scaling
    for workers in [1usize, 2, 4, bbit_mh::config::available_workers()] {
        let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 128, queue_depth: 4 });
        b.bench_elems(&format!("pipeline/workers={workers}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), &job).unwrap().1.docs
        });
    }

    // chunk-size ablation (scheduling granularity vs channel overhead)
    for chunk in [16usize, 64, 256, 1024] {
        let pipe = Pipeline::new(PipelineConfig {
            workers: bbit_mh::config::available_workers(),
            chunk_size: chunk,
            queue_depth: 4,
        });
        b.bench_elems(&format!("pipeline/chunk={chunk}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, chunk), &job).unwrap().1.docs
        });
    }

    // queue-depth ablation (backpressure head-room)
    for depth in [1usize, 2, 8] {
        let pipe = Pipeline::new(PipelineConfig {
            workers: bbit_mh::config::available_workers(),
            chunk_size: 128,
            queue_depth: depth,
        });
        b.bench_elems(&format!("pipeline/queue_depth={depth}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), &job).unwrap().1.docs
        });
    }

    // sink comparison: same hash job through the three out-of-core sinks
    // (collect = materialize in memory, cache = stream to disk,
    //  train = one-pass SGD), plus the reorder-window high-water mark
    let pipe = Pipeline::new(PipelineConfig {
        workers: bbit_mh::config::available_workers(),
        chunk_size: 128,
        queue_depth: 4,
    });
    let sink_job = EncoderSpec::Bbit { b: 8, k: 64, d: 1 << 30, seed: 11 };
    let mut peaks: Vec<(String, usize)> = Vec::new();

    let mut peak = 0usize;
    b.bench_elems("pipeline/sink=collect", ds.len() as u64, || {
        let (out, report) = pipe.run(dataset_chunks(&ds, 128), &sink_job).unwrap();
        peak = peak.max(report.reorder_peak);
        out.len()
    });
    peaks.push(("collect".into(), peak));

    let cache_path = std::env::temp_dir().join(format!("bbit_bench_{}.cache", std::process::id()));
    let mut peak = 0usize;
    b.bench_elems("pipeline/sink=cache", ds.len() as u64, || {
        let mut sink = CacheSink::create(&cache_path, &sink_job).unwrap();
        let report = pipe.run_sink(dataset_chunks(&ds, 128), &sink_job, &mut sink).unwrap();
        peak = peak.max(report.reorder_peak);
        report.docs
    });
    peaks.push(("cache".into(), peak));
    std::fs::remove_file(&cache_path).ok();

    let sgd = SgdConfig {
        loss: SgdLoss::Logistic,
        lr0: 0.5,
        lambda: 1e-4,
        epochs: 1,
        batch: 256,
    };
    let mut peak = 0usize;
    b.bench_elems("pipeline/sink=train", ds.len() as u64, || {
        let mut sink = TrainSink::new(sgd.clone(), 8, 64);
        let report = pipe.run_sink(dataset_chunks(&ds, 128), &sink_job, &mut sink).unwrap();
        peak = peak.max(report.reorder_peak);
        report.docs
    });
    peaks.push(("train".into(), peak));

    println!("\nreorder-window peaks (chunks; hard bound = 2·(workers+queue_depth)):");
    for (name, peak) in &peaks {
        println!("  sink={name:<8} peak={peak}");
    }

    // encoder throughput: the same corpus through the trait-object worker
    // path for each scheme at comparable storage (bbit/oph: 8 bits × 200;
    // vw: 1024 bins).  OPH's one-pass hashing should dominate bbit's
    // k-pass hashing here — that gap is the scheme's whole point.
    println!();
    let encoder_specs = [
        ("bbit", EncoderSpec::Bbit { b: 8, k: 200, d: 1 << 30, seed: 11 }),
        ("vw", EncoderSpec::Vw { bins: 1024, seed: 11 }),
        ("oph", EncoderSpec::Oph { bins: 200, b: 8, seed: 11 }),
    ];
    for (name, spec) in &encoder_specs {
        b.bench_elems(&format!("pipeline/encoder={name}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), spec).unwrap().1.docs
        });
    }

    if should("ingest") {
        run_ingest_scenario();
    }
    if should("replay") {
        run_replay_scenario();
    }
    if should("serve") {
        run_serve_scenario(&ds);
    }
    if should("matrix") {
        run_matrix_scenario();
    }
}

/// The standing benchmark matrix (train-no-cache / train-from-cache /
/// predict / serve), fwumious-BENCHMARK-style: one corpus, every cell
/// reporting wall time, rows/s and peak RSS, plus the scalar-vs-unrolled
/// kernel speedup on the replay-train and predict cells.  Peak RSS is the
/// process high-water mark (`VmHWM`), so later cells report upper bounds.
/// Everything lands in `BENCH_matrix.json`.
fn run_matrix_scenario() {
    use bbit_mh::data::libsvm::{BlockReader, LibsvmWriter};
    use bbit_mh::kernels;
    use bbit_mh::solver::{eval_from_cache, train_from_cache};
    use bbit_mh::util::bench::peak_rss_bytes;

    println!();
    let corpus = CorpusGenerator::new(CorpusConfig {
        n_docs: 12_288,
        vocab: 2500,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 0xA7121,
    })
    .generate();
    let rows = corpus.len();
    let (b, k) = (8u32, 200usize);
    let spec = EncoderSpec::Bbit { b, k, d: 1 << 30, seed: 11 };
    let pid = std::process::id();
    let svm_path = std::env::temp_dir().join(format!("bbit_bench_matrix_{pid}.svm"));
    let cache_path = std::env::temp_dir().join(format!("bbit_bench_matrix_{pid}.cache"));
    {
        let mut w = LibsvmWriter::create(&svm_path).unwrap();
        w.write_dataset(&corpus).unwrap();
        w.finish().unwrap();
    }
    let pipe = Pipeline::new(PipelineConfig {
        workers: bbit_mh::config::available_workers(),
        chunk_size: 256,
        queue_depth: 4,
    });
    {
        let mut sink = CacheSink::create(&cache_path, &spec).unwrap();
        pipe.run_sink_blocks(BlockReader::open(&svm_path).unwrap(), true, &spec, &mut sink)
            .unwrap();
    }
    let epochs = 2usize;
    let sgd = SgdConfig { loss: SgdLoss::Logistic, lr0: 0.5, lambda: 1e-4, epochs, batch: 256 };
    let best = |reps: usize, f: &mut dyn FnMut()| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let cell = |name: &str, trained_rows: f64, secs: f64| {
        println!(
            "matrix/{name:<18} {:8.2} ms  ({:9.0} rows/s, peak RSS {:.1} MB)",
            secs * 1e3,
            trained_rows / secs,
            peak_rss_bytes() as f64 / 1e6,
        );
    };

    // --- train-no-cache: one-pass parse + hash + SGD (stream, no disk) ---
    let stream_cfg = SgdConfig { epochs: 1, ..sgd.clone() };
    let no_cache_s = best(3, &mut || {
        let mut sink = TrainSink::new(stream_cfg.clone(), b, k);
        pipe.run_sink_blocks(BlockReader::open(&svm_path).unwrap(), true, &spec, &mut sink)
            .unwrap();
    });
    let no_cache_rss = peak_rss_bytes();
    cell("train-no-cache", rows as f64, no_cache_s);

    // --- train-from-cache: replay SGD, scalar kernels then unrolled ---
    let trained_rows = (rows * epochs) as f64;
    kernels::force_scalar(true);
    let tc_scalar_s = best(3, &mut || {
        train_from_cache(&cache_path, &sgd).unwrap();
    });
    kernels::force_scalar(false);
    let tc_s = best(3, &mut || {
        train_from_cache(&cache_path, &sgd).unwrap();
    });
    let tc_rss = peak_rss_bytes();
    let (model, _) = train_from_cache(&cache_path, &sgd).unwrap();
    let kernel_speedup = tc_scalar_s / tc_s;
    cell("train-cache-scalar", trained_rows, tc_scalar_s);
    cell("train-from-cache", trained_rows, tc_s);
    println!("matrix/kernel-speedup    {kernel_speedup:.2}x (unrolled over scalar, same replay)");

    // --- predict: score every cached row with the trained model ---
    let saved = SavedModel::new(spec, model).unwrap();
    kernels::force_scalar(true);
    let pred_scalar_s = best(3, &mut || {
        eval_from_cache(&cache_path, &saved, SgdLoss::Logistic).unwrap();
    });
    kernels::force_scalar(false);
    let pred_s = best(3, &mut || {
        eval_from_cache(&cache_path, &saved, SgdLoss::Logistic).unwrap();
    });
    let pred_rss = peak_rss_bytes();
    let pred_speedup = pred_scalar_s / pred_s;
    cell("predict-scalar", rows as f64, pred_scalar_s);
    cell("predict", rows as f64, pred_s);

    // --- serve: the trained model resident behind the scoring endpoint ---
    let model_path = std::env::temp_dir().join(format!("bbit_bench_matrix_{pid}.bbmh"));
    saved.save(&model_path).unwrap();
    let server = ModelServer::start(
        &model_path,
        ServeConfig {
            scorer_workers: 2,
            batch_max: 64,
            batch_wait: Duration::from_micros(100),
            queue_cap: 4096,
            deadline: Duration::from_millis(100),
            ..Default::default()
        },
    )
    .unwrap();
    let docs: Vec<String> = (0..rows.min(256))
        .map(|i| {
            let mut line = String::from("+1");
            for &t in corpus.row(i).0 {
                line.push_str(&format!(" {t}:1"));
            }
            line
        })
        .collect();
    let report = loadgen::run(
        server.local_addr(),
        &LoadgenConfig {
            path: "/score".into(),
            qps: 2000.0,
            duration: Duration::from_millis(800),
            connections: 4,
            docs,
        },
    )
    .unwrap();
    let serve_rss = peak_rss_bytes();
    println!("matrix/serve             {}", report.summary());
    server.shutdown();
    std::fs::remove_file(&model_path).ok();
    std::fs::remove_file(&svm_path).ok();
    std::fs::remove_file(&cache_path).ok();

    let json = format!(
        "{{\"scenario\":\"matrix\",\"rows\":{rows},\"b\":{b},\"k\":{k},\"epochs\":{epochs},\
         \"train_no_cache\":{{\"seconds\":{no_cache_s:.6},\"rows_per_s\":{:.1},\
         \"peak_rss_bytes\":{no_cache_rss}}},\
         \"train_from_cache\":{{\"seconds\":{tc_s:.6},\"rows_per_s\":{:.1},\
         \"scalar_seconds\":{tc_scalar_s:.6},\"scalar_rows_per_s\":{:.1},\
         \"kernel_speedup\":{kernel_speedup:.3},\"peak_rss_bytes\":{tc_rss}}},\
         \"predict\":{{\"seconds\":{pred_s:.6},\"rows_per_s\":{:.1},\
         \"scalar_seconds\":{pred_scalar_s:.6},\"scalar_rows_per_s\":{:.1},\
         \"kernel_speedup\":{pred_speedup:.3},\"peak_rss_bytes\":{pred_rss}}},\
         \"serve\":{{\"achieved_qps\":{:.1},\"p50_us\":{},\"p99_us\":{},\
         \"peak_rss_bytes\":{serve_rss}}}}}",
        rows as f64 / no_cache_s,
        trained_rows / tc_s,
        trained_rows / tc_scalar_s,
        rows as f64 / pred_s,
        rows as f64 / pred_scalar_s,
        report.achieved_qps,
        report.p50_us,
        report.p99_us,
    );
    std::fs::write("BENCH_matrix.json", json + "\n").ok();
}

/// Ingest throughput: serialize a corpus to a LibSVM file once, then time
/// (a) raw sequential reads — the paper's Table-2 "data loading" baseline,
/// (b) the legacy single-thread line parser, (c) the byte-block parser on
/// one thread, (d) the W-worker block-parallel parse, (e) end-to-end
/// `preprocess` (parse + b-bit hash + cache write) whose ratio to (a) is
/// the paper's preprocessing-vs-loading claim, and (f) the same
/// preprocess with `--device xla` hashing (CPU fallback when no PJRT
/// artifacts exist — `device_used` records which path ran).  Best-of-R
/// wall clock; rows/s and MB/s go to stdout and `BENCH_ingest.json`.
fn run_ingest_scenario() {
    use bbit_mh::data::libsvm::{parse_block, BlockReader, LibsvmReader, LibsvmWriter, ParsedChunk};
    use bbit_mh::util::bench::black_box;

    println!();
    let corpus = CorpusGenerator::new(CorpusConfig {
        n_docs: 20_000,
        vocab: 2500,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 0x16E57,
    })
    .generate();
    let path =
        std::env::temp_dir().join(format!("bbit_bench_ingest_{}.svm", std::process::id()));
    {
        let mut w = LibsvmWriter::create(&path).unwrap();
        w.write_dataset(&corpus).unwrap();
        w.finish().unwrap();
    }
    let file_bytes = std::fs::metadata(&path).unwrap().len();
    let mb = file_bytes as f64 / 1e6;
    let reps = 5usize;
    let best = |f: &mut dyn FnMut() -> usize| -> (f64, usize) {
        let mut best = f64::INFINITY;
        let mut rows = 0usize;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            rows = f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        (best, rows)
    };

    // (a) raw read: the load-time floor every parse is compared against
    let (load_s, _) = best(&mut || {
        let mut f = std::fs::File::open(&path).unwrap();
        let mut buf = vec![0u8; 1 << 20];
        let mut total = 0usize;
        loop {
            let n = std::io::Read::read(&mut f, &mut buf).unwrap();
            if n == 0 {
                break;
            }
            black_box(&buf[..n]);
            total += n;
        }
        total
    });

    // (b) legacy line parser, one thread
    let (legacy_s, legacy_rows) = best(&mut || {
        let mut rows = 0usize;
        for ex in LibsvmReader::open(&path).unwrap().binary() {
            black_box(ex.unwrap());
            rows += 1;
        }
        rows
    });

    // (c) byte-block parser, one thread
    let (byte_s, byte_rows) = best(&mut || {
        let mut parsed = ParsedChunk::default();
        let mut rows = 0usize;
        for block in BlockReader::open(&path).unwrap() {
            let block = block.unwrap();
            parsed.clear();
            parse_block(&block.bytes, block.first_line, true, &mut parsed).unwrap();
            rows += parsed.len();
        }
        rows
    });
    assert_eq!(byte_rows, legacy_rows, "parsers must cover the same rows");

    // (d) W-worker block-parallel parse (trivial work body: parse only)
    let workers = bbit_mh::config::available_workers().max(2);
    let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 256, queue_depth: 4 });
    let (par_s, par_rows) = best(&mut || {
        let report = pipe
            .run_blocks_each(
                BlockReader::open(&path).unwrap(),
                true,
                |parsed, _| Ok(black_box(parsed.len())),
                |_, _| Ok(()),
            )
            .unwrap();
        report.docs
    });
    assert_eq!(par_rows, legacy_rows, "block-parallel parse must cover the same rows");

    // (e) end-to-end preprocess (parse + bbit hash + cache write)
    let spec = EncoderSpec::Bbit { b: 8, k: 200, d: 1 << 30, seed: 11 };
    let cache_path =
        std::env::temp_dir().join(format!("bbit_bench_ingest_{}.cache", std::process::id()));
    let (pre_s, _) = best(&mut || {
        let mut sink = CacheSink::create(&cache_path, &spec).unwrap();
        let report = pipe
            .run_sink_blocks(BlockReader::open(&path).unwrap(), true, &spec, &mut sink)
            .unwrap();
        report.docs
    });
    let ratio = pre_s / load_s.max(1e-9);

    // (f) the same end-to-end preprocess with `--device xla` hashing —
    // the paper's "by using a GPU, the preprocessing cost can be reduced
    // to a small fraction of the data loading time" tracker.  When no
    // PJRT artifacts exist the encoder falls back to CPU, so the column
    // is always present; `device_used` says which path actually ran.
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let device_encoder = bbit_mh::encode::DeviceEncoder::new(&spec, &artifacts).unwrap();
    let device_used = device_encoder.device_active();
    let (dev_s, _) = best(&mut || {
        let mut sink = CacheSink::create(&cache_path, &spec).unwrap();
        let report = pipe
            .run_encoder_blocks(
                BlockReader::open(&path).unwrap(),
                true,
                &device_encoder,
                &mut sink,
            )
            .unwrap();
        report.docs
    });
    let device_ratio = dev_s / load_s.max(1e-9);

    let rows = legacy_rows;
    let line = |name: &str, secs: f64| {
        println!(
            "ingest/{name:<22} {rows} rows in {:8.2} ms  ({:9.0} rows/s, {:6.1} MB/s)",
            secs * 1e3,
            rows as f64 / secs,
            mb / secs,
        );
    };
    println!(
        "ingest/raw-read          {file_bytes} bytes in {:.2} ms  ({:.1} MB/s)",
        load_s * 1e3,
        mb / load_s,
    );
    line("legacy-parse", legacy_s);
    line("byte-parse", byte_s);
    line(&format!("block-parallel w={workers}"), par_s);
    line("preprocess-e2e", pre_s);
    line(
        if device_used { "preprocess-device" } else { "preprocess-device (cpu fb)" },
        dev_s,
    );
    println!(
        "ingest/preprocess-vs-load ratio: {ratio:.2}x (Table-2 target: O(1)× load time)"
    );
    println!(
        "ingest/device-vs-load ratio: {device_ratio:.2}x (device_used={device_used}; \
         paper target: small fraction of load time)"
    );
    let json = format!(
        "{{\"scenario\":\"ingest\",\"rows\":{rows},\"file_bytes\":{file_bytes},\
         \"workers\":{workers},\"raw_read_seconds\":{load_s:.6},\
         \"legacy_parse_seconds\":{legacy_s:.6},\"byte_parse_seconds\":{byte_s:.6},\
         \"parallel_parse_seconds\":{par_s:.6},\"preprocess_seconds\":{pre_s:.6},\
         \"device_preprocess_seconds\":{dev_s:.6},\"device_used\":{device_used},\
         \"legacy_rows_per_s\":{:.1},\"byte_rows_per_s\":{:.1},\
         \"parallel_rows_per_s\":{:.1},\"raw_read_mb_per_s\":{:.3},\
         \"byte_parse_mb_per_s\":{:.3},\"parallel_parse_mb_per_s\":{:.3},\
         \"preprocess_over_load\":{ratio:.3},\"device_over_load\":{device_ratio:.3}}}",
        rows as f64 / legacy_s,
        rows as f64 / byte_s,
        rows as f64 / par_s,
        mb / load_s,
        mb / byte_s,
        mb / par_s,
    );
    std::fs::write("BENCH_ingest.json", json + "\n").ok();
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&cache_path).ok();
}

/// Cache replay throughput: hash a corpus into a v3 cache once, then time
/// full replays — the sequential scan vs. the N-thread reader pool (both
/// through `coordinator::replay_cache`, so the emitted chunk stream is
/// identical).  Best-of-R wall clock; rows/s and MB/s (file bytes) go to
/// stdout and `BENCH_replay.json`.
fn run_replay_scenario() {
    println!();
    let corpus = CorpusGenerator::new(CorpusConfig {
        n_docs: 16_384,
        vocab: 2500,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 0x9E71,
    })
    .generate();
    let spec = EncoderSpec::Bbit { b: 8, k: 64, d: 1 << 30, seed: 11 };
    let path =
        std::env::temp_dir().join(format!("bbit_bench_replay_{}.cache", std::process::id()));
    let pipe = Pipeline::new(PipelineConfig {
        workers: bbit_mh::config::available_workers(),
        chunk_size: 256,
        queue_depth: 4,
    });
    let mut sink = CacheSink::create(&path, &spec).unwrap();
    pipe.run_sink(dataset_chunks(&corpus, 256), &spec, &mut sink).unwrap();
    let file_bytes = std::fs::metadata(&path).unwrap().len();

    // best-of-R replays at a given pool width (decode + verify every
    // record; the emit body is deliberately trivial so the measurement is
    // the replay layer, not a consumer)
    let time_replay = |threads: usize| -> (f64, usize) {
        let mut best = f64::INFINITY;
        let mut rows = 0usize;
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            let report = bbit_mh::coordinator::replay_cache(&path, threads, |_, _, codes, _| {
                bbit_mh::util::bench::black_box(codes.n);
                Ok(())
            })
            .unwrap();
            best = best.min(t0.elapsed().as_secs_f64());
            rows = report.docs;
        }
        (best, rows)
    };
    let threads = bbit_mh::config::available_workers().max(2);
    let (seq_s, rows) = time_replay(1);
    let (par_s, rows_par) = time_replay(threads);
    assert_eq!(rows, rows_par, "pool replay must cover the same rows");
    let mb = file_bytes as f64 / 1e6;
    let speedup = seq_s / par_s;
    println!(
        "replay/sequential      {rows} rows in {:.2} ms  ({:.0} rows/s, {:.1} MB/s)",
        seq_s * 1e3,
        rows as f64 / seq_s,
        mb / seq_s,
    );
    println!(
        "replay/threads={threads}       {rows} rows in {:.2} ms  ({:.0} rows/s, {:.1} MB/s)",
        par_s * 1e3,
        rows as f64 / par_s,
        mb / par_s,
    );
    println!("replay/speedup         {speedup:.2}x over sequential");
    let json = format!(
        "{{\"scenario\":\"replay\",\"rows\":{rows},\"file_bytes\":{file_bytes},\
         \"threads\":{threads},\"seq_seconds\":{seq_s:.6},\"par_seconds\":{par_s:.6},\
         \"seq_rows_per_s\":{:.1},\"par_rows_per_s\":{:.1},\
         \"seq_mb_per_s\":{:.3},\"par_mb_per_s\":{:.3},\"speedup\":{speedup:.3}}}",
        rows as f64 / seq_s,
        rows as f64 / par_s,
        mb / seq_s,
        mb / par_s,
    );
    std::fs::write("BENCH_replay.json", json + "\n").ok();
    std::fs::remove_file(&path).ok();
}

/// The serving path: a resident model behind the micro-batched server,
/// driven over loopback by `serve::loadgen` at two target rates, then the
/// fleet tier — two shard backends behind the consistent-hash router,
/// driven on `POST /similar`.  The higher-rate single-server report plus
/// the fleet report are dumped to `BENCH_serve.json` (`"fleet"` key) so
/// both layers get longitudinal tracking.
fn run_serve_scenario(ds: &bbit_mh::data::SparseDataset) {
    use bbit_mh::hashing::lsh::LshConfig;
    use bbit_mh::serve::{shard_assignment, Router, RouterConfig};
    use bbit_mh::similarity::{snapshot, LshIndex};
    println!();
    let pid = std::process::id();
    let spec = EncoderSpec::Oph { bins: 200, b: 8, seed: 11 };
    let w: Vec<f32> = (0..spec.output_dim()).map(|j| (j as f32 * 0.173).sin()).collect();
    let model = SavedModel::new(spec, LinearModel { w }).unwrap();
    let model_path = std::env::temp_dir().join(format!("bbit_bench_{pid}.bbmh"));
    model.save(&model_path).unwrap();
    let serve_cfg = ServeConfig {
        scorer_workers: 2,
        batch_max: 64,
        batch_wait: Duration::from_micros(100),
        queue_cap: 4096,
        deadline: Duration::from_millis(100),
        ..Default::default()
    };
    let server = ModelServer::start(&model_path, serve_cfg.clone()).unwrap();
    // score the same expanded documents the hashing scenarios preprocess
    let docs: Vec<String> = (0..ds.len().min(256))
        .map(|i| {
            let mut line = String::from("+1");
            for &t in ds.row(i).0 {
                line.push_str(&format!(" {t}:1"));
            }
            line
        })
        .collect();
    let mut single_json = String::new();
    for qps in [1000.0, 4000.0] {
        let report = loadgen::run(
            server.local_addr(),
            &LoadgenConfig {
                path: "/score".into(),
                qps,
                duration: Duration::from_millis(800),
                connections: 4,
                docs: docs.clone(),
            },
        )
        .unwrap();
        println!("serve/loadgen qps_target={qps}: {}", report.summary());
        if qps == 4000.0 {
            single_json = report.to_json();
        }
    }
    println!("serve/shutdown-report:");
    print!("{}", server.shutdown());

    // --- fleet: 2 shard backends behind the consistent-hash router ------
    // the same signatures a classifier trains on, sharded 4 ways
    let sim_spec = EncoderSpec::Bbit { b: 8, k: 64, d: ds.dim, seed: 17 };
    let pipe = Pipeline::new(PipelineConfig::default());
    let (hashed, _) = pipe.run(dataset_chunks(ds, 256), &sim_spec).unwrap();
    let codes = hashed.into_bbit().unwrap().codes;
    let full =
        LshIndex::from_codes(&codes, sim_spec, LshConfig { bands: 16, rows_per_band: 4 }, 4)
            .unwrap();
    // reserve backend ports up front: the shard placement is a function of
    // the address list, and each backend must hold exactly its shards
    let reserve = || {
        std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
    };
    let (backends, assignment) = loop {
        let backends: Vec<String> =
            (0..2).map(|_| format!("127.0.0.1:{}", reserve())).collect();
        let assignment = shard_assignment(&backends, 4);
        if assignment.contains(&0) && assignment.contains(&1) {
            break (backends, assignment);
        }
    };
    let mut fleet_servers = Vec::new();
    let mut snap_paths = Vec::new();
    for (i, backend) in backends.iter().enumerate() {
        let snaps: Vec<std::path::PathBuf> = assignment
            .iter()
            .enumerate()
            .filter(|(_, &b)| b == i)
            .map(|(s, _)| {
                let p = std::env::temp_dir().join(format!("bbit_bench_{pid}.idx.shard{s}"));
                snapshot::save_shard(&full, s, &p).unwrap();
                p
            })
            .collect();
        let idx = std::sync::Arc::new(snapshot::load_many(&snaps).unwrap());
        snap_paths.extend(snaps);
        let port: u16 = backend.rsplit(':').next().unwrap().parse().unwrap();
        let cfg = ServeConfig { port, ..serve_cfg.clone() };
        fleet_servers.push(
            ModelServer::start_with_index(&model_path, cfg, Some(idx)).unwrap(),
        );
    }
    let router = Router::start(RouterConfig {
        backends,
        shards: 4,
        health_poll: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();
    // half routed doc lookups, half scatter-gather raw queries
    let sim_docs: Vec<String> = (0..ds.len().min(256))
        .map(|i| if i % 2 == 0 { format!("doc:{i}") } else { docs[i].clone() })
        .collect();
    let fleet_report = loadgen::run(
        router.local_addr(),
        &LoadgenConfig {
            path: "/similar".into(),
            qps: 2000.0,
            duration: Duration::from_millis(800),
            connections: 4,
            docs: sim_docs,
        },
    )
    .unwrap();
    println!("serve/fleet qps_target=2000: {}", fleet_report.summary());
    println!("serve/fleet router-report:");
    print!("{}", router.shutdown());
    for s in fleet_servers {
        s.shutdown();
    }

    // single-server report + nested fleet report, one line
    let json = format!(
        "{},\"fleet\":{}}}\n",
        &single_json[..single_json.len() - 1],
        fleet_report.to_json()
    );
    std::fs::write("BENCH_serve.json", json).ok();
    for p in snap_paths {
        std::fs::remove_file(p).ok();
    }
    std::fs::remove_file(&model_path).ok();
}
