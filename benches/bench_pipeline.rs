//! End-to-end pipeline throughput + ablations over the coordinator's
//! tuning knobs (worker count, chunk size, queue depth) — the DESIGN.md
//! §Perf L3 target is that hashing saturates the parse rate.
//!
//! Run: `cargo bench --bench bench_pipeline`

use bbit_mh::coordinator::pipeline::{dataset_chunks, HashJob, Pipeline, PipelineConfig};
use bbit_mh::data::expand::{expand_dataset, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::util::bench::Bench;

fn main() {
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs: 800,
        vocab: 2500,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 0x9199,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 2500, dim: 1 << 30, three_way_rate: 30, seed: 4 };
    let ds = expand_dataset(&cfg, &base);
    println!("corpus: {} docs, mean nnz {:.0}\n", ds.len(), ds.stats().nnz_mean);
    let job = HashJob::Bbit { b: 8, k: 200, d: 1 << 30, seed: 11 };
    let mut b = Bench::quick();

    // worker scaling
    for workers in [1usize, 2, 4, bbit_mh::config::available_workers()] {
        let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 128, queue_depth: 4 });
        b.bench_elems(&format!("pipeline/workers={workers}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), &job).unwrap().1.docs
        });
    }

    // chunk-size ablation (scheduling granularity vs channel overhead)
    for chunk in [16usize, 64, 256, 1024] {
        let pipe = Pipeline::new(PipelineConfig {
            workers: bbit_mh::config::available_workers(),
            chunk_size: chunk,
            queue_depth: 4,
        });
        b.bench_elems(&format!("pipeline/chunk={chunk}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, chunk), &job).unwrap().1.docs
        });
    }

    // queue-depth ablation (backpressure head-room)
    for depth in [1usize, 2, 8] {
        let pipe = Pipeline::new(PipelineConfig {
            workers: bbit_mh::config::available_workers(),
            chunk_size: 128,
            queue_depth: depth,
        });
        b.bench_elems(&format!("pipeline/queue_depth={depth}"), ds.len() as u64, || {
            pipe.run(dataset_chunks(&ds, 128), &job).unwrap().1.docs
        });
    }
}
