//! Training-time micro-benchmarks (the Figures 2/4/7 timing shapes):
//! solver cost as a function of representation (b-bit vs VW), k, and C.
//!
//! Run: `cargo bench --bench bench_train`

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::data::expand::{expand_dataset, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::solver::{
    train_lr, train_sgd, train_svm, LrConfig, SgdConfig, SvmConfig,
};
use bbit_mh::util::bench::Bench;

fn main() {
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs: 1000,
        vocab: 2000,
        zipf_alpha: 1.05,
        mean_tokens: 25.0,
        class_signal: 0.55,
        pos_fraction: 0.5,
        seed: 0x7124,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 2000, dim: 1 << 30, three_way_rate: 30, seed: 2 };
    let ds = expand_dataset(&cfg, &base);
    let pipe = Pipeline::new(PipelineConfig::default());
    let mut b = Bench::quick();

    // --- b-bit representations: SVM + LR time vs k (Figure 2/4 shape) ---
    for k in [30usize, 100, 200] {
        let (out, _) = pipe
            .run(dataset_chunks(&ds, 128), &EncoderSpec::Bbit { b: 8, k, d: 1 << 30, seed: 3 })
            .unwrap();
        let bb = out.into_bbit().unwrap();
        b.bench_elems(&format!("svm_dcd/bbit_b8_k{k}/docs"), bb.len() as u64, || {
            train_svm(&bb, &SvmConfig::with_c(1.0)).1.iterations
        });
        b.bench_elems(&format!("lr_newton/bbit_b8_k{k}/docs"), bb.len() as u64, || {
            train_lr(&bb, &LrConfig::with_c(1.0)).1.iterations
        });
        b.bench_elems(&format!("sgd_logistic/bbit_b8_k{k}/docs"), bb.len() as u64, || {
            train_sgd(&bb, &SgdConfig { epochs: 3, ..Default::default() }).1.iterations
        });
    }

    // --- VW representations: time vs bins (Figure 7 shape) ---
    for bins in [256usize, 1024, 4096] {
        let (out, _) = pipe
            .run(dataset_chunks(&ds, 128), &EncoderSpec::Vw { bins, seed: 5 })
            .unwrap();
        let vw = out.into_vw().unwrap();
        b.bench_elems(&format!("svm_dcd/vw_bins{bins}/docs"), vw.len() as u64, || {
            train_svm(&vw, &SvmConfig::with_c(1.0)).1.iterations
        });
        b.bench_elems(&format!("lr_newton/vw_bins{bins}/docs"), vw.len() as u64, || {
            train_lr(&vw, &LrConfig::with_c(1.0)).1.iterations
        });
    }

    // --- shrinking ablation (DESIGN.md: why the default is off) ---
    let (out, _) = pipe
        .run(dataset_chunks(&ds, 128), &EncoderSpec::Bbit { b: 8, k: 200, d: 1 << 30, seed: 3 })
        .unwrap();
    let bb_s = out.into_bbit().unwrap();
    for shrinking in [false, true] {
        b.bench(&format!("svm_dcd/shrinking={shrinking}/b8_k200"), || {
            train_svm(
                &bb_s,
                &SvmConfig { c: 1.0, eps: 1e-3, max_iter: 1000, shrinking, ..Default::default() },
            )
            .1
            .iterations
        });
    }

    // --- C dependence (Figures 2/4 x-axis) ---
    let (out, _) = pipe
        .run(dataset_chunks(&ds, 128), &EncoderSpec::Bbit { b: 8, k: 100, d: 1 << 30, seed: 3 })
        .unwrap();
    let bb = out.into_bbit().unwrap();
    for c in [0.01, 1.0, 100.0] {
        b.bench(&format!("svm_dcd/b8_k100_C{c}"), || {
            train_svm(&bb, &SvmConfig::with_c(c)).1.iterations
        });
    }
}
