//! Table 2 micro-benchmark: LibSVM parse rate vs hashing rate (per worker
//! count), on an in-memory corpus so disk speed doesn't pollute the
//! comparison.
//!
//! Run: `cargo bench --bench bench_preprocess`

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::data::expand::{expand_dataset, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::{LibsvmReader, LibsvmWriter};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::util::bench::Bench;

fn main() {
    let n_docs = 500;
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs,
        vocab: 3000,
        zipf_alpha: 1.05,
        mean_tokens: 30.0,
        class_signal: 0.55,
        pos_fraction: 0.47,
        seed: 0x9E,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 3000, dim: 1 << 30, three_way_rate: 30, seed: 1 };
    let ds = expand_dataset(&cfg, &base);
    let mut buf = Vec::new();
    {
        let mut w = LibsvmWriter::new(&mut buf);
        w.write_dataset(&ds).unwrap();
        w.finish().unwrap();
    }
    println!(
        "corpus: {n_docs} docs, mean nnz {:.0}, {:.1} MB libsvm\n",
        ds.stats().nnz_mean,
        buf.len() as f64 / 1e6
    );

    let mut b = Bench::quick();

    // (1) the paper's "data loading": full parse of the byte buffer
    b.bench_elems("libsvm_parse/docs", n_docs as u64, || {
        let mut n = 0usize;
        for ex in LibsvmReader::new(&buf[..]).binary() {
            n += ex.unwrap().nnz();
        }
        n
    });

    // (2) preprocessing at k=500 across worker counts
    for workers in [1usize, 2, bbit_mh::config::available_workers()] {
        let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 64, queue_depth: 4 });
        b.bench_elems(
            &format!("pipeline_bbit/k=500_w={workers}/docs"),
            n_docs as u64,
            || {
                let (out, _) = pipe
                    .run(
                        dataset_chunks(&ds, 64),
                        &EncoderSpec::Bbit { b: 16, k: 500, d: 1 << 30, seed: 7 },
                    )
                    .unwrap();
                out.len()
            },
        );
    }

    // (3) VW preprocessing for comparison
    let pipe = Pipeline::new(PipelineConfig::default());
    b.bench_elems("pipeline_vw/bins=1024/docs", n_docs as u64, || {
        let (out, _) = pipe
            .run(dataset_chunks(&ds, 64), &EncoderSpec::Vw { bins: 1024, seed: 7 })
            .unwrap();
        out.len()
    });
}
