//! PJRT artifact execution latency — the L1/L2 hot-path numbers
//! (per-batch preprocessing and per-chunk training through the AOT'd HLO).
//!
//! Requires `make artifacts`; prints a skip message otherwise.
//!
//! Run: `cargo bench --bench bench_runtime`

use std::path::Path;

use bbit_mh::hashing::universal::UniversalFamily;
use bbit_mh::runtime::{MinhashEngine, PjrtRuntime, TrainEngine, VwEngine};
use bbit_mh::util::bench::Bench;
use bbit_mh::util::Rng;

fn main() {
    let rt = match PjrtRuntime::cpu(Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping runtime bench (run `make artifacts`): {e}");
            return;
        }
    };
    let mut rng = Rng::new(0xBEC);
    let mut b = Bench::quick();

    // --- minhash artifact: full 256-doc batch, realistic nnz ---
    for name in ["minhash_k200", "minhash_k512"] {
        let engine = MinhashEngine::new(&rt, name).unwrap();
        let family = UniversalFamily::draw(engine.k, engine.d_space, &mut rng);
        let sets: Vec<Vec<u32>> = (0..engine.batch)
            .map(|_| {
                rng.sample_distinct(engine.d_space, 800)
                    .into_iter()
                    .map(|x| x as u32)
                    .collect()
            })
            .collect();
        let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
        b.bench_elems(&format!("pjrt/{name}/batch256"), engine.batch as u64, || {
            engine.minhash_batch(&refs, &family).unwrap().len()
        });
    }

    // --- vw artifact ---
    let engine = VwEngine::new(&rt, "vw_bins1024").unwrap();
    let sets: Vec<Vec<u32>> = (0..engine.batch)
        .map(|_| {
            rng.sample_distinct(1 << 30, 800)
                .into_iter()
                .map(|x| x as u32)
                .collect()
        })
        .collect();
    let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
    b.bench_elems("pjrt/vw_bins1024/batch256", engine.batch as u64, || {
        engine.hash_batch(&refs, [1, 2, 3, 4]).unwrap().len()
    });

    // --- train + predict artifacts ---
    for name in ["train_logistic_b8_k200", "train_sqhinge_b8_k200"] {
        let mut engine = TrainEngine::new(&rt, name, "predict_b8_k200").unwrap();
        let codes: Vec<i32> = (0..engine.chunk * engine.k)
            .map(|_| rng.below(256) as i32)
            .collect();
        let y: Vec<f32> = (0..engine.chunk)
            .map(|_| if rng.bool() { 1.0 } else { -1.0 })
            .collect();
        let steps = engine.chunk / engine.batch;
        b.bench_elems(
            &format!("pjrt/{name}/chunk2048 ({steps} sgd steps)"),
            engine.chunk as u64,
            || {
                engine.train_chunk(&codes, &y, 0.1, 1e-4).unwrap();
            },
        );
        b.bench_elems("pjrt/predict_b8_k200/rows2048", 2048, || {
            engine.margins(&codes[..2048 * engine.k]).unwrap().len()
        });
    }
}
