//! Online similarity acceptance tests (ISSUE 7): the out-of-core LSH
//! index and `POST /similar`.
//!
//! - index builds from a v3 hashed cache through the replay reader pool
//!   and the snapshot bytes are identical for every `--replay-threads`;
//! - a loopback server started with the index answers `/similar` doc and
//!   raw-line queries with top-K estimates that match the offline
//!   [`LshIndex`] query path *bit-for-bit*;
//! - `/similar` rides the same bounded batcher as `/score`: concurrent
//!   overload sheds (503) or expires (504) instead of hanging, and the
//!   server stays healthy.
//!
//! Every server binds port 0 so parallel test binaries cannot collide.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::CacheSink;
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::cache::CacheWriteOptions;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::hashing::lsh::LshConfig;
use bbit_mh::serve::http;
use bbit_mh::serve::{loadgen, LoadgenConfig, ModelServer, ServeConfig};
use bbit_mh::similarity::{snapshot, LshIndex};
use bbit_mh::solver::{LinearModel, SavedModel};

fn corpus(n: usize, seed: u64) -> SparseDataset {
    CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 2000,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed,
    })
    .generate()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bbmh_sim_e2e_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Hash `ds` into a fresh v3 cache with `chunk` rows per record.
fn build_cache(dir: &std::path::Path, ds: &SparseDataset, spec: &EncoderSpec) -> PathBuf {
    let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 53, queue_depth: 2 });
    let path = dir.join("sim.cache");
    let mut sink = CacheSink::create_opts(&path, spec, CacheWriteOptions::default()).unwrap();
    pipe.run_sink(dataset_chunks(ds, 53), spec, &mut sink).unwrap();
    path
}

/// Any valid model — `/similar` does not touch it, but `serve` needs one.
fn model_for(spec: EncoderSpec) -> SavedModel {
    let w: Vec<f32> = (0..spec.output_dim()).map(|j| (j as f32 * 0.17).cos()).collect();
    SavedModel::new(spec, LinearModel { w }).unwrap()
}

/// The LibSVM line for row `i` of `ds` (indices only, unit values).
fn libsvm_line(ds: &SparseDataset, i: usize) -> (String, Vec<u32>) {
    let (idx, _) = ds.row(i);
    let mut line = String::from("+1");
    for x in idx {
        line.push_str(&format!(" {x}:1"));
    }
    (line, idx.to_vec())
}

/// Tiny keep-alive HTTP client over the crate's own framing.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to test server");
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn post(&mut self, path: &str, body: &str) -> http::Response {
        http::write_post(&mut self.stream, path, body.as_bytes()).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }

    fn post_top_k(&mut self, path: &str, body: &str, top_k: usize) -> http::Response {
        let hdr = [("X-Top-K", top_k.to_string())];
        http::write_post_with(&mut self.stream, path, &hdr, body.as_bytes()).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }

    fn get(&mut self, path: &str) -> http::Response {
        http::write_get(&mut self.stream, path).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }
}

/// Parse a `/similar` 200 body back into `(id, estimate)` rows.  The
/// server prints estimates with `{}` (shortest round-trip form), so the
/// parse is bit-exact.
fn parse_hits(body: &str) -> Vec<(u64, f64)> {
    body.lines()
        .map(|l| {
            let mut toks = l.split_ascii_whitespace();
            (toks.next().unwrap().parse().unwrap(), toks.next().unwrap().parse().unwrap())
        })
        .collect()
}

#[test]
fn snapshot_bytes_are_identical_for_every_replay_thread_count() {
    let ds = corpus(500, 0xD1CE);
    let spec = EncoderSpec::Bbit { b: 6, k: 20, d: ds.dim, seed: 5 };
    let dir = tmp_dir("det");
    let cache = build_cache(&dir, &ds, &spec);
    let cfg = LshConfig { bands: 5, rows_per_band: 4 };

    // single shard: one snapshot file per thread count, bytes must agree
    let mut reference: Option<Vec<u8>> = None;
    for threads in [1usize, 2, 4] {
        let idx = LshIndex::build_from_cache(&cache, cfg, 1, threads).unwrap();
        assert_eq!(idx.rows(), 500, "threads={threads}");
        let path = dir.join(format!("one.t{threads}.idx"));
        snapshot::save(&idx, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        match &reference {
            None => reference = Some(bytes),
            Some(r) => assert_eq!(&bytes, r, "threads={threads}: snapshot bytes diverged"),
        }
    }

    // sharded: per-shard snapshots must also be thread-count-invariant
    let mut shard_ref: Option<Vec<Vec<u8>>> = None;
    for threads in [1usize, 3] {
        let idx = LshIndex::build_from_cache(&cache, cfg, 3, threads).unwrap();
        assert_eq!(idx.shard_ids(), vec![0, 1, 2]);
        let mut per_shard = Vec::new();
        for s in idx.shard_ids() {
            let path = dir.join(format!("s{s}.t{threads}.idx"));
            snapshot::save_shard(&idx, s, &path).unwrap();
            per_shard.push(std::fs::read(&path).unwrap());
        }
        match &shard_ref {
            None => shard_ref = Some(per_shard),
            Some(r) => assert_eq!(&per_shard, r, "threads={threads}: shard bytes diverged"),
        }
    }

    // and a loaded snapshot answers queries like the index it came from
    let built = LshIndex::build_from_cache(&cache, cfg, 1, 2).unwrap();
    let loaded = snapshot::load(dir.join("one.t1.idx")).unwrap();
    for id in [0u64, 7, 499] {
        let (a, sa) = built.query_doc(id, 8).unwrap();
        let (b, sb) = loaded.query_doc(id, 8).unwrap();
        assert_eq!(a, b, "doc {id}");
        assert_eq!(sa, sb, "doc {id}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn post_similar_matches_the_offline_index_bit_for_bit() {
    let ds = corpus(400, 0x51A7);
    let spec = EncoderSpec::Bbit { b: 8, k: 32, d: ds.dim, seed: 11 };
    let dir = tmp_dir("exact");
    let cache = build_cache(&dir, &ds, &spec);
    let cfg = LshConfig { bands: 8, rows_per_band: 4 };

    // offline reference and the serving copy go through the same
    // build→snapshot→load path the CLI uses
    let offline = LshIndex::build_from_cache(&cache, cfg, 1, 2).unwrap();
    let snap = dir.join("sim.idx");
    snapshot::save(&offline, &snap).unwrap();
    let serving = Arc::new(snapshot::load(&snap).unwrap());

    let model_path = dir.join("m.bbmh");
    model_for(spec).save(&model_path).unwrap();
    let server = ModelServer::start_with_index(
        &model_path,
        ServeConfig {
            scorer_workers: 2,
            deadline: Duration::from_secs(5),
            ..Default::default()
        },
        Some(serving),
    )
    .unwrap();
    let mut client = Client::connect(server.local_addr());

    // healthz advertises the resident shards
    let health = client.get("/healthz").body_text();
    assert!(health.contains("similar_shards=0/1"), "{health}");

    // doc queries: ids resolved inside the index
    for id in [3u64, 42, 399] {
        let resp = client.post_top_k("/similar", &format!("doc:{id}\n"), 7);
        assert_eq!(resp.status, 200, "doc {id}: {}", resp.body_text());
        let (expect, stats) = offline.query_doc(id, 7).unwrap();
        let got = parse_hits(&resp.body_text());
        assert_eq!(got.len(), expect.len(), "doc {id}");
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!(g.0, e.id, "doc {id}");
            assert_eq!(g.1.to_bits(), e.estimate.to_bits(), "doc {id}: estimate drifted");
        }
        assert_eq!(
            resp.header("x-candidates"),
            Some(stats.candidates.to_string().as_str()),
            "doc {id}"
        );
        assert_eq!(
            resp.header("x-reranked"),
            Some(stats.reranked.to_string().as_str()),
            "doc {id}"
        );
    }

    // raw LibSVM queries: hashed online, must equal hash_query + query
    let mut scratch = offline.scratch();
    for i in [0usize, 17, 250] {
        let (line, idx) = libsvm_line(&ds, i);
        let resp = client.post_top_k("/similar", &format!("{line}\n"), 5);
        assert_eq!(resp.status, 200, "row {i}: {}", resp.body_text());
        offline.hash_query(&idx, &mut scratch).unwrap();
        let (expect, _) = offline.query(&scratch.codes, 5).unwrap();
        let got = parse_hits(&resp.body_text());
        assert_eq!(got.len(), expect.len(), "row {i}");
        for (g, e) in got.iter().zip(&expect) {
            assert_eq!((g.0, g.1.to_bits()), (e.id, e.estimate.to_bits()), "row {i}");
        }
        // a row in the index matches itself with agreement exactly 1.0
        assert!(got.contains(&(i as u64, 1.0)), "row {i}: {got:?}");
        assert_eq!(got[0].1, 1.0, "row {i}: top hit must be a perfect match");
    }

    // error surfaces: unknown doc, empty body, bad top-k
    assert_eq!(client.post("/similar", "doc:40000\n").status, 404);
    assert_eq!(client.post("/similar", "\n\n").status, 400);
    assert_eq!(client.post_top_k("/similar", "doc:1\n", 0).status, 200, "top-k clamps");
    let resp = {
        let hdr = [("X-Top-K", "banana".to_string())];
        http::write_post_with(&mut client.stream, "/similar", &hdr, b"doc:1\n").unwrap();
        http::read_response(&mut client.reader).unwrap()
    };
    assert_eq!(resp.status, 400, "{}", resp.body_text());

    // /score still works on the same connection — one batcher, two jobs
    let (line, _) = libsvm_line(&ds, 9);
    assert_eq!(client.post("/score", &format!("{line}\n")).status, 200);

    let report = server.shutdown();
    assert!(report.contains("serve_similar_served_total"), "{report}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn similar_overload_sheds_or_expires_through_the_shared_batcher() {
    let ds = corpus(3000, 0x0AD5);
    let spec = EncoderSpec::Bbit { b: 8, k: 64, d: ds.dim, seed: 3 };
    let dir = tmp_dir("shed");
    let cache = build_cache(&dir, &ds, &spec);
    // degenerate banding (threshold ≈ 0): every query reranks a large
    // slice of the corpus, so a single scorer is easy to overrun
    let cfg = LshConfig { bands: 2, rows_per_band: 1 };
    let idx = Arc::new(LshIndex::build_from_cache(&cache, cfg, 1, 2).unwrap());

    let model_path = dir.join("m.bbmh");
    model_for(spec).save(&model_path).unwrap();
    let server = ModelServer::start_with_index(
        &model_path,
        ServeConfig {
            scorer_workers: 1,
            batch_max: 2,
            batch_wait: Duration::ZERO,
            queue_cap: 4,
            deadline: Duration::from_millis(5),
            ..Default::default()
        },
        Some(idx),
    )
    .unwrap();
    let addr = server.local_addr();

    let docs: Vec<String> = (0..64).map(|i| format!("doc:{}", i * 40)).collect();
    let report = loadgen::run(
        addr,
        &LoadgenConfig {
            path: "/similar".into(),
            qps: 4000.0,
            duration: Duration::from_millis(700),
            connections: 8,
            docs,
        },
    )
    .unwrap();

    assert!(report.sent > 50, "{report:?}");
    assert!(report.ok > 0, "some queries must land: {report:?}");
    assert!(
        report.shed + report.expired > 0,
        "overload must shed (503) or expire (504), not absorb: {report:?}"
    );
    assert!(
        report.ok + report.shed + report.expired + report.errors >= report.sent,
        "{report:?}"
    );
    assert!((report.shed_rate - report.shed as f64 / report.sent as f64).abs() < 1e-12);

    // the server survives the burst
    let mut client = Client::connect(addr);
    assert!(client.get("/healthz").body_text().starts_with("ok"));
    let metrics = client.get("/metrics").body_text();
    let received: u64 = metrics
        .lines()
        .find(|l| l.starts_with("serve_similar_received_total"))
        .and_then(|l| l.split_ascii_whitespace().nth(1))
        .unwrap()
        .parse()
        .unwrap();
    assert!(received >= report.ok, "{metrics}");

    server.shutdown();
    std::fs::remove_dir_all(dir).ok();
}
