//! Telemetry acceptance tests (ISSUE 8): one JSONL trace file
//! reconstructs a `/similar` request's full fleet path, and every
//! `/metrics` body survives the Prometheus format validator.
//!
//! This test binary owns the process-wide trace sink (`init_file` is
//! once per process, which is why the unit tests in `metrics/trace.rs`
//! never call it).  Router and backends all run in this process, so
//! their spans land in the *same* JSONL file — exactly the "one grep
//! reconstructs the request" story, minus the grep.
//!
//! Events are buffered per thread and drain when a thread's span stack
//! empties (after the response is written), so assertions poll with
//! [`trace::flush`] instead of assuming synchronous arrival.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::CacheSink;
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::cache::CacheWriteOptions;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::hashing::lsh::LshConfig;
use bbit_mh::metrics::{prom, trace};
use bbit_mh::serve::http;
use bbit_mh::serve::{shard_assignment, ModelServer, Router, RouterConfig, ServeConfig};
use bbit_mh::similarity::{snapshot, LshIndex};
use bbit_mh::solver::{LinearModel, SavedModel};

const SHARDS: usize = 4;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bbmh_telem_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn corpus(n: usize, seed: u64) -> SparseDataset {
    CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 2000,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed,
    })
    .generate()
}

fn reserve_port() -> u16 {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

/// Two reserved backend addresses whose consistent-hash assignment uses
/// both backends — the scatter-gather must fan out for the per-leg spans
/// to mean anything.
fn two_backends() -> Vec<String> {
    for _ in 0..32 {
        let backends: Vec<String> =
            (0..2).map(|_| format!("127.0.0.1:{}", reserve_port())).collect();
        let assignment = shard_assignment(&backends, SHARDS);
        if assignment.contains(&0) && assignment.contains(&1) {
            return backends;
        }
    }
    panic!("could not reserve a port pair covering both backends");
}

fn start_backend(model: &Path, port: u16, snaps: &[PathBuf]) -> ModelServer {
    let idx = Arc::new(snapshot::load_many(snaps).unwrap());
    let cfg = ServeConfig {
        port,
        scorer_workers: 2,
        deadline: Duration::from_secs(5),
        ..Default::default()
    };
    let t0 = Instant::now();
    loop {
        match ModelServer::start_with_index(model, cfg.clone(), Some(idx.clone())) {
            Ok(s) => return s,
            Err(e) => {
                assert!(t0.elapsed() < Duration::from_secs(5), "backend never bound: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn post(&mut self, path: &str, headers: &[(&str, String)], body: &str) -> http::Response {
        http::write_post_with(&mut self.stream, path, headers, body.as_bytes()).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }

    fn get(&mut self, path: &str) -> http::Response {
        http::write_get(&mut self.stream, path).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }
}

fn wait_healthz(addr: SocketAddr, pred: impl Fn(&str) -> bool, what: &str) {
    let t0 = Instant::now();
    loop {
        let body = Client::connect(addr).get("/healthz").body_text();
        if pred(&body) {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(15), "{what} never happened:\n{body}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

// ---- hand-rolled JSONL event extraction (the schema is flat) ----

#[derive(Debug, Clone)]
struct Event {
    name: String,
    span: u64,
    parent: u64,
    dur_us: Option<u64>,
}

fn field_str(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let s = line.find(&pat)? + pat.len();
    let e = line[s..].find('"')?;
    Some(line[s..s + e].to_string())
}

fn field_u64(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let s = line.find(&pat)? + pat.len();
    let rest = &line[s..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Events for one trace id — the grep the module docs promise.
fn events_for(path: &Path, tid: &str) -> Vec<Event> {
    let needle = format!("\"trace\":\"{tid}\"");
    std::fs::read_to_string(path)
        .unwrap_or_default()
        .lines()
        .filter(|l| l.contains(&needle))
        .map(|l| {
            assert!(l.ends_with('}'), "truncated event line: {l}");
            Event {
                name: field_str(l, "name").expect("every event has a name"),
                span: field_u64(l, "span").unwrap_or(0),
                parent: field_u64(l, "parent").unwrap_or(0),
                dur_us: field_u64(l, "dur_us"),
            }
        })
        .collect()
}

/// Poll (buffers drain asynchronously) until every `needed` span name
/// has arrived for `tid`.
fn wait_for_spans(path: &Path, tid: &str, needed: &[&str]) -> Vec<Event> {
    let t0 = Instant::now();
    loop {
        trace::flush();
        let evs = events_for(path, tid);
        if needed.iter().all(|n| evs.iter().any(|e| e.name == *n)) {
            return evs;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "spans never arrived for {tid}: want {needed:?}, have {:?}",
            evs.iter().map(|e| e.name.clone()).collect::<Vec<_>>()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn one_trace_reconstructs_a_similar_requests_fleet_path() {
    let dir = tmp_dir("fleet");
    let trace_path = dir.join("trace.jsonl");
    trace::init_file(&trace_path).unwrap();
    assert!(trace::enabled());

    // ---- build the fleet: cache -> sharded index -> 2 backends -> router
    let ds = corpus(400, 0x7E1E);
    let spec = EncoderSpec::Bbit { b: 8, k: 32, d: ds.dim, seed: 17 };
    let cache = dir.join("telem.cache");
    {
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 64, queue_depth: 2 });
        let mut sink =
            CacheSink::create_opts(&cache, &spec, CacheWriteOptions::default()).unwrap();
        pipe.run_sink(dataset_chunks(&ds, 64), &spec, &mut sink).unwrap();
    }
    let full =
        LshIndex::build_from_cache(&cache, LshConfig { bands: 8, rows_per_band: 4 }, SHARDS, 2)
            .unwrap();
    let mut snaps = Vec::new();
    for s in 0..SHARDS {
        let p = dir.join(format!("telem.idx.shard{s}"));
        snapshot::save_shard(&full, s, &p).unwrap();
        snaps.push(p);
    }
    let model_path = dir.join("m.bbmh");
    let w: Vec<f32> = (0..spec.output_dim()).map(|j| (j as f32 * 0.3).sin()).collect();
    SavedModel::new(spec, LinearModel { w }).unwrap().save(&model_path).unwrap();

    let backends = two_backends();
    let assignment = shard_assignment(&backends, SHARDS);
    let shards_of = |backend: usize| -> Vec<PathBuf> {
        assignment
            .iter()
            .enumerate()
            .filter(|(_, &b)| b == backend)
            .map(|(s, _)| snaps[s].clone())
            .collect()
    };
    let port_of = |b: &str| -> u16 { b.rsplit(':').next().unwrap().parse().unwrap() };
    let server_a = start_backend(&model_path, port_of(&backends[0]), &shards_of(0));
    let server_b = start_backend(&model_path, port_of(&backends[1]), &shards_of(1));
    let router = Router::start(RouterConfig {
        backends: backends.clone(),
        shards: SHARDS,
        health_poll: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();
    let addr = router.local_addr();
    wait_healthz(addr, |b| b.contains("backends=2/2"), "both backends up");

    // ---- a raw /similar query with an explicit trace id ---------------
    let line = {
        let (idx, _) = ds.row(7);
        let mut l = String::from("+1");
        for x in idx {
            l.push_str(&format!(" {x}:1"));
        }
        l.push('\n');
        l
    };
    let tid = "f1ee7c0ffee12345";
    let mut client = Client::connect(addr);
    let hdrs = [("X-Top-K", "8".to_string()), (http::TRACE_HEADER, tid.to_string())];
    let resp = client.post("/similar", &hdrs, &line);
    assert_eq!(resp.status, 200, "{}", resp.body_text());
    assert_eq!(resp.trace_id(), Some(tid), "router echoes the client's trace id");
    // the backend's own echo is filtered at the router — one copy only
    assert_eq!(
        resp.headers.iter().filter(|(k, _)| k.as_str() == "x-trace-id").count(),
        1,
        "{:?}",
        resp.headers
    );

    // the full path, reconstructed from one file by trace id alone:
    // router root -> scatter legs -> backend roots -> admission wait,
    // batch assembly, kernel
    let evs = wait_for_spans(
        &trace_path,
        tid,
        &[
            "route.similar",
            "route.scatter_leg",
            "serve.similar",
            "serve.admission_wait",
            "serve.batch_assembly",
            "serve.kernel",
        ],
    );
    let roots: Vec<&Event> = evs.iter().filter(|e| e.name == "route.similar").collect();
    assert_eq!(roots.len(), 1, "exactly one router root: {evs:?}");
    assert_eq!(roots[0].parent, 0, "the router span is the trace root");
    let legs: Vec<&Event> = evs.iter().filter(|e| e.name == "route.scatter_leg").collect();
    assert_eq!(legs.len(), 2, "one leg per backend: {evs:?}");
    for leg in &legs {
        assert_eq!(leg.parent, roots[0].span, "legs parent on the router root");
    }
    let backend_roots: Vec<&Event> =
        evs.iter().filter(|e| e.name == "serve.similar").collect();
    assert_eq!(backend_roots.len(), 2, "each backend opens its own root: {evs:?}");
    let backend_spans: Vec<u64> = backend_roots.iter().map(|e| e.span).collect();
    for root in &backend_roots {
        assert_eq!(root.parent, 0, "backend roots carry the trace, not a parent span");
    }
    // queue wait and service time are separate spans under the same root
    for stage in ["serve.admission_wait", "serve.batch_assembly", "serve.kernel"] {
        let stages: Vec<&Event> = evs.iter().filter(|e| e.name == stage).collect();
        assert!(!stages.is_empty(), "{stage} missing: {evs:?}");
        for s in &stages {
            assert!(
                backend_spans.contains(&s.parent),
                "{stage} must parent on a backend root: {evs:?}"
            );
            assert!(s.dur_us.is_some(), "{stage} is a timed span: {evs:?}");
        }
    }

    // ---- /score propagates through the proxy leg too ------------------
    let tid2 = "00000000000beef5";
    let resp = client.post(
        "/score",
        &[(http::TRACE_HEADER, tid2.to_string())],
        "+1 3:1 17:1 99:1\n",
    );
    assert_eq!(resp.status, 200, "{}", resp.body_text());
    assert_eq!(resp.trace_id(), Some(tid2), "score echo survives the router hop");
    let evs = wait_for_spans(&trace_path, tid2, &["route.score", "route.forward", "serve.score"]);
    let root = evs.iter().find(|e| e.name == "route.score").unwrap();
    let fwd = evs.iter().find(|e| e.name == "route.forward").unwrap();
    assert_eq!(fwd.parent, root.span, "the proxy leg parents on the router root");
    assert_eq!(
        evs.iter().find(|e| e.name == "serve.score").unwrap().parent,
        0,
        "the backend opens its own root under the same trace"
    );

    // ---- a client that sends no id still gets one minted at the edge --
    let mut direct = Client::connect(server_a.local_addr());
    let resp = direct.post("/score", &[], "+1 5:1\n");
    assert_eq!(resp.status, 200, "{}", resp.body_text());
    let minted = resp.trace_id().expect("edge mints an id when the client sends none");
    assert!(trace::parse_id(minted).is_some(), "minted id is wire-valid: {minted:?}");

    // ---- every /metrics body passes the format validator ---------------
    for (what, addr) in
        [("router", addr), ("backend A", server_a.local_addr()), ("backend B", server_b.local_addr())]
    {
        let resp = Client::connect(addr).get("/metrics");
        assert_eq!(resp.status, 200);
        assert!(resp.trace_id().is_some(), "{what}: even /metrics echoes a trace id");
        prom::validate(&resp.body_text())
            .unwrap_or_else(|e| panic!("{what} /metrics is not valid Prometheus: {e}"));
    }
    let m = Client::connect(addr).get("/metrics").body_text();
    assert!(m.contains("route_backends_up 2"), "{m}");

    router.shutdown();
    server_a.shutdown();
    server_b.shutdown();
    std::fs::remove_dir_all(dir).ok();
}
