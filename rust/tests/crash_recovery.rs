//! Crash-recovery acceptance tests (ISSUE 10): kill -9 and injected
//! faults against real `bbit-mh` subprocesses, proving the crash-safe
//! pipeline story end to end —
//!
//!   * a preprocess killed mid-write (or torn by a failpoint) resumes to
//!     a cache **byte-identical** to an uninterrupted run, and a crash
//!     before commit never publishes the destination path;
//!   * `train --checkpoint` + `--resume` reaches bit-identical final
//!     weights vs. a straight run;
//!   * a served model drains gracefully on SIGTERM: `/healthz` fails
//!     first, in-flight requests still complete, the process exits 0.
//!
//! Failpoint arming (`BBMH_FAILPOINTS`) is process-global and read once,
//! which is why armed behavior lives here, in subprocesses: every
//! `Command` states its failpoint value explicitly (set or removed), so
//! the suite stays hermetic even when CI arms the variable globally.

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::Duration;

use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::LibsvmWriter;

const BIN: &str = env!("CARGO_BIN_EXE_bbit-mh");

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bbmh_crash_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A `bbit-mh` invocation with failpoints explicitly disarmed; tests
/// that want an armed child layer `.env("BBMH_FAILPOINTS", ...)` on top.
fn cli() -> Command {
    let mut c = Command::new(BIN);
    c.env_remove("BBMH_FAILPOINTS");
    c
}

fn write_corpus(path: &Path, n_docs: usize, seed: u64) {
    let corpus = CorpusGenerator::new(CorpusConfig {
        n_docs,
        vocab: 2000,
        zipf_alpha: 1.05,
        mean_tokens: 30.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed,
    })
    .generate();
    let mut w = LibsvmWriter::new(std::fs::File::create(path).unwrap());
    w.write_dataset(&corpus).unwrap();
    w.finish().unwrap();
}

/// Durable preprocess flags shared by every cache test: small blocks so
/// a run has many records (= many kill windows), journal fsync on every
/// chunk so the salvageable prefix tracks the kill point tightly.
fn preprocess_args(input: &Path, cache: &Path) -> Vec<String> {
    [
        "preprocess",
        "--input",
        input.to_str().unwrap(),
        "--cache-out",
        cache.to_str().unwrap(),
        "--encoder",
        "oph",
        "--bins",
        "64",
        "--b",
        "4",
        "--seed",
        "7",
        "--workers",
        "2",
        "--block-kb",
        "4",
        "--sync-chunks",
        "1",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn resume_to_completion(input: &Path, cache: &Path, what: &str) {
    let out = cli()
        .args(preprocess_args(input, cache))
        .arg("--resume")
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{what}: resume failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn kill9_mid_write_then_resume_is_byte_identical() {
    let dir = tmp_dir("kill9");
    let input = dir.join("in.svm");
    write_corpus(&input, 3000, 0xC0);
    let reference = dir.join("ref.cache");
    assert!(cli().args(preprocess_args(&input, &reference)).status().unwrap().success());
    let ref_bytes = std::fs::read(&reference).unwrap();

    // slow each record write down so the kill lands at a different depth
    // into the cache each round: early (maybe before the header settles),
    // mid-stream, and late
    for (i, kill_ms) in [60u64, 150, 300].into_iter().enumerate() {
        let cache = dir.join(format!("kill{i}.cache"));
        let mut child = cli()
            .args(preprocess_args(&input, &cache))
            .env("BBMH_FAILPOINTS", "cache.write_record=delay-ms:5")
            .stderr(Stdio::null())
            .spawn()
            .unwrap();
        std::thread::sleep(Duration::from_millis(kill_ms));
        child.kill().ok(); // SIGKILL: no destructors, no flush
        let _ = child.wait();
        assert!(
            !cache.exists(),
            "kill at {kill_ms}ms: a killed run must never publish the destination"
        );
        resume_to_completion(&input, &cache, &format!("kill at {kill_ms}ms"));
        assert_eq!(
            std::fs::read(&cache).unwrap(),
            ref_bytes,
            "kill at {kill_ms}ms: resumed cache must be byte-identical"
        );
        // resuming a finished cache is an explicit no-op
        let out = cli()
            .args(preprocess_args(&input, &cache))
            .arg("--resume")
            .output()
            .unwrap();
        assert!(out.status.success());
        assert!(
            String::from_utf8_lossy(&out.stderr).contains("nothing to resume"),
            "second --resume should report there is nothing to do"
        );
        assert_eq!(std::fs::read(&cache).unwrap(), ref_bytes);
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn torn_record_write_fails_typed_and_resumes_clean() {
    let dir = tmp_dir("torn");
    let input = dir.join("in.svm");
    write_corpus(&input, 1500, 0xC1);
    let reference = dir.join("ref.cache");
    assert!(cli().args(preprocess_args(&input, &reference)).status().unwrap().success());
    let ref_bytes = std::fs::read(&reference).unwrap();

    // one record, somewhere in the stream (fixed-seed draw, so the same
    // record every run), persists a torn prefix and then errors
    let cache = dir.join("torn.cache");
    let out = cli()
        .args(preprocess_args(&input, &cache))
        .env("BBMH_FAILPOINTS", "cache.write_record=partial-write:0.25:1")
        .output()
        .unwrap();
    assert!(!out.status.success(), "an injected torn write must exit nonzero");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("failpoint"), "stderr should name the failpoint:\n{err}");
    assert!(!cache.exists(), "a torn run must not publish the destination");

    resume_to_completion(&input, &cache, "torn write");
    assert_eq!(
        std::fs::read(&cache).unwrap(),
        ref_bytes,
        "the torn tail must be truncated and re-ingested, not kept"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn finalize_crash_never_publishes_and_resume_commits() {
    let dir = tmp_dir("finalize");
    let input = dir.join("in.svm");
    write_corpus(&input, 1000, 0xC2);
    let reference = dir.join("ref.cache");
    assert!(cli().args(preprocess_args(&input, &reference)).status().unwrap().success());
    let ref_bytes = std::fs::read(&reference).unwrap();

    // error: typed failure on the commit path; panic: abrupt death inside
    // it.  Either way every record is already on disk and journaled, so
    // the resume replays nothing and just commits.
    for action in ["error", "panic"] {
        let cache = dir.join(format!("fin_{action}.cache"));
        let out = cli()
            .args(preprocess_args(&input, &cache))
            .env("BBMH_FAILPOINTS", format!("cache.finalize={action}"))
            .output()
            .unwrap();
        assert!(!out.status.success(), "cache.finalize={action} must exit nonzero");
        assert!(
            !cache.exists(),
            "cache.finalize={action}: a crash before commit must not publish"
        );
        resume_to_completion(&input, &cache, &format!("finalize {action}"));
        assert_eq!(std::fs::read(&cache).unwrap(), ref_bytes, "cache.finalize={action}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn train_resume_reaches_bit_identical_weights() {
    let dir = tmp_dir("train");
    let input = dir.join("in.svm");
    write_corpus(&input, 800, 0xC3);
    let cache = dir.join("train.cache");
    assert!(cli().args(preprocess_args(&input, &cache)).status().unwrap().success());

    let train = |extra: &[&str], model: &Path| {
        cli()
            .args([
                "train",
                "--cache",
                cache.to_str().unwrap(),
                "--solver",
                "sgd",
                "--loss",
                "logistic",
                "--lr0",
                "0.5",
                "--lambda",
                "0.0001",
                "--batch",
                "64",
                "--save-model",
                model.to_str().unwrap(),
            ])
            .args(extra)
            .output()
            .unwrap()
    };

    let straight = dir.join("straight.model");
    let out = train(&["--epochs", "6"], &straight);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // "crash" after epoch 3: run the first half checkpointed, then resume
    // the full schedule from the snapshot
    let ck = dir.join("ck.model");
    let part = dir.join("part.model");
    let out = train(
        &["--epochs", "3", "--checkpoint", ck.to_str().unwrap(), "--checkpoint-every", "1"],
        &part,
    );
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // a checkpoint is a valid model file (the serve tier can hot-load it)
    assert!(
        bbit_mh::solver::SavedModel::load(&ck).is_ok(),
        "checkpoint must load as a model"
    );

    let resumed = dir.join("resumed.model");
    let out = train(
        &["--epochs", "6", "--checkpoint", ck.to_str().unwrap(), "--resume"],
        &resumed,
    );
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("resuming from checkpoint"), "{err}");
    assert_eq!(
        std::fs::read(&straight).unwrap(),
        std::fs::read(&resumed).unwrap(),
        "resume must continue to bit-identical final weights"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[cfg(unix)]
#[test]
fn sigterm_drain_completes_inflight_requests() {
    use std::net::{SocketAddr, TcpStream};
    use std::time::Instant;

    use bbit_mh::encode::EncoderSpec;
    use bbit_mh::serve::http;
    use bbit_mh::solver::{LinearModel, SavedModel};

    fn get(addr: SocketAddr, path: &str) -> http::Response {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut w = stream;
        http::write_get(&mut w, path).unwrap();
        http::read_response(&mut reader).unwrap()
    }

    let dir = tmp_dir("drain");
    // serving needs only a spec + weights; hand-build a tiny model
    let spec = EncoderSpec::Oph { bins: 64, b: 4, seed: 7 };
    let w: Vec<f32> = (0..spec.output_dim()).map(|j| j as f32 * 0.01 - 1.0).collect();
    let model = dir.join("m.bbmh");
    SavedModel::new(spec, LinearModel { w }).unwrap().save(&model).unwrap();

    // every scored batch sleeps 400ms — wide enough to land SIGTERM while
    // requests are verifiably in flight
    let mut child = cli()
        .args([
            "serve",
            "--model",
            model.to_str().unwrap(),
            "--port",
            "0",
            "--workers",
            "2",
            "--deadline-ms",
            "5000",
            "--drain-ms",
            "10000",
        ])
        .env("BBMH_FAILPOINTS", "serve.batch=delay-ms:400")
        .stdin(Stdio::piped()) // held open: stdin EOF would stop the server
        .stdout(Stdio::null())
        .stderr(Stdio::piped())
        .spawn()
        .unwrap();
    let mut stderr = BufReader::new(child.stderr.take().unwrap());
    let addr: SocketAddr = loop {
        let mut line = String::new();
        if stderr.read_line(&mut line).unwrap() == 0 {
            let _ = child.kill();
            panic!("serve exited before announcing its address");
        }
        if let Some(s) = line.find("http://") {
            let rest = &line[s + "http://".len()..];
            let end = rest.find([' ', '/']).unwrap_or(rest.len());
            break rest[..end].trim().parse().unwrap();
        }
    };
    // keep draining stderr so the child never blocks on a full pipe
    std::thread::spawn(move || {
        let mut sink = String::new();
        while {
            sink.clear();
            stderr.read_line(&mut sink).unwrap_or(0) > 0
        } {}
    });
    assert_eq!(get(addr, "/healthz").status, 200);

    let workers: Vec<_> = (0..3)
        .map(|_| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut w = stream;
                http::write_post(&mut w, "/score", b"1 5:1 9:1 40:1\n").unwrap();
                http::read_response(&mut reader).unwrap()
            })
        })
        .collect();
    // let the requests reach the scorer (each batch holds 400ms), then
    // ask the platform's question: SIGTERM
    std::thread::sleep(Duration::from_millis(150));
    let st = Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .unwrap();
    assert!(st.success());

    // drain fails /healthz first — pollers stop routing here while the
    // in-flight work finishes
    let t0 = Instant::now();
    loop {
        let resp = get(addr, "/healthz");
        if resp.status == 503 {
            assert!(resp.body_text().contains("draining"), "{}", resp.body_text());
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "/healthz never went 503 after SIGTERM"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    for h in workers {
        let resp = h.join().unwrap();
        assert_eq!(
            resp.status,
            200,
            "in-flight request must finish during drain: {}",
            resp.body_text()
        );
    }
    let t0 = Instant::now();
    let status = loop {
        if let Some(st) = child.try_wait().unwrap() {
            break st;
        }
        assert!(t0.elapsed() < Duration::from_secs(15), "server never exited after drain");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(status.success(), "a drained server must exit 0");
    std::fs::remove_dir_all(dir).ok();
}
