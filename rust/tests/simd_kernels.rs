//! Acceptance tests for the unrolled train/score kernels (ISSUE 6).
//!
//! The contract (`kernels` module docs, "Exact vs tolerance-bounded"):
//!
//! - row decode and axpy are **bit-identical** to their scalar references
//!   for every `b` ∈ 1..=16 and awkward `k` (word-straddling codes,
//!   non-multiple-of-LANES lengths, padding tails);
//! - dot products are **tolerance-bounded** against an f64 reference
//!   (the 8-accumulator reduction reassociates the f32 sum);
//! - `dot_codes` (the classify/serve margin kernel) is **bitwise equal**
//!   to decode-then-`dot_idx` — one margin definition across train and
//!   serve;
//! - the codec's word-wise run scanner produces **byte-identical**
//!   compressed streams to a byte-wise reference encoder;
//! - end-to-end: replay training and evaluation stay **bit-for-bit
//!   deterministic across reader-pool thread counts** with the unrolled
//!   kernels in the loop.
//!
//! None of these tests touch `kernels::force_scalar` — that global is for
//! single-threaded bench A/Bs, and the test harness runs tests in parallel
//! threads.  Scalar/unrolled variants are called directly instead; CI
//! additionally runs this whole suite under `--cfg bbmh_force_scalar`.

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::CacheSink;
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::codec;
use bbit_mh::encode::packed::PackedCodes;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::kernels;
use bbit_mh::solver::{
    eval_from_cache_threads, train_from_cache_holdout_threads, SavedModel, SgdConfig, SgdLoss,
};
use bbit_mh::util::Rng;

/// Awkward row lengths: 1, sub-lane, lane-exact, lane+1, primes, the
/// paper's k=200, and a word-boundary-heavy 64.
const AWKWARD_K: [usize; 10] = [1, 2, 3, 5, 8, 13, 21, 37, 64, 200];

fn packed(b: u32, k: usize, n: usize, seed: u64) -> PackedCodes {
    let mut rng = Rng::new(seed);
    let mut pc = PackedCodes::new(b, k);
    for _ in 0..n {
        let row: Vec<u16> = (0..k).map(|_| rng.below(1u64 << b) as u16).collect();
        pc.push_row(&row).unwrap();
    }
    pc
}

fn weights(dim: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..dim).map(|_| rng.f32() - 0.5).collect()
}

// ---------------------------------------------------------------------------
// decode parity: every b × awkward k, fast vs scalar vs per-element get

#[test]
fn row_decode_is_bit_identical_for_every_b_and_awkward_k() {
    for b in 1u32..=16 {
        for &k in &AWKWARD_K {
            let pc = packed(b, k, 4, 0xDEC0 + (b as u64) * 131 + k as u64);
            let mut fast = vec![0u32; k];
            let mut scalar = vec![0u32; k];
            for i in 0..pc.n {
                pc.row_indices_into(i, &mut fast);
                pc.row_indices_scalar_into(i, &mut scalar);
                assert_eq!(fast, scalar, "b={b} k={k} row {i}");
                for (j, &t) in fast.iter().enumerate() {
                    assert_eq!(
                        t,
                        ((j as u32) << b) | pc.get(i, j) as u32,
                        "b={b} k={k} row {i} col {j}"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// axpy exact / dot tolerance-bounded

#[test]
fn axpy_is_bit_identical_across_kernels() {
    for b in [1u32, 3, 8, 16] {
        for &k in &AWKWARD_K {
            let pc = packed(b, k, 3, 0xABE ^ ((b as u64) << 8) ^ k as u64);
            let dim = k << b;
            let mut idx = vec![0u32; k];
            for i in 0..pc.n {
                pc.row_indices_into(i, &mut idx);
                let mut ws = weights(dim, 5);
                let mut wu = ws.clone();
                kernels::axpy_idx_scalar(&idx, -0.731, &mut ws);
                kernels::axpy_idx_unrolled(&idx, -0.731, &mut wu);
                assert_eq!(ws, wu, "b={b} k={k} row {i}");
            }
        }
    }
}

/// Documented dot tolerance: the unrolled reduction reassociates the f32
/// sum, so both variants are held to the same f64-reference band
/// (4·k·ε_f32·Σ|terms|) rather than to each other bitwise.
#[test]
fn dot_is_within_documented_tolerance_of_f64_reference() {
    for b in [2u32, 8, 16] {
        for &k in &AWKWARD_K {
            let pc = packed(b, k, 3, 0xD0 ^ ((b as u64) << 16) ^ k as u64);
            let w = weights(k << b, 29);
            let mut idx = vec![0u32; k];
            for i in 0..pc.n {
                pc.row_indices_into(i, &mut idx);
                let exact: f64 = idx.iter().map(|&t| w[t as usize] as f64).sum();
                let scale: f64 = idx.iter().map(|&t| (w[t as usize] as f64).abs()).sum();
                let tol = 4.0 * k as f64 * f32::EPSILON as f64 * scale + 1e-12;
                for got in
                    [kernels::dot_idx_scalar(&idx, &w), kernels::dot_idx_unrolled(&idx, &w)]
                {
                    assert!(
                        (got as f64 - exact).abs() <= tol,
                        "b={b} k={k} row {i}: {got} vs {exact} (tol {tol:e})"
                    );
                }
            }
        }
    }
}

#[test]
fn dot_codes_is_bitwise_the_decoded_dot() {
    for b in [1u32, 4, 7, 8, 16] {
        for &k in &[5usize, 8, 200] {
            let pc = packed(b, k, 3, 0x5E ^ (b as u64) << 20 ^ k as u64);
            let w = weights(k << b, 41);
            let mut idx = vec![0u32; k];
            let mut codes = vec![0u16; k];
            for i in 0..pc.n {
                pc.row_indices_into(i, &mut idx);
                pc.row_into(i, &mut codes);
                assert_eq!(
                    kernels::dot_codes(b, &codes, &w).to_bits(),
                    kernels::dot_idx_unrolled(&idx, &w).to_bits(),
                    "b={b} k={k} row {i}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// valued (VW/RP CSR) kernels

#[test]
fn valued_kernels_axpy_exact_and_dot_bounded() {
    let mut rng = Rng::new(0xCB);
    for len in [1usize, 4, 8, 9, 31, 100] {
        let idx: Vec<u32> = (0..len as u32).map(|j| j * 5 + 2).collect();
        let vals: Vec<f32> = (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let dim = 5 * len + 3;
        let w = weights(dim, 0xB0 + len as u64);
        let mut ws = w.clone();
        let mut wu = w.clone();
        kernels::axpy_vals_scalar(&idx, &vals, 1.19, &mut ws);
        kernels::axpy_vals_unrolled(&idx, &vals, 1.19, &mut wu);
        assert_eq!(ws, wu, "len={len}");

        let exact: f64 =
            idx.iter().zip(&vals).map(|(&t, &v)| w[t as usize] as f64 * v as f64).sum();
        let scale: f64 = idx
            .iter()
            .zip(&vals)
            .map(|(&t, &v)| (w[t as usize] as f64 * v as f64).abs())
            .sum();
        let tol = 4.0 * len as f64 * f32::EPSILON as f64 * scale + 1e-12;
        for got in
            [kernels::dot_vals_scalar(&idx, &vals, &w), kernels::dot_vals_unrolled(&idx, &vals, &w)]
        {
            assert!((got as f64 - exact).abs() <= tol, "len={len}: {got} vs {exact}");
        }
    }
}

// ---------------------------------------------------------------------------
// codec: word-wise run scan vs a byte-wise reference encoder

/// Byte-wise reimplementation of `codec::compress` (MIN_RUN = 4, maximal
/// literals, LEB128 `len<<1|is_run` tokens) — the pre-word-scan shape.
fn compress_reference(src: &[u8]) -> Vec<u8> {
    fn put_varint(dst: &mut Vec<u8>, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                dst.push(byte);
                return;
            }
            dst.push(byte | 0x80);
        }
    }
    let mut dst = Vec::new();
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i < src.len() {
        let mut run = 1usize;
        while i + run < src.len() && src[i + run] == src[i] {
            run += 1;
        }
        if run >= 4 {
            if lit_start < i {
                put_varint(&mut dst, ((i - lit_start) as u64) << 1);
                dst.extend_from_slice(&src[lit_start..i]);
            }
            put_varint(&mut dst, ((run as u64) << 1) | 1);
            dst.push(src[i]);
            i += run;
            lit_start = i;
        } else {
            i += run;
        }
    }
    if lit_start < src.len() {
        put_varint(&mut dst, ((src.len() - lit_start) as u64) << 1);
        dst.extend_from_slice(&src[lit_start..]);
    }
    dst
}

#[test]
fn codec_word_scan_is_byte_identical_to_reference_encoder() {
    let mut rng = Rng::new(0x90DE);
    let mut payloads: Vec<Vec<u8>> = vec![
        vec![],
        vec![9],
        vec![0; 3],
        vec![0; 4],
        vec![0; 4096],
        (0..=255u8).collect(),
    ];
    for n in [7usize, 8, 9, 63, 64, 65, 1023, 4096] {
        // run-heavy (few distinct bytes → runs straddle word boundaries)
        payloads.push((0..n).map(|_| rng.below(3) as u8).collect());
        // incompressible
        payloads.push((0..n).map(|_| rng.next_u64() as u8).collect());
        // alternating padding/noise, the packed-cache shape
        payloads.push(
            (0..n).map(|i| if (i / 16) % 2 == 0 { 0 } else { rng.next_u64() as u8 }).collect(),
        );
    }
    let mut comp = Vec::new();
    for (pi, p) in payloads.iter().enumerate() {
        codec::compress(p, &mut comp);
        assert_eq!(comp, compress_reference(p), "payload {pi} (len {})", p.len());
        let mut back = Vec::new();
        codec::decompress(&comp, &mut back, p.len()).unwrap();
        assert_eq!(&back, p, "payload {pi}");
    }
}

// ---------------------------------------------------------------------------
// end-to-end determinism: the unrolled kernels keep replay bit-for-bit
// reproducible across reader-pool thread counts

#[test]
fn replay_training_and_eval_stay_bitwise_deterministic_across_threads() {
    let ds: SparseDataset = CorpusGenerator::new(CorpusConfig {
        n_docs: 500,
        vocab: 1500,
        zipf_alpha: 1.05,
        mean_tokens: 24.0,
        class_signal: 0.55,
        pos_fraction: 0.5,
        seed: 0x51D3,
    })
    .generate();
    let spec = EncoderSpec::Bbit { b: 8, k: 48, d: 1 << 22, seed: 17 };
    let dir = std::env::temp_dir().join(format!("bbit_simdk_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("c.cache");
    {
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 53, queue_depth: 2 });
        let mut sink = CacheSink::create(&path, &spec).unwrap();
        pipe.run_sink(dataset_chunks(&ds, 53), &spec, &mut sink).unwrap();
    }
    let cfg = SgdConfig { loss: SgdLoss::Logistic, lr0: 0.5, lambda: 1e-3, epochs: 3, batch: 64 };

    let (m1, s1, h1) = train_from_cache_holdout_threads(&path, &cfg, 0.2, 11, 1).unwrap();
    for threads in [2usize, 4] {
        let (mt, st, ht) = train_from_cache_holdout_threads(&path, &cfg, 0.2, 11, threads).unwrap();
        assert_eq!(mt.w, m1.w, "threads={threads}: weights must be bit-for-bit");
        assert_eq!(st.objective.to_bits(), s1.objective.to_bits(), "threads={threads}");
        assert_eq!(ht.mean_loss.to_bits(), h1.mean_loss.to_bits(), "threads={threads}");
        assert_eq!(ht.accuracy, h1.accuracy, "threads={threads}");
    }

    let saved = SavedModel::new(spec, m1).unwrap();
    let e1 = eval_from_cache_threads(&path, &saved, SgdLoss::Logistic, 1).unwrap();
    for threads in [2usize, 3, 8] {
        let et = eval_from_cache_threads(&path, &saved, SgdLoss::Logistic, threads).unwrap();
        assert_eq!(et.rows, e1.rows, "threads={threads}");
        assert_eq!(et.accuracy, e1.accuracy, "threads={threads}");
        assert_eq!(et.mean_loss.to_bits(), e1.mean_loss.to_bits(), "threads={threads}");
    }
    std::fs::remove_dir_all(dir).ok();
}
