//! Acceptance tests for parallel cache replay (ISSUE 4):
//!
//! - order-preserving consumers are exact: N-thread replay produces
//!   bit-for-bit the same model / holdout report / eval numbers as the
//!   sequential scan;
//! - iterate-averaged SGD (`train_from_cache_threads`) is deterministic
//!   and lands within tolerance of the sequential run on separable data;
//! - parallel materialization equals `read_all`;
//! - a truncated index footer falls back to the sequential scan instead
//!   of failing;
//! - compressed (v3 flag) and v2-transplanted caches train identically to
//!   their uncompressed v3 twin.

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::CacheSink;
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::cache::{
    CacheReader, CacheWriteOptions, ChunkIndex, HEADER_BYTES_V2, HEADER_BYTES_V3,
};
use bbit_mh::coordinator::materialize_cache;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::solver::{
    accuracy, eval_from_cache, eval_from_cache_threads, train_from_cache,
    train_from_cache_holdout, train_from_cache_holdout_threads, train_from_cache_threads,
    LinearModel, SavedModel, SgdConfig, SgdLoss,
};

fn corpus(n: usize, signal: f64, seed: u64) -> SparseDataset {
    CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 1500,
        zipf_alpha: 1.05,
        mean_tokens: 24.0,
        class_signal: signal,
        pos_fraction: 0.5,
        seed,
    })
    .generate()
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bbit_preplay_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Hash `ds` into a fresh v3 cache with `chunk` rows per record.
fn build_cache(
    dir: &std::path::Path,
    name: &str,
    ds: &SparseDataset,
    spec: &EncoderSpec,
    chunk: usize,
    opts: CacheWriteOptions,
) -> std::path::PathBuf {
    let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: chunk, queue_depth: 2 });
    let path = dir.join(name);
    let mut sink = CacheSink::create_opts(&path, spec, opts).unwrap();
    pipe.run_sink(dataset_chunks(ds, chunk), spec, &mut sink).unwrap();
    path
}

fn sgd_cfg(epochs: usize) -> SgdConfig {
    SgdConfig { loss: SgdLoss::Logistic, lr0: 0.5, lambda: 1e-3, epochs, batch: 64 }
}

#[test]
fn pooled_eval_is_identical_for_every_thread_count() {
    let ds = corpus(700, 0.55, 0xE7A1);
    let spec = EncoderSpec::Bbit { b: 6, k: 32, d: 1 << 22, seed: 9 };
    let dir = tmp_dir("eval");
    let path = build_cache(&dir, "c.cache", &ds, &spec, 37, CacheWriteOptions::default());
    let (model, _) = train_from_cache(&path, &sgd_cfg(2)).unwrap();
    let saved = SavedModel::new(spec, model).unwrap();

    let seq = eval_from_cache(&path, &saved, SgdLoss::Logistic).unwrap();
    assert_eq!(seq.rows, 700);
    for threads in [1usize, 2, 3, 8] {
        let par = eval_from_cache_threads(&path, &saved, SgdLoss::Logistic, threads).unwrap();
        assert_eq!(par.rows, seq.rows, "threads={threads}");
        assert_eq!(par.accuracy, seq.accuracy, "threads={threads}");
        // bitwise, not approximate: the per-record fold order is fixed
        assert_eq!(
            par.mean_loss.to_bits(),
            seq.mean_loss.to_bits(),
            "threads={threads}: {} vs {}",
            par.mean_loss,
            seq.mean_loss
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn pooled_holdout_training_is_bit_for_bit_sequential() {
    let ds = corpus(600, 0.55, 0x401D2);
    let spec = EncoderSpec::Oph { bins: 32, b: 6, seed: 3 };
    let dir = tmp_dir("holdout");
    let path = build_cache(&dir, "c.cache", &ds, &spec, 64, CacheWriteOptions::default());
    let cfg = sgd_cfg(4);
    let (m_seq, s_seq, h_seq) = train_from_cache_holdout(&path, &cfg, 0.25, 7).unwrap();
    for threads in [2usize, 4] {
        let (m_par, s_par, h_par) =
            train_from_cache_holdout_threads(&path, &cfg, 0.25, 7, threads).unwrap();
        assert_eq!(m_par.w, m_seq.w, "threads={threads}: weights must be exact");
        assert_eq!(s_par.objective.to_bits(), s_seq.objective.to_bits());
        assert_eq!(h_par.holdout_rows, h_seq.holdout_rows);
        assert_eq!(h_par.accuracy, h_seq.accuracy);
        assert_eq!(h_par.mean_loss.to_bits(), h_seq.mean_loss.to_bits());
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn iterate_averaged_sgd_is_deterministic_and_within_tolerance() {
    // a strongly separable corpus: both the sequential and the averaged
    // parallel iterates must classify it well
    let ds = corpus(900, 0.85, 0x5E9A);
    let spec = EncoderSpec::Bbit { b: 8, k: 48, d: 1 << 24, seed: 21 };
    let dir = tmp_dir("avg");
    let path = build_cache(&dir, "c.cache", &ds, &spec, 64, CacheWriteOptions::default());
    let cfg = sgd_cfg(6);
    let (m_seq, s_seq) = train_from_cache(&path, &sgd_cfg(6)).unwrap();
    let (m_par, s_par) = train_from_cache_threads(&path, &cfg, 4).unwrap();
    assert_eq!(s_par.iterations, 6);
    assert!(s_par.objective.is_finite());

    let materialized = CacheReader::open(&path).unwrap().read_all().unwrap();
    let acc_seq = accuracy(&m_seq, &materialized);
    let acc_par = accuracy(&m_par, &materialized);
    assert!(acc_seq > 0.85, "sequential baseline failed to learn: {acc_seq}");
    assert!(acc_par > 0.85, "averaged iterate failed to learn: {acc_par}");
    assert!(
        (acc_seq - acc_par).abs() < 0.08,
        "averaged iterate too far from sequential: {acc_par} vs {acc_seq}"
    );
    // progressive losses agree to first order too
    assert!((s_par.objective - s_seq.objective).abs() < 0.25 * s_seq.objective.max(0.1));

    // fixed (cache, config, threads) → identical weights on rerun
    let (m_par2, _) = train_from_cache_threads(&path, &cfg, 4).unwrap();
    assert_eq!(m_par.w, m_par2.w, "parallel SGD must be deterministic");
    // single-thread request is exactly the sequential path
    let (m_one, _) = train_from_cache_threads(&path, &cfg, 1).unwrap();
    assert_eq!(m_one.w, m_seq.w);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn parallel_materialization_equals_read_all() {
    let ds = corpus(500, 0.55, 0xA7E);
    let spec = EncoderSpec::Bbit { b: 6, k: 40, d: 1 << 22, seed: 23 };
    let dir = tmp_dir("mat");
    let path = build_cache(&dir, "c.cache", &ds, &spec, 41, CacheWriteOptions::default());
    let seq = CacheReader::open(&path).unwrap().read_all().unwrap();
    for threads in [1usize, 2, 4, 16] {
        let par = materialize_cache(&path, threads).unwrap();
        assert_eq!(par.codes.words(), seq.codes.words(), "threads={threads}");
        assert_eq!(par.labels, seq.labels);
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn truncated_footer_falls_back_to_sequential_scan() {
    let ds = corpus(400, 0.55, 0xF007E);
    let spec = EncoderSpec::Bbit { b: 4, k: 24, d: 1 << 20, seed: 5 };
    let dir = tmp_dir("fallback");
    let path = build_cache(&dir, "c.cache", &ds, &spec, 50, CacheWriteOptions::default());
    let (model, _) = train_from_cache(&path, &sgd_cfg(1)).unwrap();
    let saved = SavedModel::new(spec, model).unwrap();
    let reference = eval_from_cache(&path, &saved, SgdLoss::Logistic).unwrap();
    let ds_ref = CacheReader::open(&path).unwrap().read_all().unwrap();

    // tear the trailer: the index dies, the records survive
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 11]).unwrap();
    assert!(ChunkIndex::load(&path).unwrap().is_none(), "footer must be unusable");

    // every parallel entry point downgrades to the sequential result
    let eval = eval_from_cache_threads(&path, &saved, SgdLoss::Logistic, 4).unwrap();
    assert_eq!(eval.rows, reference.rows);
    assert_eq!(eval.mean_loss.to_bits(), reference.mean_loss.to_bits());
    let mat = materialize_cache(&path, 4).unwrap();
    assert_eq!(mat.codes.words(), ds_ref.codes.words());
    let (m_seq, _) = train_from_cache(&path, &sgd_cfg(2)).unwrap();
    let (m_par, _) = train_from_cache_threads(&path, &sgd_cfg(2), 4).unwrap();
    assert_eq!(m_par.w, m_seq.w, "no index → parallel SGD degrades to sequential");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn compressed_cache_trains_identically_to_uncompressed() {
    let ds = corpus(500, 0.6, 0xC0BB);
    let spec = EncoderSpec::Bbit { b: 2, k: 12, d: 1 << 20, seed: 13 };
    let dir = tmp_dir("compress");
    let plain = build_cache(&dir, "plain.cache", &ds, &spec, 64, CacheWriteOptions::default());
    let packed = build_cache(
        &dir,
        "packed.cache",
        &ds,
        &spec,
        64,
        CacheWriteOptions { compress: true },
    );
    let meta = CacheReader::open(&packed).unwrap().meta();
    assert!(meta.compressed);
    assert_eq!(meta.n, 500);
    assert!(meta.raw_bytes > 0 && meta.stored_bytes > 0);
    // b=2, k=12 packs 24 bits into one word per row: five zero pad bytes
    // per row guarantee real RLE wins on top of any label runs
    assert!(
        meta.stored_bytes < meta.raw_bytes,
        "padded codes must compress: stored {} raw {}",
        meta.stored_bytes,
        meta.raw_bytes
    );

    // byte-identical replay → bit-identical training, sequential and pooled
    let cfg = sgd_cfg(3);
    let (m_plain, _) = train_from_cache(&plain, &cfg).unwrap();
    let (m_comp, _) = train_from_cache(&packed, &cfg).unwrap();
    assert_eq!(m_plain.w, m_comp.w, "compression must be transparent to training");
    let (m_comp_par, _, _) =
        train_from_cache_holdout_threads(&packed, &cfg, 0.2, 3, 4).unwrap();
    let (m_plain_seq, _, _) = train_from_cache_holdout(&plain, &cfg, 0.2, 3).unwrap();
    assert_eq!(m_comp_par.w, m_plain_seq.w);
    std::fs::remove_dir_all(dir).ok();
}

/// v1→v2→v3 read compatibility: the same record stream behind each
/// header version trains to identical weights (v1 is covered in
/// `encoder_api.rs`; here the v3 writer's records are transplanted behind
/// a hand-built v2 header).
#[test]
fn v2_transplant_trains_identically_to_v3() {
    let ds = corpus(300, 0.55, 0x2C0DE);
    let spec = EncoderSpec::Bbit { b: 6, k: 24, d: 1 << 22, seed: 0x51 };
    let dir = tmp_dir("v2parity");
    let v3_path = build_cache(&dir, "v3.cache", &ds, &spec, 50, CacheWriteOptions::default());
    let v3_bytes = std::fs::read(&v3_path).unwrap();
    let index = ChunkIndex::load(&v3_path).unwrap().unwrap();
    // records live between the v3 header and the footer; the framing is
    // identical to v2, so a v2 header + the same records is a valid file
    let records = &v3_bytes[HEADER_BYTES_V3 as usize..index.records_end as usize];
    let (tag, p0, p1, p2, seed) = spec.header_fields();
    let mut v2_bytes = Vec::new();
    v2_bytes.extend_from_slice(b"BBHC");
    v2_bytes.extend_from_slice(&2u32.to_le_bytes());
    v2_bytes.extend_from_slice(&tag.to_le_bytes());
    v2_bytes.extend_from_slice(&p0.to_le_bytes());
    for v in [p1, p2, seed, ds.len() as u64] {
        v2_bytes.extend_from_slice(&v.to_le_bytes());
    }
    assert_eq!(v2_bytes.len() as u64, HEADER_BYTES_V2);
    v2_bytes.extend_from_slice(records);
    let v2_path = dir.join("v2.cache");
    std::fs::write(&v2_path, &v2_bytes).unwrap();

    let m2 = CacheReader::open(&v2_path).unwrap().meta();
    let m3 = CacheReader::open(&v3_path).unwrap().meta();
    assert_eq!(m2.spec, m3.spec);
    assert_eq!(m2.n, m3.n);
    let cfg = sgd_cfg(2);
    let (w2, _) = train_from_cache(&v2_path, &cfg).unwrap();
    let (w3, _) = train_from_cache(&v3_path, &cfg).unwrap();
    assert_eq!(w2.w, w3.w, "v2 and v3 replays must train identically");
    // asking for parallel replay on the v2 file warns + falls back, same
    // weights again
    let (w2p, _) = train_from_cache_threads(&v2_path, &cfg, 4).unwrap();
    assert_eq!(w2p.w, w2.w);
    std::fs::remove_dir_all(dir).ok();
}

/// The replay-threads surface keeps the spec-mismatch guarantees of the
/// sequential path.
#[test]
fn pooled_eval_rejects_spec_mismatch() {
    let ds = corpus(200, 0.55, 0x5BEC2);
    let spec = EncoderSpec::Bbit { b: 4, k: 12, d: 1 << 20, seed: 5 };
    let dir = tmp_dir("mismatch");
    let path = build_cache(&dir, "c.cache", &ds, &spec, 40, CacheWriteOptions::default());
    let other = EncoderSpec::Bbit { b: 4, k: 12, d: 1 << 20, seed: 6 };
    let saved =
        SavedModel::new(other, LinearModel { w: vec![0.25; other.output_dim()] }).unwrap();
    assert!(eval_from_cache_threads(&path, &saved, SgdLoss::Logistic, 4).is_err());
    std::fs::remove_dir_all(dir).ok();
}
