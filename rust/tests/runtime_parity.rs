//! Cross-layer integration tests: the PJRT artifacts (pallas kernels AOT'd
//! through jax → HLO text) must agree with the native rust substrates on
//! identical inputs.  This closes the loop rust ⇄ HLO ⇄ pallas ⇄ jnp-ref:
//! the python suite pins pallas == ref, these tests pin rust == HLO.
//!
//! Requires `artifacts/` (run `make artifacts` first); tests are skipped
//! with a message if the manifest is missing so `cargo test` stays green
//! in a fresh checkout.

use std::path::Path;

use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::encode::packed::PackedCodes;
use bbit_mh::hashing::minwise::BbitMinHash;
use bbit_mh::hashing::universal::UniversalFamily;
use bbit_mh::hashing::vw::VwHasher;
use bbit_mh::runtime::{MinhashEngine, PjrtRuntime, TrainEngine, VwEngine};
use bbit_mh::solver::sgd::{train_sgd, SgdConfig, SgdLoss};
use bbit_mh::util::Rng;

// The PJRT client is not Sync, so each test builds its own runtime (cheap:
// compilation of these small modules is tens of milliseconds).
fn runtime() -> Option<PjrtRuntime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtRuntime::cpu(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime tests (no artifacts?): {e}");
            None
        }
    }
}

macro_rules! require_rt {
    () => {
        match runtime() {
            Some(rt) => rt,
            None => return,
        }
    };
}

fn sample_sets(n: usize, d: u64, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = 1 + rng.below_usize(400);
            rng.sample_distinct(d.min(1 << 30), len)
                .into_iter()
                .map(|x| x as u32)
                .collect()
        })
        .collect()
}

#[test]
fn minhash_artifact_matches_native_hasher() {
    let rt = &require_rt!();
    let engine = MinhashEngine::new(rt, "minhash_k200").unwrap();
    assert_eq!(engine.k, 200);
    let mut rng = Rng::new(0xA11CE);
    let family = UniversalFamily::draw(engine.k, engine.d_space, &mut rng);
    let sets = sample_sets(20, engine.d_space, 42);
    let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
    let z = engine.minhash_batch(&refs, &family).unwrap();

    // native twin over the same family
    let hasher = bbit_mh::hashing::minwise::MinwiseHasher { family: family.clone() };
    let mut scratch = vec![0u64; engine.k];
    for (r, set) in sets.iter().enumerate() {
        hasher.hash_into(set, &mut scratch);
        for j in 0..engine.k {
            assert_eq!(
                z[r * engine.k + j] as u64,
                scratch[j],
                "row {r} hash {j} disagrees"
            );
        }
    }
}

#[test]
fn minhash_artifact_bbit_codes_roundtrip() {
    let rt = &require_rt!();
    let engine = MinhashEngine::new(rt, "minhash_k200").unwrap();
    let mut rng = Rng::new(0xB0B);
    let family = UniversalFamily::draw(engine.k, engine.d_space, &mut rng);
    let sets = sample_sets(10, engine.d_space, 77);
    let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
    let mut packed = PackedCodes::new(8, engine.k);
    engine.codes_batch(&refs, &family, 8, &mut packed).unwrap();
    assert_eq!(packed.n, 10);
    // native b-bit codes from the same family
    let bb = BbitMinHash {
        hasher: bbit_mh::hashing::minwise::MinwiseHasher { family },
        b: 8,
    };
    for (r, set) in sets.iter().enumerate() {
        assert_eq!(packed.row(r), bb.codes(set), "row {r}");
    }
}

#[test]
fn vw_artifact_matches_native_hasher() {
    let rt = &require_rt!();
    let engine = VwEngine::new(rt, "vw_bins1024").unwrap();
    let mut rng = Rng::new(0x77);
    let hasher = VwHasher::draw(engine.bins, &mut rng);
    let sets = sample_sets(12, 1 << 30, 99);
    let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
    let got = engine.hash_batch(&refs, hasher.param_array()).unwrap();
    for (r, set) in sets.iter().enumerate() {
        let mut want = vec![0.0f32; engine.bins];
        hasher.hash_into(set, &mut want);
        assert_eq!(
            &got[r * engine.bins..(r + 1) * engine.bins],
            &want[..],
            "row {r}"
        );
    }
}

#[test]
fn execute_validates_input_geometry_before_launch() {
    // a geometry mismatch must surface as a typed runtime error naming
    // the artifact and the offending input — not an opaque XLA failure
    let rt = &require_rt!();
    let engine = MinhashEngine::new(rt, "minhash_k200").unwrap();
    let cap = engine.batch * engine.nnz;
    let idx = vec![0i32; cap - 1]; // one element short of [batch, nnz]
    let mask = vec![0i32; cap];
    let mut rng = Rng::new(1);
    let (c1, c2) = UniversalFamily::draw(engine.k, engine.d_space, &mut rng).param_arrays();
    let err = engine.minhash_padded(&idx, &mask, &c1, &c2).unwrap_err().to_string();
    assert!(err.contains("minhash_k200"), "must name the artifact: {err}");
    assert!(err.contains("input 0"), "must name the offending input: {err}");
}

/// Build a small correlated code dataset shared by the train parity tests.
fn code_data(
    n: usize,
    k: usize,
    b: u32,
    seed: u64,
) -> (bbit_mh::encode::expansion::BbitDataset, Vec<i32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut pc = PackedCodes::new(b, k);
    let mut labels = Vec::new();
    let half = 1u64 << (b - 1);
    for _ in 0..n {
        let pos = rng.bool();
        let row: Vec<u16> = (0..k)
            .map(|_| {
                if pos {
                    rng.below(half) as u16
                } else {
                    (half + rng.below(half)) as u16
                }
            })
            .collect();
        pc.push_row(&row).unwrap();
        labels.push(if pos { 1i8 } else { -1 });
    }
    let ds = bbit_mh::encode::expansion::BbitDataset::new(pc, labels);
    let codes_i32 = ds.codes_i32(0, n);
    let y: Vec<f32> = ds.labels.iter().map(|&l| l as f32).collect();
    (ds, codes_i32, y)
}

#[test]
fn train_artifact_matches_native_sgd() {
    let rt = &require_rt!();
    let mut engine = TrainEngine::new(rt, "train_logistic_b8_k200", "predict_b8_k200").unwrap();
    let n = engine.chunk; // one full chunk => identical minibatch layout
    let (ds, codes, y) = code_data(n, engine.k, engine.b, 0xC0DE);
    let (lr0, lambda) = (0.5f32, 1e-4f32);
    engine.train_chunk(&codes, &y, lr0, lambda).unwrap();
    assert_eq!(engine.steps_done() as usize, n / engine.batch);

    let native = train_sgd(
        &ds,
        &SgdConfig {
            loss: SgdLoss::Logistic,
            lr0: lr0 as f64,
            lambda: lambda as f64,
            epochs: 1,
            batch: engine.batch,
        },
    )
    .0;
    let max_diff = engine
        .w
        .iter()
        .zip(&native.w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 5e-4, "PJRT vs native SGD weights differ by {max_diff}");
}

#[test]
fn predict_artifact_matches_native_margins() {
    let rt = &require_rt!();
    let mut engine = TrainEngine::new(rt, "train_sqhinge_b8_k200", "predict_b8_k200").unwrap();
    let (ds, codes, y) = code_data(engine.chunk, engine.k, engine.b, 0xFACE);
    engine.train_chunk(&codes, &y, 0.5, 1e-4).unwrap();
    let margins = engine.margins(&codes).unwrap();
    assert_eq!(margins.len(), ds.len());
    // native margins with the engine's weights
    use bbit_mh::solver::linear::FeatureMatrix;
    for i in (0..ds.len()).step_by(97) {
        let want = ds.dot(i, &engine.w);
        assert!(
            (margins[i] - want).abs() < 1e-4 * (1.0 + want.abs()),
            "row {i}: {} vs {want}",
            margins[i]
        );
    }
    // trained on separable codes → high accuracy through the PJRT path
    let correct = margins
        .iter()
        .zip(&ds.labels)
        .filter(|(m, &l)| (**m >= 0.0) == (l > 0))
        .count();
    assert!(correct as f64 / ds.len() as f64 > 0.95);
}

#[test]
fn routed_minhash_matches_native_and_preserves_order() {
    use bbit_mh::runtime::RoutedMinhash;
    let rt = &require_rt!();
    let routed = RoutedMinhash::new(rt, "minhash_k512_nnz512", "minhash_k512").unwrap();
    let mut rng = Rng::new(0x0707);
    let family = UniversalFamily::draw(routed.k(), routed.d_space(), &mut rng);
    // mix of short (routes small) and long (routes large) documents
    let mut sets: Vec<Vec<u32>> = Vec::new();
    for i in 0..40 {
        let len = if i % 3 == 0 { 600 + rng.below_usize(1200) } else { 1 + rng.below_usize(500) };
        sets.push(
            rng.sample_distinct(routed.d_space(), len)
                .into_iter()
                .map(|x| x as u32)
                .collect(),
        );
    }
    let refs: Vec<&[u32]> = sets.iter().map(|s| s.as_slice()).collect();
    let z = routed.minhash_all(&refs, &family).unwrap();
    let hasher = bbit_mh::hashing::minwise::MinwiseHasher { family };
    let mut scratch = vec![0u64; routed.k()];
    for (r, set) in sets.iter().enumerate() {
        hasher.hash_into(set, &mut scratch);
        for j in 0..routed.k() {
            assert_eq!(z[r * routed.k() + j] as u64, scratch[j], "row {r} hash {j}");
        }
    }
    // oversize documents error cleanly
    let huge: Vec<u32> = (0..3000u32).collect();
    assert!(routed.minhash_all(&[&huge], &hasher.family).is_err());
}

#[test]
fn pipeline_with_pjrt_worker_matches_native_pipeline() {
    // The Table-2 "GPU column" path: pipeline whose worker body calls the
    // PJRT minhash engine must produce the same packed codes as the native
    // multi-threaded path.
    let rt = &require_rt!();
    let engine = MinhashEngine::new(rt, "minhash_k200").unwrap();
    let corpus = CorpusGenerator::new(CorpusConfig {
        n_docs: 300,
        vocab: 2000,
        zipf_alpha: 1.05,
        mean_tokens: 25.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed: 5,
    })
    .generate();

    let k = engine.k;
    let b = 8u32;
    let mut rng = Rng::new(123);
    let family = UniversalFamily::draw(k, engine.d_space, &mut rng);

    // PJRT path (single engine, batched)
    let mut packed = PackedCodes::new(b, k);
    let mut batch: Vec<&[u32]> = Vec::new();
    for i in 0..corpus.len() {
        batch.push(corpus.row(i).0);
        if batch.len() == engine.batch || i + 1 == corpus.len() {
            engine.codes_batch(&batch, &family, b, &mut packed).unwrap();
            batch.clear();
        }
    }

    // native path
    let hasher = BbitMinHash {
        hasher: bbit_mh::hashing::minwise::MinwiseHasher { family },
        b,
    };
    for i in 0..corpus.len() {
        assert_eq!(packed.row(i), hasher.codes(corpus.row(i).0), "row {i}");
    }
}
