//! Ingest fast-path conformance: the byte-block parser must produce
//! bit-identical `Example`s to the legacy line reader over every edge case
//! the LibSVM dialect allows, and the block-parallel pipeline must hash
//! them into bit-identical output for every encoder — the acceptance gate
//! for making the byte path the default raw-input reader.

use bbit_mh::coordinator::pipeline::{Pipeline, PipelineConfig, PipelineOutput};
use bbit_mh::coordinator::sink::CollectSink;
use bbit_mh::data::libsvm::{
    parse_block, BlockReader, ChunkedReader, LibsvmReader, ParsedChunk,
};
use bbit_mh::data::Example;
use bbit_mh::encode::cache::CacheReader;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::Error;

/// Parse `data` through the byte-block path at a given slab size.
fn byte_parse(
    data: &[u8],
    block_bytes: usize,
    binary: bool,
) -> Result<Vec<Example>, Error> {
    let mut out = Vec::new();
    let mut parsed = ParsedChunk::default();
    for block in BlockReader::new(data).with_block_bytes(block_bytes) {
        let block = block?;
        parsed.clear();
        parse_block(&block.bytes, block.first_line, binary, &mut parsed)?;
        out.extend(parsed.to_examples());
    }
    Ok(out)
}

/// Parse `data` through the legacy line reader.
fn legacy_parse(data: &[u8], binary: bool) -> Result<Vec<Example>, Error> {
    let rd = LibsvmReader::new(data);
    let rd = if binary { rd.binary() } else { rd };
    rd.collect()
}

/// Assert byte-path == legacy-path for `data`, across slab sizes that
/// place block boundaries inside lines, between lines, and past EOF.
fn assert_conformant(data: &[u8]) {
    let legacy = legacy_parse(data, false).unwrap();
    let legacy_bin = legacy_parse(data, true).unwrap();
    for block_bytes in [1usize, 3, 7, 16, 61, 256, 1 << 20] {
        assert_eq!(
            byte_parse(data, block_bytes, false).unwrap(),
            legacy,
            "valued mode, block_bytes={block_bytes}, data={:?}",
            String::from_utf8_lossy(data)
        );
        assert_eq!(
            byte_parse(data, block_bytes, true).unwrap(),
            legacy_bin,
            "binary mode, block_bytes={block_bytes}, data={:?}",
            String::from_utf8_lossy(data)
        );
    }
}

#[test]
fn crlf_line_endings() {
    assert_conformant(b"+1 1:1 5:1\r\n-1 2:1 3:1\r\n");
    // mixed endings in one file
    assert_conformant(b"+1 1:1\r\n-1 2:1\n+1 3:1\r\n");
}

#[test]
fn comments_blanks_and_trailing_comment_tokens() {
    assert_conformant(b"# header comment\n\n+1 1:1 2:1 # trailing note\n\n-1 3:1\n# tail\n\n");
    // '#' glued to a token boundary starts the comment mid-line
    assert_conformant(b"+1 4:1 #5:1 6:1\n");
}

#[test]
fn label_dialects() {
    // 0/1 dumps, +1/-1 dumps, float labels, negative floats, zero
    assert_conformant(b"0 1:1\n1 2:1\n+1 3:1\n-1 4:1\n");
    assert_conformant(b"0.5 1:1\n-2e0 2:1\n0.0 3:1\n2 4:1\n-0 5:1\n");
}

#[test]
fn zero_and_one_based_indices() {
    // 0-based and 1-based corpora both pass through with raw indices
    assert_conformant(b"+1 0:1 1:1 2:1\n-1 0:1 9:1\n");
    assert_conformant(b"+1 1:1 2:1 3:1\n-1 10:1\n");
}

#[test]
fn valued_rows_unsorted_and_duplicate_indices() {
    assert_conformant(b"+1 9:0.5 1:2 5:1\n");
    // duplicates in binary/all-ones rows dedup
    assert_conformant(b"+1 5:1 5:1 1:1\n");
    // all-ones valued rows demote to binary (values None)
    assert_conformant(b"+1 3:1 2:1 2:1\n");
    // scientific notation and precise decimals
    assert_conformant(b"-1 1:0.0078125 2:1.25e-3 3:305.2 4:1e10\n");
}

#[test]
fn whitespace_extremes() {
    assert_conformant(b"   +1   1:1    5:1   \n\t-1\t2:1\t\n");
    // ASCII vertical tab (0x0B): str::trim strips it at line edges (it is
    // Unicode whitespace) even though is_ascii_whitespace excludes it —
    // both readers must trim it, skip VT-only lines, and agree that a
    // mid-token VT is a parse error on the same line
    assert_conformant(b"\x0B+1 3:1\x0B\n\x0B\x0B\n-1 2:1\x0B \n");
    let data = b"+1 1:1\n+1 3:0.5\x0B4:1\n";
    let legacy_err = legacy_parse(data, false).unwrap_err();
    let byte_err = byte_parse(data, 8, false).unwrap_err();
    match (legacy_err, byte_err) {
        (Error::LibsvmParse { line: ll, .. }, Error::LibsvmParse { line: bl, .. }) => {
            assert_eq!(ll, 2);
            assert_eq!(bl, 2);
        }
        other => panic!("wrong errors {other:?}"),
    }
}

#[test]
fn truncated_final_line_parses() {
    // no trailing newline on the last record
    assert_conformant(b"+1 1:1\n-1 7:1 9:1");
    // file ending in blanks/comments yields no phantom rows
    assert_conformant(b"+1 1:1\n\n# done");
}

#[test]
fn out_of_range_index_is_an_error_with_the_legacy_line_number() {
    let data = b"+1 1:1\n+1 4294967296:1\n";
    let legacy_err = legacy_parse(data, true).unwrap_err();
    let byte_err = byte_parse(data, 8, true).unwrap_err();
    match (legacy_err, byte_err) {
        (
            Error::LibsvmParse { line: ll, .. },
            Error::LibsvmParse { line: bl, msg },
        ) => {
            assert_eq!(ll, 2);
            assert_eq!(bl, 2, "{msg}");
            assert!(msg.contains("bad index"), "{msg}");
        }
        other => panic!("wrong errors {other:?}"),
    }
}

#[test]
fn malformed_tokens_error_on_the_same_line_as_legacy() {
    for data in [
        &b"+1 1:1\nbroken token\n"[..],
        b"+1 1:1\n-1 2:\n",
        b"+1 1:1\n-1 :5\n",
        b"bogus 1:1\n",
    ] {
        let legacy_err = legacy_parse(data, false).unwrap_err();
        let byte_err = byte_parse(data, 4, false).unwrap_err();
        match (legacy_err, byte_err) {
            (
                Error::LibsvmParse { line: ll, .. },
                Error::LibsvmParse { line: bl, .. },
            ) => assert_eq!(ll, bl, "data={:?}", String::from_utf8_lossy(data)),
            other => panic!("wrong errors {other:?}"),
        }
    }
}

#[test]
fn non_utf8_bytes_in_comments_parse_on_the_byte_path() {
    // the legacy reader dies on invalid UTF-8 anywhere in the file; the
    // byte parser never validates UTF-8 and only looks at a comment's
    // first byte — the data lines still come through
    let mut data = Vec::new();
    data.extend_from_slice(b"# \xC0\xFF\xEE raw bytes \x00\n+1 1:1 8:1\n-1 2:1\n");
    assert!(legacy_parse(&data, true).is_err(), "legacy reader should reject");
    let fast = byte_parse(&data, 16, true).unwrap();
    assert_eq!(
        fast,
        vec![Example::binary(1, vec![1, 8]), Example::binary(-1, vec![2])]
    );
}

#[test]
fn steady_state_parsing_reuses_one_scratch() {
    // N docs through one reused ParsedChunk: after the first block the
    // arenas must never grow again (the no-per-document-allocation gate)
    let mut data = String::new();
    for i in 0..500 {
        data.push_str(&format!("+1 {}:1 {}:1 {}:1 {}:1\n", i + 1, i + 600, i + 1200, i + 1800));
    }
    let mut parsed = ParsedChunk::default();
    parse_block(data.as_bytes(), 1, true, &mut parsed).unwrap();
    let n = parsed.len();
    assert_eq!(n, 500);
    let snapshot = |p: &ParsedChunk| (p.len(), p.row(0).0.to_vec(), p.row(n - 1).0.to_vec());
    let first = snapshot(&parsed);
    for _ in 0..8 {
        parsed.clear();
        parse_block(data.as_bytes(), 1, true, &mut parsed).unwrap();
        assert_eq!(snapshot(&parsed), first);
    }
}

/// Hash a LibSVM byte buffer through (a) the legacy chunk pipeline and
/// (b) the block-parallel pipeline, returning both outputs.
fn hash_both_paths(
    data: &[u8],
    spec: &EncoderSpec,
    workers: usize,
) -> (PipelineOutput, PipelineOutput) {
    let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 64, queue_depth: 2 });
    let legacy_src = ChunkedReader::new(LibsvmReader::new(data).binary(), 64);
    let mut legacy_sink = CollectSink::for_spec(spec).unwrap();
    pipe.run_sink(legacy_src, spec, &mut legacy_sink).unwrap();
    let blocks = BlockReader::new(data).with_block_bytes(301);
    let mut block_sink = CollectSink::for_spec(spec).unwrap();
    pipe.run_sink_blocks(blocks, true, spec, &mut block_sink).unwrap();
    (legacy_sink.into_output(), block_sink.into_output())
}

#[test]
fn block_parallel_hashing_is_bit_identical_for_every_encoder() {
    // a corpus big enough for many blocks and unbalanced rows
    let mut data = String::new();
    for i in 0..400u32 {
        let label = if i % 3 == 0 { "+1" } else { "-1" };
        data.push_str(label);
        for j in 0..(5 + i % 37) {
            data.push_str(&format!(" {}:1", (i * 131 + j * 17) % 100_000));
        }
        data.push('\n');
    }
    let specs = [
        EncoderSpec::Bbit { b: 8, k: 50, d: 1 << 20, seed: 5 },
        EncoderSpec::Oph { bins: 64, b: 4, seed: 7 },
        EncoderSpec::Vw { bins: 256, seed: 9 },
        EncoderSpec::Rp { proj: 24, s: 3.0, seed: 11 },
    ];
    for spec in &specs {
        for workers in [1usize, 4] {
            let (legacy, fast) = hash_both_paths(data.as_bytes(), spec, workers);
            match (legacy, fast) {
                (PipelineOutput::Packed(a), PipelineOutput::Packed(b)) => {
                    assert_eq!(a.labels, b.labels, "{} w={workers}", spec.scheme());
                    assert_eq!(a.len(), b.len());
                    for i in 0..a.len() {
                        assert_eq!(
                            a.codes.row(i),
                            b.codes.row(i),
                            "{} w={workers} row {i}",
                            spec.scheme()
                        );
                    }
                }
                (PipelineOutput::Sparse(a), PipelineOutput::Sparse(b)) => {
                    assert_eq!(a.labels, b.labels, "{} w={workers}", spec.scheme());
                    assert_eq!(a.indptr, b.indptr);
                    assert_eq!(a.indices, b.indices);
                    assert_eq!(a.values, b.values);
                }
                _ => panic!("{}: output kinds diverged", spec.scheme()),
            }
        }
    }
}

#[test]
fn cache_from_block_path_replays_identically_to_legacy_cache() {
    // preprocess → cache through both ingest paths; the cache *records*
    // may be framed differently (row-count per record follows the source
    // chunking) but decoded rows must match exactly — so `train --cache`
    // sees the identical corpus whichever parser built the cache
    let mut data = String::new();
    for i in 0..300u32 {
        data.push_str(&format!("+1 {}:1 {}:1\n", i % 97, (i * 7) % 89 + 100));
    }
    let spec = EncoderSpec::Bbit { b: 6, k: 17, d: 1 << 18, seed: 3 };
    let dir = std::env::temp_dir().join(format!("bbit_ingest_cache_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (legacy_path, block_path) = (dir.join("legacy.cache"), dir.join("block.cache"));

    let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 32, queue_depth: 2 });
    {
        let mut sink = bbit_mh::coordinator::CacheSink::create(&legacy_path, &spec).unwrap();
        let src = ChunkedReader::new(LibsvmReader::new(data.as_bytes()).binary(), 32);
        pipe.run_sink(src, &spec, &mut sink).unwrap();
    }
    {
        let mut sink = bbit_mh::coordinator::CacheSink::create(&block_path, &spec).unwrap();
        let blocks = BlockReader::new(data.as_bytes()).with_block_bytes(128);
        pipe.run_sink_blocks(blocks, true, &spec, &mut sink).unwrap();
    }
    let a = CacheReader::open(&legacy_path).unwrap().read_all().unwrap();
    let b = CacheReader::open(&block_path).unwrap().read_all().unwrap();
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert_eq!(a.codes.row(i), b.codes.row(i), "row {i}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
