//! Fleet-tier acceptance tests (ISSUE 7): `bbit-mh route` in front of
//! N ≥ 2 backends holding disjoint index shards.
//!
//! - shard placement is the deterministic consistent-hash assignment, and
//!   a raw-query scatter-gather over disjoint shards reproduces the
//!   single-index top-K bit-for-bit;
//! - killing one backend degrades *only its shards*: doc lookups for the
//!   dead shard answer `503`, healthy-shard lookups keep answering `200`,
//!   raw queries answer `200` flagged `X-Partial-Results`;
//! - restarting the backend on the same port recovers the fleet (health
//!   probes flip it back up, the partial flag disappears).
//!
//! The router and backends all talk loopback; backend ports are reserved
//! up front (bind :0, note the port, drop the listener) because the
//! consistent-hash assignment is a function of the backend address list.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::CacheSink;
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::cache::CacheWriteOptions;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::hashing::lsh::LshConfig;
use bbit_mh::serve::http;
use bbit_mh::serve::{shard_assignment, ModelServer, Router, RouterConfig, ServeConfig};
use bbit_mh::similarity::{snapshot, LshIndex};
use bbit_mh::solver::{LinearModel, SavedModel};

const SHARDS: usize = 4;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bbmh_route_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn corpus(n: usize, seed: u64) -> SparseDataset {
    CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 2000,
        zipf_alpha: 1.05,
        mean_tokens: 28.0,
        class_signal: 0.5,
        pos_fraction: 0.5,
        seed,
    })
    .generate()
}

/// Reserve a loopback port: bind :0, note the port, release it.
fn reserve_port() -> u16 {
    TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

/// Two reserved backend addresses whose consistent-hash assignment uses
/// both backends (re-rolled otherwise — a 2-backend fleet where one owns
/// every shard would make the degradation test vacuous).
fn two_backends() -> (Vec<String>, Vec<usize>) {
    for _ in 0..32 {
        let backends: Vec<String> =
            (0..2).map(|_| format!("127.0.0.1:{}", reserve_port())).collect();
        let assignment = shard_assignment(&backends, SHARDS);
        if assignment.contains(&0) && assignment.contains(&1) {
            return (backends, assignment);
        }
    }
    panic!("could not reserve a port pair covering both backends");
}

fn backend_port(backend: &str) -> u16 {
    backend.rsplit(':').next().unwrap().parse().unwrap()
}

/// Start a backend on its reserved port with the given shard snapshots.
fn start_backend(
    model: &std::path::Path,
    port: u16,
    snaps: &[PathBuf],
) -> (ModelServer, Arc<LshIndex>) {
    let idx = Arc::new(snapshot::load_many(snaps).unwrap());
    let cfg = ServeConfig {
        port,
        scorer_workers: 2,
        deadline: Duration::from_secs(5),
        ..Default::default()
    };
    // the reserved port was released above; re-binding can race with the
    // OS (or a previous incarnation's teardown), so retry briefly
    let t0 = Instant::now();
    loop {
        match ModelServer::start_with_index(model, cfg.clone(), Some(idx.clone())) {
            Ok(s) => return (s, idx),
            Err(e) => {
                assert!(t0.elapsed() < Duration::from_secs(5), "backend never bound: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to router");
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn post_top_k(&mut self, path: &str, body: &str, top_k: usize) -> http::Response {
        let hdr = [("X-Top-K", top_k.to_string())];
        http::write_post_with(&mut self.stream, path, &hdr, body.as_bytes()).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }

    fn get(&mut self, path: &str) -> http::Response {
        http::write_get(&mut self.stream, path).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }
}

fn parse_hits(body: &str) -> Vec<(u64, f64)> {
    body.lines()
        .map(|l| {
            let mut toks = l.split_ascii_whitespace();
            (toks.next().unwrap().parse().unwrap(), toks.next().unwrap().parse().unwrap())
        })
        .collect()
}

fn assert_hits_match(got: &http::Response, expect: &[bbit_mh::similarity::Neighbor], ctx: &str) {
    assert_eq!(got.status, 200, "{ctx}: {}", got.body_text());
    let got = parse_hits(&got.body_text());
    assert_eq!(got.len(), expect.len(), "{ctx}");
    for (g, e) in got.iter().zip(expect) {
        assert_eq!((g.0, g.1.to_bits()), (e.id, e.estimate.to_bits()), "{ctx}");
    }
}

/// Poll the router's `/healthz` until `pred` holds (fresh connection per
/// probe — the router may have been mid-transition on the last one).
fn wait_healthz(addr: SocketAddr, pred: impl Fn(&str) -> bool, what: &str) {
    let t0 = Instant::now();
    loop {
        let body = Client::connect(addr).get("/healthz").body_text();
        if pred(&body) {
            return;
        }
        assert!(t0.elapsed() < Duration::from_secs(15), "{what} never happened:\n{body}");
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn fleet_degrades_per_shard_and_recovers_after_restart() {
    let ds = corpus(600, 0xF1EE7);
    let spec = EncoderSpec::Bbit { b: 8, k: 32, d: ds.dim, seed: 13 };
    let dir = tmp_dir("fleet");

    // hash once, build the sharded index, snapshot per shard
    let cache = {
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 53, queue_depth: 2 });
        let path = dir.join("fleet.cache");
        let mut sink =
            CacheSink::create_opts(&path, &spec, CacheWriteOptions::default()).unwrap();
        pipe.run_sink(dataset_chunks(&ds, 53), &spec, &mut sink).unwrap();
        path
    };
    let cfg = LshConfig { bands: 8, rows_per_band: 4 };
    let full = LshIndex::build_from_cache(&cache, cfg, SHARDS, 2).unwrap();
    let mut snaps = Vec::new();
    for s in 0..SHARDS {
        let p = dir.join(format!("fleet.idx.shard{s}"));
        snapshot::save_shard(&full, s, &p).unwrap();
        snaps.push(p);
    }

    let model_path = dir.join("m.bbmh");
    let w: Vec<f32> = (0..spec.output_dim()).map(|j| (j as f32 * 0.3).sin()).collect();
    SavedModel::new(spec, LinearModel { w }).unwrap().save(&model_path).unwrap();

    // place shards by the router's own assignment and start the backends
    let (backends, assignment) = two_backends();
    let shards_of = |backend: usize| -> Vec<PathBuf> {
        assignment
            .iter()
            .enumerate()
            .filter(|(_, &b)| b == backend)
            .map(|(s, _)| snaps[s].clone())
            .collect()
    };
    let (server_a, index_a) = start_backend(&model_path, backend_port(&backends[0]), &shards_of(0));
    let (server_b, index_b) = start_backend(&model_path, backend_port(&backends[1]), &shards_of(1));

    let router = Router::start(RouterConfig {
        backends: backends.clone(),
        shards: SHARDS,
        health_poll: Duration::from_millis(50),
        health_timeout: Duration::from_millis(500),
        fail_threshold: 2,
        max_backoff: Duration::from_millis(200),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(router.assignment(), assignment.as_slice(), "router must use the same map");
    let addr = router.local_addr();
    wait_healthz(addr, |b| b.contains("backends=2/2"), "both backends up");

    // ---- healthy fleet: scatter-gather == the single full index --------
    let line = {
        let (idx, _) = ds.row(11);
        let mut l = String::from("+1");
        for x in idx {
            l.push_str(&format!(" {x}:1"));
        }
        l.push('\n');
        l
    };
    let mut scratch = full.scratch();
    let (idx11, _) = ds.row(11);
    full.hash_query(&idx11.to_vec(), &mut scratch).unwrap();
    let (expect_full, _) = full.query(&scratch.codes, 9).unwrap();
    let mut client = Client::connect(addr);
    let resp = client.post_top_k("/similar", &line, 9);
    assert!(resp.header("x-partial-results").is_none(), "healthy fleet is never partial");
    assert_hits_match(&resp, &expect_full, "healthy scatter-gather");

    // doc lookups route to the owner backend and answer from its shards
    for id in [0u64, 1, 2, 3] {
        let owner_index = if assignment[(id % SHARDS as u64) as usize] == 0 {
            &index_a
        } else {
            &index_b
        };
        let (expect, _) = owner_index.query_doc(id, 6).unwrap();
        let resp = client.post_top_k("/similar", &format!("doc:{id}\n"), 6);
        assert_hits_match(&resp, &expect, &format!("doc {id} via owner backend"));
    }

    // ---- kill backend B: only its shards degrade -----------------------
    let report_b = server_b.shutdown();
    assert!(report_b.contains("serve_similar_received_total"), "{report_b}");
    wait_healthz(addr, |b| b.contains("backends=1/2"), "B marked down");

    let b_shards: Vec<usize> =
        assignment.iter().enumerate().filter(|(_, &b)| b == 1).map(|(s, _)| s).collect();
    // a doc owned by a dead shard: 503, that shard only
    let dead_doc = b_shards[0] as u64; // id s has id % SHARDS == s for s < SHARDS
    let mut client = Client::connect(addr);
    let resp = client.post_top_k("/similar", &format!("doc:{dead_doc}\n"), 6);
    assert_eq!(resp.status, 503, "{}", resp.body_text());
    assert!(
        resp.body_text().contains(&format!("shard {} unavailable", b_shards[0])),
        "{}",
        resp.body_text()
    );
    // docs owned by A's shards still answer
    let a_shard = assignment.iter().position(|&b| b == 0).unwrap();
    let (expect, _) = index_a.query_doc(a_shard as u64, 6).unwrap();
    let resp = client.post_top_k("/similar", &format!("doc:{a_shard}\n"), 6);
    assert_hits_match(&resp, &expect, "healthy shard while B is down");

    // raw queries still answer, flagged partial, equal to A's local view
    full.hash_query(&idx11.to_vec(), &mut scratch).unwrap();
    let (expect_a, _) = index_a.query(&scratch.codes, 9).unwrap();
    let resp = client.post_top_k("/similar", &line, 9);
    assert_eq!(resp.header("x-partial-results"), Some("true"), "{:?}", resp.headers);
    let missing = resp.header("x-shards-missing").unwrap().to_string();
    let listed: Vec<usize> = missing.split(',').map(|s| s.parse().unwrap()).collect();
    assert_eq!(listed, b_shards, "exactly B's shards must be flagged missing");
    assert_hits_match(&resp, &expect_a, "partial scatter-gather");

    // ---- restart B on the same port: the fleet heals -------------------
    let (server_b2, _) = start_backend(&model_path, backend_port(&backends[1]), &shards_of(1));
    wait_healthz(addr, |b| b.contains("backends=2/2"), "B probed back up");

    let mut client = Client::connect(addr);
    let resp = client.post_top_k("/similar", &format!("doc:{dead_doc}\n"), 6);
    let (expect, _) = index_b.query_doc(dead_doc, 6).unwrap();
    assert_hits_match(&resp, &expect, "recovered shard");
    let resp = client.post_top_k("/similar", &line, 9);
    assert!(resp.header("x-partial-results").is_none(), "recovered fleet is whole again");
    assert_hits_match(&resp, &expect_full, "recovered scatter-gather");

    // the router's own exposition reflects the journey
    let metrics = Client::connect(addr).get("/metrics").body_text();
    assert!(metrics.contains("route_backends_configured 2"), "{metrics}");
    for series in
        ["route_requests_total", "route_shard_unavailable_total", "route_partial_results_total"]
    {
        assert!(metrics.contains(series), "{series} missing:\n{metrics}");
    }

    let report = router.shutdown();
    assert!(report.contains("route_health_transitions_total"), "{report}");
    server_a.shutdown();
    server_b2.shutdown();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn router_forwards_score_to_a_healthy_backend() {
    let dir = tmp_dir("score");
    let spec = EncoderSpec::Oph { bins: 32, b: 4, seed: 0x5C0 };
    let model_path = dir.join("m.bbmh");
    let w: Vec<f32> = (0..spec.output_dim()).map(|j| (j as f32 * 0.7).sin()).collect();
    SavedModel::new(spec, LinearModel { w }).unwrap().save(&model_path).unwrap();

    let port = reserve_port();
    let cfg = ServeConfig { port, scorer_workers: 1, ..Default::default() };
    let server = ModelServer::start(&model_path, cfg).unwrap();
    let router = Router::start(RouterConfig {
        backends: vec![format!("127.0.0.1:{port}")],
        shards: 1,
        health_poll: Duration::from_millis(50),
        ..Default::default()
    })
    .unwrap();
    wait_healthz(router.local_addr(), |b| b.contains("backends=1/1"), "backend up");

    // the same line scored directly and through the router answers the
    // same margin (the router relays the backend body verbatim)
    let mut direct = Client::connect(server.local_addr());
    let mut via = Client::connect(router.local_addr());
    let body = "+1 3:1 17:1 99:1\n";
    let d = direct.post_top_k("/score", body, 1);
    let v = via.post_top_k("/score", body, 1);
    assert_eq!(d.status, 200);
    assert_eq!(v.status, 200);
    assert_eq!(d.body_text(), v.body_text());
    assert_eq!(v.header("x-model-epoch"), Some("1"), "backend headers relay");

    // /similar without any index: the backend's 404 relays through
    let resp = via.post_top_k("/similar", "doc:0\n", 1);
    assert_eq!(resp.status, 404, "{}", resp.body_text());

    router.shutdown();
    server.shutdown();
    std::fs::remove_dir_all(dir).ok();
}
