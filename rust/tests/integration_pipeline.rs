//! Integration tests: the full generate -> expand -> pipeline -> train ->
//! evaluate flow, plus cross-module behaviours no unit test covers.

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use bbit_mh::data::expand::{expand_dataset, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::{ChunkedReader, LibsvmReader, LibsvmWriter};
use bbit_mh::encode::EncoderSpec;
use bbit_mh::hashing::minwise::resemblance;
use bbit_mh::util::Rng;

fn expanded_corpus(n: usize, seed: u64) -> bbit_mh::data::SparseDataset {
    let base = CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 1200,
        zipf_alpha: 1.05,
        mean_tokens: 22.0,
        class_signal: 0.55,
        pos_fraction: 0.5,
        seed,
    })
    .generate();
    let cfg = ExpandConfig { vocab: 1200, dim: 1 << 28, three_way_rate: 30, seed: seed ^ 1 };
    expand_dataset(&cfg, &base)
}

#[test]
fn end_to_end_bbit_beats_chance_and_vw_at_equal_storage() {
    let ds = expanded_corpus(900, 0x1E57);
    let (train_raw, test_raw) = ds.split(0.5, &mut Rng::new(2));
    let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 128, queue_depth: 2 });
    let sched = Scheduler::new(2);

    // b-bit: b=8, k=64 => 512 bits/doc
    let job = EncoderSpec::Bbit { b: 8, k: 64, d: 1 << 28, seed: 5 };
    let (tr, _) = pipe.run(dataset_chunks(&train_raw, 128), &job).unwrap();
    let (te, _) = pipe.run(dataset_chunks(&test_raw, 128), &job).unwrap();
    let (tr, te) = (tr.into_bbit().unwrap(), te.into_bbit().unwrap());
    let bbit = sched
        .run_grid(&tr, &te, &[TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c: 1.0 }])
        .unwrap()[0]
        .test_accuracy;

    // VW at the same storage: 16 bins x 32 bits = 512 bits/doc
    let job = EncoderSpec::Vw { bins: 16, seed: 7 };
    let (tr, _) = pipe.run(dataset_chunks(&train_raw, 128), &job).unwrap();
    let (te, _) = pipe.run(dataset_chunks(&test_raw, 128), &job).unwrap();
    let (tr, te) = (tr.into_vw().unwrap(), te.into_vw().unwrap());
    let vw = sched
        .run_grid(&tr, &te, &[TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c: 1.0 }])
        .unwrap()[0]
        .test_accuracy;

    assert!(bbit > 0.75, "b-bit accuracy too low: {bbit}");
    assert!(
        bbit > vw + 0.03,
        "paper's core claim violated at equal storage: bbit={bbit} vw={vw}"
    );
}

#[test]
fn hashing_preserves_resemblance_ordering() {
    // documents more similar in raw space stay more similar in code space
    let ds = expanded_corpus(60, 0xABC);
    let job = EncoderSpec::Bbit { b: 16, k: 128, d: 1 << 28, seed: 9 };
    let pipe = Pipeline::new(PipelineConfig::default());
    let (out, _) = pipe.run(dataset_chunks(&ds, 32), &job).unwrap();
    let bb = out.into_bbit().unwrap();
    let mut rng = Rng::new(11);
    let mut agree = 0;
    let mut total = 0;
    for _ in 0..3000 {
        let (i, j, l) = (
            rng.below_usize(60),
            rng.below_usize(60),
            rng.below_usize(60),
        );
        if i == j || j == l || i == l {
            continue;
        }
        let r_ij = resemblance(ds.row(i).0, ds.row(j).0);
        let r_il = resemblance(ds.row(i).0, ds.row(l).0);
        if (r_ij - r_il).abs() < 0.03 {
            continue; // too close to call under sampling noise
        }
        let m_ij = (0..128).filter(|&q| bb.codes.get(i, q) == bb.codes.get(j, q)).count();
        let m_il = (0..128).filter(|&q| bb.codes.get(i, q) == bb.codes.get(l, q)).count();
        total += 1;
        if (r_ij > r_il) == (m_ij > m_il) {
            agree += 1;
        }
    }
    assert!(total > 30, "not enough separated triples ({total})");
    assert!(
        agree as f64 / total as f64 > 0.75,
        "ordering broken: {agree}/{total}"
    );
}

#[test]
fn libsvm_file_pipeline_equals_in_memory_pipeline() {
    let ds = expanded_corpus(150, 0xF11E);
    let dir = std::env::temp_dir().join(format!("bbit_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ds.svm");
    {
        let mut w = LibsvmWriter::create(&path).unwrap();
        w.write_dataset(&ds).unwrap();
        w.finish().unwrap();
    }
    let job = EncoderSpec::Bbit { b: 8, k: 32, d: 1 << 28, seed: 21 };
    let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 40, queue_depth: 2 });
    let (mem, _) = pipe.run(dataset_chunks(&ds, 40), &job).unwrap();
    let source = ChunkedReader::new(LibsvmReader::open(&path).unwrap().binary(), 40);
    let (file, _) = pipe.run(source, &job).unwrap();
    let (mem, file) = (mem.into_bbit().unwrap(), file.into_bbit().unwrap());
    assert_eq!(mem.len(), file.len());
    assert_eq!(mem.labels, file.labels);
    for i in 0..mem.len() {
        assert_eq!(mem.codes.row(i), file.codes.row(i), "row {i}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn scheduler_c_sweep_on_hashed_data_shows_accuracy_plateau() {
    // the Figures 1/3 qualitative shape: accuracy rises with C then plateaus
    let ds = expanded_corpus(800, 0x51EE);
    let (train_raw, test_raw) = ds.split(0.5, &mut Rng::new(4));
    let pipe = Pipeline::new(PipelineConfig::default());
    let job = EncoderSpec::Bbit { b: 8, k: 128, d: 1 << 28, seed: 31 };
    let (tr, _) = pipe.run(dataset_chunks(&train_raw, 128), &job).unwrap();
    let (te, _) = pipe.run(dataset_chunks(&test_raw, 128), &job).unwrap();
    let (tr, te) = (tr.into_bbit().unwrap(), te.into_bbit().unwrap());
    let jobs: Vec<TrainJob> = [0.0001, 0.01, 1.0, 10.0]
        .iter()
        .map(|&c| TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c })
        .collect();
    let out = Scheduler::new(4).run_grid(&tr, &te, &jobs).unwrap();
    let accs: Vec<f64> = out.iter().map(|o| o.test_accuracy).collect();
    // tiny C underfits; the C>=1 end must beat it
    assert!(
        accs[2].max(accs[3]) > accs[0] + 0.02,
        "no C-shape: {accs:?}"
    );
}

#[test]
fn error_paths_surface_cleanly() {
    // missing file
    assert!(LibsvmReader::open("/definitely/not/here.svm").is_err());
    // malformed libsvm inside pipeline propagates
    let bad = "+1 3:1\nnot a line\n";
    let dir = std::env::temp_dir().join(format!("bbit_bad_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.svm");
    std::fs::write(&path, bad).unwrap();
    let pipe = Pipeline::new(PipelineConfig::default());
    let source = ChunkedReader::new(LibsvmReader::open(&path).unwrap().binary(), 8);
    let out = pipe.run(source, &EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 20, seed: 1 });
    assert!(out.is_err());
    std::fs::remove_dir_all(dir).ok();
}
