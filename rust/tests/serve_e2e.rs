//! Online-scoring e2e tests (ISSUE 3 acceptance criteria): a loopback
//! server on an ephemeral port must
//! - answer concurrent `/score` requests with margins that match
//!   `SavedModel::margin` *exactly* (Display round-trip, bit-for-bit);
//! - hot-swap the model when the file is rewritten, observable as an
//!   epoch bump, without dropping the established connection;
//! - shed (503 + Retry-After) when the bounded admission queue overflows,
//!   instead of hanging or queueing unboundedly;
//! - sustain a 2+-worker load-generator run that reports p50/p99 latency
//!   and achieved QPS.
//!
//! Every server binds port 0 so parallel test binaries / CI jobs cannot
//! collide.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use bbit_mh::encode::EncoderSpec;
use bbit_mh::serve::http;
use bbit_mh::serve::{loadgen, LoadgenConfig, ModelServer, ServeConfig};
use bbit_mh::solver::{LinearModel, SavedModel};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bbmh_serve_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A deterministic model: weights are a fixed function of the index, so
/// the test can reconstruct the exact serving-side margins locally.
fn model_with(spec: EncoderSpec, scale: f32) -> SavedModel {
    let w: Vec<f32> =
        (0..spec.output_dim()).map(|j| (j as f32 * 0.7331).sin() * scale).collect();
    SavedModel::new(spec, LinearModel { w }).unwrap()
}

/// Deterministic document `i`: sorted unique indices plus its LibSVM line.
fn doc(i: usize) -> (String, Vec<u32>) {
    let mut idx: Vec<u32> = (0..24u32).map(|t| (i as u32 * 31 + t * 97) % 5000).collect();
    idx.sort_unstable();
    idx.dedup();
    let mut line = String::from("+1");
    for x in &idx {
        line.push_str(&format!(" {x}:1"));
    }
    (line, idx)
}

/// Tiny keep-alive HTTP client over the crate's own framing.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to test server");
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { stream, reader }
    }

    fn post(&mut self, path: &str, body: &str) -> http::Response {
        http::write_post(&mut self.stream, path, body.as_bytes()).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }

    fn get(&mut self, path: &str) -> http::Response {
        http::write_get(&mut self.stream, path).unwrap();
        http::read_response(&mut self.reader).unwrap()
    }
}

#[test]
fn concurrent_scores_match_local_margins_exactly() {
    let dir = temp_dir("exact");
    let spec = EncoderSpec::Oph { bins: 64, b: 4, seed: 0xE2E };
    let path = dir.join("m.bbmh");
    model_with(spec, 1.0).save(&path).unwrap();
    let server = ModelServer::start(
        &path,
        ServeConfig {
            scorer_workers: 2,
            batch_max: 8,
            batch_wait: Duration::from_micros(200),
            queue_cap: 512,
            deadline: Duration::from_secs(5),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();
    let reference = SavedModel::load(&path).unwrap();

    // 4 concurrent keep-alive connections, 25 documents each
    std::thread::scope(|s| {
        for t in 0..4usize {
            let reference = &reference;
            s.spawn(move || {
                let mut client = Client::connect(addr);
                let mut scratch = reference.scratch();
                for i in 0..25usize {
                    let (line, idx) = doc(i * 4 + t);
                    let resp = client.post("/score", &format!("{line}\n"));
                    assert_eq!(resp.status, 200, "doc {i}/{t}: {}", resp.body_text());
                    let body = resp.body_text();
                    let mut toks = body.split_ascii_whitespace();
                    let pred: i8 = toks.next().unwrap().parse().unwrap();
                    let margin: f32 = toks.next().unwrap().parse().unwrap();
                    let expect = reference.margin(&idx, &mut scratch);
                    assert_eq!(margin, expect, "margin mismatch for doc {i}/{t}");
                    assert_eq!(pred, if expect >= 0.0 { 1 } else { -1 });
                }
            });
        }
    });

    // a multi-document body answers one line per document, in order
    let mut client = Client::connect(addr);
    let docs: Vec<(String, Vec<u32>)> = (100..105).map(doc).collect();
    let body: String = docs.iter().map(|(l, _)| format!("{l}\n")).collect();
    let resp = client.post("/score", &body);
    assert_eq!(resp.status, 200);
    let text = resp.body_text();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), docs.len());
    let mut scratch = reference.scratch();
    for (line, (_, idx)) in lines.iter().zip(&docs) {
        let margin: f32 = line.split_ascii_whitespace().nth(1).unwrap().parse().unwrap();
        assert_eq!(margin, reference.margin(idx, &mut scratch));
    }

    let report = server.shutdown();
    assert!(
        report.contains("serve_docs_scored_total 105"),
        "4×25 + 5 documents must all be scored:\n{report}"
    );
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn hot_swap_bumps_epoch_without_dropping_connections() {
    let dir = temp_dir("hotswap");
    let spec = EncoderSpec::Oph { bins: 32, b: 4, seed: 0x5A9 };
    let path = dir.join("m.bbmh");
    model_with(spec, 1.0).save(&path).unwrap();
    let server = ModelServer::start(
        &path,
        ServeConfig {
            scorer_workers: 2,
            deadline: Duration::from_secs(5),
            reload_poll: Duration::from_millis(25),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    let mut client = Client::connect(addr);
    assert!(client.get("/healthz").body_text().contains("epoch=1"));
    let (line, idx) = doc(7);
    let v1 = model_with(spec, 1.0);
    let mut scratch = v1.scratch();
    let resp = client.post("/score", &format!("{line}\n"));
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-model-epoch"), Some("1"));
    let m1: f32 =
        resp.body_text().split_ascii_whitespace().nth(1).unwrap().parse().unwrap();
    assert_eq!(m1, v1.margin(&idx, &mut scratch));

    // rewrite the model file (same byte length — only weights change);
    // the 1.1s sleep guards against coarse-mtime filesystems where an
    // (mtime, len) fingerprint could miss a same-second same-size rewrite
    std::thread::sleep(Duration::from_millis(1100));
    let v2 = model_with(spec, -2.0);
    v2.save(&path).unwrap();

    // the watcher must observe the swap: epoch bumps to 2
    let t0 = Instant::now();
    loop {
        if client.get("/healthz").body_text().contains("epoch=2") {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "hot reload never landed");
        std::thread::sleep(Duration::from_millis(25));
    }

    // the same (never re-dialed) connection now scores with the new model
    let resp = client.post("/score", &format!("{line}\n"));
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-model-epoch"), Some("2"));
    let m2: f32 =
        resp.body_text().split_ascii_whitespace().nth(1).unwrap().parse().unwrap();
    let mut scratch2 = v2.scratch();
    assert_eq!(m2, v2.margin(&idx, &mut scratch2));
    assert_ne!(m1, m2, "new weights must change the margin");

    let report = server.shutdown();
    assert!(report.contains("serve_model_epoch 2"), "{report}");
    assert!(!report.contains("serve_model_reloads_total 0"), "{report}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn overload_sheds_with_503_instead_of_hanging() {
    let dir = temp_dir("shed");
    // expensive per-document scoring (k-way minwise over many indices) so
    // the enqueue side outruns a single scorer by orders of magnitude
    let spec = EncoderSpec::Bbit { b: 8, k: 256, d: 1 << 30, seed: 0x10AD };
    let path = dir.join("m.bbmh");
    model_with(spec, 1.0).save(&path).unwrap();
    let server = ModelServer::start(
        &path,
        ServeConfig {
            scorer_workers: 1,
            batch_max: 4,
            batch_wait: Duration::ZERO,
            queue_cap: 8,
            deadline: Duration::from_secs(10),
            ..Default::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    // one request with 800 documents of ~120 indices each: admission is
    // bounded at 8, so the burst must shed
    let mut body = String::new();
    for i in 0..800usize {
        let mut line = String::from("+1");
        for t in 0..120u32 {
            line.push_str(&format!(" {}:1", (i as u32 * 13 + t * 211) % 100_000));
        }
        body.push_str(&line);
        body.push('\n');
    }
    let mut client = Client::connect(addr);
    let t0 = Instant::now();
    let resp = client.post("/score", &body);
    let elapsed = t0.elapsed();
    assert_eq!(resp.status, 503, "overload must shed: {}", resp.body_text());
    assert_eq!(resp.header("retry-after"), Some("1"));
    assert!(
        elapsed < Duration::from_secs(30),
        "shed must be prompt, not a queue-drain hang ({elapsed:?})"
    );

    // the server is still healthy afterwards
    assert!(client.get("/healthz").body_text().starts_with("ok"));
    let metrics = client.get("/metrics").body_text();
    let shed_line = metrics
        .lines()
        .find(|l| l.starts_with("serve_docs_shed_total"))
        .expect("shed counter exposed");
    let shed: u64 = shed_line.split_ascii_whitespace().nth(1).unwrap().parse().unwrap();
    assert!(shed >= 1, "at least one document must have been shed:\n{metrics}");

    server.shutdown();
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn loadgen_reports_latency_percentiles_and_qps() {
    let dir = temp_dir("loadgen");
    let spec = EncoderSpec::Oph { bins: 64, b: 4, seed: 0x10AD6E4 };
    let path = dir.join("m.bbmh");
    model_with(spec, 1.0).save(&path).unwrap();
    let server = ModelServer::start(
        &path,
        ServeConfig {
            scorer_workers: 2, // the acceptance criterion's 2+-worker run
            batch_max: 32,
            batch_wait: Duration::from_micros(100),
            queue_cap: 1024,
            deadline: Duration::from_secs(1),
            ..Default::default()
        },
    )
    .unwrap();

    let docs: Vec<String> = (0..32).map(|i| doc(i).0).collect();
    let report = loadgen::run(
        server.local_addr(),
        &LoadgenConfig {
            path: "/score".into(),
            qps: 400.0,
            duration: Duration::from_millis(800),
            connections: 4,
            docs,
        },
    )
    .unwrap();

    assert!(report.sent > 50, "{report:?}");
    assert!(report.ok > 0, "{report:?}");
    // every request is accounted for exactly once (>= because an initial
    // connect failure counts as an error without a send)
    assert!(
        report.ok + report.shed + report.expired + report.errors >= report.sent,
        "{report:?}"
    );
    assert!(report.p50_us > 0 && report.p50_us <= report.p99_us, "{report:?}");
    assert!(report.p99_us <= report.max_us, "{report:?}");
    assert!(report.achieved_qps > 50.0, "{report:?}");
    assert!(report.wall_seconds > 0.5, "{report:?}");
    let summary = report.summary();
    assert!(summary.contains("p50") && summary.contains("p99"), "{summary}");

    let final_report = server.shutdown();
    assert!(final_report.contains("serve_docs_scored_total"), "{final_report}");
    // the shutdown report IS the Prometheus exposition now — it must carry
    // the batch-size histogram and survive the format validator
    assert!(final_report.contains("serve_batch_size_bucket"), "{final_report}");
    bbit_mh::metrics::prom::validate(&final_report)
        .unwrap_or_else(|e| panic!("shutdown report is not valid Prometheus: {e}"));
    std::fs::remove_dir_all(dir).ok();
}
