//! Smoke-run every experiment harness at CI (tiny) scale: each must
//! produce non-empty tables and its claims' minimal sanity conditions.

use bbit_mh::experiments::{self, Ctx, Scale};

fn tiny_ctx() -> Ctx {
    let mut s = Scale::tiny();
    s.results_dir = std::env::temp_dir()
        .join(format!("bbit_results_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    Ctx::new(s)
}

#[test]
fn table1_reports_both_datasets() {
    let mut ctx = tiny_ctx();
    let tables = experiments::run("table1", &mut ctx).unwrap();
    assert_eq!(tables[0].n_rows(), 2);
}

#[test]
fn fig1_accuracy_increases_with_b() {
    let mut ctx = tiny_ctx();
    let tables = experiments::run("fig1", &mut ctx).unwrap();
    // headline table is last: rows of (b, k, best-acc)
    let headline = tables.last().unwrap();
    let get = |b: &str, k: &str| -> f64 {
        headline
            .rows_raw()
            .iter()
            .find(|r| r[0] == b && r[1] == k)
            .unwrap()[2]
            .parse()
            .unwrap()
    };
    let k = "64";
    assert!(get("8", k) > get("1", k) + 5.0, "b=8 must beat b=1 clearly");
    assert!(get("4", k) > get("1", k));
}

#[test]
fn fig5_bbit_beats_vw_at_far_less_storage() {
    let mut ctx = tiny_ctx();
    let tables =
        experiments::run("fig5", &mut ctx).unwrap();
    let t = &tables[0];
    // rows: (method, k, C, acc, bits)
    let rows: Vec<(String, f64, u64)> = t
        .rows_raw()
        .iter()
        .map(|r| (r[0].clone(), r[3].parse().unwrap(), r[4].parse().unwrap()))
        .collect();
    // the paper's claim, storage-normalized: whatever accuracy 8-bit
    // minwise reaches at its *smallest* budget, VW needs a multiple of
    // that storage to match it.
    let bbit_min_bits = rows
        .iter()
        .filter(|r| r.0.starts_with("8-bit"))
        .map(|r| r.2)
        .min()
        .unwrap();
    let bbit_acc_at_min = rows
        .iter()
        .filter(|r| r.0.starts_with("8-bit") && r.2 == bbit_min_bits)
        .map(|r| r.1)
        .fold(0.0f64, f64::max);
    let vw_bits_to_match = rows
        .iter()
        .filter(|r| r.0 == "VW" && r.1 >= bbit_acc_at_min)
        .map(|r| r.2)
        .min();
    match vw_bits_to_match {
        None => {} // no VW config matches at all — claim holds trivially
        Some(bits) => assert!(
            bits >= 4 * bbit_min_bits,
            "VW matched {bbit_acc_at_min}% with only {bits} bits vs b-bit {bbit_min_bits}"
        ),
    }
}

#[test]
fn variance_tables_track_theory() {
    let mut ctx = tiny_ctx();
    let tables = experiments::run("variance", &mut ctx).unwrap();
    // first table: ratio column (index 4) near 1 for every estimator
    for row in tables[0].rows_raw() {
        let ratio: f64 = row[4].parse().unwrap();
        assert!((0.6..1.6).contains(&ratio), "{row:?}");
    }
    // storage-ratio table strictly > 5x everywhere
    for row in tables[2].rows_raw() {
        let ratio: f64 = row[3].parse().unwrap();
        assert!(ratio > 5.0, "{row:?}");
    }
}

#[test]
fn fig8_permutation_and_universal_overlap() {
    let mut ctx = tiny_ctx();
    let tables = experiments::run("fig8", &mut ctx).unwrap();
    for row in tables[0].rows_raw() {
        let (perm, univ): (f64, f64) = (row[3].parse().unwrap(), row[4].parse().unwrap());
        let sd: f64 = row[5].parse::<f64>().unwrap().max(row[6].parse().unwrap());
        assert!(
            (perm - univ).abs() <= 3.0 * sd.max(0.5),
            "arms diverge: {row:?}"
        );
    }
}
