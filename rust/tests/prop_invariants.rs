//! Property-based tests over coordinator/substrate invariants.
//!
//! The offline crate set has no proptest, so this file uses a small
//! generate-and-check harness (`cases`) driven by the crate's seeded PRNG:
//! hundreds of random cases per property, with the failing seed printed so
//! any counterexample is reproducible with `SEED=<n> cargo test`.

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sharding::ShardPlan;
use bbit_mh::data::dataset::{Example, SparseDataset};
use bbit_mh::data::libsvm::{LibsvmReader, LibsvmWriter};
use bbit_mh::encode::expansion::BbitDataset;
use bbit_mh::encode::packed::PackedCodes;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::hashing::minwise::{resemblance, BbitMinHash, MinwiseHasher};
use bbit_mh::hashing::permutation::{FeistelPermutation, Permutation};
use bbit_mh::solver::linear::FeatureMatrix;
use bbit_mh::util::Rng;

/// Run `body(case_rng, case_no)` for `n` random cases, printing the seed on
/// failure so any counterexample reproduces with `SEED=<n> cargo test`.
fn cases(n: usize, tag: &str, body: impl Fn(&mut Rng, usize)) {
    let base = std::env::var("SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD15EA5Eu64);
    for case in 0..n {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng, case)
        }));
        if let Err(e) = result {
            panic!("property {tag:?} failed at case {case} (SEED={seed}): {e:?}");
        }
    }
}

fn random_set(rng: &mut Rng, d: u64, max_len: usize) -> Vec<u32> {
    let len = 1 + rng.below_usize(max_len);
    rng.sample_distinct(d, len.min(d as usize))
        .into_iter()
        .map(|x| x as u32)
        .collect()
}

// ---------------------------------------------------------------------------
// hashing invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_minwise_subset_monotonicity() {
    // min over a superset can only be <= min over the subset
    cases(100, "minwise_subset", |rng, _| {
        let d = 1u64 << (16 + rng.below(12) as u32);
        let sup = random_set(rng, d, 300);
        let take = 1 + rng.below_usize(sup.len());
        let sub: Vec<u32> = sup[..take].to_vec();
        let mh = MinwiseHasher::draw(1 + rng.below_usize(32), d, rng);
        let (zs, zp) = (mh.hash(&sub), mh.hash(&sup));
        for (a, b) in zs.iter().zip(&zp) {
            assert!(b <= a, "superset min must not exceed subset min");
        }
    });
}

#[test]
fn prop_minwise_identical_sets_collide_everywhere() {
    cases(50, "minwise_identical", |rng, _| {
        let d = 1u64 << 20;
        let s = random_set(rng, d, 200);
        let mh = MinwiseHasher::draw(16, d, rng);
        assert_eq!(mh.hash(&s), mh.hash(&s));
        // and a permuted copy
        let mut s2 = s.clone();
        rng.shuffle(&mut s2);
        assert_eq!(mh.hash(&s), mh.hash(&s2));
    });
}

#[test]
fn prop_bbit_code_range() {
    cases(60, "bbit_range", |rng, _| {
        let b = 1 + rng.below(16) as u32;
        let d = 1u64 << 24;
        let bb = BbitMinHash::draw(8, b, d, rng);
        let s = random_set(rng, d, 100);
        for c in bb.codes(&s) {
            assert!((c as u32) < (1u32 << b));
        }
    });
}

#[test]
fn prop_feistel_bijection_random_domains() {
    cases(20, "feistel", |rng, _| {
        let d = 2 + rng.below(5000);
        let p = FeistelPermutation::draw(d, rng);
        let mut seen = vec![false; d as usize];
        for t in 0..d {
            let v = p.apply(t);
            assert!(v < d && !seen[v as usize]);
            seen[v as usize] = true;
        }
    });
}

#[test]
fn prop_resemblance_bounds_and_symmetry() {
    cases(100, "resemblance", |rng, _| {
        let d = 1u64 << 16;
        let (mut a, mut b) = (random_set(rng, d, 150), random_set(rng, d, 150));
        a.sort_unstable();
        b.sort_unstable();
        let r1 = resemblance(&a, &b);
        let r2 = resemblance(&b, &a);
        assert!((0.0..=1.0).contains(&r1));
        assert_eq!(r1, r2);
        assert_eq!(resemblance(&a, &a), 1.0);
    });
}

// ---------------------------------------------------------------------------
// encoding invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_packed_roundtrip_random_geometry() {
    cases(80, "packed_roundtrip", |rng, _| {
        let b = 1 + rng.below(16) as u32;
        let k = 1 + rng.below_usize(70);
        let n = 1 + rng.below_usize(30);
        let mut pc = PackedCodes::new(b, k);
        let mut rows = Vec::new();
        for _ in 0..n {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
            rows.push(row);
        }
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(&pc.row(i), row);
        }
        // save/load roundtrip preserves everything
        let mut buf = Vec::new();
        pc.save(&mut buf).unwrap();
        assert_eq!(PackedCodes::load(&buf[..]).unwrap(), pc);
    });
}

#[test]
fn prop_truncate_bits_commutes_with_masking() {
    cases(60, "truncate_bits", |rng, _| {
        let b = 2 + rng.below(15) as u32;
        let b2 = 1 + rng.below(b as u64 - 1) as u32;
        let k = 1 + rng.below_usize(40);
        let mut pc = PackedCodes::new(b, k);
        let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
        pc.push_row(&row).unwrap();
        let t = pc.truncate_bits(b2).unwrap();
        let mask = (1u16 << b2) - 1;
        for j in 0..k {
            assert_eq!(t.get(0, j), row[j] & mask);
        }
    });
}

#[test]
fn prop_bbit_dot_matches_materialized_expansion() {
    cases(40, "bbit_dot", |rng, _| {
        let b = 1 + rng.below(8) as u32;
        let k = 1 + rng.below_usize(30);
        let n = 1 + rng.below_usize(20);
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for _ in 0..n {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
            labels.push(if rng.bool() { 1i8 } else { -1 });
        }
        let bb = BbitDataset::new(pc, labels);
        let csr = bb.to_sparse_dataset();
        let w: Vec<f32> = (0..bb.dim()).map(|_| rng.f32() - 0.5).collect();
        for i in 0..n {
            let a = FeatureMatrix::dot(&bb, i, &w);
            let c = csr.dot(i, &w);
            assert!((a - c).abs() < 1e-4, "row {i}: {a} vs {c}");
        }
    });
}

// ---------------------------------------------------------------------------
// coordinator invariants (routing / batching / state)
// ---------------------------------------------------------------------------

#[test]
fn prop_shard_plan_tiles_exactly() {
    cases(200, "shard_plan", |rng, _| {
        let n = rng.below_usize(10_000);
        let cs = 1 + rng.below_usize(500);
        let plan = ShardPlan::new(n, cs);
        assert!(plan.covers_exactly());
        let total: usize = plan.iter().map(|a| a.rows).sum();
        assert_eq!(total, n);
    });
}

#[test]
fn prop_pipeline_preserves_every_example_in_order() {
    // the central routing/batching invariant: any (workers, chunk, queue)
    // configuration must emit exactly the input rows, in input order
    cases(12, "pipeline_integrity", |rng, _| {
        let n = 20 + rng.below_usize(300);
        let d = 1u64 << 18;
        let mut ds = SparseDataset::new(d);
        for i in 0..n {
            let mut set = random_set(rng, d - 2, 30);
            set.push((d - 1) as u32);
            set.sort_unstable();
            set.dedup();
            ds.push(&Example::binary(if i % 3 == 0 { 1 } else { -1 }, set));
        }
        let workers = 1 + rng.below_usize(6);
        let chunk = 1 + rng.below_usize(50);
        let depth = 1 + rng.below_usize(4);
        let k = 1 + rng.below_usize(16);
        let b = 1 + rng.below(8) as u32;
        let job = EncoderSpec::Bbit { b, k, d, seed: 99 };
        let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: chunk, queue_depth: depth });
        let (out, report) = pipe.run(dataset_chunks(&ds, chunk), &job).unwrap();
        let bb = out.into_bbit().unwrap();
        assert_eq!(bb.len(), n, "row count");
        assert_eq!(report.docs, n);
        assert_eq!(bb.labels, ds.labels, "label order");
        // spot-check rows against the sequential hasher
        let hasher = BbitMinHash::draw(k, b, d, &mut Rng::new(99));
        for i in (0..n).step_by(17.max(n / 7)) {
            assert_eq!(bb.codes.row(i), hasher.codes(ds.row(i).0), "row {i}");
        }
    });
}

#[test]
fn prop_libsvm_roundtrip_arbitrary_examples() {
    cases(60, "libsvm_roundtrip", |rng, _| {
        let n = 1 + rng.below_usize(20);
        let mut examples = Vec::new();
        for _ in 0..n {
            let set = random_set(rng, 1 << 28, 50);
            if rng.bool() {
                examples.push(Example::binary(if rng.bool() { 1 } else { -1 }, set));
            } else {
                let vals: Vec<f32> =
                    set.iter().map(|_| (rng.below(1000) as f32) / 8.0 + 0.125).collect();
                examples.push(Example {
                    label: if rng.bool() { 1 } else { -1 },
                    indices: set,
                    values: Some(vals),
                });
            }
        }
        let mut buf = Vec::new();
        {
            let mut w = LibsvmWriter::new(&mut buf);
            for ex in &examples {
                w.write_example(ex).unwrap();
            }
            w.finish().unwrap();
        }
        let back: Vec<Example> =
            LibsvmReader::new(&buf[..]).map(|e| e.unwrap()).collect();
        assert_eq!(back.len(), examples.len());
        for (a, b) in examples.iter().zip(&back) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.indices, b.indices);
            match (&a.values, &b.values) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    for (u, v) in x.iter().zip(y) {
                        assert!((u - v).abs() < 1e-4);
                    }
                }
                other => panic!("value presence mismatch {other:?}"),
            }
        }
    });
}

// ---------------------------------------------------------------------------
// solver invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_svm_tighter_eps_never_worse_objective() {
    use bbit_mh::solver::{train_svm, SvmConfig};
    cases(10, "svm_objective", |rng, _| {
        let n = 50 + rng.below_usize(100);
        let d = 40u64;
        let mut ds = SparseDataset::new(d);
        for _ in 0..n {
            ds.push(&Example::binary(
                if rng.bool() { 1 } else { -1 },
                random_set(rng, d, 8),
            ));
        }
        let c = [0.01, 0.1, 1.0][rng.below_usize(3)];
        let loose = train_svm(&ds, &SvmConfig { eps: 0.5, c, ..Default::default() });
        let tight =
            train_svm(&ds, &SvmConfig { eps: 1e-5, max_iter: 2000, c, ..Default::default() });
        assert!(
            tight.1.objective <= loose.1.objective + 1e-6 * loose.1.objective.abs().max(1.0),
            "tight {} loose {}",
            tight.1.objective,
            loose.1.objective
        );
    });
}

#[test]
fn prop_sgd_determinism_across_runs() {
    use bbit_mh::solver::{train_sgd, SgdConfig};
    cases(10, "sgd_determinism", |rng, _| {
        let n = 30 + rng.below_usize(100);
        let mut ds = SparseDataset::new(64);
        for _ in 0..n {
            ds.push(&Example::binary(
                if rng.bool() { 1 } else { -1 },
                random_set(rng, 64, 10),
            ));
        }
        let cfg = SgdConfig { epochs: 2, batch: 16, ..Default::default() };
        assert_eq!(train_sgd(&ds, &cfg).0.w, train_sgd(&ds, &cfg).0.w);
    });
}
