//! Out-of-core integration tests: the sink-based pipeline, the on-disk
//! hashed cache, and one-pass hash-and-train.
//!
//! Acceptance invariants (ISSUE 1):
//! - stream-train ≡ materialize-then-train: a `TrainSink` run produces the
//!   same weights (within fp tolerance) as hashing, materializing, and
//!   calling `train_sgd` on the same seed/corpus;
//! - cache roundtrip: pipeline → `CacheSink` → `CacheReader` reproduces
//!   the `CollectSink` output exactly (codes, labels, order), and training
//!   from the cache matches training from memory;
//! - the collector's reorder window tracks in-flight work, not corpus
//!   size (high-water-mark stat).

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::{CacheSink, TrainSink};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::cache::CacheReader;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::solver::{train_from_cache, train_sgd, SgdConfig, SgdLoss};

fn corpus(n: usize, seed: u64) -> SparseDataset {
    CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 1500,
        zipf_alpha: 1.05,
        mean_tokens: 24.0,
        class_signal: 0.55,
        pos_fraction: 0.5,
        seed,
    })
    .generate()
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bbit_stream_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("hashed.cache")
}

fn max_weight_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

#[test]
fn stream_train_equals_materialize_then_train() {
    let ds = corpus(700, 0x57E4);
    let job = EncoderSpec::Bbit { b: 8, k: 48, d: 1 << 24, seed: 17 };
    let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 37, queue_depth: 2 });
    let cfg = SgdConfig {
        loss: SgdLoss::Logistic,
        lr0: 0.5,
        lambda: 1e-3,
        epochs: 1,
        batch: 64,
    };

    // reference: hash → materialize → batch train_sgd
    let (out, _) = pipe.run(dataset_chunks(&ds, 37), &job).unwrap();
    let materialized = out.into_bbit().unwrap();
    let (reference, _) = train_sgd(&materialized, &cfg);

    // one-pass: hash → TrainSink, nothing materialized
    let mut sink = TrainSink::new(cfg.clone(), 8, 48);
    let report = pipe.run_sink(dataset_chunks(&ds, 37), &job, &mut sink).unwrap();
    assert_eq!(report.docs, 700);
    assert_eq!(sink.rows_seen(), 700);
    let (streamed, stats) = sink.into_result();
    assert_eq!(stats.iterations, 1);
    assert!(stats.objective.is_finite());

    let diff = max_weight_diff(&streamed.w, &reference.w);
    assert!(diff < 1e-6, "stream-train deviates from materialize-then-train: {diff}");
}

#[test]
fn cache_write_read_train_roundtrip() {
    let ds = corpus(500, 0xCAC4E);
    let job = EncoderSpec::Bbit { b: 6, k: 40, d: 1 << 22, seed: 23 };
    let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 41, queue_depth: 2 });
    let path = tmp_path("roundtrip");

    // write once through the cache sink
    let mut sink = CacheSink::create(&path, &job).unwrap();
    let report = pipe.run_sink(dataset_chunks(&ds, 41), &job, &mut sink).unwrap();
    assert_eq!(report.docs, 500);
    assert_eq!(sink.rows_written(), 500);

    // in-memory reference via the collect path
    let (out, _) = pipe.run(dataset_chunks(&ds, 41), &job).unwrap();
    let reference = out.into_bbit().unwrap();

    // header carries the encoder spec; payload is byte-identical
    let reader = CacheReader::open(&path).unwrap();
    let meta = reader.meta();
    assert_eq!(meta.spec, job);
    assert_eq!(meta.n, 500);
    let replayed = reader.read_all().unwrap();
    assert_eq!(replayed.len(), reference.len());
    assert_eq!(replayed.labels, reference.labels);
    assert_eq!(replayed.codes.words(), reference.codes.words());

    // multi-epoch cache replay == multi-epoch batch training on the
    // materialized dataset
    let cfg = SgdConfig {
        loss: SgdLoss::SquaredHinge,
        lr0: 0.5,
        lambda: 5e-4,
        epochs: 3,
        batch: 32,
    };
    let (from_cache, stats) = train_from_cache(&path, &cfg).unwrap();
    assert_eq!(stats.iterations, 3);
    let (from_memory, _) = train_sgd(&reference, &cfg);
    let diff = max_weight_diff(&from_cache.w, &from_memory.w);
    assert!(diff < 1e-6, "cache-train deviates from in-memory train: {diff}");

    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn cache_detects_corruption_end_to_end() {
    let ds = corpus(120, 0xBAD);
    let job = EncoderSpec::Bbit { b: 8, k: 16, d: 1 << 20, seed: 3 };
    let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 25, queue_depth: 2 });
    let path = tmp_path("corrupt");
    let mut sink = CacheSink::create(&path, &job).unwrap();
    pipe.run_sink(dataset_chunks(&ds, 25), &job, &mut sink).unwrap();

    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2; // somewhere inside a record payload
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();

    let mut reader = CacheReader::open(&path).unwrap();
    let mut failed = false;
    loop {
        match reader.next_chunk() {
            Ok(Some(_)) => continue,
            Ok(None) => break,
            Err(_) => {
                failed = true;
                break;
            }
        }
    }
    assert!(failed, "flipped byte went undetected");
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn reorder_window_tracks_inflight_work_not_corpus_size() {
    // 1000 docs / chunk_size 10 = 100 chunks, far more than can ever be
    // in flight with 4 workers + queue_depth 2 — a collector that buffered
    // until end-of-run (the old behavior) would peak at ~100
    let ds = corpus(1000, 0x9EAD);
    let job = EncoderSpec::Bbit { b: 4, k: 16, d: 1 << 20, seed: 7 };
    let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 10, queue_depth: 2 });
    let (_, report) = pipe.run(dataset_chunks(&ds, 10), &job).unwrap();
    assert_eq!(report.chunks, 100);
    assert!(report.reorder_peak >= 1);
    assert!(
        report.reorder_peak < report.chunks / 2,
        "reorder window ({}) scales with corpus ({} chunks) — collector is buffering",
        report.reorder_peak,
        report.chunks
    );
    // with one worker completion order is emission order: the hard bound
    let pipe1 = Pipeline::new(PipelineConfig { workers: 1, chunk_size: 10, queue_depth: 2 });
    let (_, report1) = pipe1.run(dataset_chunks(&ds, 10), &job).unwrap();
    assert_eq!(report1.reorder_peak, 1);
    // stall accounting: blocked-send time is reported separately from
    // productive read time and both fit inside the wall clock
    assert!(report.stall_seconds >= 0.0);
    assert!(report.read_seconds >= 0.0);
    assert!(report.read_seconds + report.stall_seconds <= report.wall_seconds + 0.05);
}
