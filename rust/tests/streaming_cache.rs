//! Out-of-core integration tests: the sink-based pipeline, the on-disk
//! hashed cache, and one-pass hash-and-train.
//!
//! Acceptance invariants (ISSUE 1):
//! - stream-train ≡ materialize-then-train: a `TrainSink` run produces the
//!   same weights (within fp tolerance) as hashing, materializing, and
//!   calling `train_sgd` on the same seed/corpus;
//! - cache roundtrip: pipeline → `CacheSink` → `CacheReader` reproduces
//!   the `CollectSink` output exactly (codes, labels, order), and training
//!   from the cache matches training from memory;
//! - the collector's reorder window tracks in-flight work, not corpus
//!   size (high-water-mark stat).

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::{CacheSink, TrainSink};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::cache::CacheReader;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::solver::{
    eval_from_cache, train_from_cache, train_from_cache_holdout, train_sgd, LinearModel,
    SavedModel, SgdConfig, SgdLoss,
};
use bbit_mh::Error;

fn corpus(n: usize, seed: u64) -> SparseDataset {
    CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 1500,
        zipf_alpha: 1.05,
        mean_tokens: 24.0,
        class_signal: 0.55,
        pos_fraction: 0.5,
        seed,
    })
    .generate()
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bbit_stream_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join("hashed.cache")
}

fn max_weight_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

#[test]
fn stream_train_equals_materialize_then_train() {
    let ds = corpus(700, 0x57E4);
    let job = EncoderSpec::Bbit { b: 8, k: 48, d: 1 << 24, seed: 17 };
    let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 37, queue_depth: 2 });
    let cfg = SgdConfig {
        loss: SgdLoss::Logistic,
        lr0: 0.5,
        lambda: 1e-3,
        epochs: 1,
        batch: 64,
    };

    // reference: hash → materialize → batch train_sgd
    let (out, _) = pipe.run(dataset_chunks(&ds, 37), &job).unwrap();
    let materialized = out.into_bbit().unwrap();
    let (reference, _) = train_sgd(&materialized, &cfg);

    // one-pass: hash → TrainSink, nothing materialized
    let mut sink = TrainSink::new(cfg.clone(), 8, 48);
    let report = pipe.run_sink(dataset_chunks(&ds, 37), &job, &mut sink).unwrap();
    assert_eq!(report.docs, 700);
    assert_eq!(sink.rows_seen(), 700);
    let (streamed, stats) = sink.into_result();
    assert_eq!(stats.iterations, 1);
    assert!(stats.objective.is_finite());

    let diff = max_weight_diff(&streamed.w, &reference.w);
    assert!(diff < 1e-6, "stream-train deviates from materialize-then-train: {diff}");
}

#[test]
fn cache_write_read_train_roundtrip() {
    let ds = corpus(500, 0xCAC4E);
    let job = EncoderSpec::Bbit { b: 6, k: 40, d: 1 << 22, seed: 23 };
    let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 41, queue_depth: 2 });
    let path = tmp_path("roundtrip");

    // write once through the cache sink
    let mut sink = CacheSink::create(&path, &job).unwrap();
    let report = pipe.run_sink(dataset_chunks(&ds, 41), &job, &mut sink).unwrap();
    assert_eq!(report.docs, 500);
    assert_eq!(sink.rows_written(), 500);

    // in-memory reference via the collect path
    let (out, _) = pipe.run(dataset_chunks(&ds, 41), &job).unwrap();
    let reference = out.into_bbit().unwrap();

    // header carries the encoder spec; payload is byte-identical
    let reader = CacheReader::open(&path).unwrap();
    let meta = reader.meta();
    assert_eq!(meta.spec, job);
    assert_eq!(meta.n, 500);
    let replayed = reader.read_all().unwrap();
    assert_eq!(replayed.len(), reference.len());
    assert_eq!(replayed.labels, reference.labels);
    assert_eq!(replayed.codes.words(), reference.codes.words());

    // multi-epoch cache replay == multi-epoch batch training on the
    // materialized dataset
    let cfg = SgdConfig {
        loss: SgdLoss::SquaredHinge,
        lr0: 0.5,
        lambda: 5e-4,
        epochs: 3,
        batch: 32,
    };
    let (from_cache, stats) = train_from_cache(&path, &cfg).unwrap();
    assert_eq!(stats.iterations, 3);
    let (from_memory, _) = train_sgd(&reference, &cfg);
    let diff = max_weight_diff(&from_cache.w, &from_memory.w);
    assert!(diff < 1e-6, "cache-train deviates from in-memory train: {diff}");

    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn cache_detects_corruption_end_to_end() {
    let ds = corpus(120, 0xBAD);
    let job = EncoderSpec::Bbit { b: 8, k: 16, d: 1 << 20, seed: 3 };
    let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 25, queue_depth: 2 });
    let path = tmp_path("corrupt");
    let mut sink = CacheSink::create(&path, &job).unwrap();
    pipe.run_sink(dataset_chunks(&ds, 25), &job, &mut sink).unwrap();

    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2; // somewhere inside a record payload
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();

    let mut reader = CacheReader::open(&path).unwrap();
    let mut failed = false;
    loop {
        match reader.next_chunk() {
            Ok(Some(_)) => continue,
            Ok(None) => break,
            Err(_) => {
                failed = true;
                break;
            }
        }
    }
    assert!(failed, "flipped byte went undetected");
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

/// Hash `n` docs into a fresh cache with `chunk` rows per record; returns
/// the cache path (caller removes the parent dir).
fn build_cache(
    tag: &str,
    n: usize,
    seed: u64,
    job: &EncoderSpec,
    chunk: usize,
) -> std::path::PathBuf {
    let ds = corpus(n, seed);
    let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: chunk, queue_depth: 2 });
    let path = tmp_path(tag);
    let mut sink = CacheSink::create(&path, job).unwrap();
    pipe.run_sink(dataset_chunks(&ds, chunk), job, &mut sink).unwrap();
    path
}

#[test]
fn truncated_final_record_is_a_typed_error_not_a_panic() {
    let job = EncoderSpec::Bbit { b: 4, k: 12, d: 1 << 20, seed: 5 };
    let path = build_cache("truncated", 300, 0x7A11, &job, 50);
    let bytes = std::fs::read(&path).unwrap();
    // lose the v3 index footer AND the tail of the final record
    // (checksum + some payload)
    let records_end = bbit_mh::encode::ChunkIndex::load(&path)
        .unwrap()
        .expect("fresh v3 cache carries an index")
        .records_end as usize;
    std::fs::write(&path, &bytes[..records_end - 13]).unwrap();

    let mut reader = CacheReader::open(&path).unwrap();
    assert_eq!(reader.meta().n, 300, "header is intact");
    let mut rows = 0usize;
    let err = loop {
        match reader.next_chunk() {
            Ok(Some((codes, _))) => rows += codes.n,
            Ok(None) => panic!("truncation must not read clean to the end"),
            Err(e) => break e,
        }
    };
    assert!(rows < 300, "the damaged record must not be returned");
    assert!(
        matches!(err, Error::Io(_) | Error::InvalidArg(_)),
        "typed error expected, got {err:?}"
    );
    // the poisoned reader keeps failing instead of looping
    assert!(reader.next_chunk().is_err());
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn checksum_mismatch_mid_file_fails_at_the_damaged_record() {
    let (b, k) = (4u32, 12usize);
    let job = EncoderSpec::Bbit { b, k, d: 1 << 20, seed: 5 };
    let chunk = 50usize;
    let path = build_cache("midfile", 300, 0xC0DE, &job, chunk);
    // record layout (cache.rs): the v3 header is HEADER_BYTES_V3 bytes;
    // each record is u32 rows + u64 payload_len + payload(rows +
    // 8·rows·stride) + u64 sum
    let stride = (k * b as usize).div_ceil(64);
    let record = 4 + 8 + (chunk + 8 * chunk * stride) + 8;
    let header = bbit_mh::encode::cache::HEADER_BYTES_V3 as usize;
    let mut bytes = std::fs::read(&path).unwrap();
    let target = header + 3 * record + 12 + 5; // record 3's payload, byte 5
    bytes[target] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();

    let mut reader = CacheReader::open(&path).unwrap();
    let mut rows = 0usize;
    let err = loop {
        match reader.next_chunk() {
            Ok(Some((codes, _))) => rows += codes.n,
            Ok(None) => panic!("flipped byte went undetected"),
            Err(e) => break e,
        }
    };
    assert_eq!(rows, 3 * chunk, "records before the damage replay clean");
    match err {
        Error::InvalidArg(msg) => {
            assert!(msg.contains("checksum"), "expected a checksum error, got {msg:?}")
        }
        other => panic!("typed InvalidArg expected, got {other:?}"),
    }
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn cache_model_spec_mismatch_is_a_typed_error_not_a_panic() {
    let job = EncoderSpec::Bbit { b: 4, k: 12, d: 1 << 20, seed: 5 };
    let path = build_cache("specmismatch", 120, 0x5BEC, &job, 40);

    let model_for = |spec: EncoderSpec| {
        SavedModel::new(spec, LinearModel { w: vec![0.25; spec.output_dim()] }).unwrap()
    };
    // smaller k: the weight vector is shorter than the cache's expanded
    // dim — unchecked, this would index out of bounds, not error
    let narrower = model_for(EncoderSpec::Bbit { b: 4, k: 10, d: 1 << 20, seed: 5 });
    match eval_from_cache(&path, &narrower, SgdLoss::Logistic) {
        Err(Error::InvalidArg(msg)) => assert!(msg.contains("spec"), "{msg}"),
        other => panic!("expected InvalidArg, got {other:?}"),
    }
    // same geometry but a different hash-family seed: codes from one
    // family are meaningless under another's weights — also rejected
    let reseeded = model_for(EncoderSpec::Bbit { b: 4, k: 12, d: 1 << 20, seed: 6 });
    assert!(eval_from_cache(&path, &reseeded, SgdLoss::Logistic).is_err());
    // a different scheme entirely (same output dim) is rejected too
    let oph = model_for(EncoderSpec::Oph { bins: 12, b: 4, seed: 5 });
    assert_eq!(oph.spec.output_dim(), job.output_dim());
    assert!(eval_from_cache(&path, &oph, SgdLoss::Logistic).is_err());
    // the matching spec evaluates every row
    let matching = model_for(job);
    let eval = eval_from_cache(&path, &matching, SgdLoss::Logistic).unwrap();
    assert_eq!(eval.rows, 120);
    assert!(eval.mean_loss.is_finite());
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn holdout_split_is_deterministic_and_reports_generalization() {
    let job = EncoderSpec::Bbit { b: 6, k: 32, d: 1 << 22, seed: 9 };
    let path = build_cache("holdout", 600, 0x401D, &job, 64);
    let cfg = SgdConfig {
        loss: SgdLoss::Logistic,
        lr0: 0.5,
        lambda: 1e-3,
        epochs: 6,
        batch: 64,
    };
    let (m1, stats, h) = train_from_cache_holdout(&path, &cfg, 0.25, 7).unwrap();
    assert_eq!(stats.iterations, 6);
    assert_eq!(h.train_rows + h.holdout_rows, 600);
    // the realized split concentrates around the requested fraction
    assert!((60..=240).contains(&h.holdout_rows), "{h:?}");
    assert!(h.accuracy > 0.6 && h.accuracy <= 1.0, "{h:?}");
    assert!(h.mean_loss.is_finite() && h.mean_loss > 0.0, "{h:?}");

    // identical rerun: identical split, identical weights
    let (m2, _, h2) = train_from_cache_holdout(&path, &cfg, 0.25, 7).unwrap();
    assert_eq!(m1.w, m2.w);
    assert_eq!(h.holdout_rows, h2.holdout_rows);
    // a different salt trains on a different subset → different weights
    let (m3, _, _) = train_from_cache_holdout(&path, &cfg, 0.25, 8).unwrap();
    assert_ne!(m1.w, m3.w);
    // holding out rows means training on fewer than all of them: the
    // weights differ from the no-holdout run over the same cache
    let (all, _) = train_from_cache(&path, &cfg).unwrap();
    assert_ne!(m1.w, all.w);
    std::fs::remove_dir_all(path.parent().unwrap()).ok();
}

#[test]
fn reorder_window_tracks_inflight_work_not_corpus_size() {
    // 1000 docs / chunk_size 10 = 100 chunks, far more than can ever be
    // in flight with 4 workers + queue_depth 2 — a collector that buffered
    // until end-of-run (the old behavior) would peak at ~100
    let ds = corpus(1000, 0x9EAD);
    let job = EncoderSpec::Bbit { b: 4, k: 16, d: 1 << 20, seed: 7 };
    let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 10, queue_depth: 2 });
    let (_, report) = pipe.run(dataset_chunks(&ds, 10), &job).unwrap();
    assert_eq!(report.chunks, 100);
    assert!(report.reorder_peak >= 1);
    assert!(
        report.reorder_peak < report.chunks / 2,
        "reorder window ({}) scales with corpus ({} chunks) — collector is buffering",
        report.reorder_peak,
        report.chunks
    );
    // with one worker completion order is emission order: the hard bound
    let pipe1 = Pipeline::new(PipelineConfig { workers: 1, chunk_size: 10, queue_depth: 2 });
    let (_, report1) = pipe1.run(dataset_chunks(&ds, 10), &job).unwrap();
    assert_eq!(report1.reorder_peak, 1);
    // stall accounting: blocked-send time is reported separately from
    // productive read time and both fit inside the wall clock
    assert!(report.stall_seconds >= 0.0);
    assert!(report.read_seconds >= 0.0);
    assert!(report.read_seconds + report.stall_seconds <= report.wall_seconds + 0.05);
}
