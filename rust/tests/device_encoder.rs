//! Device-encoder integration tests: `DeviceEncoder` (`--device xla`)
//! must be bit-identical to the CPU `FeatureEncoder`s on every path —
//! packed b-bit codes, sparse VW rows, and the on-disk cache — and must
//! degrade to CPU hashing gracefully when no PJRT stack is available.
//!
//! Parity tests require `artifacts/` (run `make artifacts` first) and
//! skip with a visible notice otherwise, so `cargo test` stays green in
//! a fresh checkout.  The fallback tests run everywhere by design.

use std::path::{Path, PathBuf};

use bbit_mh::coordinator::{CacheSink, Pipeline, PipelineConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::{parse_block, BlockReader, LibsvmWriter, ParsedChunk};
use bbit_mh::encode::{DeviceEncoder, EncodedChunk, EncoderSpec, FeatureEncoder};
use bbit_mh::util::Rng;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Build a device encoder for `spec`, or `None` (with a visible skip
/// notice) when the PJRT stack / matching artifact is unavailable.
fn device_encoder(spec: &EncoderSpec) -> Option<DeviceEncoder> {
    let enc = DeviceEncoder::new(spec, &artifacts_dir()).unwrap();
    if enc.device_active() {
        Some(enc)
    } else {
        eprintln!(
            "skipping device-parity test ({} has no live PJRT artifact)",
            spec.scheme()
        );
        None
    }
}

macro_rules! require_device {
    ($spec:expr) => {
        match device_encoder($spec) {
            Some(enc) => enc,
            None => return,
        }
    };
}

const BBIT_SPEC: EncoderSpec = EncoderSpec::Bbit { b: 8, k: 200, d: 1 << 30, seed: 7 };
const VW_SPEC: EncoderSpec = EncoderSpec::Vw { bins: 1024, seed: 9 };

/// LibSVM text with deliberately awkward geometry: `n` ordinary rows
/// (so ~n+3 total — not a multiple of any compiled batch), plus an empty
/// row, a max-index row (`d−1`), and an oversize row larger than any
/// compiled nnz so the per-row CPU-twin path runs mid-chunk.
fn awkward_text(n: usize, d: u64, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut text = String::new();
    let mut push_row = |text: &mut String, label: &str, set: &[u64]| {
        text.push_str(label);
        for &t in set {
            text.push_str(&format!(" {t}:1"));
        }
        text.push('\n');
    };
    for i in 0..n {
        let len = 1 + rng.below_usize(60);
        let mut set = rng.sample_distinct(d, len);
        set.sort_unstable();
        push_row(&mut text, if i % 2 == 0 { "+1" } else { "-1" }, &set);
        if i == n / 3 {
            // empty document: the kernel's all-masked sentinel row
            text.push_str("-1\n");
        }
        if i == n / 2 {
            // top of the feature space, and an oversize row (> any
            // compiled nnz) that must take the CPU-twin slot path
            push_row(&mut text, "+1", &[d - 2, d - 1]);
            let mut big = rng.sample_distinct(d, 2500);
            big.sort_unstable();
            push_row(&mut text, "-1", &big);
        }
    }
    text
}

fn parsed(text: &str) -> ParsedChunk {
    let mut chunk = ParsedChunk::default();
    parse_block(text.as_bytes(), 1, true, &mut chunk).unwrap();
    chunk
}

#[test]
fn bbit_device_codes_match_cpu_across_awkward_geometry() {
    let enc = require_device!(&BBIT_SPEC);
    let cpu = BBIT_SPEC.encoder().unwrap();
    // 300-ish rows: crosses the compiled batch boundary with a remainder
    let chunk = parsed(&awkward_text(300, 1 << 30, 0xA3));
    let dev_out = enc.encode_parsed(&chunk).unwrap();
    let cpu_out = cpu.encode_parsed(&chunk).unwrap();
    match (dev_out, cpu_out) {
        (
            EncodedChunk::Packed { codes: dc, labels: dl },
            EncodedChunk::Packed { codes: cc, labels: cl },
        ) => {
            assert_eq!(dl, cl);
            assert_eq!(dc.n, cc.n);
            assert_eq!(dc.n, chunk.len());
            for i in 0..dc.n {
                assert_eq!(dc.row(i), cc.row(i), "packed codes disagree at row {i}");
            }
        }
        _ => panic!("bbit must encode to packed chunks on both paths"),
    }
    let stats = enc.device_stats().unwrap();
    assert_eq!(stats.device_chunks, 1);
    assert_eq!(stats.device_fallbacks, 0);
}

#[test]
fn vw_device_rows_match_cpu_across_awkward_geometry() {
    let enc = require_device!(&VW_SPEC);
    let cpu = VW_SPEC.encoder().unwrap();
    let chunk = parsed(&awkward_text(300, 1 << 30, 0xB4));
    let dev_out = enc.encode_parsed(&chunk).unwrap();
    let cpu_out = cpu.encode_parsed(&chunk).unwrap();
    match (dev_out, cpu_out) {
        (EncodedChunk::Sparse { rows: dr }, EncodedChunk::Sparse { rows: cr }) => {
            assert_eq!(dr.len(), chunk.len());
            // exact f32 equality: the ±1 bin sums are exact on both paths
            assert_eq!(dr, cr);
        }
        _ => panic!("vw must encode to sparse chunks on both paths"),
    }
}

#[test]
fn empty_chunk_is_fine_on_the_device_path() {
    let enc = require_device!(&BBIT_SPEC);
    let chunk = ParsedChunk::default();
    match enc.encode_parsed(&chunk).unwrap() {
        EncodedChunk::Packed { codes, labels } => {
            assert_eq!(codes.n, 0);
            assert!(labels.is_empty());
        }
        _ => panic!("bbit encodes packed"),
    }
}

/// Write an awkward corpus to a LibSVM temp file; returns its path.
fn corpus_file(tag: &str) -> PathBuf {
    let path = std::env::temp_dir()
        .join(format!("bbmh_device_enc_{tag}_{}.svm", std::process::id()));
    std::fs::write(&path, awkward_text(900, 1 << 30, 0xC5)).unwrap();
    path
}

#[test]
fn device_pipeline_cache_is_byte_identical_to_cpu_cache() {
    let enc = require_device!(&BBIT_SPEC);
    let input = corpus_file("cache");
    let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 256, queue_depth: 4 });
    let tmp = std::env::temp_dir();
    let cpu_cache = tmp.join(format!("bbmh_device_enc_cpu_{}.cache", std::process::id()));
    let dev_cache = tmp.join(format!("bbmh_device_enc_dev_{}.cache", std::process::id()));

    let mut sink = CacheSink::create(&cpu_cache, &BBIT_SPEC).unwrap();
    pipe.run_sink_blocks(BlockReader::open(&input).unwrap(), true, &BBIT_SPEC, &mut sink)
        .unwrap();
    let mut sink = CacheSink::create(&dev_cache, &BBIT_SPEC).unwrap();
    let report = pipe
        .run_encoder_blocks(BlockReader::open(&input).unwrap(), true, &enc, &mut sink)
        .unwrap();
    assert!(report.device_chunks > 0, "device path must have run");
    assert_eq!(report.device_fallbacks, 0);
    assert!(report.encode_device_seconds > 0.0);

    let cpu_bytes = std::fs::read(&cpu_cache).unwrap();
    let dev_bytes = std::fs::read(&dev_cache).unwrap();
    assert_eq!(cpu_bytes, dev_bytes, "device cache must be byte-identical to CPU cache");

    std::fs::remove_file(&input).ok();
    std::fs::remove_file(&cpu_cache).ok();
    std::fs::remove_file(&dev_cache).ok();
}

/// End-to-end CLI check: `preprocess --device xla --cache-out` writes the
/// same bytes as the CPU run.  With a live PJRT stack this pins the
/// device path; without one it pins the other acceptance requirement —
/// `--device xla` falls back to CPU *without erroring* — so it runs
/// everywhere.
#[test]
fn preprocess_cli_device_flag_matches_cpu_cache_or_falls_back() {
    let tmp = std::env::temp_dir();
    let input = tmp.join(format!("bbmh_device_cli_{}.svm", std::process::id()));
    {
        let corpus = CorpusGenerator::new(CorpusConfig {
            n_docs: 400,
            vocab: 2000,
            zipf_alpha: 1.05,
            mean_tokens: 25.0,
            class_signal: 0.5,
            pos_fraction: 0.5,
            seed: 0xD6,
        })
        .generate();
        let mut w = LibsvmWriter::new(std::fs::File::create(&input).unwrap());
        w.write_dataset(&corpus).unwrap();
        w.finish().unwrap();
    }
    let cpu_cache = tmp.join(format!("bbmh_device_cli_cpu_{}.cache", std::process::id()));
    let dev_cache = tmp.join(format!("bbmh_device_cli_dev_{}.cache", std::process::id()));
    let run = |device: &[&str], out: &Path| {
        let st = std::process::Command::new(env!("CARGO_BIN_EXE_bbit-mh"))
            .args([
                "preprocess",
                "--input",
                input.to_str().unwrap(),
                "--cache-out",
                out.to_str().unwrap(),
                "--encoder",
                "bbit",
                "--k",
                "200",
                "--seed",
                "11",
                "--workers",
                "2",
            ])
            .args(device)
            .status()
            .unwrap();
        assert!(st.success(), "preprocess {device:?} must not error");
    };
    run(&[], &cpu_cache);
    let art = artifacts_dir();
    run(&["--device", "xla", "--artifacts", art.to_str().unwrap()], &dev_cache);
    assert_eq!(
        std::fs::read(&cpu_cache).unwrap(),
        std::fs::read(&dev_cache).unwrap(),
        "--device xla cache must be byte-identical to the CPU cache"
    );
    std::fs::remove_file(&input).ok();
    std::fs::remove_file(&cpu_cache).ok();
    std::fs::remove_file(&dev_cache).ok();
}

// ---- fallback paths: these must pass with or without a PJRT stack ----

#[test]
fn missing_artifacts_dir_falls_back_to_cpu() {
    let dir = Path::new("/definitely/not/an/artifacts/dir");
    let enc = DeviceEncoder::new(&BBIT_SPEC, dir).unwrap();
    assert!(!enc.device_active());
    assert!(enc.batch_geometry().is_none());
    let chunk = parsed(&awkward_text(40, 1 << 30, 0xE7));
    let cpu = BBIT_SPEC.encoder().unwrap();
    let (dev_out, cpu_out) =
        (enc.encode_parsed(&chunk).unwrap(), cpu.encode_parsed(&chunk).unwrap());
    match (dev_out, cpu_out) {
        (
            EncodedChunk::Packed { codes: dc, labels: dl },
            EncodedChunk::Packed { codes: cc, labels: cl },
        ) => {
            assert_eq!(dl, cl);
            for i in 0..dc.n {
                assert_eq!(dc.row(i), cc.row(i), "fallback differs at row {i}");
            }
        }
        _ => panic!("fallback must still pack codes"),
    }
    let stats = enc.device_stats().unwrap();
    assert_eq!(stats.device_chunks, 0);
    assert_eq!(stats.device_fallbacks, 1, "the chunk must be counted as a fallback");
}

#[test]
fn scheme_without_device_kernel_falls_back_to_cpu() {
    // rp/oph have no AOT kernel — the encoder must say so and run on CPU,
    // even when the artifacts dir is real
    for spec in [
        EncoderSpec::Rp { proj: 64, s: 1.0, seed: 3 },
        EncoderSpec::Oph { bins: 256, b: 8, seed: 3 },
    ] {
        let enc = DeviceEncoder::new(&spec, &artifacts_dir()).unwrap();
        assert!(!enc.device_active(), "{} must not claim a device", spec.scheme());
        let chunk = parsed(&awkward_text(20, 1 << 20, 0xF8));
        let cpu = spec.encoder().unwrap();
        let dev_out = enc.encode_parsed(&chunk).unwrap();
        let cpu_out = cpu.encode_parsed(&chunk).unwrap();
        match (dev_out, cpu_out) {
            (EncodedChunk::Sparse { rows: a }, EncodedChunk::Sparse { rows: b }) => {
                assert_eq!(a, b)
            }
            (
                EncodedChunk::Packed { codes: a, labels: la },
                EncodedChunk::Packed { codes: b, labels: lb },
            ) => {
                assert_eq!(la, lb);
                for i in 0..a.n {
                    assert_eq!(a.row(i), b.row(i));
                }
            }
            _ => panic!("fallback output kind must match the CPU encoder"),
        }
    }
}

#[test]
fn invalid_spec_is_still_an_error() {
    // device fallback must not swallow spec validation
    let bad = EncoderSpec::Bbit { b: 99, k: 200, d: 1 << 30, seed: 1 };
    assert!(DeviceEncoder::new(&bad, &artifacts_dir()).is_err());
}
