//! Acceptance tests for the unified `FeatureEncoder` API (ISSUE 2):
//!
//! - spec round-trips: every `EncoderSpec` variant survives
//!   spec → encoder → spec() and spec → model file → spec;
//! - redesign equality: the trait-object pipeline reproduces the legacy
//!   `HashJob::Bbit` / `HashJob::Vw` worker outputs bit-for-bit;
//! - cache v1→v3 read-compat: a hand-written v1 cache still trains
//!   (the v2 transplant lives in `parallel_replay.rs`);
//! - OPH end-to-end: `preprocess --encoder oph` → cache → `train --cache`
//!   → `classify`, with the scheme recorded in cache and model.

use bbit_mh::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use bbit_mh::coordinator::sink::CacheSink;
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::SparseDataset;
use bbit_mh::encode::cache::{CacheReader, CacheWriter, CACHE_MAGIC};
use bbit_mh::encode::{EncodedChunk, EncoderSpec};
use bbit_mh::hashing::minwise::BbitMinHash;
use bbit_mh::hashing::vw::VwHasher;
use bbit_mh::solver::{train_from_cache, SavedModel, SgdConfig, SgdLoss};
use bbit_mh::util::Rng;

fn corpus(n: usize, seed: u64) -> SparseDataset {
    CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab: 1500,
        zipf_alpha: 1.05,
        mean_tokens: 24.0,
        class_signal: 0.6,
        pos_fraction: 0.5,
        seed,
    })
    .generate()
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bbit_encoder_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn every_spec_roundtrips_through_its_encoder() {
    let specs = [
        EncoderSpec::Bbit { b: 8, k: 32, d: 1 << 24, seed: 5 },
        EncoderSpec::Vw { bins: 128, seed: 7 },
        EncoderSpec::Rp { proj: 64, s: 3.0, seed: 11 },
        EncoderSpec::Oph { bins: 96, b: 4, seed: 13 },
    ];
    for spec in specs {
        let enc = spec.encoder().unwrap();
        assert_eq!(enc.spec(), spec, "{}", spec.scheme());
        assert_eq!(enc.output_dim(), spec.output_dim(), "{}", spec.scheme());
    }
}

/// The acceptance bar for the redesign: the trait-object pipeline must
/// produce byte-identical packed words (bbit) and identical sparse rows
/// (vw) vs. the pre-redesign dispatch, which drew the hasher directly
/// from `Rng::new(seed)`.
#[test]
fn trait_pipeline_reproduces_legacy_outputs_bit_for_bit() {
    let ds = corpus(300, 0x1DE4);
    let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 37, queue_depth: 2 });

    // ---- bbit ----
    let (b, k, d, seed) = (8u32, 48usize, 1u64 << 24, 0xAB5u64);
    let spec = EncoderSpec::Bbit { b, k, d, seed };
    let (out, _) = pipe.run(dataset_chunks(&ds, 37), &spec).unwrap();
    let got = out.into_packed().unwrap();
    // legacy worker body: draw BbitMinHash from Rng::new(seed), hash rows
    let legacy = BbitMinHash::draw(k, b, d, &mut Rng::new(seed));
    let mut reference = bbit_mh::encode::packed::PackedCodes::new(b, k);
    for i in 0..ds.len() {
        reference.push_row(&legacy.codes(ds.row(i).0)).unwrap();
    }
    assert_eq!(got.codes.words(), reference.words(), "packed words must be byte-identical");

    // ---- vw ----
    let (bins, seed) = (64usize, 0x77AAu64);
    let spec = EncoderSpec::Vw { bins, seed };
    let (out, _) = pipe.run(dataset_chunks(&ds, 37), &spec).unwrap();
    let got = out.into_sparse().unwrap();
    let legacy = VwHasher::draw(bins, &mut Rng::new(seed));
    for i in 0..ds.len() {
        let pairs = legacy.hash_sparse(ds.row(i).0);
        let (idx, vals) = got.row(i);
        let got_pairs: Vec<(u32, f32)> =
            idx.iter().copied().zip(vals.unwrap().iter().copied()).collect();
        assert_eq!(got_pairs, pairs, "row {i}");
    }
}

#[test]
fn oph_end_to_end_cache_train_classify() {
    let ds = corpus(600, 0x0F4E2E);
    let dir = tmp_dir("oph_e2e");
    let cache_path = dir.join("oph.cache");
    let model_path = dir.join("oph.bbmh");
    // bins ≈ nnz keeps most partitions occupied (mean_tokens is 24), so
    // the densification path is exercised without dominating the codes
    let spec = EncoderSpec::Oph { bins: 32, b: 8, seed: 0x09 };
    let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 64, queue_depth: 2 });

    // preprocess --encoder oph --cache-out
    let mut sink = CacheSink::create(&cache_path, &spec).unwrap();
    let report = pipe.run_sink(dataset_chunks(&ds, 64), &spec, &mut sink).unwrap();
    assert_eq!(report.docs, 600);

    // the cache records the scheme
    let meta = CacheReader::open(&cache_path).unwrap().meta();
    assert_eq!(meta.spec, spec);
    assert_eq!(meta.n, 600);

    // train --cache (streaming SGD over OPH codes)
    let cfg = SgdConfig {
        loss: SgdLoss::Logistic,
        lr0: 0.5,
        lambda: 1e-4,
        epochs: 5,
        batch: 64,
    };
    let (model, stats) = train_from_cache(&cache_path, &cfg).unwrap();
    assert_eq!(stats.iterations, 5);
    assert_eq!(model.w.len(), spec.output_dim());

    // save a spec-carrying model, reload, classify raw documents
    let saved = SavedModel::new(spec, model).unwrap();
    saved.save(&model_path).unwrap();
    let loaded = SavedModel::load(&model_path).unwrap();
    assert_eq!(loaded.spec, spec);

    let mut scratch = loaded.scratch();
    let correct = (0..ds.len())
        .filter(|&i| {
            let m = loaded.margin(ds.row(i).0, &mut scratch);
            (m >= 0.0) == (ds.labels[i] > 0)
        })
        .count();
    let acc = correct as f64 / ds.len() as f64;
    assert!(acc > 0.8, "OPH end-to-end train accuracy too low: {acc}");
    std::fs::remove_dir_all(dir).ok();
}

/// A v1 cache (pre-redesign fixed b-bit header) keeps working end to end:
/// parsed as `EncoderSpec::Bbit`, replayable, trainable.
#[test]
fn v1_cache_reads_and_trains_as_bbit() {
    let ds = corpus(200, 0xC0DE);
    let (b, k, d, seed) = (6u32, 24usize, 1u64 << 22, 0x51u64);
    let spec = EncoderSpec::Bbit { b, k, d, seed };
    let dir = tmp_dir("v1compat");

    // build the record stream with today's (v3) writer, then transplant
    // it behind a hand-written v1 header — the record framing is shared
    // by every version; only the header and the v3-only footer differ
    let v3_path = dir.join("v3.cache");
    let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 50, queue_depth: 2 });
    let mut sink = CacheSink::create(&v3_path, &spec).unwrap();
    pipe.run_sink(dataset_chunks(&ds, 50), &spec, &mut sink).unwrap();
    let v3_bytes = std::fs::read(&v3_path).unwrap();
    let records_end = bbit_mh::encode::ChunkIndex::load(&v3_path)
        .unwrap()
        .expect("v3 cache carries an index")
        .records_end as usize;
    let records =
        &v3_bytes[bbit_mh::encode::cache::HEADER_BYTES_V3 as usize..records_end];

    let mut v1_bytes = Vec::new();
    v1_bytes.extend_from_slice(CACHE_MAGIC);
    v1_bytes.extend_from_slice(&1u32.to_le_bytes());
    v1_bytes.extend_from_slice(&b.to_le_bytes());
    for v in [k as u64, d, seed, ds.len() as u64] {
        v1_bytes.extend_from_slice(&v.to_le_bytes());
    }
    v1_bytes.extend_from_slice(records);
    let v1_path = dir.join("v1.cache");
    std::fs::write(&v1_path, &v1_bytes).unwrap();

    // both versions parse to the same spec/rows and replay the same data
    // (v1 headers carry no payload byte totals, so compare fields, not
    // the whole meta struct)
    let m1 = CacheReader::open(&v1_path).unwrap().meta();
    let m3 = CacheReader::open(&v3_path).unwrap().meta();
    assert_eq!(m1.spec, m3.spec);
    assert_eq!(m1.n, m3.n);
    let ds1 = CacheReader::open(&v1_path).unwrap().read_all().unwrap();
    let ds3 = CacheReader::open(&v3_path).unwrap().read_all().unwrap();
    assert_eq!(ds1.codes.words(), ds3.codes.words());
    assert_eq!(ds1.labels, ds3.labels);

    // and the v1 file trains through the same streaming path
    let cfg = SgdConfig { epochs: 2, batch: 32, ..Default::default() };
    let (w1, _) = train_from_cache(&v1_path, &cfg).unwrap();
    let (w3, _) = train_from_cache(&v3_path, &cfg).unwrap();
    assert_eq!(w1.w, w3.w, "v1 and v3 replays must train identically");
    std::fs::remove_dir_all(dir).ok();
}

/// New-writer caches are v3 (scheme-tagged + indexed); the version
/// constant and the on-disk bytes agree.
#[test]
fn writer_emits_v3_headers() {
    let spec = EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 16, seed: 3 };
    let mut buf = std::io::Cursor::new(Vec::new());
    let mut w = CacheWriter::new(&mut buf, &spec).unwrap();
    w.finalize().unwrap();
    let bytes = buf.into_inner();
    assert_eq!(&bytes[0..4], CACHE_MAGIC);
    assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 3);
    assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), 0); // bbit tag
}

/// `encode_chunk` is the single seam the pipeline workers use: a chunk
/// encoded directly equals the chunk coming out of the full pipeline.
#[test]
fn encode_chunk_equals_pipeline_output_for_every_scheme() {
    let ds = corpus(90, 0x5EAD);
    let chunk: Vec<_> = (0..ds.len())
        .map(|i| {
            let (idx, vals) = ds.row(i);
            bbit_mh::data::dataset::Example {
                label: ds.labels[i],
                indices: idx.to_vec(),
                values: vals.map(|v| v.to_vec()),
            }
        })
        .collect();
    let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 13, queue_depth: 2 });
    let specs = [
        EncoderSpec::Bbit { b: 8, k: 16, d: 1 << 20, seed: 1 },
        EncoderSpec::Oph { bins: 32, b: 8, seed: 2 },
        EncoderSpec::Vw { bins: 64, seed: 3 },
        EncoderSpec::Rp { proj: 16, s: 1.0, seed: 4 },
    ];
    for spec in specs {
        let enc = spec.encoder().unwrap();
        let direct = enc.encode_chunk(&chunk).unwrap();
        let (out, _) = pipe.run(dataset_chunks(&ds, 13), &spec).unwrap();
        match (direct, out) {
            (
                EncodedChunk::Packed { codes, labels },
                bbit_mh::coordinator::pipeline::PipelineOutput::Packed(got),
            ) => {
                assert_eq!(got.codes.words(), codes.words(), "{}", spec.scheme());
                assert_eq!(got.labels, labels);
            }
            (
                EncodedChunk::Sparse { rows },
                bbit_mh::coordinator::pipeline::PipelineOutput::Sparse(got),
            ) => {
                assert_eq!(got.len(), rows.len());
                for (i, (label, pairs)) in rows.iter().enumerate() {
                    assert_eq!(got.labels[i], *label, "{}", spec.scheme());
                    let (idx, vals) = got.row(i);
                    let got_pairs: Vec<(u32, f32)> =
                        idx.iter().copied().zip(vals.unwrap().iter().copied()).collect();
                    assert_eq!(&got_pairs, pairs, "{} row {i}", spec.scheme());
                }
            }
            _ => panic!("{}: chunk kind diverged between direct and pipeline", spec.scheme()),
        }
    }
}
