//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Every randomized component in the crate (data generation, hash-parameter
//! draws, permutations, solvers' index shuffles) takes an explicit seed and
//! goes through this generator, so experiments are bit-reproducible across
//! runs and machines.  The generator matches the published reference
//! implementations of SplitMix64 / xoshiro256** (Blackman & Vigna).

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams for all practical purposes.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (used to give each pipeline
    /// worker / hash function its own generator deterministically).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64 random bits (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, bound)` via Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform u32 in `[lo, hi)`.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        lo + self.below((hi - lo) as u64) as u32
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fair coin.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct values from `[0, n)` (Floyd's algorithm; output
    /// sorted).  Panics if `m > n`.
    pub fn sample_distinct(&mut self, n: u64, m: usize) -> Vec<u64> {
        assert!(m as u64 <= n);
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - m as u64)..n {
            let t = self.below(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }

    /// Poisson sample (Knuth for small lambda, normal approx above 64).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda > 64.0 {
            let v = lambda + lambda.sqrt() * self.normal();
            return v.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
}

/// Zipf sampler over `{0, .., n-1}` with exponent `alpha` (rank 0 most
/// frequent).  Uses the rejection-inversion method of Hörmann & Derflinger,
/// O(1) per sample, exact for alpha != 1 as well as alpha == 1.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n > 0 && alpha > 0.0);
        let h = |x: f64| -> f64 {
            if (alpha - 1.0).abs() < 1e-12 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - alpha) - 1.0) / (1.0 - alpha)
            }
        };
        Zipf {
            n,
            alpha,
            h_x1: h(1.5) - 1.0,
            h_n: h(n as f64 - 0.5),
            s: 2.0 - Self::h_inv_static(alpha, Self::h_static(alpha, 2.5) - 2f64.powf(-alpha)),
        }
    }

    fn h_static(alpha: f64, x: f64) -> f64 {
        if (alpha - 1.0).abs() < 1e-12 {
            x.ln()
        } else {
            (x.powf(1.0 - alpha) - 1.0) / (1.0 - alpha)
        }
    }

    fn h_inv_static(alpha: f64, x: f64) -> f64 {
        if (alpha - 1.0).abs() < 1e-12 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - alpha)).powf(1.0 / (1.0 - alpha))
        }
    }

    fn h(&self, x: f64) -> f64 {
        Self::h_static(self.alpha, 1.0 + x)
    }

    fn h_inv(&self, x: f64) -> f64 {
        Self::h_inv_static(self.alpha, x) - 1.0
    }

    /// Draw a rank in `[0, n)`; rank r has probability ∝ 1/(r+1)^alpha.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(0.0, self.n as f64 - 1.0);
            if k - x <= self.s || u >= self.h(k + 0.5) - (1.0 + k).powf(-self.alpha) {
                return k as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let v = rng.below(10) as usize;
            counts[v] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(7);
        let mut v: Vec<u32> = (0..1000).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Rng::new(9);
        let s = rng.sample_distinct(1000, 100);
        assert_eq!(s.len(), 100);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&x| x < 1000));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zipf_is_monotone_and_in_range() {
        let mut rng = Rng::new(13);
        let z = Zipf::new(1000, 1.1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..200_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[200]);
        // mass check: rank-0 frequency should be far above uniform
        assert!(counts[0] > 5 * 200_000 / 1000);
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Rng::new(17);
        for &lam in &[0.5, 4.0, 30.0, 100.0] {
            let n = 50_000;
            let mean =
                (0..n).map(|_| rng.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < 0.1 * lam + 0.1, "lam {lam} mean {mean}");
        }
    }
}
