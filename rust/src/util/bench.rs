//! Micro-benchmark harness (criterion-style, dependency-free).
//!
//! The image's offline crate set does not include criterion, so the
//! `benches/` binaries (declared `harness = false`) use this module: warmup,
//! adaptive iteration count targeting a fixed measurement window, and
//! mean/σ/median/p95 reporting in a criterion-like one-line format.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration across measurement batches.
    pub ns_per_iter: Vec<f64>,
    /// Optional throughput denominator (elements processed per iteration).
    pub elements: Option<u64>,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.ns_per_iter)
    }

    /// Human-readable line, criterion-like.
    pub fn report(&self) -> String {
        let mean = self.mean_ns();
        let sd = stats::stddev(&self.ns_per_iter);
        let med = stats::median(&self.ns_per_iter);
        let mut line = format!(
            "{:<44} time: [{} ± {} med {}]",
            self.name,
            fmt_ns(mean),
            fmt_ns(sd),
            fmt_ns(med),
        );
        if let Some(elems) = self.elements {
            let per_sec = elems as f64 / (mean * 1e-9);
            line.push_str(&format!("  thrpt: {}/s", fmt_count(per_sec)));
        }
        line
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fmt_count(c: f64) -> String {
    if c >= 1e9 {
        format!("{:.2}G", c / 1e9)
    } else if c >= 1e6 {
        format!("{:.2}M", c / 1e6)
    } else if c >= 1e3 {
        format!("{:.2}K", c / 1e3)
    } else {
        format!("{c:.1}")
    }
}

/// Benchmark runner with a fixed measurement budget per case.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    batches: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(Duration::from_millis(300), Duration::from_secs(1), 10)
    }
}

impl Bench {
    pub fn new(warmup: Duration, measure: Duration, batches: usize) -> Self {
        Bench { warmup, measure, batches, results: Vec::new() }
    }

    /// Quick profile for expensive end-to-end cases.
    pub fn quick() -> Self {
        Bench::new(Duration::from_millis(50), Duration::from_millis(400), 5)
    }

    /// Run `f` repeatedly; `f` must return something observable to prevent
    /// the optimizer from deleting the work (returned value is black-boxed).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.bench_with_elements(name, None, &mut f)
    }

    /// As [`bench`], reporting throughput as `elements`/iteration/second.
    pub fn bench_elems<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        elements: u64,
        mut f: F,
    ) -> &Measurement {
        self.bench_with_elements(name, Some(elements), &mut f)
    }

    fn bench_with_elements<T>(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut dyn FnMut() -> T,
    ) -> &Measurement {
        // Warmup + calibration: find iters that fill measure/batches.
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters as f64;
        let batch_time = self.measure.as_secs_f64() / self.batches as f64;
        let iters = ((batch_time / per_iter).ceil() as u64).max(1);

        let mut ns = Vec::with_capacity(self.batches);
        for _ in 0..self.batches {
            let t0 = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            ns.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let m = Measurement { name: name.to_string(), ns_per_iter: ns, elements };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

/// Optimizer barrier (stable-rust equivalent of `std::hint::black_box`,
/// kept as a wrapper so benches read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Peak resident set size of this process in bytes (VmHWM from
/// `/proc/self/status`), or 0 where that interface doesn't exist.  A
/// monotonic high-water mark: scenario snapshots taken later in a bench
/// process can only grow, so per-scenario values are upper bounds.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench::new(
            Duration::from_millis(5),
            Duration::from_millis(20),
            3,
        );
        let m = b.bench("noop-ish", || 1 + 1).clone();
        assert!(m.mean_ns() > 0.0);
        assert_eq!(m.ns_per_iter.len(), 3);
    }

    #[test]
    fn peak_rss_is_sane() {
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            // a running test binary has certainly touched > 1 MiB
            assert!(rss > 1 << 20, "VmHWM parsed as {rss}");
        }
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bench::new(
            Duration::from_millis(5),
            Duration::from_millis(20),
            2,
        );
        let m = b.bench_elems("sum", 1000, || (0..1000u64).sum::<u64>());
        assert!(m.report().contains("thrpt"));
    }
}
