//! Summary statistics used by the experiment harnesses and benches.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (0.0 for < 2 samples).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Exact median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in `[0, 100]` by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }
}
