//! Small self-contained utilities: deterministic PRNG, micro-bench harness,
//! and stats helpers.  Hand-rolled (no external deps) so every randomized
//! result in the repo is reproducible from a single `u64` seed.

pub mod atomic_file;
pub mod bench;
pub mod rng;
pub mod signal;
pub mod stats;

pub use rng::Rng;
