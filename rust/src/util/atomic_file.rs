//! Durable file commits: write-to-tmp, fsync, atomic rename.
//!
//! Every on-disk artifact that a crashed writer could leave half-written
//! (the encode cache, saved models, training checkpoints, index snapshots)
//! commits through this module.  The protocol is the classic one:
//!
//! 1. write the full payload to `<dst>.tmp` (same directory, so the rename
//!    stays within one filesystem),
//! 2. flush and `fsync` the tmp file,
//! 3. `rename(tmp, dst)` — atomic on POSIX,
//! 4. `fsync` the parent directory so the rename itself is durable.
//!
//! A reader therefore only ever observes `dst` as either absent or complete;
//! the worst a crash leaves behind is a stale `.tmp` sibling.

use std::fs::{self, File, OpenOptions};
use std::io;
use std::path::{Path, PathBuf};

/// The conventional tmp sibling for `dst` (`<dst>.tmp`).
pub fn tmp_path(dst: &Path) -> PathBuf {
    let mut os = dst.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// fsync a file by path.  Opening read-only is sufficient on Linux: the
/// fsync applies to the inode, not the descriptor's access mode.
pub fn sync_file(path: &Path) -> io::Result<()> {
    File::open(path)?.sync_all()
}

/// fsync the directory containing `path`, making a rename into it durable.
/// Platforms that refuse to open directories (or to fsync them) are treated
/// as best-effort: the rename is still atomic, just not crash-durable.
pub fn sync_parent_dir(path: &Path) -> io::Result<()> {
    let dir = path.parent().filter(|p| !p.as_os_str().is_empty());
    let dir = match dir {
        Some(d) => d,
        None => Path::new("."),
    };
    match File::open(dir) {
        Ok(f) => match f.sync_all() {
            Ok(()) => Ok(()),
            Err(_) => Ok(()),
        },
        Err(_) => Ok(()),
    }
}

/// Steps 2–4 of the protocol: fsync `tmp`, rename it onto `dst`, fsync the
/// parent directory.  The caller has already written and flushed `tmp`.
pub fn commit(tmp: &Path, dst: &Path) -> io::Result<()> {
    sync_file(tmp)?;
    fs::rename(tmp, dst)?;
    sync_parent_dir(dst)
}

/// Write `dst` atomically: `fill` receives a fresh `<dst>.tmp` file, and on
/// success the tmp is fsync'd and renamed into place.  On error the tmp is
/// removed so a failed save never litters (or worse, resembles) real output.
pub fn write_atomic<E, F>(dst: &Path, fill: F) -> Result<(), E>
where
    E: From<io::Error>,
    F: FnOnce(&mut File) -> Result<(), E>,
{
    let tmp = tmp_path(dst);
    let mut f = OpenOptions::new()
        .write(true)
        .create(true)
        .truncate(true)
        .open(&tmp)
        .map_err(E::from)?;
    match fill(&mut f).and_then(|()| f.sync_all().map_err(E::from)) {
        Ok(()) => {
            drop(f);
            fs::rename(&tmp, dst).map_err(E::from)?;
            sync_parent_dir(dst).map_err(E::from)?;
            Ok(())
        }
        Err(e) => {
            drop(f);
            let _ = fs::remove_file(&tmp);
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bbmh_atomic_{}_{}", name, std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_atomic_lands_full_content_and_no_tmp() {
        let d = tdir("ok");
        let dst = d.join("out.bin");
        write_atomic::<io::Error, _>(&dst, |f| f.write_all(b"hello world")).unwrap();
        assert_eq!(fs::read(&dst).unwrap(), b"hello world");
        assert!(!tmp_path(&dst).exists());
    }

    #[test]
    fn failed_fill_leaves_neither_dst_nor_tmp() {
        let d = tdir("fail");
        let dst = d.join("out.bin");
        let err = write_atomic::<io::Error, _>(&dst, |f| {
            f.write_all(b"partial")?;
            Err(io::Error::new(io::ErrorKind::Other, "boom"))
        })
        .unwrap_err();
        assert_eq!(err.to_string(), "boom");
        assert!(!dst.exists());
        assert!(!tmp_path(&dst).exists());
    }

    #[test]
    fn write_atomic_replaces_existing_file() {
        let d = tdir("replace");
        let dst = d.join("out.bin");
        fs::write(&dst, b"old").unwrap();
        write_atomic::<io::Error, _>(&dst, |f| f.write_all(b"new content")).unwrap();
        assert_eq!(fs::read(&dst).unwrap(), b"new content");
    }
}
