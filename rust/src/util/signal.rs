//! Minimal std-only SIGTERM latch for the online tier.
//!
//! std already links libc on unix, so we declare `signal(2)` ourselves
//! rather than pulling in a crate.  The handler does the only
//! async-signal-safe thing worth doing: it sets a flag.  The serving loop
//! polls the flag and runs the drain sequence on the main thread.

use std::sync::atomic::{AtomicBool, Ordering};

static TERM_REQUESTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
}

#[cfg(unix)]
extern "C" fn on_sigterm(_sig: i32) {
    TERM_REQUESTED.store(true, Ordering::Relaxed);
}

/// Install the SIGTERM handler (idempotent).  On non-unix platforms this is
/// a no-op and [`term_requested`] simply never fires.
pub fn install_sigterm_handler() {
    #[cfg(unix)]
    unsafe {
        signal(SIGTERM, on_sigterm as usize);
    }
}

/// Has a SIGTERM arrived since the handler was installed?
pub fn term_requested() -> bool {
    TERM_REQUESTED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The latch itself is process-global; delivering a real SIGTERM to the
    // test harness would stop other tests, so end-to-end delivery is covered
    // by the subprocess drain test in tests/crash_recovery.rs.  Here we only
    // check that installation is safe and the flag starts clear.
    #[test]
    fn install_is_idempotent_and_flag_starts_clear() {
        install_sigterm_handler();
        install_sigterm_handler();
        assert!(!term_requested());
    }
}
