//! `bbit-mh` — the layer-3 coordinator CLI.
//!
//! Subcommands:
//!   gen-data     generate the rcv1-like corpus (optionally expanded) as LibSVM
//!   preprocess   stream a LibSVM file through the hashing pipeline
//!   train        train + evaluate on a hashed dataset
//!   experiments  regenerate a paper table/figure (or `all`)
//!   runtime-info check the PJRT artifacts load and run
//!
//! The argument parser is hand-rolled (the offline crate set has no clap);
//! flags are `--key value` or `--key=value`.

use std::collections::BTreeMap;
use std::path::Path;
use std::process::ExitCode;

use bbit_mh::coordinator::pipeline::{HashJob, Pipeline, PipelineConfig};
use bbit_mh::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use bbit_mh::data::expand::{expand_example, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::{ChunkedReader, LibsvmReader, LibsvmWriter};
use bbit_mh::experiments::{self, Ctx, Scale};
use bbit_mh::{Error, Result};

const USAGE: &str = "\
bbit-mh — b-bit minwise hashing for large-scale linear learning
  (reproduction of Li, Shrivastava & König 2011; see README.md)

USAGE:
  bbit-mh gen-data --out FILE [--n 4000] [--vocab 4000] [--expanded] [--seed N]
  bbit-mh preprocess --input FILE --out FILE --method bbit|vw
             [--b 8] [--k 200] [--bins 1024] [--dim 1073741824]
             [--workers N] [--seed N]
  bbit-mh train --input FILE --solver svm|lr [--c 1.0] [--cv FOLDS]
             [--method bbit|vw|none] [--b 8] [--k 200] [--bins 1024]
             [--train-frac 0.5] [--seed N] [--save-model FILE]
  bbit-mh classify --model FILE --input FILE [--out FILE]
  bbit-mh experiments ID [--scale tiny|small|paper] [--results DIR]
             (IDs: table1 fig1 fig3 fig5 fig6 fig7 fig8 table2 variance fig9 all)
  bbit-mh runtime-info [--artifacts DIR]
  bbit-mh help
";

/// Minimal flag parser: positional args then `--key value` / `--key=value`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::InvalidArg(format!("bad --{key} value {v:?}"))),
        }
    }

    fn required(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::InvalidArg(format!("missing --{key}")))
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "gen-data" => cmd_gen_data(&args),
        "preprocess" => cmd_preprocess(&args),
        "train" => cmd_train(&args),
        "classify" => cmd_classify(&args),
        "experiments" => cmd_experiments(&args),
        "runtime-info" => cmd_runtime_info(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::InvalidArg(format!("unknown command {other:?}; try help"))),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let out = args.required("out")?;
    let n: usize = args.get("n", 4000)?;
    let vocab: u32 = args.get("vocab", 4000)?;
    let seed: u64 = args.get("seed", 0xB_B17)?;
    let expanded = args.has("expanded");
    let corpus = CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab,
        zipf_alpha: 1.05,
        mean_tokens: args.get("mean-tokens", 30.0)?,
        class_signal: 0.55,
        pos_fraction: 0.47,
        seed,
    })
    .generate();
    let mut writer = LibsvmWriter::create(out)?;
    if expanded {
        let cfg = ExpandConfig {
            vocab,
            dim: args.get("dim", 1u64 << 30)?,
            three_way_rate: 30,
            seed: seed ^ 0xEE,
        };
        cfg.validate()?;
        for ex in corpus.iter() {
            writer.write_example(&expand_example(&cfg, &ex))?;
        }
    } else {
        writer.write_dataset(&corpus)?;
    }
    writer.finish()?;
    let s = corpus.stats();
    eprintln!(
        "wrote {} docs (base nnz mean {:.1}{}) to {}",
        n,
        s.nnz_mean,
        if expanded { ", expanded" } else { "" },
        out
    );
    Ok(())
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let input = args.required("input")?;
    let out = args.required("out")?;
    let method = args.get("method", "bbit".to_string())?;
    let workers: usize = args.get("workers", bbit_mh::config::available_workers())?;
    let seed: u64 = args.get("seed", 1)?;
    let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 256, queue_depth: 4 });
    let source = ChunkedReader::new(LibsvmReader::open(input)?.binary(), 256);
    match method.as_str() {
        "bbit" => {
            let job = HashJob::Bbit {
                b: args.get("b", 8u32)?,
                k: args.get("k", 200usize)?,
                d: args.get("dim", 1u64 << 30)?,
                seed,
            };
            let (outp, report) = pipe.run(source, &job)?;
            let bb = outp.into_bbit()?;
            let f = std::fs::File::create(out)?;
            bb.codes.save(std::io::BufWriter::new(f))?;
            // labels ride alongside
            std::fs::write(
                format!("{out}.labels"),
                bb.labels
                    .iter()
                    .map(|l| l.to_string())
                    .collect::<Vec<_>>()
                    .join("\n"),
            )?;
            eprintln!(
                "hashed {} docs in {:.2}s wall ({:.2}s read, {:.2} hash-cpu-s, {} stalls) -> {} ({} ideal bytes)",
                report.docs,
                report.wall_seconds,
                report.read_seconds,
                report.hash_cpu_seconds,
                report.backpressure_stalls,
                out,
                bb.codes.ideal_bytes(),
            );
        }
        "vw" => {
            let job = HashJob::Vw { bins: args.get("bins", 1024usize)?, seed };
            let (outp, report) = pipe.run(source, &job)?;
            let ds = outp.into_vw()?;
            let mut w = LibsvmWriter::create(out)?;
            w.write_dataset(&ds)?;
            w.finish()?;
            eprintln!(
                "VW-hashed {} docs in {:.2}s wall -> {out}",
                report.docs, report.wall_seconds
            );
        }
        other => return Err(Error::InvalidArg(format!("unknown method {other:?}"))),
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let input = args.required("input")?;
    let solver = args.get("solver", "svm".to_string())?;
    let c: f64 = args.get("c", 1.0)?;
    let seed: u64 = args.get("seed", 3)?;
    let train_frac: f64 = args.get("train-frac", 0.5)?;
    let method = args.get("method", "bbit".to_string())?;

    let dim: u64 = args.get("dim", 1u64 << 30)?;
    let raw = bbit_mh::data::libsvm::load(input, dim)?;
    let (train_raw, test_raw) = raw.split(train_frac, &mut bbit_mh::util::Rng::new(seed));
    eprintln!(
        "loaded {} examples ({} train / {} test)",
        raw.len(),
        train_raw.len(),
        test_raw.len()
    );

    let kind = match solver.as_str() {
        "svm" => SolverKind::SvmDcd,
        "lr" => SolverKind::LrNewton,
        other => return Err(Error::InvalidArg(format!("unknown solver {other:?}"))),
    };
    let job = vec![TrainJob { tag: String::new(), solver: kind, c }];
    let cv_folds: usize = args.get("cv", 0)?;
    let outcome = match method.as_str() {
        "bbit" => {
            let pipe = Pipeline::new(PipelineConfig::default());
            let hash = HashJob::Bbit {
                b: args.get("b", 8u32)?,
                k: args.get("k", 200usize)?,
                d: dim,
                seed: seed ^ 0x4A5E,
            };
            let (tr, _) = pipe.run(
                bbit_mh::coordinator::pipeline::dataset_chunks(&train_raw, 256),
                &hash,
            )?;
            let (te, _) = pipe.run(
                bbit_mh::coordinator::pipeline::dataset_chunks(&test_raw, 256),
                &hash,
            )?;
            let (tr, te) = (tr.into_bbit()?, te.into_bbit()?);
            if let Some(model_path) = args.flags.get("save-model") {
                // fit on the train half at the requested C, persist the
                // model + hashing recipe for `classify`
                let model = match kind {
                    SolverKind::SvmDcd => {
                        bbit_mh::solver::train_svm(
                            &tr,
                            &bbit_mh::solver::SvmConfig::with_c(c),
                        )
                        .0
                    }
                    SolverKind::LrNewton => {
                        bbit_mh::solver::train_lr(
                            &tr,
                            &bbit_mh::solver::LrConfig::with_c(c),
                        )
                        .0
                    }
                };
                let saved = bbit_mh::solver::SavedModel {
                    b: args.get("b", 8u32)?,
                    k: args.get("k", 200usize)?,
                    d: dim,
                    seed: seed ^ 0x4A5E,
                    model,
                };
                saved.save(model_path)?;
                eprintln!("saved model to {model_path}");
            }
            if cv_folds >= 2 {
                // C selection by k-fold CV on the hashed training half —
                // the paper's "many C values on one preprocessing pass"
                let report = bbit_mh::solver::cross_validate(
                    &tr,
                    kind,
                    &bbit_mh::coordinator::scheduler::paper_c_grid(),
                    cv_folds,
                    seed,
                    bbit_mh::config::available_workers(),
                )?;
                for p in &report.points {
                    eprintln!(
                        "  cv C={:<8} acc {:.3}% ± {:.3}",
                        p.c,
                        100.0 * p.mean_accuracy,
                        100.0 * p.std_accuracy
                    );
                }
                eprintln!("cv selected C = {}", report.best_c);
                let job =
                    vec![TrainJob { tag: String::new(), solver: kind, c: report.best_c }];
                return print_outcome(
                    &solver,
                    &method,
                    report.best_c,
                    &Scheduler::new(1).run_grid(&tr, &te, &job)?[0],
                );
            }
            Scheduler::new(1).run_grid(&tr, &te, &job)?
        }
        "vw" => {
            let pipe = Pipeline::new(PipelineConfig::default());
            let hash = HashJob::Vw { bins: args.get("bins", 1024usize)?, seed: seed ^ 0x77 };
            let (tr, _) = pipe.run(
                bbit_mh::coordinator::pipeline::dataset_chunks(&train_raw, 256),
                &hash,
            )?;
            let (te, _) = pipe.run(
                bbit_mh::coordinator::pipeline::dataset_chunks(&test_raw, 256),
                &hash,
            )?;
            Scheduler::new(1).run_grid(&tr.into_vw()?, &te.into_vw()?, &job)?
        }
        "none" => Scheduler::new(1).run_grid(&train_raw, &test_raw, &job)?,
        other => return Err(Error::InvalidArg(format!("unknown method {other:?}"))),
    };
    print_outcome(&solver, &method, c, &outcome[0])
}

fn print_outcome(
    solver: &str,
    method: &str,
    c: f64,
    o: &bbit_mh::coordinator::scheduler::TrainOutcome,
) -> Result<()> {
    println!(
        "solver={solver} method={method} C={c}: test acc {:.3}% (train {:.3}%), {:.3}s, {} iters{}",
        100.0 * o.test_accuracy,
        100.0 * o.train_accuracy,
        o.train_seconds,
        o.iterations,
        if o.converged { "" } else { " (hit iteration cap)" },
    );
    Ok(())
}

/// Score raw LibSVM documents with a saved model — the L3 "request path":
/// parse → minwise hash → b-bit gather margin, no python, no retraining.
fn cmd_classify(args: &Args) -> Result<()> {
    let model_path = args.required("model")?;
    let input = args.required("input")?;
    let saved = bbit_mh::solver::SavedModel::load(model_path)?;
    let mut scratch = saved.scratch();
    let mut out: Box<dyn std::io::Write> = match args.flags.get("out") {
        Some(p) => Box::new(std::io::BufWriter::new(std::fs::File::create(p)?)),
        None => Box::new(std::io::BufWriter::new(std::io::stdout())),
    };
    let (mut n, mut correct) = (0usize, 0usize);
    let t0 = std::time::Instant::now();
    for ex in LibsvmReader::open(input)?.binary() {
        let ex = ex?;
        let margin = saved.margin(&ex.indices, &mut scratch);
        let pred: i8 = if margin >= 0.0 { 1 } else { -1 };
        writeln!(out, "{pred} {margin:.6}")?;
        n += 1;
        if pred == ex.label {
            correct += 1;
        }
    }
    out.flush()?;
    let secs = t0.elapsed().as_secs_f64();
    eprintln!(
        "classified {n} docs in {secs:.3}s ({:.0} docs/s); accuracy vs file labels: {:.3}%",
        n as f64 / secs.max(1e-9),
        100.0 * correct as f64 / n.max(1) as f64
    );
    Ok(())
}

fn cmd_experiments(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let mut scale = match args.get("scale", "small".to_string())?.as_str() {
        "tiny" => Scale::tiny(),
        "small" => Scale::small(),
        "paper" => Scale::paper(),
        other => return Err(Error::InvalidArg(format!("unknown scale {other:?}"))),
    };
    if let Some(dir) = args.flags.get("results") {
        scale.results_dir = dir.clone();
    }
    scale.seed = args.get("seed", scale.seed)?;
    let mut ctx = Ctx::new(scale);
    let t0 = std::time::Instant::now();
    if id == "all" {
        experiments::run_all(&mut ctx)?;
    } else {
        experiments::run(&id, &mut ctx)?;
    }
    eprintln!("experiments '{id}' finished in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&argv(&["fig1", "--scale", "tiny", "--seed=42", "--expanded"]))
            .unwrap();
        assert_eq!(a.positional, vec!["fig1"]);
        assert_eq!(a.get::<String>("scale", "x".into()).unwrap(), "tiny");
        assert_eq!(a.get::<u64>("seed", 0).unwrap(), 42);
        assert!(a.has("expanded"));
        assert_eq!(a.get::<usize>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_values_and_missing_required() {
        let a = Args::parse(&argv(&["--n", "notanum"])).unwrap();
        assert!(a.get::<usize>("n", 0).is_err());
        assert!(a.required("out").is_err());
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = Args::parse(&argv(&["--expanded", "--n", "5"])).unwrap();
        assert!(a.has("expanded"));
        assert_eq!(a.get::<usize>("n", 0).unwrap(), 5);
    }

    #[test]
    fn unknown_command_is_an_error() {
        assert!(run(&argv(&["frobnicate"])).is_err());
        assert!(run(&argv(&["help"])).is_ok());
        assert!(run(&argv(&[])).is_ok());
    }

    #[test]
    fn experiments_rejects_unknown_scale_and_id() {
        assert!(run(&argv(&["experiments", "table1", "--scale", "galactic"])).is_err());
        assert!(run(&argv(&["experiments", "figZZ", "--scale", "tiny"])).is_err());
    }
}

fn cmd_runtime_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts", "artifacts".to_string())?;
    let rt = bbit_mh::runtime::PjrtRuntime::cpu(Path::new(&dir))?;
    println!("PJRT platform: {}", rt.platform());
    for (name, spec) in &rt.manifest.artifacts {
        print!(
            "  {name}: {} inputs, {} outputs, consts {{",
            spec.inputs.len(),
            spec.outputs.len()
        );
        for (k, v) in &spec.consts {
            print!(" {k}={v}");
        }
        println!(" }}");
        rt.load(name)?; // compile to prove it loads
    }
    println!("all {} artifacts compiled OK", rt.manifest.artifacts.len());
    Ok(())
}
