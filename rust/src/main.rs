//! `bbit-mh` — the layer-3 coordinator CLI.
//!
//! Subcommands:
//!   gen-data     generate the rcv1-like corpus (optionally expanded) as LibSVM
//!   preprocess   stream a LibSVM file through the encoding pipeline
//!   train        train + evaluate on an encoded dataset
//!   classify     score raw documents (or a hashed cache) with a saved model
//!   serve        keep a saved model resident behind a micro-batched HTTP
//!                scoring endpoint with hot reload (the online request path);
//!                --similar-index also serves POST /similar near-neighbor
//!                queries from an LSH snapshot
//!   similar-index build a sharded LSH index snapshot from a hashed cache
//!   route        consistent-hash fleet router over shard serve backends
//!   experiments  regenerate a paper table/figure (or `all`)
//!   runtime-info check the PJRT artifacts load and run
//!
//! Every subcommand that hashes data takes `--encoder bbit|vw|rp|oph`
//! (legacy alias `--method`) plus that scheme's parameter flags; the flags
//! are parsed into an [`EncoderSpec`] and everything downstream — the
//! pipeline workers, the cache header, the saved model — is scheme-
//! agnostic from there.
//!
//! The argument parser is hand-rolled (the offline crate set has no clap);
//! flags are `--key value` or `--key=value`.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;
use std::process::ExitCode;

use bbit_mh::coordinator::pipeline::{Pipeline, PipelineConfig, PipelineOutput};
use bbit_mh::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use bbit_mh::coordinator::sink::{CacheSink, TrainSink};
use bbit_mh::data::expand::{expand_example, ExpandConfig};
use bbit_mh::data::gen::{CorpusConfig, CorpusGenerator};
use bbit_mh::data::libsvm::{
    parse_block, BlockReader, ChunkedReader, LibsvmReader, LibsvmWriter, ParsedChunk,
};
use bbit_mh::encode::cache::CacheReader;
use bbit_mh::encode::expansion::BbitDataset;
use bbit_mh::encode::EncoderSpec;
use bbit_mh::experiments::{self, Ctx, Scale};
use bbit_mh::solver::{FeatureMatrix, LinearModel, SgdConfig, SgdLoss};
use bbit_mh::{Error, Result};

const USAGE: &str = "\
bbit-mh — b-bit minwise hashing for large-scale linear learning
  (reproduction of Li, Shrivastava & König 2011; see README.md)

ENCODERS (--encoder, legacy alias --method):
  bbit   b-bit minwise hashing     [--b 8] [--k 200] [--dim 1073741824]
  vw     VW feature hashing        [--bins 1024]
  rp     sparse random projections [--proj 256] [--s 1.0]
  oph    one-permutation hashing   [--bins 1024] [--b 8]
  (bbit and oph emit packed codes — cacheable and streamable; vw and rp
   emit sparse rows)

RAW-INPUT PARSING (preprocess, train --input, classify --input):
  the byte-block parser is the default — the reader carves newline-aligned
  blocks ([--block-kb 256] sets the slab size) and the pipeline workers
  parse and encode in parallel; --legacy-reader falls back to the
  single-threaded line reader (kept for one release).

TELEMETRY:
  --trace-out FILE (preprocess, train, serve, route) streams structured
  JSONL spans — pipeline stages, epochs, request roots, admission waits,
  batch assembly, kernels, router legs — to FILE; trace ids propagate
  across the serve fleet via the X-Trace-Id header, so one grep over the
  fleet's trace files reconstructs a request's full path.
  --slow-ms N (serve, route) logs any request slower than N ms to stderr
  with its trace id.  --report-json FILE (preprocess, train --stream)
  dumps the machine-readable pipeline report.

DEVICE PREPROCESSING:
  --device xla (preprocess, train --stream) batches chunk hashing into the
  AOT-compiled PJRT minwise/VW kernels ([--artifacts artifacts] names the
  compiled-artifacts dir).  Encoded output — including the on-disk cache —
  is bit-identical to the CPU path; when the artifacts dir is missing, no
  artifact matches the spec, or the scheme has no device kernel, the run
  logs the reason and falls back to CPU hashing.

USAGE:
  bbit-mh gen-data --out FILE [--n 4000] [--vocab 4000] [--expanded] [--seed N]
  bbit-mh preprocess --input FILE (--out FILE | --cache-out FILE)
             [--encoder bbit|vw|rp|oph] [scheme flags] [--workers N] [--seed N]
             [--cache-compress] [--block-kb 256] [--legacy-reader]
             [--device cpu|xla] [--artifacts DIR]
             [--on-error fail|skip] [--quarantine FILE]
             [--sync-chunks 64] [--resume]
             [--trace-out FILE] [--report-json FILE]
             (--cache-out streams packed-code chunks to the on-disk hashed
              cache: hash once, train many times, constant memory; the v3
              cache carries a chunk index for parallel replay, and
              --cache-compress RLE-compresses record payloads.
              The cache write is crash-safe: records land in CACHE.tmp
              beside a resume journal fsynced every --sync-chunks chunks,
              and an atomic rename publishes the finished cache; after a
              crash, --resume salvages the validated prefix and restarts
              ingest at the journaled byte offset — the resumed cache is
              byte-identical to an uninterrupted run.
              --on-error skip parses past malformed LibSVM lines instead
              of failing fast, counting them in the summary/report;
              --quarantine FILE appends each skipped line's raw bytes)
  bbit-mh train --input FILE --solver svm|lr [--c 1.0] [--cv FOLDS]
             [--encoder bbit|vw|rp|oph|none] [scheme flags]
             [--train-frac 0.5] [--seed N] [--save-model FILE]
  bbit-mh train --cache FILE [--solver sgd|svm|lr] [--c 1.0] [--epochs 5]
             [--loss logistic|sqhinge] [--lr0 0.5] [--batch 256] [--lambda L]
             [--holdout FRAC] [--holdout-seed N] [--eval] [--save-model FILE]
             [--replay-threads N]
             [--checkpoint FILE] [--checkpoint-every 1] [--resume]
             (multi-epoch replay of a hashed cache; the cache header
              records the encoder spec; sgd streams in O(dim) memory;
              --holdout (sgd only) carves a deterministic FRAC held-out
              split during replay and reports held-out accuracy/loss;
              --eval adds a train-accuracy pass over the cache;
              --replay-threads N>1 fans replay across a reader pool —
              svm/lr materialize and --holdout decode in parallel with
              bit-identical results; plain sgd runs per-shard workers
              synchronized by iterate averaging at epoch boundaries;
              --checkpoint FILE (sgd, sequential replay) atomically
              snapshots weights + optimizer state every --checkpoint-every
              epochs — a checkpoint is a valid model file serve can
              hot-load — and --resume continues a crashed run to
              bit-identical final weights)
  bbit-mh train --input FILE --stream [--encoder bbit|oph] [scheme flags]
             [--loss logistic|sqhinge] [--lr0 0.5] [--batch 256] [--lambda 1e-4]
             [--seed N] [--save-model FILE] [--device cpu|xla] [--artifacts DIR]
             [--on-error fail|skip] [--quarantine FILE]
             [--trace-out FILE] [--report-json FILE]
             (one-pass hash-and-train: nothing materialized, prints progressive loss)
  bbit-mh classify --model FILE (--input FILE [--out FILE] [--block-kb 256]
             [--legacy-reader] [--chunk-size 256]
             | --cache FILE [--replay-threads N])
             (the model file embeds its encoder spec — any scheme classifies;
              --input streams raw LibSVM through the byte-block parser in
              constant memory (--chunk-size applies to --legacy-reader);
              --cache reports aggregate accuracy/loss, specs must match;
              --replay-threads shards cache scoring across a reader pool,
              results identical for every N)
  bbit-mh serve --model FILE [--host 127.0.0.1] [--port 0] [--workers N]
             [--batch-max 64] [--batch-wait-us 200] [--queue 1024]
             [--deadline-ms 50] [--reload-poll-ms 200] [--idle-timeout-s 10]
             [--similar-index FILE[,FILE...]] [--slow-ms N] [--trace-out FILE]
             [--drain-ms 5000]
             (micro-batched HTTP scoring: POST /score LibSVM lines,
              GET /metrics, GET /healthz; bounded queue sheds with 503;
              the model file is watched and hot-reloaded; port 0 picks an
              ephemeral port; Enter or EOF on stdin stops the server;
              SIGTERM drains gracefully — /healthz fails first so load
              balancers stop routing here, in-flight requests finish,
              bounded by --drain-ms;
              --similar-index loads one or more BBMHSIM1 shard snapshots
              and adds POST /similar: body `doc:<id>` or a LibSVM line,
              optional X-Top-K header, answers top-K neighbor ids with
              b-bit resemblance estimates)
  bbit-mh similar-index --cache FILE --out FILE [--shards 1] [--bands 16]
             [--rows 4] [--replay-threads N]
             (build the online LSH index out-of-core from a v3 hashed
              cache via the replay reader pool — deterministic for every
              --replay-threads; records shard by id % shards; one snapshot
              per shard is written to OUT.shard<i> when --shards > 1,
              plain OUT otherwise)
  bbit-mh route --backends HOST:PORT,HOST:PORT[,...] --shards N
             [--host 127.0.0.1] [--port 0] [--health-poll-ms 200]
             [--timeout-ms 2000] [--fail-threshold 2] [--max-backoff-ms 2000]
             [--idle-timeout-s 10] [--slow-ms N] [--trace-out FILE]
             (the fleet tier: consistent-hash shard placement over the
              backends, /healthz-driven per-backend health with backoff,
              POST /similar doc lookups routed to the owner shard and raw
              queries scatter-gathered with partial-result flagging,
              POST /score round-robined; Enter or EOF on stdin stops it)
  bbit-mh experiments ID [--scale tiny|small|paper] [--results DIR]
             (IDs: table1 fig1 fig3 fig5 fig6 fig7 fig8 table2 variance fig9 all)
  bbit-mh runtime-info [--artifacts DIR]
  bbit-mh help
";

/// Minimal flag parser: positional args then `--key value` / `--key=value`.
struct Args {
    positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args { positional, flags })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::InvalidArg(format!("bad --{key} value {v:?}"))),
        }
    }

    fn required(&self, key: &str) -> Result<&str> {
        self.flags
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::InvalidArg(format!("missing --{key}")))
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    // --trace-out arms the process-wide JSONL span sink before the command
    // runs, so even the earliest pipeline spans land in the file; only the
    // commands that emit spans accept it (a trace file that stays silently
    // empty would read as "nothing happened")
    if let Some(path) = args.flags.get("trace-out") {
        const TRACED: &[&str] = &["preprocess", "train", "serve", "route"];
        if !TRACED.contains(&cmd) {
            return Err(Error::InvalidArg(format!(
                "--trace-out applies to preprocess|train|serve|route, got {cmd:?}"
            )));
        }
        bbit_mh::metrics::trace::init_file(path)?;
    }
    let result = match cmd {
        "gen-data" => cmd_gen_data(&args),
        "preprocess" => cmd_preprocess(&args),
        "train" => cmd_train(&args),
        "classify" => cmd_classify(&args),
        "serve" => cmd_serve(&args),
        "similar-index" => cmd_similar_index(&args),
        "route" => cmd_route(&args),
        "experiments" => cmd_experiments(&args),
        "runtime-info" => cmd_runtime_info(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(Error::InvalidArg(format!("unknown command {other:?}; try help"))),
    };
    // drain every thread-local span buffer before exit — a trace file cut
    // off mid-request would fail downstream JSONL parsers
    if args.has("trace-out") {
        bbit_mh::metrics::trace::flush();
    }
    result
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let out = args.required("out")?;
    let n: usize = args.get("n", 4000)?;
    let vocab: u32 = args.get("vocab", 4000)?;
    let seed: u64 = args.get("seed", 0xB_B17)?;
    let expanded = args.has("expanded");
    let corpus = CorpusGenerator::new(CorpusConfig {
        n_docs: n,
        vocab,
        zipf_alpha: 1.05,
        mean_tokens: args.get("mean-tokens", 30.0)?,
        class_signal: 0.55,
        pos_fraction: 0.47,
        seed,
    })
    .generate();
    let mut writer = LibsvmWriter::create(out)?;
    if expanded {
        let cfg = ExpandConfig {
            vocab,
            dim: args.get("dim", 1u64 << 30)?,
            three_way_rate: 30,
            seed: seed ^ 0xEE,
        };
        cfg.validate()?;
        for ex in corpus.iter() {
            writer.write_example(&expand_example(&cfg, &ex))?;
        }
    } else {
        writer.write_dataset(&corpus)?;
    }
    writer.finish()?;
    let s = corpus.stats();
    eprintln!(
        "wrote {} docs (base nnz mean {:.1}{}) to {}",
        n,
        s.nnz_mean,
        if expanded { ", expanded" } else { "" },
        out
    );
    Ok(())
}

/// The `--encoder` scheme name (`--method` stays as the legacy alias).
fn scheme_flag(args: &Args, default: &str) -> Result<String> {
    if let Some(e) = args.flags.get("encoder") {
        return Ok(e.clone());
    }
    args.get("method", default.to_string())
}

/// Parse one scheme's parameter flags into an [`EncoderSpec`].
fn encoder_spec(args: &Args, scheme: &str, seed: u64) -> Result<EncoderSpec> {
    let spec = match scheme {
        "bbit" => EncoderSpec::Bbit {
            b: args.get("b", 8u32)?,
            k: args.get("k", 200usize)?,
            d: args.get("dim", 1u64 << 30)?,
            seed,
        },
        "vw" => EncoderSpec::Vw { bins: args.get("bins", 1024usize)?, seed },
        "rp" => EncoderSpec::Rp {
            proj: args.get("proj", 256usize)?,
            s: args.get("s", 1.0f64)?,
            seed,
        },
        "oph" => EncoderSpec::Oph {
            bins: args.get("bins", 1024usize)?,
            b: args.get("b", 8u32)?,
            seed,
        },
        other => {
            return Err(Error::InvalidArg(format!(
                "unknown encoder {other:?} (want bbit|vw|rp|oph)"
            )))
        }
    };
    spec.validate()?;
    Ok(spec)
}

/// Shared raw-input ingest flags: `--block-kb` slab size (byte-block
/// path) and the `--legacy-reader` fallback.
fn block_bytes_flag(args: &Args) -> Result<usize> {
    let kb: usize = args.get("block-kb", 256usize)?;
    if kb == 0 {
        return Err(Error::InvalidArg("--block-kb must be >= 1".into()));
    }
    Ok(kb << 10)
}

/// Parse `--device cpu|xla` (+ `--artifacts DIR`): `Some(dir)` selects the
/// device-batched encode path over the compiled artifacts in `dir`.
fn device_flag(args: &Args) -> Result<Option<std::path::PathBuf>> {
    match args.get("device", "cpu".to_string())?.as_str() {
        "cpu" => {
            // silently ignoring --artifacts would let users believe the
            // device path ran
            if args.has("artifacts") {
                return Err(Error::InvalidArg(
                    "--artifacts only applies with --device xla".into(),
                ));
            }
            Ok(None)
        }
        "xla" => {
            if args.has("legacy-reader") {
                return Err(Error::InvalidArg(
                    "--device xla batches parsed chunks on the byte-block path; \
                     drop --legacy-reader"
                        .into(),
                ));
            }
            Ok(Some(args.get("artifacts", "artifacts".to_string())?.into()))
        }
        other => Err(Error::InvalidArg(format!("unknown --device {other:?} (want cpu|xla)"))),
    }
}

/// Device-encode counters for the summaries — empty when no device
/// encoder drove the run.
fn device_summary(report: &bbit_mh::coordinator::PipelineReport) -> String {
    if report.device_chunks == 0 && report.device_fallbacks == 0 {
        return String::new();
    }
    format!(
        ", device {} chunks in {:.2}s ({} cpu-fallback)",
        report.device_chunks, report.encode_device_seconds, report.device_fallbacks,
    )
}

/// Ingest-side counters for the `preprocess`/`train --stream` summaries —
/// empty for the legacy reader path (where parsing is `read_seconds`).
fn ingest_summary(report: &bbit_mh::coordinator::PipelineReport) -> String {
    if report.input_bytes == 0 {
        return String::new();
    }
    format!(
        ", {:.1} MB in at {:.1} MB/s, {:.2}s parse-cpu ({:.0} rows/s)",
        report.input_bytes as f64 / 1e6,
        report.ingest_mb_per_sec(),
        report.parse_cpu_seconds,
        report.parse_rows_per_sec(),
    )
}

/// Skipped-line counter for the `preprocess`/`train --stream` summaries —
/// empty unless `--on-error skip` actually skipped something.
fn errors_summary(report: &bbit_mh::coordinator::PipelineReport) -> String {
    if report.parse_errors == 0 {
        return String::new();
    }
    format!(", {} malformed lines skipped", report.parse_errors)
}

/// `--report-json FILE`: persist the machine-readable [`PipelineReport`]
/// alongside the human summary — the hook the benchmark harness and any
/// dashboard scrape instead of parsing stderr.
fn write_report_json(
    args: &Args,
    report: &bbit_mh::coordinator::PipelineReport,
) -> Result<()> {
    if let Some(path) = args.flags.get("report-json") {
        let mut body = report.to_json();
        body.push('\n');
        std::fs::write(path, body)?;
        eprintln!("wrote pipeline report to {path}");
    }
    Ok(())
}

/// `--on-error fail|skip` (+ `--quarantine FILE`): the raw-ingest error
/// policy.  Returns whether malformed lines are skipped.  `--quarantine`
/// without skip would read as "errors recorded" while the run still
/// fails fast, and the legacy line reader has no lossy parse — both are
/// typed errors, checked before any IO.
fn ingest_error_flags(args: &Args) -> Result<bool> {
    let skip = match args.get("on-error", "fail".to_string())?.as_str() {
        "fail" => false,
        "skip" => true,
        other => {
            return Err(Error::InvalidArg(format!(
                "unknown --on-error {other:?} (want fail|skip)"
            )))
        }
    };
    if args.has("quarantine") && !skip {
        return Err(Error::InvalidArg(
            "--quarantine records skipped lines; it requires --on-error skip".into(),
        ));
    }
    if skip && args.has("legacy-reader") {
        return Err(Error::InvalidArg(
            "--on-error skip lives in the byte-block parser; drop --legacy-reader".into(),
        ));
    }
    Ok(skip)
}

/// Run `spec` over a raw LibSVM file into `sink`, choosing the default
/// byte-block parse-in-worker path or the legacy line reader
/// (`--legacy-reader`).
fn run_raw_input<S: bbit_mh::coordinator::PipelineSink>(
    args: &Args,
    pipe: &Pipeline,
    input: &str,
    spec: &EncoderSpec,
    sink: &mut S,
) -> Result<bbit_mh::coordinator::PipelineReport> {
    run_raw_input_at(args, pipe, input, spec, sink, None)
}

/// [`run_raw_input`] with an optional resume cursor: `Some((byte_offset,
/// next_line))` — the [`ResumePoint`](bbit_mh::encode::cache::ResumePoint)
/// a durable cache journaled — starts the block reader mid-file instead
/// of at byte 0.  Callers reject `--legacy-reader` before passing a
/// cursor (the line reader cannot seek).
fn run_raw_input_at<S: bbit_mh::coordinator::PipelineSink>(
    args: &Args,
    pipe: &Pipeline,
    input: &str,
    spec: &EncoderSpec,
    sink: &mut S,
    resume_at: Option<(u64, u64)>,
) -> Result<bbit_mh::coordinator::PipelineReport> {
    let device_dir = device_flag(args)?; // validate before IO
    let skip = ingest_error_flags(args)?; // validate before IO
    if args.has("legacy-reader") {
        let source = ChunkedReader::new(LibsvmReader::open(input)?.binary(), 256);
        return pipe.run_sink(source, spec, sink);
    }
    let block_bytes = block_bytes_flag(args)?; // validate before IO
    let blocks = match resume_at {
        Some((offset, line)) => BlockReader::open_at(input, offset, line as usize)?,
        None => BlockReader::open(input)?,
    }
    .with_block_bytes(block_bytes);
    // skipped lines land here raw, with their line number and parse error,
    // so a quarantine file is directly re-feedable after hand repair
    let mut qw = match args.flags.get("quarantine") {
        Some(p) => Some(std::io::BufWriter::new(std::fs::File::create(p)?)),
        None => None,
    };
    let mut on_bad = |b: &bbit_mh::data::libsvm::BadLine| -> Result<()> {
        if let Some(w) = qw.as_mut() {
            writeln!(w, "# line {}: {}", b.line, b.msg)?;
            w.write_all(&b.bytes)?;
            w.write_all(b"\n")?;
        }
        Ok(())
    };
    let ingest = bbit_mh::coordinator::pipeline::IngestOptions {
        skip_errors: skip,
        on_bad_line: Some(&mut on_bad),
    };
    let report = if let Some(dir) = device_dir {
        let encoder = bbit_mh::encode::DeviceEncoder::new(spec, &dir)?;
        pipe.run_encoder_blocks_opts(blocks, true, &encoder, sink, ingest)?
    } else {
        let encoder = spec.encoder()?;
        pipe.run_encoder_blocks_opts(blocks, true, encoder.as_ref(), sink, ingest)?
    };
    if let Some(mut w) = qw {
        w.flush()?;
    }
    Ok(report)
}

fn cmd_preprocess(args: &Args) -> Result<()> {
    let input = args.required("input")?;
    let scheme = scheme_flag(args, "bbit")?;
    let workers: usize = args.get("workers", bbit_mh::config::available_workers())?;
    let seed: u64 = args.get("seed", 1)?;
    let spec = encoder_spec(args, &scheme, seed)?;
    // durable-cache flags, validated before any IO: --resume restarts a
    // crashed --cache-out run at its journaled byte offset, --sync-chunks
    // sets how often the journal fsyncs (bounding replayed work)
    let resume = args.has("resume");
    let sync_chunks: usize =
        args.get("sync-chunks", bbit_mh::encode::cache::DEFAULT_SYNC_CHUNKS)?;
    if sync_chunks == 0 {
        return Err(Error::InvalidArg("--sync-chunks must be >= 1".into()));
    }
    if (resume || args.has("sync-chunks")) && !args.has("cache-out") {
        return Err(Error::InvalidArg(
            "--resume/--sync-chunks apply to the durable --cache-out path".into(),
        ));
    }
    if resume && args.has("legacy-reader") {
        return Err(Error::InvalidArg(
            "--resume restarts at a journaled byte offset; the legacy line reader \
             cannot seek — drop --legacy-reader"
                .into(),
        ));
    }
    if resume && device_flag(args)?.is_some() {
        return Err(Error::InvalidArg(
            "--resume with --device xla is untested; rerun the resumed pass with \
             --device cpu (output is bit-identical)"
                .into(),
        ));
    }
    let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 256, queue_depth: 4 });
    if let Some(cache_out) = args.flags.get("cache-out") {
        if spec.packed_geometry().is_none() {
            return Err(Error::InvalidArg(format!(
                "--cache-out stores packed codes; --encoder {scheme} emits sparse rows \
                 (use bbit or oph)"
            )));
        }
        // out-of-core path: chunks stream to disk as they are encoded;
        // memory stays bounded by the pipeline queues.  The write is
        // crash-safe: records land in <cache>.tmp beside a resume
        // journal, and finalize renames the tmp into place — a reader
        // never sees a partial cache under the destination name.
        let opts = bbit_mh::encode::cache::CacheWriteOptions {
            compress: args.has("cache-compress"),
        };
        let (mut sink, resume_at) = if resume {
            match CacheSink::resume_durable(cache_out, &spec, opts, sync_chunks)? {
                Some((sink, point)) => {
                    eprintln!(
                        "resuming {cache_out}: {} records ({} rows) salvaged; \
                         restarting input at byte {} (line {})",
                        point.records, point.rows, point.input_offset, point.next_line,
                    );
                    (sink, Some((point.input_offset, point.next_line)))
                }
                None if Path::new(cache_out).exists() => {
                    // no tmp/journal but the destination is there: the
                    // crashed run actually finished its rename
                    eprintln!("{cache_out} is already finalized; nothing to resume");
                    return Ok(());
                }
                None => {
                    eprintln!("no partial cache for {cache_out}; starting fresh");
                    (CacheSink::create_durable(cache_out, &spec, opts, sync_chunks)?, None)
                }
            }
        } else {
            (CacheSink::create_durable(cache_out, &spec, opts, sync_chunks)?, None)
        };
        let report = run_raw_input_at(args, &pipe, input, &spec, &mut sink, resume_at)?;
        write_report_json(args, &report)?;
        let bytes = if opts.compress {
            let m = sink.meta();
            format!(
                ", payload {} -> {} bytes ({:.1}% of raw)",
                m.raw_bytes,
                m.stored_bytes,
                100.0 * m.stored_bytes as f64 / m.raw_bytes.max(1) as f64,
            )
        } else {
            String::new()
        };
        eprintln!(
            "{scheme}-encoded {} docs in {:.2}s wall ({:.2}s read + {:.2}s stalled, \
             {:.2} hash-cpu-s, {:.2}s cache write, reorder peak {} chunks{}{}{}{}) -> {}",
            report.docs,
            report.wall_seconds,
            report.read_seconds,
            report.stall_seconds,
            report.hash_cpu_seconds,
            report.sink_seconds,
            report.reorder_peak,
            ingest_summary(&report),
            device_summary(&report),
            errors_summary(&report),
            bytes,
            cache_out,
        );
        return Ok(());
    }
    let out = args.required("out")?;
    let mut collect = bbit_mh::coordinator::CollectSink::for_spec(&spec)?;
    let report = run_raw_input(args, &pipe, input, &spec, &mut collect)?;
    write_report_json(args, &report)?;
    let outp = collect.into_output();
    match outp {
        PipelineOutput::Packed(bb) => {
            let f = std::fs::File::create(out)?;
            bb.codes.save(std::io::BufWriter::new(f))?;
            // labels ride alongside
            std::fs::write(
                format!("{out}.labels"),
                bb.labels
                    .iter()
                    .map(|l| l.to_string())
                    .collect::<Vec<_>>()
                    .join("\n"),
            )?;
            eprintln!(
                "{scheme}-encoded {} docs in {:.2}s wall ({:.2}s read, {:.2} hash-cpu-s, \
                 {} stalls{}{}{}) -> {} ({} ideal bytes)",
                report.docs,
                report.wall_seconds,
                report.read_seconds,
                report.hash_cpu_seconds,
                report.backpressure_stalls,
                ingest_summary(&report),
                device_summary(&report),
                errors_summary(&report),
                out,
                bb.codes.ideal_bytes(),
            );
        }
        PipelineOutput::Sparse(ds) => {
            let mut w = LibsvmWriter::create(out)?;
            w.write_dataset(&ds)?;
            w.finish()?;
            eprintln!(
                "{scheme}-encoded {} docs in {:.2}s wall{}{}{} -> {out}",
                report.docs,
                report.wall_seconds,
                ingest_summary(&report),
                device_summary(&report),
                errors_summary(&report),
            );
        }
    }
    Ok(())
}

/// Parse the `--loss` flag for the streaming SGD paths.
fn sgd_loss_flag(args: &Args) -> Result<SgdLoss> {
    match args.get("loss", "logistic".to_string())?.as_str() {
        "logistic" => Ok(SgdLoss::Logistic),
        "sqhinge" | "hinge" => Ok(SgdLoss::SquaredHinge),
        other => Err(Error::InvalidArg(format!("unknown loss {other:?}"))),
    }
}

/// Parse + validate `--replay-threads` (1 = the sequential replay path,
/// which stays bit-for-bit identical to the pre-pool behavior).
fn replay_threads_flag(args: &Args) -> Result<usize> {
    let threads: usize = args.get("replay-threads", 1usize)?;
    if threads == 0 {
        return Err(Error::InvalidArg(
            "--replay-threads must be >= 1 (1 = sequential replay)".into(),
        ));
    }
    Ok(threads)
}

/// Streaming accuracy of `model` over a hashed cache (one sequential pass
/// through reusable scratch buffers — nothing allocated per record).
fn cache_accuracy(path: &str, model: &LinearModel) -> Result<f64> {
    let mut reader = CacheReader::open(path)?;
    let meta = reader.meta();
    let (b, k) = meta.spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!("cache scheme {} is not packed", meta.spec.scheme()))
    })?;
    // the dataset doubles as the reusable scratch (its fields are the
    // decode buffers), so the loop allocates nothing per record
    let mut ds = BbitDataset::new(bbit_mh::encode::PackedCodes::new(b, k), Vec::new());
    let (mut n, mut correct) = (0u64, 0u64);
    while reader.next_chunk_into(&mut ds.codes, &mut ds.labels)? {
        for i in 0..ds.len() {
            n += 1;
            if model.predict(&ds, i) == ds.labels[i] {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / n.max(1) as f64)
}

/// `train --cache FILE`: replay an on-disk hashed cache — the "hash once,
/// train many times" half of the out-of-core workflow.  The cache header
/// records the encoder spec, so the trained model carries it too.
fn cmd_train_cache(args: &Args, cache: &str) -> Result<()> {
    let solver = args.get("solver", "sgd".to_string())?;
    // the held-out split lives in the streaming replay path; silently
    // training the batch solvers on all rows would report train-set
    // numbers the user believes are validated
    if args.has("holdout") && solver != "sgd" {
        return Err(Error::InvalidArg(format!(
            "--holdout is only implemented for --solver sgd (cache replay), got --solver {solver}"
        )));
    }
    let c: f64 = args.get("c", 1.0)?;
    let replay_threads = replay_threads_flag(args)?;
    // crash-safe training: --checkpoint PATH snapshots weights + optimizer
    // state atomically every --checkpoint-every epochs; --resume continues
    // a crashed run to bit-identical final weights.  Validated before the
    // cache is opened so misuse fails fast and typed.
    let checkpoint = args.flags.get("checkpoint");
    if checkpoint.is_none() && (args.has("checkpoint-every") || args.has("resume")) {
        return Err(Error::InvalidArg(
            "--checkpoint-every/--resume ride on --checkpoint PATH".into(),
        ));
    }
    let checkpoint_every: usize = args.get("checkpoint-every", 1usize)?;
    if checkpoint.is_some() {
        if solver != "sgd" {
            return Err(Error::InvalidArg(format!(
                "--checkpoint snapshots streaming SGD state between epochs; \
                 --solver {solver} trains in one batch"
            )));
        }
        if args.has("holdout") {
            return Err(Error::InvalidArg(
                "--checkpoint with --holdout is not supported: the holdout replay \
                 carries split state the checkpoint format does not"
                    .into(),
            ));
        }
        if replay_threads != 1 {
            return Err(Error::InvalidArg(
                "--checkpoint requires --replay-threads 1: iterate-averaged shards \
                 have per-worker state the checkpoint format does not carry"
                    .into(),
            ));
        }
        if checkpoint_every == 0 {
            return Err(Error::InvalidArg(
                "--checkpoint-every must be >= 1 (epochs between snapshots)".into(),
            ));
        }
    }
    let meta = CacheReader::open(cache)?.meta();
    eprintln!("cache {cache}: {} docs, encoder {:?}", meta.n, meta.spec);
    let model = match solver.as_str() {
        "sgd" => {
            let cfg = SgdConfig {
                loss: sgd_loss_flag(args)?,
                lr0: args.get("lr0", 0.5)?,
                lambda: args.get(
                    "lambda",
                    bbit_mh::solver::sgd::lambda_from_c(c, meta.n as usize),
                )?,
                epochs: args.get("epochs", 5usize)?,
                batch: args.get("batch", 256usize)?,
            };
            // --holdout FRAC: exclude a deterministic split from every
            // epoch and report generalization on it (one extra cache pass).
            // With --replay-threads N the holdout path decodes through the
            // in-order reader pool (bit-identical results), while plain
            // sgd runs per-shard workers + iterate averaging.
            let (model, stats, held) = match args.flags.get("holdout") {
                Some(v) => {
                    let frac: f64 = v.parse().map_err(|_| {
                        Error::InvalidArg(format!("bad --holdout value {v:?}"))
                    })?;
                    let salt: u64 = args.get("holdout-seed", 0x4001D)?;
                    let (m, s, h) = bbit_mh::solver::train_from_cache_holdout_threads(
                        cache, &cfg, frac, salt, replay_threads,
                    )?;
                    (m, s, Some(h))
                }
                None => {
                    let (m, s) = match checkpoint {
                        Some(ck) => bbit_mh::solver::train_from_cache_checkpointed(
                            cache,
                            &cfg,
                            Path::new(ck),
                            checkpoint_every,
                            args.has("resume"),
                        )?,
                        None => bbit_mh::solver::train_from_cache_threads(
                            cache, &cfg, replay_threads,
                        )?,
                    };
                    (m, s, None)
                }
            };
            // the accuracy pass re-reads the whole cache — opt-in so the
            // model-search loop pays epochs reads, not epochs + 1
            let acc = if args.has("eval") {
                format!(", train acc {:.3}%", 100.0 * cache_accuracy(cache, &model)?)
            } else {
                String::new()
            };
            let held = match held {
                Some(h) => format!(
                    ", held-out acc {:.3}% / loss {:.4} ({} of {} rows held out)",
                    100.0 * h.accuracy,
                    h.mean_loss,
                    h.holdout_rows,
                    h.holdout_rows + h.train_rows,
                ),
                None => String::new(),
            };
            println!(
                "solver=sgd method=cache epochs={}: progressive loss {:.4}{}{}, {:.3}s",
                stats.iterations, stats.objective, acc, held, stats.train_seconds,
            );
            model
        }
        "svm" | "lr" => {
            // batch solvers need random access: materialize (fanned across
            // the reader pool when --replay-threads > 1 — output identical
            // to the sequential read), then train at the requested C
            let ds = bbit_mh::coordinator::materialize_cache(cache, replay_threads)?;
            let (model, stats) = match solver.as_str() {
                "svm" => bbit_mh::solver::train_svm(&ds, &bbit_mh::solver::SvmConfig::with_c(c)),
                _ => bbit_mh::solver::train_lr(&ds, &bbit_mh::solver::LrConfig::with_c(c)),
            };
            let acc = bbit_mh::solver::accuracy(&model, &ds);
            println!(
                "solver={solver} method=cache C={c}: train acc {:.3}%, {:.3}s, {} iters{}",
                100.0 * acc,
                stats.train_seconds,
                stats.iterations,
                if stats.converged { "" } else { " (hit iteration cap)" },
            );
            model
        }
        other => return Err(Error::InvalidArg(format!("unknown solver {other:?}"))),
    };
    if let Some(model_path) = args.flags.get("save-model") {
        let saved = bbit_mh::solver::SavedModel::new(meta.spec, model)?;
        saved.save(model_path)?;
        eprintln!("saved model to {model_path}");
    }
    Ok(())
}

/// `train --input FILE --stream`: one-pass hash-and-train.  Nothing is
/// materialized — parsed chunks flow through the encode workers straight
/// into the streaming SGD update.  Any packed-code encoder works
/// (`--encoder bbit|oph`).
fn cmd_train_stream(args: &Args) -> Result<()> {
    let input = args.required("input")?;
    let seed: u64 = args.get("seed", 1)?;
    let scheme = scheme_flag(args, "bbit")?;
    let spec = encoder_spec(args, &scheme, seed)?;
    let cfg = SgdConfig {
        loss: sgd_loss_flag(args)?,
        lr0: args.get("lr0", 0.5)?,
        // n is unknown until the stream ends, so λ cannot be derived from
        // C here — take it directly
        lambda: args.get("lambda", 1e-4)?,
        epochs: 1,
        batch: args.get("batch", 256usize)?,
    };
    let workers: usize = args.get("workers", bbit_mh::config::available_workers())?;
    let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 256, queue_depth: 4 });
    let mut sink = TrainSink::for_spec(cfg, &spec)?;
    let report = run_raw_input(args, &pipe, input, &spec, &mut sink)?;
    write_report_json(args, &report)?;
    let (model, stats) = sink.into_result();
    println!(
        "solver=sgd method=stream: one-pass trained on {} docs, progressive loss {:.4}, \
         {:.2}s wall ({:.2}s read + {:.2}s stalled, {:.2} hash-cpu-s, {:.2}s solver, \
         reorder peak {} chunks{}{}{})",
        report.docs,
        stats.objective,
        report.wall_seconds,
        report.read_seconds,
        report.stall_seconds,
        report.hash_cpu_seconds,
        report.sink_seconds,
        report.reorder_peak,
        ingest_summary(&report),
        device_summary(&report),
        errors_summary(&report),
    );
    if let Some(model_path) = args.flags.get("save-model") {
        let saved = bbit_mh::solver::SavedModel::new(spec, model)?;
        saved.save(model_path)?;
        eprintln!("saved model to {model_path}");
    }
    Ok(())
}

/// Fit one explicit model at C on the training half and persist it with
/// its encoder spec — shared by every `train --save-model` scheme path.
fn fit_and_save<F: FeatureMatrix>(
    kind: SolverKind,
    c: f64,
    tr: &F,
    spec: EncoderSpec,
    model_path: &str,
) -> Result<()> {
    let model = match kind {
        SolverKind::SvmDcd => {
            bbit_mh::solver::train_svm(tr, &bbit_mh::solver::SvmConfig::with_c(c)).0
        }
        SolverKind::LrNewton => {
            bbit_mh::solver::train_lr(tr, &bbit_mh::solver::LrConfig::with_c(c)).0
        }
    };
    let saved = bbit_mh::solver::SavedModel::new(spec, model)?;
    saved.save(model_path)?;
    eprintln!("saved model to {model_path}");
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    // the pipeline report exists only where the ingest pipeline runs —
    // silently ignoring the flag would leave a stale or missing file that
    // the harness would read as this run's numbers
    if args.has("report-json") && !args.has("stream") {
        return Err(Error::InvalidArg(
            "--report-json applies to preprocess and train --stream (the ingest \
             pipeline paths); cache replay and the in-memory split have no \
             pipeline report"
                .into(),
        ));
    }
    // device-batched hashing lives in the ingest pipeline's encode workers;
    // cache replay and the in-memory split never touch that stage, so
    // accepting the flag there would silently run on CPU
    if (args.has("device") || args.has("artifacts")) && !args.has("stream") {
        return Err(Error::InvalidArg(
            "--device/--artifacts apply to preprocess and train --stream (the \
             ingest pipeline encode paths); cache replay and the in-memory \
             split encode on the CPU"
                .into(),
        ));
    }
    // the skip/quarantine policy lives in the byte-block ingest pipeline;
    // cache replay and the in-memory split read already-validated bytes
    if (args.has("on-error") || args.has("quarantine")) && !args.has("stream") {
        return Err(Error::InvalidArg(
            "--on-error/--quarantine apply to preprocess and train --stream \
             (the raw-ingest pipeline paths)"
                .into(),
        ));
    }
    if let Some(cache) = args.flags.get("cache") {
        return cmd_train_cache(args, cache.as_str());
    }
    // epoch checkpoints ride the streaming cache replay — every other
    // train path rejects the flags rather than silently not snapshotting
    if args.has("checkpoint") || args.has("checkpoint-every") || args.has("resume") {
        return Err(Error::InvalidArg(
            "--checkpoint/--checkpoint-every/--resume apply to train --cache \
             (streaming cache replay)"
                .into(),
        ));
    }
    // the held-out split is carved during cache replay; the one-pass
    // stream and the in-memory paths have their own eval story
    // (progressive loss / --train-frac) — ignoring the flag would report
    // numbers the user believes are validated
    if args.has("holdout") {
        return Err(Error::InvalidArg(
            "--holdout applies to train --cache (use --train-frac for the in-memory \
             split, progressive loss for --stream)"
                .into(),
        ));
    }
    // the reader pool replays the on-disk cache; the other train paths
    // have no cache to fan out over — silently ignoring the flag would let
    // users believe they ran the parallel path
    if args.has("replay-threads") {
        return Err(Error::InvalidArg(
            "--replay-threads applies to train --cache (cache replay); the --input \
             paths hash with --workers instead"
                .into(),
        ));
    }
    if args.has("stream") {
        return cmd_train_stream(args);
    }
    let input = args.required("input")?;
    let solver = args.get("solver", "svm".to_string())?;
    let c: f64 = args.get("c", 1.0)?;
    let seed: u64 = args.get("seed", 3)?;
    let train_frac: f64 = args.get("train-frac", 0.5)?;
    let scheme = scheme_flag(args, "bbit")?;

    let dim: u64 = args.get("dim", 1u64 << 30)?;
    // byte-block parser by default (honoring --block-kb); --legacy-reader
    // keeps the line reader (conformance-tested to load identically)
    let raw = if args.has("legacy-reader") {
        let mut ds = bbit_mh::data::SparseDataset::new(dim);
        for ex in LibsvmReader::open(input)? {
            ds.push(&ex?);
        }
        ds.validate()?;
        ds
    } else {
        let block_bytes = block_bytes_flag(args)?; // validate before IO
        bbit_mh::data::libsvm::load_with_block_bytes(input, dim, block_bytes)?
    };
    let (train_raw, test_raw) = raw.split(train_frac, &mut bbit_mh::util::Rng::new(seed));
    eprintln!(
        "loaded {} examples ({} train / {} test)",
        raw.len(),
        train_raw.len(),
        test_raw.len()
    );

    let kind = match solver.as_str() {
        "svm" => SolverKind::SvmDcd,
        "lr" => SolverKind::LrNewton,
        other => return Err(Error::InvalidArg(format!("unknown solver {other:?}"))),
    };
    let job = vec![TrainJob { tag: String::new(), solver: kind, c }];
    let cv_folds: usize = args.get("cv", 0)?;
    if scheme == "none" {
        let outcome = Scheduler::new(1).run_grid(&train_raw, &test_raw, &job)?;
        return print_outcome(&solver, &scheme, c, &outcome[0]);
    }
    // the legacy per-scheme seed transforms are preserved so pre-redesign
    // runs reproduce byte-for-byte (bbit: ^0x4A5E, vw: ^0x77)
    let spec = encoder_spec(
        args,
        &scheme,
        match scheme.as_str() {
            "bbit" | "oph" => seed ^ 0x4A5E,
            _ => seed ^ 0x77,
        },
    )?;
    let pipe = Pipeline::new(PipelineConfig::default());
    let (tr, _) = pipe.run(
        bbit_mh::coordinator::pipeline::dataset_chunks(&train_raw, 256),
        &spec,
    )?;
    let (te, _) = pipe.run(
        bbit_mh::coordinator::pipeline::dataset_chunks(&test_raw, 256),
        &spec,
    )?;
    let outcome = match (tr, te) {
        (PipelineOutput::Packed(tr), PipelineOutput::Packed(te)) => {
            if let Some(model_path) = args.flags.get("save-model") {
                // fit on the train half at the requested C, persist the
                // model + encoder spec for `classify`
                fit_and_save(kind, c, &tr, spec, model_path)?;
            }
            if cv_folds >= 2 {
                // C selection by k-fold CV on the hashed training half —
                // the paper's "many C values on one preprocessing pass"
                let report = bbit_mh::solver::cross_validate(
                    &tr,
                    kind,
                    &bbit_mh::coordinator::scheduler::paper_c_grid(),
                    cv_folds,
                    seed,
                    bbit_mh::config::available_workers(),
                )?;
                for p in &report.points {
                    eprintln!(
                        "  cv C={:<8} acc {:.3}% ± {:.3}",
                        p.c,
                        100.0 * p.mean_accuracy,
                        100.0 * p.std_accuracy
                    );
                }
                eprintln!("cv selected C = {}", report.best_c);
                let job =
                    vec![TrainJob { tag: String::new(), solver: kind, c: report.best_c }];
                return print_outcome(
                    &solver,
                    &scheme,
                    report.best_c,
                    &Scheduler::new(1).run_grid(&tr, &te, &job)?[0],
                );
            }
            Scheduler::new(1).run_grid(&tr, &te, &job)?
        }
        (PipelineOutput::Sparse(tr), PipelineOutput::Sparse(te)) => {
            if let Some(model_path) = args.flags.get("save-model") {
                fit_and_save(kind, c, &tr, spec, model_path)?;
            }
            Scheduler::new(1).run_grid(&tr, &te, &job)?
        }
        _ => unreachable!("one spec always produces one output kind"),
    };
    print_outcome(&solver, &scheme, c, &outcome[0])
}

fn print_outcome(
    solver: &str,
    method: &str,
    c: f64,
    o: &bbit_mh::coordinator::scheduler::TrainOutcome,
) -> Result<()> {
    println!(
        "solver={solver} method={method} C={c}: test acc {:.3}% (train {:.3}%), {:.3}s, {} iters{}",
        100.0 * o.test_accuracy,
        100.0 * o.train_accuracy,
        o.train_seconds,
        o.iterations,
        if o.converged { "" } else { " (hit iteration cap)" },
    );
    Ok(())
}

/// Score raw LibSVM documents (or a hashed cache) with a saved model —
/// the batch form of the request path: parse → encode (whatever scheme
/// the model's spec records) → margin, no python, no retraining.  The
/// encoder is drawn once at model load; raw input streams through the
/// chunked LibSVM reader in constant memory, like `preprocess`.  For the
/// resident, online form of this path see `serve`.
fn cmd_classify(args: &Args) -> Result<()> {
    let model_path = args.required("model")?;
    // flag validation before any IO, so misuse fails fast and typed
    if args.has("cache") && args.has("out") {
        return Err(Error::InvalidArg(
            "--out writes per-document predictions and applies to --input; \
             --cache reports aggregate accuracy/loss only"
                .into(),
        ));
    }
    let chunk_size: usize = args.get("chunk-size", 256)?;
    if chunk_size == 0 {
        return Err(Error::InvalidArg("--chunk-size must be >= 1".into()));
    }
    let block_bytes = block_bytes_flag(args)?;
    if args.has("replay-threads") && !args.has("cache") {
        return Err(Error::InvalidArg(
            "--replay-threads applies to classify --cache (cache replay); raw --input \
             already streams in chunks"
                .into(),
        ));
    }
    let replay_threads = replay_threads_flag(args)?;
    let saved = bbit_mh::solver::SavedModel::load(model_path)?;
    if let Some(cache) = args.flags.get("cache") {
        // pre-hashed input: stream the cache through the final weights —
        // sharded across the reader pool when --replay-threads > 1, with
        // results identical for every thread count.  A cache whose header
        // spec differs from the model's is a typed error (codes from one
        // hash family mean nothing under another's weights — and a dim
        // mismatch would index out of bounds).
        let eval = bbit_mh::solver::eval_from_cache_threads(
            cache,
            &saved,
            sgd_loss_flag(args)?,
            replay_threads,
        )?;
        println!(
            "classified {} cached rows: accuracy {:.3}%, mean loss {:.4}",
            eval.rows,
            100.0 * eval.accuracy,
            eval.mean_loss,
        );
        return Ok(());
    }
    let input = args.required("input")?;
    let mut scratch = saved.scratch();
    let mut out: Box<dyn std::io::Write> = match args.flags.get("out") {
        Some(p) => Box::new(std::io::BufWriter::new(std::fs::File::create(p)?)),
        None => Box::new(std::io::BufWriter::new(std::io::stdout())),
    };
    let (mut n, mut correct) = (0usize, 0usize);
    let mut score = |indices: &[u32], label: i8, out: &mut dyn std::io::Write| -> Result<()> {
        let margin = saved.margin(indices, &mut scratch);
        let pred: i8 = if margin >= 0.0 { 1 } else { -1 };
        writeln!(out, "{pred} {margin:.6}")?;
        n += 1;
        if pred == label {
            correct += 1;
        }
        Ok(())
    };
    let t0 = std::time::Instant::now();
    if args.has("legacy-reader") {
        for chunk in ChunkedReader::new(LibsvmReader::open(input)?.binary(), chunk_size) {
            for ex in &chunk? {
                score(&ex.indices, ex.label, &mut out)?;
            }
        }
    } else {
        // byte-block fast path: parse each slab into reused scratch and
        // margin the rows straight off the CSR views — no per-document
        // allocation anywhere on the scoring loop
        let mut parsed = ParsedChunk::default();
        for block in BlockReader::open(input)?.with_block_bytes(block_bytes) {
            let block = block?;
            parsed.clear();
            parse_block(&block.bytes, block.first_line, true, &mut parsed)?;
            for (label, indices, _) in parsed.rows() {
                score(indices, label, &mut out)?;
            }
        }
    }
    out.flush()?;
    let secs = t0.elapsed().as_secs_f64();
    eprintln!(
        "classified {n} docs in {secs:.3}s ({:.0} docs/s); accuracy vs file labels: {:.3}%",
        n as f64 / secs.max(1e-9),
        100.0 * correct as f64 / n.max(1) as f64
    );
    Ok(())
}

/// `--slow-ms N` (serve, route): absent means no slow-request log; 0 is
/// valid and logs every request (the firehose debugging mode).
fn slow_ms_flag(args: &Args) -> Result<Option<u64>> {
    match args.flags.get("slow-ms") {
        None => Ok(None),
        Some(v) => Ok(Some(v.parse().map_err(|_| {
            Error::InvalidArg(format!("bad --slow-ms value {v:?}"))
        })?)),
    }
}

/// `serve --model FILE`: the online request path — load the model once,
/// keep it resident behind the micro-batched HTTP scoring endpoint
/// ([`bbit_mh::serve`]), hot-reload it when the file changes, and print
/// the metrics report on shutdown (Enter / EOF on stdin).
fn cmd_serve(args: &Args) -> Result<()> {
    use std::time::Duration;
    let model = args.required("model")?;
    let cfg = bbit_mh::serve::ServeConfig {
        host: args.get("host", "127.0.0.1".to_string())?,
        port: args.get("port", 0u16)?,
        scorer_workers: args.get("workers", bbit_mh::config::available_workers())?,
        batch_max: args.get("batch-max", 64usize)?,
        batch_wait: Duration::from_micros(args.get("batch-wait-us", 200u64)?),
        queue_cap: args.get("queue", 1024usize)?,
        deadline: Duration::from_millis(args.get("deadline-ms", 50u64)?),
        reload_poll: Duration::from_millis(args.get("reload-poll-ms", 200u64)?),
        idle_timeout: Duration::from_secs(args.get("idle-timeout-s", 10u64)?),
        slow_ms: slow_ms_flag(args)?,
    };
    let drain_ms: u64 = args.get("drain-ms", 5000u64)?;
    let similar = match args.flags.get("similar-index") {
        None => None,
        Some(list) => {
            let paths: Vec<&str> =
                list.split(',').map(str::trim).filter(|p| !p.is_empty()).collect();
            if paths.is_empty() {
                return Err(Error::InvalidArg(
                    "--similar-index needs at least one snapshot path".into(),
                ));
            }
            let idx = bbit_mh::similarity::snapshot::load_many(&paths)?;
            eprintln!(
                "similarity index: {} rows across shards {:?} of {} ({} signature bytes)",
                idx.rows(),
                idx.shard_ids(),
                idx.num_shards(),
                idx.storage_bytes(),
            );
            Some(std::sync::Arc::new(idx))
        }
    };
    let routes = if similar.is_some() {
        "POST /score, POST /similar, GET /metrics, GET /healthz"
    } else {
        "POST /score, GET /metrics, GET /healthz"
    };
    // arm the SIGTERM flag before the listener exists so a signal racing
    // startup is never lost
    bbit_mh::util::signal::install_sigterm_handler();
    let server = bbit_mh::serve::ModelServer::start_with_index(model, cfg, similar)?;
    eprintln!(
        "serving {model} at http://{} ({routes}); \
         watching the model file for hot reload; press Enter (or close stdin) to \
         stop, SIGTERM to drain (fails /healthz, finishes in-flight work, \
         bounded by --drain-ms)",
        server.local_addr(),
    );
    // stdin blocks, so it gets its own thread; the main loop multiplexes
    // "operator pressed Enter" against "the platform sent SIGTERM"
    let (stdin_tx, stdin_rx) = std::sync::mpsc::channel::<()>();
    std::thread::spawn(move || {
        let mut line = String::new();
        let _ = std::io::stdin().read_line(&mut line);
        let _ = stdin_tx.send(());
    });
    let report = loop {
        if bbit_mh::util::signal::term_requested() {
            eprintln!("SIGTERM: draining (bound {drain_ms} ms)");
            break server.drain(Duration::from_millis(drain_ms));
        }
        match stdin_rx.recv_timeout(Duration::from_millis(100)) {
            Ok(()) | Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                break server.shutdown()
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
        }
    };
    eprintln!("--- shutdown report ---");
    eprint!("{report}");
    Ok(())
}

/// `similar-index --cache c --out idx`: build the online LSH index from a
/// hashed cache through the replay reader pool and snapshot it (one file,
/// or one per shard when `--shards > 1` — the fleet layout).
fn cmd_similar_index(args: &Args) -> Result<()> {
    use bbit_mh::hashing::lsh::LshConfig;
    use bbit_mh::similarity::{snapshot, LshIndex};
    let cache = args.required("cache")?;
    let out = args.required("out")?;
    let shards: usize = args.get("shards", 1usize)?;
    if shards == 0 {
        return Err(Error::InvalidArg("--shards must be >= 1".into()));
    }
    let cfg = LshConfig {
        bands: args.get("bands", 16usize)?,
        rows_per_band: args.get("rows", 4usize)?,
    };
    if cfg.bands == 0 || cfg.rows_per_band == 0 {
        return Err(Error::InvalidArg("--bands and --rows must be >= 1".into()));
    }
    let threads = replay_threads_flag(args)?;
    let t0 = std::time::Instant::now();
    let idx = LshIndex::build_from_cache(cache, cfg, shards, threads)?;
    eprintln!(
        "indexed {} rows into {} shards (bands {} x rows {}, threshold {:.3}) in {:.2}s",
        idx.rows(),
        shards,
        cfg.bands,
        cfg.rows_per_band,
        cfg.threshold(),
        t0.elapsed().as_secs_f64(),
    );
    for s in idx.band_stats() {
        eprintln!(
            "  band {:>3}: {} buckets, max {} mean {:.2}",
            s.band, s.buckets, s.max_bucket, s.mean_bucket
        );
    }
    if shards == 1 {
        snapshot::save(&idx, out)?;
        eprintln!("wrote {out}");
    } else {
        for s in idx.shard_ids() {
            let path = format!("{out}.shard{s}");
            snapshot::save_shard(&idx, s, &path)?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

/// `route --backends h:p,h:p --shards N`: the consistent-hash fleet tier
/// ([`bbit_mh::serve::router`]); blocks on stdin like `serve`.
fn cmd_route(args: &Args) -> Result<()> {
    use std::time::Duration;
    let backends: Vec<String> = args
        .required("backends")?
        .split(',')
        .map(str::trim)
        .filter(|b| !b.is_empty())
        .map(str::to_string)
        .collect();
    if backends.is_empty() {
        return Err(Error::InvalidArg("--backends must list at least one host:port".into()));
    }
    let shards: usize = args.get("shards", backends.len())?;
    if shards == 0 {
        return Err(Error::InvalidArg("--shards must be >= 1".into()));
    }
    let cfg = bbit_mh::serve::RouterConfig {
        host: args.get("host", "127.0.0.1".to_string())?,
        port: args.get("port", 0u16)?,
        backends,
        shards,
        health_poll: Duration::from_millis(args.get("health-poll-ms", 200u64)?),
        health_timeout: Duration::from_millis(args.get("timeout-ms", 2000u64)?),
        fail_threshold: args.get("fail-threshold", 2u32)?,
        max_backoff: Duration::from_millis(args.get("max-backoff-ms", 2000u64)?),
        idle_timeout: Duration::from_secs(args.get("idle-timeout-s", 10u64)?),
        slow_ms: slow_ms_flag(args)?,
    };
    let router = bbit_mh::serve::Router::start(cfg)?;
    eprintln!(
        "routing at http://{} (POST /similar, POST /score, GET /metrics, GET /healthz)",
        router.local_addr(),
    );
    for (s, b) in router.assignment().iter().enumerate() {
        eprintln!("  shard {s} -> backend {b}");
    }
    eprintln!("press Enter (or close stdin) to stop");
    let mut line = String::new();
    let _ = std::io::stdin().read_line(&mut line);
    eprintln!("--- shutdown report ---");
    eprint!("{}", router.shutdown());
    Ok(())
}

fn cmd_experiments(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let mut scale = match args.get("scale", "small".to_string())?.as_str() {
        "tiny" => Scale::tiny(),
        "small" => Scale::small(),
        "paper" => Scale::paper(),
        other => return Err(Error::InvalidArg(format!("unknown scale {other:?}"))),
    };
    if let Some(dir) = args.flags.get("results") {
        scale.results_dir = dir.clone();
    }
    scale.seed = args.get("seed", scale.seed)?;
    let mut ctx = Ctx::new(scale);
    let t0 = std::time::Instant::now();
    if id == "all" {
        experiments::run_all(&mut ctx)?;
    } else {
        experiments::run(&id, &mut ctx)?;
    }
    eprintln!("experiments '{id}' finished in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&argv(&["fig1", "--scale", "tiny", "--seed=42", "--expanded"]))
            .unwrap();
        assert_eq!(a.positional, vec!["fig1"]);
        assert_eq!(a.get::<String>("scale", "x".into()).unwrap(), "tiny");
        assert_eq!(a.get::<u64>("seed", 0).unwrap(), 42);
        assert!(a.has("expanded"));
        assert_eq!(a.get::<usize>("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_values_and_missing_required() {
        let a = Args::parse(&argv(&["--n", "notanum"])).unwrap();
        assert!(a.get::<usize>("n", 0).is_err());
        assert!(a.required("out").is_err());
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let a = Args::parse(&argv(&["--expanded", "--n", "5"])).unwrap();
        assert!(a.has("expanded"));
        assert_eq!(a.get::<usize>("n", 0).unwrap(), 5);
    }

    #[test]
    fn unknown_command_is_an_error() {
        assert!(run(&argv(&["frobnicate"])).is_err());
        assert!(run(&argv(&["help"])).is_ok());
        assert!(run(&argv(&[])).is_ok());
    }

    #[test]
    fn experiments_rejects_unknown_scale_and_id() {
        assert!(run(&argv(&["experiments", "table1", "--scale", "galactic"])).is_err());
        assert!(run(&argv(&["experiments", "figZZ", "--scale", "tiny"])).is_err());
    }

    #[test]
    fn classify_flag_conflicts_are_typed_errors() {
        // rejected before any file IO — bogus paths never get opened
        let err = run(&argv(&["classify", "--model", "m", "--cache", "c", "--out", "o"]))
            .unwrap_err();
        assert!(err.to_string().contains("--out"), "{err}");
        let err = run(&argv(&[
            "classify", "--model", "m", "--input", "f", "--chunk-size", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("chunk-size"), "{err}");
    }

    #[test]
    fn replay_threads_flag_is_validated_before_io() {
        // zero threads is nonsense
        let err = run(&argv(&[
            "train", "--cache", "c", "--replay-threads", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("replay-threads"), "{err}");
        let err = run(&argv(&[
            "classify", "--model", "m", "--cache", "c", "--replay-threads", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("replay-threads"), "{err}");
        // the flag only means something for cache replay — reject elsewhere
        let err = run(&argv(&[
            "train", "--input", "f", "--replay-threads", "4",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("replay-threads"), "{err}");
        let err = run(&argv(&[
            "classify", "--model", "m", "--input", "f", "--replay-threads", "4",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("replay-threads"), "{err}");
    }

    #[test]
    fn device_flag_conflicts_are_typed_errors() {
        // rejected before any file IO — bogus input paths never get opened
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--out", "o", "--device", "tpu",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("--device"), "{err}");
        // --artifacts without --device xla would silently run on CPU
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--out", "o", "--artifacts", "a",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("--artifacts"), "{err}");
        // the device path batches worker-parsed chunks — the legacy line
        // reader never produces them
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--out", "o", "--device", "xla", "--legacy-reader",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("legacy-reader"), "{err}");
        // ingest-pipeline-only flag: the non-stream train paths reject it
        let err = run(&argv(&["train", "--input", "f", "--device", "xla"])).unwrap_err();
        assert!(err.to_string().contains("--device"), "{err}");
        let err = run(&argv(&["train", "--cache", "c", "--device", "xla"])).unwrap_err();
        assert!(err.to_string().contains("--device"), "{err}");
    }

    #[test]
    fn block_kb_zero_is_rejected_before_io() {
        let err = run(&argv(&[
            "classify", "--model", "m", "--input", "f", "--block-kb", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("block-kb"), "{err}");
    }

    #[test]
    fn similar_index_flags_are_validated_before_io() {
        // bogus paths never get opened: geometry flags are checked first
        let err = run(&argv(&[
            "similar-index", "--cache", "c", "--out", "o", "--shards", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("--shards"), "{err}");
        let err = run(&argv(&[
            "similar-index", "--cache", "c", "--out", "o", "--bands", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("--bands"), "{err}");
        let err = run(&argv(&[
            "similar-index", "--cache", "c", "--out", "o", "--replay-threads", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("replay-threads"), "{err}");
        let err = run(&argv(&["similar-index", "--out", "o"])).unwrap_err();
        assert!(err.to_string().contains("--cache"), "{err}");
        let err = run(&argv(&["similar-index", "--cache", "c"])).unwrap_err();
        assert!(err.to_string().contains("--out"), "{err}");
    }

    #[test]
    fn route_flags_are_validated_before_binding() {
        let err = run(&argv(&["route"])).unwrap_err();
        assert!(err.to_string().contains("--backends"), "{err}");
        let err = run(&argv(&["route", "--backends", " , "])).unwrap_err();
        assert!(err.to_string().contains("--backends"), "{err}");
        let err = run(&argv(&[
            "route", "--backends", "127.0.0.1:7001", "--shards", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("--shards"), "{err}");
    }

    #[test]
    fn serve_rejects_an_empty_similar_index_list() {
        let err = run(&argv(&[
            "serve", "--model", "m", "--similar-index", " , ",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("similar-index"), "{err}");
    }

    #[test]
    fn trace_out_is_rejected_for_untraced_commands() {
        // only rejection paths run here — init_file is once per process,
        // so a test that actually armed the sink would poison every later
        // test in this binary
        let err = run(&argv(&[
            "classify", "--model", "m", "--input", "f", "--trace-out", "t",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("trace-out"), "{err}");
        let err = run(&argv(&["gen-data", "--out", "o", "--trace-out", "t"])).unwrap_err();
        assert!(err.to_string().contains("trace-out"), "{err}");
        let err = run(&argv(&["help", "--trace-out", "t"])).unwrap_err();
        assert!(err.to_string().contains("trace-out"), "{err}");
    }

    #[test]
    fn report_json_requires_a_pipeline_path() {
        // cache replay and the in-memory split have no pipeline report —
        // rejected before any file IO
        let err = run(&argv(&[
            "train", "--cache", "c", "--report-json", "r",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("report-json"), "{err}");
        let err = run(&argv(&[
            "train", "--input", "f", "--report-json", "r",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("report-json"), "{err}");
    }

    #[test]
    fn slow_ms_rejects_garbage_before_binding() {
        let err = run(&argv(&[
            "serve", "--model", "m", "--slow-ms", "fast",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("slow-ms"), "{err}");
        let err = run(&argv(&[
            "route", "--backends", "127.0.0.1:7001", "--slow-ms", "fast",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("slow-ms"), "{err}");
    }

    #[test]
    fn holdout_requires_the_sgd_cache_path() {
        let err = run(&argv(&[
            "train", "--cache", "c", "--solver", "svm", "--holdout", "0.2",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("holdout"), "{err}");
        // silently training on all rows would masquerade as validation:
        // the stream and in-memory paths reject the flag too
        let err = run(&argv(&[
            "train", "--input", "f", "--stream", "--holdout", "0.2",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("holdout"), "{err}");
        let err =
            run(&argv(&["train", "--input", "f", "--holdout", "0.2"])).unwrap_err();
        assert!(err.to_string().contains("holdout"), "{err}");
    }

    #[test]
    fn preprocess_resume_flags_are_validated_before_io() {
        // rejected before any file IO — bogus paths never get opened and
        // no .tmp/journal files appear
        let err = run(&argv(&["preprocess", "--input", "f", "--out", "o", "--resume"]))
            .unwrap_err();
        assert!(err.to_string().contains("--resume"), "{err}");
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--out", "o", "--sync-chunks", "8",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("sync-chunks"), "{err}");
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--cache-out", "c", "--sync-chunks", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("sync-chunks"), "{err}");
        // the legacy line reader cannot seek to the journaled offset
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--cache-out", "c", "--resume", "--legacy-reader",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("legacy-reader"), "{err}");
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--cache-out", "c", "--resume", "--device", "xla",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("--device"), "{err}");
    }

    #[test]
    fn ingest_error_flags_are_validated_before_io() {
        // --quarantine without skip would read as "errors recorded" while
        // the run still fails fast on the first bad line
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--out", "o", "--quarantine", "q",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("quarantine"), "{err}");
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--out", "o", "--on-error", "explode",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("on-error"), "{err}");
        // the lossy parse lives in the byte-block path only
        let err = run(&argv(&[
            "preprocess", "--input", "f", "--out", "o", "--on-error", "skip",
            "--legacy-reader",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("legacy-reader"), "{err}");
        // cache replay and the in-memory split read already-validated bytes
        let err = run(&argv(&["train", "--cache", "c", "--on-error", "skip"])).unwrap_err();
        assert!(err.to_string().contains("on-error"), "{err}");
        let err = run(&argv(&["train", "--input", "f", "--quarantine", "q"])).unwrap_err();
        assert!(err.to_string().contains("quarantine"), "{err}");
    }

    #[test]
    fn checkpoint_flags_are_validated_before_io() {
        // checkpoints ride the streaming cache replay — other paths reject
        let err = run(&argv(&["train", "--input", "f", "--checkpoint", "ck"])).unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "{err}");
        let err = run(&argv(&["train", "--input", "f", "--stream", "--resume"])).unwrap_err();
        assert!(err.to_string().contains("resume"), "{err}");
        // the companion flags mean nothing without --checkpoint PATH
        let err =
            run(&argv(&["train", "--cache", "c", "--checkpoint-every", "2"])).unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "{err}");
        let err = run(&argv(&["train", "--cache", "c", "--resume"])).unwrap_err();
        assert!(err.to_string().contains("resume"), "{err}");
        // sequential sgd only: batch solvers, the holdout split and the
        // reader pool all carry state the checkpoint format does not
        let err = run(&argv(&[
            "train", "--cache", "c", "--solver", "svm", "--checkpoint", "ck",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("checkpoint"), "{err}");
        let err = run(&argv(&[
            "train", "--cache", "c", "--checkpoint", "ck", "--holdout", "0.2",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("holdout"), "{err}");
        let err = run(&argv(&[
            "train", "--cache", "c", "--checkpoint", "ck", "--replay-threads", "2",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("replay-threads"), "{err}");
        let err = run(&argv(&[
            "train", "--cache", "c", "--checkpoint", "ck", "--checkpoint-every", "0",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("checkpoint-every"), "{err}");
    }

    #[test]
    fn drain_ms_rejects_garbage_before_binding() {
        let err = run(&argv(&["serve", "--model", "m", "--drain-ms", "soon"])).unwrap_err();
        assert!(err.to_string().contains("drain-ms"), "{err}");
    }
}

fn cmd_runtime_info(args: &Args) -> Result<()> {
    let dir = args.get("artifacts", "artifacts".to_string())?;
    let rt = bbit_mh::runtime::PjrtRuntime::cpu(Path::new(&dir))?;
    println!("PJRT platform: {}", rt.platform());
    for (name, spec) in &rt.manifest.artifacts {
        print!(
            "  {name}: {} inputs, {} outputs, consts {{",
            spec.inputs.len(),
            spec.outputs.len()
        );
        for (k, v) in &spec.consts {
            print!(" {k}={v}");
        }
        println!(" }}");
        rt.load(name)?; // compile to prove it loads
    }
    println!("all {} artifacts compiled OK", rt.manifest.artifacts.len());
    Ok(())
}
