//! Env-armed failpoints for crash and fault testing.
//!
//! Modeled on the [`crate::metrics::trace`] pattern: a process-global
//! facility that is **off by default** and costs exactly one relaxed atomic
//! load per site when disarmed, so failpoints can sit permanently on hot
//! paths (the cache writer, the replay decoder, the batch scorer).
//!
//! ## Arming
//!
//! ```text
//! BBMH_FAILPOINTS=site=action[:prob][:count][;site=action...]
//! ```
//!
//! Clauses are separated by `;` (or `,`).  Actions:
//!
//! | action | effect at the site |
//! |---|---|
//! | `error` | the site reports an injected [`crate::Error`] |
//! | `panic` | the site panics (simulates an abrupt crash) |
//! | `partial-write` | write sites persist a truncated prefix, then error (a torn write) |
//! | `delay-ms:N` | the site sleeps `N` milliseconds, then proceeds normally |
//!
//! `prob` is an optional trigger probability and **must contain a decimal
//! point** (`0.25`, `1.0`); it defaults to always-fire.  `count` is an
//! optional integer cap on total triggers.  The probability draw uses a
//! fixed-seed xorshift so a given arming is reproducible run-to-run.
//!
//! Non-write sites treat `partial-write` as `error`.
//!
//! ## Sites
//!
//! The named sites are listed in [`site`]; the "Fault tolerance" section of
//! the crate docs maps each to the subsystem it cuts.  Example:
//!
//! ```text
//! BBMH_FAILPOINTS='cache.write_record=partial-write:1.0:1;route.forward=delay-ms:20'
//! ```
//!
//! ## Testing discipline
//!
//! Arming is read from the environment once per process (same discipline as
//! `trace::init_file`), so unit tests exercise only the parser and the
//! disarmed fast path; armed behavior is driven through `CARGO_BIN_EXE`
//! subprocesses in `tests/crash_recovery.rs`, each with an explicit
//! `BBMH_FAILPOINTS` value so the suite stays hermetic even when CI arms
//! the environment globally.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Duration;

use crate::error::{Error, Result};

/// The named failpoint sites wired through the codebase.
pub mod site {
    /// [`crate::encode::cache::CacheWriter`] staging one chunk record.
    pub const CACHE_WRITE_RECORD: &str = "cache.write_record";
    /// [`crate::encode::cache::CacheWriter::finalize`] committing the cache.
    pub const CACHE_FINALIZE: &str = "cache.finalize";
    /// [`crate::encode::cache::RecordDecoder`] decoding a replayed record.
    pub const REPLAY_DECODE: &str = "replay.decode";
    /// The serve tier scoring one assembled batch.
    pub const SERVE_BATCH: &str = "serve.batch";
    /// The router forwarding a request to a backend.
    pub const ROUTE_FORWARD: &str = "route.forward";
    /// The device encoder launching a compiled artifact.
    pub const DEVICE_LAUNCH: &str = "device.launch";

    /// Every site, for docs and spec validation.
    pub const ALL: &[&str] = &[
        CACHE_WRITE_RECORD,
        CACHE_FINALIZE,
        REPLAY_DECODE,
        SERVE_BATCH,
        ROUTE_FORWARD,
        DEVICE_LAUNCH,
    ];
}

/// What an armed failpoint asks the *caller* to do.  Delays and panics are
/// handled inside [`trigger`]; these two need site-specific behavior.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Injected {
    /// Report an injected error.
    Error,
    /// Persist a truncated prefix of the pending write, then error.
    PartialWrite,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Action {
    Error,
    Panic,
    PartialWrite,
    DelayMs(u64),
}

#[derive(Debug)]
struct Rule {
    site: String,
    action: Action,
    /// Trigger probability in (0, 1]; 1.0 = always.
    prob: f64,
    /// Remaining triggers; `u64::MAX` = unlimited.
    remaining: AtomicU64,
}

const STATE_UNKNOWN: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(STATE_UNKNOWN);
static RULES: OnceLock<Vec<Rule>> = OnceLock::new();
// Fixed seed: a given BBMH_FAILPOINTS arming fires at the same call
// sequence every run, which is what a CI matrix wants.
static RNG: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);

fn parse_clause(clause: &str) -> std::result::Result<Rule, String> {
    let (site, rest) = clause
        .split_once('=')
        .ok_or_else(|| format!("'{clause}': expected site=action"))?;
    let site = site.trim();
    if !site::ALL.contains(&site) {
        return Err(format!("'{site}': unknown failpoint site"));
    }
    let mut toks = rest.trim().split(':');
    let action_tok = toks.next().unwrap_or("");
    let action = match action_tok {
        "error" => Action::Error,
        "panic" => Action::Panic,
        "partial-write" => Action::PartialWrite,
        "delay-ms" => {
            let ms = toks
                .next()
                .ok_or_else(|| format!("'{clause}': delay-ms needs a value (delay-ms:N)"))?;
            let ms: u64 = ms
                .parse()
                .map_err(|_| format!("'{clause}': bad delay-ms value '{ms}'"))?;
            Action::DelayMs(ms)
        }
        other => return Err(format!("'{clause}': unknown action '{other}'")),
    };
    let mut prob = 1.0f64;
    let mut count = u64::MAX;
    for tok in toks {
        if tok.contains('.') {
            let p: f64 = tok
                .parse()
                .map_err(|_| format!("'{clause}': bad probability '{tok}'"))?;
            if !(p > 0.0 && p <= 1.0) {
                return Err(format!("'{clause}': probability must be in (0, 1]"));
            }
            prob = p;
        } else {
            count = tok
                .parse()
                .map_err(|_| format!("'{clause}': bad count '{tok}'"))?;
        }
    }
    Ok(Rule {
        site: site.to_string(),
        action,
        prob,
        remaining: AtomicU64::new(count),
    })
}

/// Parse a full `BBMH_FAILPOINTS` value.  Public so unit tests can cover
/// the grammar without arming the process.
#[doc(hidden)]
pub fn parse_spec(spec: &str) -> std::result::Result<(), String> {
    parse_rules(spec).map(|_| ())
}

fn parse_rules(spec: &str) -> std::result::Result<Vec<Rule>, String> {
    let mut rules = Vec::new();
    for clause in spec.split([';', ',']) {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        rules.push(parse_clause(clause)?);
    }
    Ok(rules)
}

#[cold]
fn init() -> bool {
    let armed = match std::env::var("BBMH_FAILPOINTS") {
        Ok(spec) if !spec.trim().is_empty() => match parse_rules(&spec) {
            Ok(rules) if !rules.is_empty() => {
                let _ = RULES.set(rules);
                true
            }
            Ok(_) => false,
            Err(e) => {
                eprintln!("warning: BBMH_FAILPOINTS ignored: {e}");
                false
            }
        },
        _ => false,
    };
    STATE.store(if armed { STATE_ON } else { STATE_OFF }, Ordering::Relaxed);
    armed
}

#[inline]
fn armed() -> bool {
    match STATE.load(Ordering::Relaxed) {
        STATE_OFF => false,
        STATE_ON => true,
        _ => init(),
    }
}

fn rng_next() -> f64 {
    // xorshift64*; a lost race between concurrent callers only perturbs the
    // stream, which is fine for a trigger probability.
    let mut x = RNG.load(Ordering::Relaxed);
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    RNG.store(x, Ordering::Relaxed);
    (x.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64 / (1u64 << 53) as f64
}

#[cold]
fn evaluate(name: &str) -> Option<Injected> {
    let rules = RULES.get()?;
    let rule = rules.iter().find(|r| r.site == name)?;
    if rule.prob < 1.0 && rng_next() >= rule.prob {
        return None;
    }
    // Claim one trigger from the budget.
    let mut left = rule.remaining.load(Ordering::Relaxed);
    loop {
        if left == 0 {
            return None;
        }
        let next = if left == u64::MAX { left } else { left - 1 };
        match rule.remaining.compare_exchange_weak(
            left,
            next,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => break,
            Err(cur) => left = cur,
        }
    }
    match rule.action {
        Action::DelayMs(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
        Action::Panic => panic!("failpoint '{name}' injected panic"),
        Action::Error => Some(Injected::Error),
        Action::PartialWrite => Some(Injected::PartialWrite),
    }
}

/// Evaluate the failpoint `name`.  Disarmed cost: one relaxed atomic load.
///
/// `delay-ms` sleeps here and returns `None`; `panic` panics here.  `error`
/// and `partial-write` are returned so the site can fail in its own idiom
/// (write sites persist a torn prefix first; everything else should treat
/// both as an error — see [`fail`]).
#[inline]
pub fn trigger(name: &str) -> Option<Injected> {
    if !armed() {
        return None;
    }
    evaluate(name)
}

/// Convenience for non-write sites: any injection becomes a typed error.
#[inline]
pub fn fail(name: &str) -> Result<()> {
    match trigger(name) {
        None => Ok(()),
        Some(_) => Err(injected_error(name)),
    }
}

/// The error a failpoint injects; also used by write sites after
/// persisting a torn prefix.
pub fn injected_error(name: &str) -> Error {
    Error::Pipeline(format!("failpoint '{name}' injected error"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests never set BBMH_FAILPOINTS: arming is process-global, and
    // flipping it here would leak into sibling tests.  Armed behavior runs
    // in subprocesses in tests/crash_recovery.rs.

    #[test]
    fn disarmed_trigger_is_none_for_every_site() {
        for s in site::ALL {
            assert_eq!(trigger(s), None);
            assert!(fail(s).is_ok());
        }
    }

    #[test]
    fn parses_every_action_and_modifier() {
        for spec in [
            "cache.write_record=error",
            "cache.finalize=panic",
            "cache.write_record=partial-write:1.0:1",
            "route.forward=delay-ms:20",
            "route.forward=delay-ms:20:0.5:3",
            "cache.write_record=error;serve.batch=delay-ms:5,replay.decode=error:0.25",
            "  ",
        ] {
            assert!(parse_spec(spec).is_ok(), "spec should parse: {spec}");
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for spec in [
            "cache.write_record",              // no action
            "nosuch.site=error",               // unknown site
            "cache.write_record=explode",      // unknown action
            "route.forward=delay-ms",          // missing ms value
            "route.forward=delay-ms:abc",      // bad ms value
            "cache.write_record=error:2.0",    // probability out of range
            "cache.write_record=error:0.0",    // probability out of range
            "cache.write_record=error:notanum", // bad count
        ] {
            assert!(parse_spec(spec).is_err(), "spec should be rejected: {spec}");
        }
    }

    #[test]
    fn count_and_prob_positions_are_flexible() {
        assert!(parse_spec("cache.write_record=error:3:0.5").is_ok());
        assert!(parse_spec("cache.write_record=error:0.5:3").is_ok());
    }

    #[test]
    fn clause_parser_fills_defaults() {
        let r = parse_clause("cache.write_record=error").unwrap();
        assert_eq!(r.action, Action::Error);
        assert_eq!(r.prob, 1.0);
        assert_eq!(r.remaining.load(Ordering::Relaxed), u64::MAX);
        let r = parse_clause("serve.batch=delay-ms:7:0.25:2").unwrap();
        assert_eq!(r.action, Action::DelayMs(7));
        assert_eq!(r.prob, 0.25);
        assert_eq!(r.remaining.load(Ordering::Relaxed), 2);
    }
}
