//! The train/score inner-loop kernels: unrolled multi-accumulator
//! gather/scatter over decoded b-bit rows, with explicit weight prefetch.
//!
//! # Where the cycles go (SPEED notes)
//!
//! After PRs 4–5 made replay and ingest fast, train/score time is
//! dominated by the per-code inner loops: for every row the solver gathers
//! `k` weights at indices `(j << b) | code_j` (dot) or scatters a constant
//! into them (axpy).  The pre-PR-6 loops re-extracted each code with
//! `PackedCodes::get` (per-element shifts + a straddle branch) and
//! accumulated through one serial f32 dependency chain — the classic
//! "latency-bound gather" shape.  This module replaces that with:
//!
//! 1. **whole-row decode** ([`PackedCodes::row_indices_into`]) into a
//!    reusable `u32` scratch — branchless, word-at-a-time, specialized per
//!    `b`;
//! 2. **8-wide unrolled accumulators** for dot/axpy so the gathers pipeline
//!    instead of serializing on one add chain;
//! 3. **explicit weight prefetch** ([`prefetch_weights`]) issued one row
//!    ahead by the SGD/DCD/eval loops ([`RowGather`] owns the
//!    double-buffered decode+prefetch idiom), hiding the cache misses of
//!    the random gather into a 2^b·k-entry weight table.
//!
//! # Exact vs tolerance-bounded (the bit-parity story)
//!
//! | kernel          | vs scalar reference | why |
//! |-----------------|---------------------|-----|
//! | row decode      | bit-identical       | integer-only |
//! | axpy (indices)  | bit-identical       | scatter of distinct slots, program order preserved |
//! | axpy (valued)   | bit-identical       | same |
//! | codec RLE scan  | byte-identical      | integer-only (see `encode::codec`) |
//! | dot / norm_sq   | tolerance-bounded   | 8 accumulators reassociate the f32 sum |
//!
//! Gather indices within one row are strictly increasing (`(j << b) | c`
//! grows with `j`), so axpy updates distinct weight slots in program order
//! — reordering-free, hence exact.  Dot products are reassociated by the
//! multi-accumulator reduction, so consumers that compare margins across
//! kernel generations use a tolerance (≈ k·ε·Σ|w| — pinned with headroom in
//! `tests/simd_kernels.rs`).  The multi-accumulator sum is typically
//! *closer* to the f64 reference than the serial chain, never exactly it.
//!
//! # Scalar fallback
//!
//! Every kernel has a scalar twin (`*_scalar`) that reproduces the
//! pre-PR-6 accumulation bit-for-bit.  Two switches select it:
//! compile-time `--cfg bbmh_force_scalar` (CI's second test pass — also
//! the behavior non-x86_64 targets can pin), and the runtime
//! [`force_scalar`] toggle the benchmark matrix uses to measure the
//! scalar-vs-unrolled speedup in one process (`bench_pipeline -- matrix`,
//! reported as `train_from_cache.kernel_speedup` in `BENCH_matrix.json`).
//! Tests never touch the global toggle (they run in parallel threads);
//! they call the `_scalar`/`_unrolled` variants directly.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::encode::packed::PackedCodes;

/// Accumulator width for the unrolled kernels.  Eight independent f32
/// chains cover the gather latency without spilling registers on x86_64.
pub const LANES: usize = 8;

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// True when the scalar reference kernels are selected, either by the
/// `bbmh_force_scalar` cfg or the runtime [`force_scalar`] toggle.
#[inline(always)]
pub fn scalar_forced() -> bool {
    cfg!(bbmh_force_scalar) || FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Select the scalar reference kernels at runtime (process-global).
/// Benchmark-only: the matrix scenario flips this to A/B the kernels in
/// one process.  Tests must not call it — they run in parallel threads
/// and would race each other through this global.
pub fn force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// prefetch

#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn prefetch_ptr(p: *const f32) {
    // SAFETY: _mm_prefetch is a pure performance hint with no memory,
    // alignment, or validity requirements — any address is allowed.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8);
    }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline(always)]
fn prefetch_ptr(p: *const f32) {
    // std-only dep policy: no inline asm / arch intrinsics off x86_64.
    // black_box keeps the address computation observable (a true no-op
    // would let the compiler delete the decode feeding it).
    std::hint::black_box(p);
}

/// Prefetch the weight cache lines a decoded row will gather.  Pointers
/// are formed with `wrapping_add` so even a bogus index is hint-safe.
#[inline]
pub fn prefetch_weights(w: &[f32], idx: &[u32]) {
    if scalar_forced() {
        return;
    }
    let base = w.as_ptr();
    for &t in idx {
        prefetch_ptr(base.wrapping_add(t as usize));
    }
}

// ---------------------------------------------------------------------------
// index-gather kernels (binary features: b-bit rows, binary CSR rows)

/// Fixed pairwise reduction tree over the lane accumulators — part of the
/// kernel contract (`tests/simd_kernels.rs` pins dot results against an
/// independent reimplementation of exactly this shape).
#[inline(always)]
fn reduce(acc: [f32; LANES]) -> f32 {
    ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]))
}

/// `Σ w[idx_j]` — serial reference chain (pre-PR-6 accumulation order).
pub fn dot_idx_scalar(idx: &[u32], w: &[f32]) -> f32 {
    idx.iter().map(|&t| w[t as usize]).sum()
}

/// `Σ w[idx_j]` with [`LANES`] independent accumulators: lane `l` sums
/// elements `j ≡ l (mod LANES)`, remainder folded into lanes `0..r`,
/// then the fixed [`reduce`] tree.
pub fn dot_idx_unrolled(idx: &[u32], w: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut chunks = idx.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for l in 0..LANES {
            acc[l] += w[c[l] as usize];
        }
    }
    for (l, &t) in chunks.remainder().iter().enumerate() {
        acc[l] += w[t as usize];
    }
    reduce(acc)
}

/// Dispatching form of the index dot product.
#[inline]
pub fn dot_idx(idx: &[u32], w: &[f32]) -> f32 {
    if scalar_forced() {
        dot_idx_scalar(idx, w)
    } else {
        dot_idx_unrolled(idx, w)
    }
}

/// `w[idx_j] += alpha` — reference loop.
pub fn axpy_idx_scalar(idx: &[u32], alpha: f32, w: &mut [f32]) {
    for &t in idx {
        w[t as usize] += alpha;
    }
}

/// `w[idx_j] += alpha`, unrolled.  The unroll only widens the loop body —
/// updates still happen in program order on (for our producers) distinct
/// slots, so this is bit-identical to the scalar twin (pinned in tests).
pub fn axpy_idx_unrolled(idx: &[u32], alpha: f32, w: &mut [f32]) {
    let mut chunks = idx.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for l in 0..LANES {
            w[c[l] as usize] += alpha;
        }
    }
    for &t in chunks.remainder() {
        w[t as usize] += alpha;
    }
}

/// Dispatching form of the index axpy.
#[inline]
pub fn axpy_idx(idx: &[u32], alpha: f32, w: &mut [f32]) {
    if scalar_forced() {
        axpy_idx_scalar(idx, alpha, w)
    } else {
        axpy_idx_unrolled(idx, alpha, w)
    }
}

// ---------------------------------------------------------------------------
// valued kernels (VW/RP real-valued CSR rows)

/// `Σ w[idx_j]·v_j` — serial reference chain.
pub fn dot_vals_scalar(idx: &[u32], vals: &[f32], w: &[f32]) -> f32 {
    idx.iter().zip(vals).map(|(&t, &v)| w[t as usize] * v).sum()
}

/// `Σ w[idx_j]·v_j`, [`LANES`]-wide.
pub fn dot_vals_unrolled(idx: &[u32], vals: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(idx.len(), vals.len());
    let mut acc = [0.0f32; LANES];
    let mut ic = idx.chunks_exact(LANES);
    let mut vc = vals.chunks_exact(LANES);
    for (ci, cv) in ic.by_ref().zip(vc.by_ref()) {
        for l in 0..LANES {
            acc[l] += w[ci[l] as usize] * cv[l];
        }
    }
    for (l, (&t, &v)) in ic.remainder().iter().zip(vc.remainder()).enumerate() {
        acc[l] += w[t as usize] * v;
    }
    reduce(acc)
}

/// Dispatching form of the valued dot product.
#[inline]
pub fn dot_vals(idx: &[u32], vals: &[f32], w: &[f32]) -> f32 {
    if scalar_forced() {
        dot_vals_scalar(idx, vals, w)
    } else {
        dot_vals_unrolled(idx, vals, w)
    }
}

/// `w[idx_j] += alpha·v_j` — reference loop.
pub fn axpy_vals_scalar(idx: &[u32], vals: &[f32], alpha: f32, w: &mut [f32]) {
    for (&t, &v) in idx.iter().zip(vals) {
        w[t as usize] += alpha * v;
    }
}

/// `w[idx_j] += alpha·v_j`, unrolled (program order preserved → exact,
/// same argument as [`axpy_idx_unrolled`]).
pub fn axpy_vals_unrolled(idx: &[u32], vals: &[f32], alpha: f32, w: &mut [f32]) {
    debug_assert_eq!(idx.len(), vals.len());
    let mut ic = idx.chunks_exact(LANES);
    let mut vc = vals.chunks_exact(LANES);
    for (ci, cv) in ic.by_ref().zip(vc.by_ref()) {
        for l in 0..LANES {
            w[ci[l] as usize] += alpha * cv[l];
        }
    }
    for (&t, &v) in ic.remainder().iter().zip(vc.remainder()) {
        w[t as usize] += alpha * v;
    }
}

/// Dispatching form of the valued axpy.
#[inline]
pub fn axpy_vals(idx: &[u32], vals: &[f32], alpha: f32, w: &mut [f32]) {
    if scalar_forced() {
        axpy_vals_scalar(idx, vals, alpha, w)
    } else {
        axpy_vals_unrolled(idx, vals, alpha, w)
    }
}

/// `Σ v_j²` — serial reference chain.
pub fn sum_sq_scalar(vals: &[f32]) -> f32 {
    vals.iter().map(|v| v * v).sum()
}

/// `Σ v_j²`, [`LANES`]-wide.
pub fn sum_sq_unrolled(vals: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut chunks = vals.chunks_exact(LANES);
    for c in chunks.by_ref() {
        for l in 0..LANES {
            acc[l] += c[l] * c[l];
        }
    }
    for (l, &v) in chunks.remainder().iter().enumerate() {
        acc[l] += v * v;
    }
    reduce(acc)
}

/// Dispatching form of the squared-norm sum.
#[inline]
pub fn sum_sq(vals: &[f32]) -> f32 {
    if scalar_forced() {
        sum_sq_scalar(vals)
    } else {
        sum_sq_unrolled(vals)
    }
}

// ---------------------------------------------------------------------------
// code-slice dot (classify / serve margin path: codes already unpacked)

/// `Σ w[(j << b) | code_j]` over an unpacked code row — the classify and
/// serve-scorer margin kernel ([`crate::encode::encoder`]'s
/// `packed_margin`).  Lane structure matches [`dot_idx_unrolled`] exactly
/// (lane `l` takes `j ≡ l (mod LANES)`), so for the same row this is
/// bitwise-equal to decoding indices first and calling `dot_idx`.
pub fn dot_codes(b: u32, codes: &[u16], w: &[f32]) -> f32 {
    if scalar_forced() {
        return dot_codes_scalar(b, codes, w);
    }
    let mut acc = [0.0f32; LANES];
    let mut chunks = codes.chunks_exact(LANES);
    let mut base = 0usize;
    for c in chunks.by_ref() {
        for l in 0..LANES {
            acc[l] += w[((base + l) << b) + c[l] as usize];
        }
        base += LANES;
    }
    for (l, &code) in chunks.remainder().iter().enumerate() {
        acc[l] += w[((base + l) << b) + code as usize];
    }
    reduce(acc)
}

/// Serial reference chain for [`dot_codes`].
pub fn dot_codes_scalar(b: u32, codes: &[u16], w: &[f32]) -> f32 {
    codes
        .iter()
        .enumerate()
        .map(|(j, &c)| w[(j << b) + c as usize])
        .sum()
}

// ---------------------------------------------------------------------------
// packed-row entry points (FeatureMatrix / generic consumers)

thread_local! {
    /// Per-thread row-index scratch for the stateless packed entry points
    /// below.  Deliberately *not* a decoded-row cache: scratch
    /// `PackedCodes` buffers get refilled in place during replay, so any
    /// cross-call keying on (pointer, row) could serve stale rows.  Loops
    /// that want decode reuse + one-row-ahead prefetch own a [`RowGather`].
    static ROW_IDX: RefCell<Vec<u32>> = const { RefCell::new(Vec::new()) };
}

fn with_row_indices<R>(codes: &PackedCodes, i: usize, f: impl FnOnce(&[u32]) -> R) -> R {
    ROW_IDX.with(|cell| {
        let mut buf = cell.borrow_mut();
        buf.resize(codes.k, 0);
        if scalar_forced() {
            codes.row_indices_scalar_into(i, &mut buf);
        } else {
            codes.row_indices_into(i, &mut buf);
        }
        f(&buf)
    })
}

/// Margin accumulation for one packed row: decode (thread-local scratch)
/// then [`dot_idx`].
pub fn packed_dot(codes: &PackedCodes, i: usize, w: &[f32]) -> f32 {
    with_row_indices(codes, i, |idx| dot_idx(idx, w))
}

/// Gradient scatter for one packed row: decode then [`axpy_idx`].
pub fn packed_axpy(codes: &PackedCodes, i: usize, alpha: f32, w: &mut [f32]) {
    with_row_indices(codes, i, |idx| axpy_idx(idx, alpha, w))
}

/// Decode row `i` and prefetch the weight lines it will gather —
/// `FeatureMatrix::prefetch_row` for packed data.  No-op when scalar
/// kernels are forced (the reference path must not change cache behavior).
pub fn packed_prefetch(codes: &PackedCodes, i: usize, w: &[f32]) {
    if scalar_forced() {
        return;
    }
    with_row_indices(codes, i, |idx| prefetch_weights(w, idx));
}

// ---------------------------------------------------------------------------
// RowGather: the decode-once / prefetch-one-row-ahead loop idiom

/// Double-buffered row decoder for the SGD/DCD/eval inner loops.
///
/// The loop idiom (`n` rows against weights `w`):
///
/// ```ignore
/// let mut g = RowGather::new(codes.k);
/// g.begin(codes, 0);
/// for i in 0..n {
///     if i + 1 < n { g.stage(codes, i + 1, &w); }   // decode + prefetch ahead
///     let m = kernels::dot_idx(g.indices(), &w);     // compute on current row
///     // ... axpy on g.indices() ...
///     if i + 1 < n { g.advance(codes, i + 1); }      // staged row becomes current
/// }
/// ```
///
/// `stage` decodes the next row into the back buffer and prefetches the
/// weight lines it will touch, so the gather for row i+1 is in flight
/// while row i computes.  The struct is stateless across loops — `begin`
/// re-decodes unconditionally, and `advance` re-decodes if the requested
/// row is not the staged one — so refilled scratch buffers can never leak
/// a stale row (the failure mode that rules out cross-call caching).
pub struct RowGather {
    cur: Vec<u32>,
    next: Vec<u32>,
    staged_row: Option<usize>,
}

impl RowGather {
    pub fn new(k: usize) -> Self {
        RowGather { cur: vec![0; k], next: vec![0; k], staged_row: None }
    }

    fn decode(codes: &PackedCodes, row: usize, out: &mut Vec<u32>) {
        out.resize(codes.k, 0);
        if scalar_forced() {
            codes.row_indices_scalar_into(row, out);
        } else {
            codes.row_indices_into(row, out);
        }
    }

    /// Decode `row` as the current row (start of a loop).
    pub fn begin(&mut self, codes: &PackedCodes, row: usize) {
        Self::decode(codes, row, &mut self.cur);
        self.staged_row = None;
    }

    /// Decode `row` into the back buffer and prefetch the weight lines it
    /// gathers.  Skipped entirely under forced-scalar mode (the reference
    /// path decodes per-row in [`advance`], matching pre-PR-6 behavior).
    pub fn stage(&mut self, codes: &PackedCodes, row: usize, w: &[f32]) {
        if scalar_forced() {
            return;
        }
        Self::decode(codes, row, &mut self.next);
        prefetch_weights(w, &self.next);
        self.staged_row = Some(row);
    }

    /// Gather indices of the current row.
    #[inline]
    pub fn indices(&self) -> &[u32] {
        &self.cur
    }

    /// Make `row` current: swap in the staged buffer when it holds exactly
    /// this row, else decode fresh.  Must be called with the same `codes`
    /// the row was staged from.
    pub fn advance(&mut self, codes: &PackedCodes, row: usize) {
        if self.staged_row == Some(row) {
            std::mem::swap(&mut self.cur, &mut self.next);
        } else {
            Self::decode(codes, row, &mut self.cur);
        }
        self.staged_row = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn packed(b: u32, k: usize, n: usize, seed: u64) -> PackedCodes {
        let mut rng = Rng::new(seed);
        let mut pc = PackedCodes::new(b, k);
        for _ in 0..n {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
        }
        pc
    }

    fn weights(dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..dim).map(|_| rng.f32() - 0.5).collect()
    }

    #[test]
    fn axpy_unrolled_is_bit_identical_to_scalar() {
        for k in [1usize, 3, 7, 8, 9, 16, 37, 200] {
            let pc = packed(8, k, 5, 0xA11 + k as u64);
            let dim = k << 8;
            let mut idx = vec![0u32; k];
            for i in 0..pc.n {
                pc.row_indices_into(i, &mut idx);
                let mut w1 = weights(dim, 7);
                let mut w2 = w1.clone();
                axpy_idx_scalar(&idx, 0.37, &mut w1);
                axpy_idx_unrolled(&idx, 0.37, &mut w2);
                assert_eq!(w1, w2, "k={k} row {i}");
            }
        }
    }

    #[test]
    fn dot_unrolled_matches_f64_reference_within_tolerance() {
        for k in [1usize, 3, 8, 13, 200] {
            let pc = packed(4, k, 5, 0xD07 + k as u64);
            let dim = k << 4;
            let w = weights(dim, 13);
            let mut idx = vec![0u32; k];
            for i in 0..pc.n {
                pc.row_indices_into(i, &mut idx);
                let exact: f64 = idx.iter().map(|&t| w[t as usize] as f64).sum();
                let sum_abs: f64 =
                    idx.iter().map(|&t| (w[t as usize] as f64).abs()).sum();
                let tol = 4.0 * k as f64 * f32::EPSILON as f64 * sum_abs + 1e-12;
                for got in [dot_idx_scalar(&idx, &w), dot_idx_unrolled(&idx, &w)] {
                    assert!(
                        (got as f64 - exact).abs() <= tol,
                        "k={k} row {i}: {got} vs {exact} (tol {tol})"
                    );
                }
            }
        }
    }

    #[test]
    fn dot_codes_matches_decoded_dot_idx_bitwise() {
        for b in [1u32, 5, 8, 16] {
            let k = 21;
            let pc = packed(b, k, 4, 0xC0DE + b as u64);
            let w = weights(k << b, 23);
            let mut idx = vec![0u32; k];
            let mut codes = vec![0u16; k];
            for i in 0..pc.n {
                pc.row_indices_into(i, &mut idx);
                pc.row_into(i, &mut codes);
                assert_eq!(
                    dot_codes(b, &codes, &w).to_bits(),
                    dot_idx_unrolled(&idx, &w).to_bits(),
                    "b={b} row {i}"
                );
                assert_eq!(
                    dot_codes_scalar(b, &codes, &w).to_bits(),
                    dot_idx_scalar(&idx, &w).to_bits(),
                    "b={b} row {i} (scalar)"
                );
            }
        }
    }

    #[test]
    fn valued_kernels_parity() {
        let mut rng = Rng::new(0x7A1);
        for len in [1usize, 2, 7, 8, 15, 64, 100] {
            let idx: Vec<u32> = {
                let mut v: Vec<u32> = (0..len as u32).map(|j| j * 3 + 1).collect();
                v.reverse(); // order must not matter for correctness
                v
            };
            let vals: Vec<f32> =
                (0..len).map(|_| rng.f32() * 2.0 - 1.0).collect();
            let dim = (3 * len + 2).max(4);
            let w = weights(dim, 0x9E3 + len as u64);
            // axpy exact
            let mut w1 = w.clone();
            let mut w2 = w.clone();
            axpy_vals_scalar(&idx, &vals, -0.83, &mut w1);
            axpy_vals_unrolled(&idx, &vals, -0.83, &mut w2);
            assert_eq!(w1, w2, "len={len}");
            // dot / sum_sq within f64-reference tolerance
            let exact: f64 = idx
                .iter()
                .zip(&vals)
                .map(|(&t, &v)| w[t as usize] as f64 * v as f64)
                .sum();
            let scale: f64 = idx
                .iter()
                .zip(&vals)
                .map(|(&t, &v)| (w[t as usize] as f64 * v as f64).abs())
                .sum();
            let tol = 4.0 * len as f64 * f32::EPSILON as f64 * scale + 1e-12;
            for got in [dot_vals_scalar(&idx, &vals, &w), dot_vals_unrolled(&idx, &vals, &w)] {
                assert!((got as f64 - exact).abs() <= tol, "len={len}: {got} vs {exact}");
            }
            let nsq: f64 = vals.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let ntol = 4.0 * len as f64 * f32::EPSILON as f64 * nsq + 1e-12;
            for got in [sum_sq_scalar(&vals), sum_sq_unrolled(&vals)] {
                assert!((got as f64 - nsq).abs() <= ntol, "len={len}");
            }
        }
    }

    #[test]
    fn row_gather_idiom_tracks_rows_and_survives_refill() {
        let pc = packed(7, 13, 6, 0x6A7);
        let w = weights(13 << 7, 3);
        let mut g = RowGather::new(pc.k);
        let mut want = vec![0u32; pc.k];
        g.begin(&pc, 0);
        for i in 0..pc.n {
            if i + 1 < pc.n {
                g.stage(&pc, i + 1, &w);
            }
            pc.row_indices_scalar_into(i, &mut want);
            assert_eq!(g.indices(), &want[..], "row {i}");
            if i + 1 < pc.n {
                g.advance(&pc, i + 1);
            }
        }
        // advance to an unstaged row must decode fresh, not reuse a buffer
        g.begin(&pc, 0);
        g.stage(&pc, 1, &w);
        g.advance(&pc, 4);
        pc.row_indices_scalar_into(4, &mut want);
        assert_eq!(g.indices(), &want[..]);
    }

    #[test]
    fn packed_entry_points_match_direct_kernels() {
        let pc = packed(6, 29, 4, 0xEE);
        let w = weights(29 << 6, 77);
        let mut idx = vec![0u32; pc.k];
        for i in 0..pc.n {
            pc.row_indices_into(i, &mut idx);
            assert_eq!(packed_dot(&pc, i, &w).to_bits(), dot_idx(&idx, &w).to_bits());
            let mut w1 = w.clone();
            let mut w2 = w.clone();
            packed_axpy(&pc, i, 0.5, &mut w1);
            axpy_idx(&idx, 0.5, &mut w2);
            assert_eq!(w1, w2);
            packed_prefetch(&pc, i, &w); // hint-only: must not panic
        }
    }
}
