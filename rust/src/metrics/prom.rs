//! Prometheus text exposition: one renderer for every `/metrics` body.
//!
//! The model server and the fleet router used to hand-roll their scrape
//! bodies in two different ad-hoc formats (bare `name value` lines, with
//! histograms flattened to `_p50`/`_p99` gauges).  This module replaces
//! both with the standard text format, so a real scraper — or the
//! promtool-style [`validate`] below, which CI runs against the live
//! endpoints via `scripts/check_metrics.sh` — can consume them:
//!
//! - `# HELP` / `# TYPE` precede each family;
//! - counters end in `_total`, time series are base-unit `_seconds`
//!   (the internal histograms count microseconds; [`Exposition::histogram`]
//!   takes a `scale` of `1e-6` to convert);
//! - histograms render *cumulative* `_bucket{le="..."}` samples plus
//!   `_sum`/`_count`, with the mandatory `le="+Inf"` bucket equal to
//!   `_count`.
//!
//! Bucket boundaries come from [`Histogram`]'s log₂ layout: bucket `i`
//! holds values with `64 − leading_zeros == i`, i.e. upper bound
//! `2^i − 1`, so the rendered `le` labels are `(2^i − 1)·scale` with the
//! last bucket open-ended.

use crate::metrics::Histogram;
use std::fmt::Write as _;

/// Builder for one exposition body.  Call the typed appenders in any
/// order, then [`finish`](Self::finish).
#[derive(Default)]
pub struct Exposition {
    out: String,
}

impl Exposition {
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    /// Monotone counter; Prometheus convention requires the `_total`
    /// suffix (enforced in debug builds, checked again by [`validate`]).
    pub fn counter(&mut self, name: &str, help: &str, value: u64) -> &mut Self {
        debug_assert!(name.ends_with("_total"), "counter {name} must end in _total");
        self.header(name, help, "counter");
        let _ = writeln!(self.out, "{name} {value}");
        self
    }

    pub fn gauge(&mut self, name: &str, help: &str, value: u64) -> &mut Self {
        debug_assert!(!name.ends_with("_total"), "gauge {name} must not look like a counter");
        self.header(name, help, "gauge");
        let _ = writeln!(self.out, "{name} {value}");
        self
    }

    /// Render a [`Histogram`] as cumulative buckets.  `scale` converts
    /// the observed integer unit to the exposed base unit (`1e-6` for
    /// histograms observed in microseconds and exposed as `_seconds`;
    /// `1.0` for unitless sizes/counts).
    pub fn histogram(&mut self, name: &str, help: &str, h: &Histogram, scale: f64) -> &mut Self {
        self.header(name, help, "histogram");
        let counts = h.bucket_counts();
        let mut cum = 0u64;
        for (i, c) in counts.iter().enumerate() {
            cum += c;
            if i + 1 == counts.len() {
                let _ = writeln!(self.out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            } else {
                let le = ((1u64 << i) - 1) as f64 * scale;
                let _ = writeln!(self.out, "{name}_bucket{{le=\"{le}\"}} {cum}");
            }
        }
        let _ = writeln!(self.out, "{name}_sum {}", h.sum() as f64 * scale);
        let _ = writeln!(self.out, "{name}_count {cum}");
        self
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Promtool-style format check, shared by unit tests, the e2e suite and
/// (re-implemented in shell+python) `scripts/check_metrics.sh`:
///
/// - every line is `# HELP`/`# TYPE` or `name[{le="..."}] value`;
/// - a family's `TYPE` appears exactly once, before any of its samples;
/// - counter samples end in `_total`;
/// - histogram `le` labels strictly increase, end at `+Inf`, cumulative
///   counts never decrease, and `+Inf == _count`;
/// - every sample value parses as a float.
pub fn validate(text: &str) -> std::result::Result<(), String> {
    use std::collections::HashMap;
    // family → declared type
    let mut types: HashMap<String, String> = HashMap::new();
    // histogram family → (last le, last cumulative, inf seen, count seen)
    struct HistState {
        last_le: f64,
        last_cum: u64,
        inf: Option<u64>,
        count: Option<u64>,
        sum: bool,
    }
    let mut hists: HashMap<String, HistState> = HashMap::new();

    for (lno, line) in text.lines().enumerate() {
        let n = lno + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let keyword = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            match keyword {
                "HELP" => {
                    if name.is_empty() {
                        return Err(format!("line {n}: HELP without a metric name"));
                    }
                }
                "TYPE" => {
                    let kind = parts.next().unwrap_or("");
                    if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                        return Err(format!("line {n}: unknown TYPE '{kind}' for {name}"));
                    }
                    if types.insert(name.to_string(), kind.to_string()).is_some() {
                        return Err(format!("line {n}: duplicate TYPE for {name}"));
                    }
                    if kind == "histogram" {
                        hists.insert(
                            name.to_string(),
                            HistState {
                                last_le: f64::NEG_INFINITY,
                                last_cum: 0,
                                inf: None,
                                count: None,
                                sum: false,
                            },
                        );
                    }
                }
                _ => return Err(format!("line {n}: unknown comment keyword '{keyword}'")),
            }
            continue;
        }
        if line.starts_with('#') {
            return Err(format!("line {n}: comment must start with '# '"));
        }
        // sample: name[{labels}] value
        let (name_labels, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {n}: sample has no value"))?;
        let value: f64 = value
            .parse()
            .map_err(|_| format!("line {n}: value '{value}' is not a float"))?;
        let (name, labels) = match name_labels.split_once('{') {
            Some((name, rest)) => {
                let labels = rest
                    .strip_suffix('}')
                    .ok_or_else(|| format!("line {n}: unterminated label set"))?;
                (name, Some(labels))
            }
            None => (name_labels, None),
        };
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || name.starts_with(|c: char| c.is_ascii_digit())
        {
            return Err(format!("line {n}: invalid metric name '{name}'"));
        }
        // resolve the family: histogram children strip their suffix
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|sfx| {
                let stem = name.strip_suffix(sfx)?;
                hists.contains_key(stem).then_some(stem)
            })
            .unwrap_or(name);
        let Some(kind) = types.get(family) else {
            return Err(format!("line {n}: sample '{name}' before any TYPE declaration"));
        };
        match kind.as_str() {
            "counter" => {
                if !name.ends_with("_total") {
                    return Err(format!("line {n}: counter sample '{name}' must end in _total"));
                }
                if value < 0.0 {
                    return Err(format!("line {n}: counter '{name}' is negative"));
                }
            }
            "histogram" => {
                let st = hists.get_mut(family).expect("tracked above");
                if name.ends_with("_bucket") {
                    let labels = labels
                        .ok_or_else(|| format!("line {n}: _bucket sample without le label"))?;
                    let le = labels
                        .strip_prefix("le=\"")
                        .and_then(|s| s.strip_suffix('"'))
                        .ok_or_else(|| format!("line {n}: malformed le label '{labels}'"))?;
                    let le_v = if le == "+Inf" {
                        f64::INFINITY
                    } else {
                        le.parse::<f64>()
                            .map_err(|_| format!("line {n}: le '{le}' is not a float"))?
                    };
                    if le_v <= st.last_le {
                        return Err(format!("line {n}: le labels must strictly increase"));
                    }
                    let cum = value as u64;
                    if cum < st.last_cum {
                        return Err(format!("line {n}: cumulative bucket counts decreased"));
                    }
                    st.last_le = le_v;
                    st.last_cum = cum;
                    if le_v.is_infinite() {
                        st.inf = Some(cum);
                    }
                } else if name.ends_with("_count") {
                    st.count = Some(value as u64);
                } else if name.ends_with("_sum") {
                    st.sum = true;
                } else {
                    return Err(format!(
                        "line {n}: bare sample '{name}' for histogram family '{family}'"
                    ));
                }
            }
            _ => {}
        }
    }
    for (family, st) in &hists {
        let inf = st
            .inf
            .ok_or_else(|| format!("histogram {family}: missing le=\"+Inf\" bucket"))?;
        let count = st
            .count
            .ok_or_else(|| format!("histogram {family}: missing _count"))?;
        if inf != count {
            return Err(format!(
                "histogram {family}: +Inf bucket {inf} != _count {count}"
            ));
        }
        if !st.sum {
            return Err(format!("histogram {family}: missing _sum"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_validates() {
        let h = Histogram::default();
        for v in [0u64, 1, 3, 900] {
            h.observe(v);
        }
        let mut exp = Exposition::new();
        exp.counter("serve_docs_scored_total", "Docs scored.", 42)
            .gauge("serve_queue_depth", "Jobs queued right now.", 3)
            .histogram("serve_queue_wait_seconds", "Admission wait.", &h, 1e-6)
            .histogram("serve_batch_size", "Docs per batch.", &h, 1.0);
        let body = exp.finish();
        validate(&body).unwrap_or_else(|e| panic!("{e}\n{body}"));
        assert!(body.contains("# TYPE serve_docs_scored_total counter"));
        assert!(body.contains("serve_docs_scored_total 42"));
        assert!(body.contains("# TYPE serve_queue_depth gauge"));
        assert!(body.contains("# TYPE serve_queue_wait_seconds histogram"));
        // µs → seconds scaling on the le labels; unitless keeps integers
        assert!(body.contains("serve_queue_wait_seconds_bucket{le=\"0.000001\"}"), "{body}");
        assert!(body.contains("serve_batch_size_bucket{le=\"1\"}"), "{body}");
        assert!(body.contains("serve_batch_size_bucket{le=\"+Inf\"} 4"), "{body}");
        assert!(body.contains("serve_batch_size_sum 904"), "{body}");
        assert!(body.contains("serve_batch_size_count 4"), "{body}");
    }

    #[test]
    fn buckets_are_cumulative_and_match_count() {
        let h = Histogram::default();
        for v in 0..100u64 {
            h.observe(v);
        }
        let mut exp = Exposition::new();
        exp.histogram("x_seconds", "h", &h, 1e-6);
        let body = exp.finish();
        validate(&body).unwrap();
        // last finite bucket already holds everything observed
        let inf_line = body
            .lines()
            .find(|l| l.contains("le=\"+Inf\""))
            .expect("+Inf bucket");
        assert!(inf_line.ends_with(" 100"), "{inf_line}");
        assert!(body.contains("x_seconds_count 100"));
    }

    #[test]
    fn validator_rejects_malformed_bodies() {
        // sample before TYPE
        assert!(validate("foo_total 1\n").is_err());
        // counter without _total
        let bad = "# HELP foo c\n# TYPE foo counter\nfoo 1\n";
        assert!(validate(bad).unwrap_err().contains("_total"));
        // non-monotonic le
        let bad = "# HELP h x\n# TYPE h histogram\n\
                   h_bucket{le=\"2\"} 1\nh_bucket{le=\"1\"} 2\n\
                   h_bucket{le=\"+Inf\"} 2\nh_sum 3\nh_count 2\n";
        assert!(validate(bad).unwrap_err().contains("strictly increase"));
        // cumulative counts must not decrease
        let bad = "# HELP h x\n# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\n\
                   h_bucket{le=\"+Inf\"} 5\nh_sum 3\nh_count 5\n";
        assert!(validate(bad).unwrap_err().contains("decreased"));
        // missing +Inf
        let bad = "# HELP h x\n# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 1\nh_sum 1\nh_count 1\n";
        assert!(validate(bad).unwrap_err().contains("+Inf"));
        // +Inf != count
        let bad = "# HELP h x\n# TYPE h histogram\n\
                   h_bucket{le=\"+Inf\"} 4\nh_sum 1\nh_count 5\n";
        assert!(validate(bad).unwrap_err().contains("_count"));
        // duplicate TYPE
        let bad = "# TYPE g gauge\n# TYPE g gauge\ng 1\n";
        assert!(validate(bad).unwrap_err().contains("duplicate"));
        // junk value
        let bad = "# TYPE g gauge\ng abc\n";
        assert!(validate(bad).unwrap_err().contains("not a float"));
        // a clean body still passes
        let ok = "# HELP up 1 when healthy.\n# TYPE up gauge\nup 1\n";
        validate(ok).unwrap();
    }
}
