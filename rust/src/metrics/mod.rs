//! Lightweight metrics: counters, gauges, timers and histograms with a
//! printable registry.  The pipeline and experiment harnesses report
//! through this module so every table in EXPERIMENTS.md comes from one
//! code path.
//!
//! Two submodules extend the primitives into a telemetry layer:
//!
//! - [`prom`] renders any set of counters/gauges/histograms in the
//!   Prometheus text exposition format — the single renderer behind both
//!   `/metrics` endpoints (model server and fleet router);
//! - [`trace`] is the structured-span side: request/stage spans with
//!   parent links and trace IDs, drained to a JSONL event log when
//!   `--trace-out` is set, near-zero cost when it is not.

pub mod prom;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Monotone counter (atomic; shared across pipeline threads).
#[derive(Default, Debug)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Instantaneous value (atomic; shared across threads).  Unlike a
/// [`Counter`] a gauge can move both ways — queue depth, loaded shards,
/// current model epoch.
#[derive(Default, Debug)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, v: u64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: u64) {
        // saturating: a racy decrement below zero clamps rather than wraps
        let _ = self
            .v
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                Some(cur.saturating_sub(n))
            });
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Wall-clock timer accumulating seconds (atomic micros internally).
#[derive(Default, Debug)]
pub struct Timer {
    micros: AtomicU64,
    count: AtomicU64,
}

impl Timer {
    /// Time one closure invocation.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.observe(t0.elapsed().as_secs_f64());
        out
    }

    pub fn observe(&self, seconds: f64) {
        self.micros
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn seconds(&self) -> f64 {
        self.micros.load(Ordering::Relaxed) as f64 * 1e-6
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_seconds(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.seconds() / c as f64
        }
    }
}

/// Fixed-bucket histogram (log-spaced), good enough for queue depths and
/// latency distributions in the pipeline.
#[derive(Debug)]
pub struct Histogram {
    /// bucket i counts values in [2^i-1, 2^i) scaled by `unit`
    buckets: Vec<AtomicU64>,
    /// running sum of observed values (Prometheus `_sum` needs it)
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..32).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn observe(&self, v: u64) {
        let idx = (64 - v.leading_zeros()).min(31) as usize;
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Sum of every observed value (same unit the values were observed in).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Snapshot of the per-bucket counts; bucket `i` covers values with
    /// `64 - leading_zeros == i`, i.e. upper bound `2^i - 1` (the last
    /// bucket is open-ended).  [`prom::Exposition::histogram`] renders
    /// these as cumulative `_bucket{le=...}` samples.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Upper-bound estimate of the p-quantile (0..=1).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << i).saturating_sub(1).max(if i == 0 { 0 } else { 1 << (i - 1) });
            }
        }
        u64::MAX
    }
}

/// Named metric registry (string keys, printable summary).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    timers: Mutex<BTreeMap<String, std::sync::Arc<Timer>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn timer(&self, name: &str) -> std::sync::Arc<Timer> {
        self.timers
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Render a two-column summary of everything observed.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (name, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{name:<40} {}\n", c.get()));
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{name:<40} {} (gauge)\n", g.get()));
        }
        for (name, t) in self.timers.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name:<40} {:.3}s over {} obs (mean {:.3}ms)\n",
                t.seconds(),
                t.count(),
                t.mean_seconds() * 1e3,
            ));
        }
        for (name, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{name:<40} {} obs (p50 ≤{} p99 ≤{})\n",
                h.count(),
                h.quantile(0.5),
                h.quantile(0.99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timers() {
        let reg = Registry::default();
        reg.counter("docs").add(10);
        reg.counter("docs").inc();
        assert_eq!(reg.counter("docs").get(), 11);
        let t = reg.timer("hash");
        let v = t.time(|| 42);
        assert_eq!(v, 42);
        assert_eq!(t.count(), 1);
        assert!(t.seconds() >= 0.0);
        let s = reg.summary();
        assert!(s.contains("docs") && s.contains("hash"));
    }

    #[test]
    fn gauges_move_both_ways() {
        let reg = Registry::default();
        let g = reg.gauge("queue_depth");
        g.set(7);
        g.add(3);
        g.sub(4);
        assert_eq!(reg.gauge("queue_depth").get(), 6);
        g.sub(100); // saturates at zero instead of wrapping
        assert_eq!(g.get(), 0);
        let s = reg.summary();
        assert!(s.contains("queue_depth") && s.contains("(gauge)"), "{s}");
    }

    #[test]
    fn histogram_sum_and_buckets() {
        let h = Histogram::default();
        for v in [0u64, 1, 2, 3, 1000] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1006);
        let buckets = h.bucket_counts();
        assert_eq!(buckets.len(), 32);
        assert_eq!(buckets.iter().sum::<u64>(), 5);
        assert_eq!(buckets[0], 1); // v=0
        assert_eq!(buckets[1], 1); // v=1
        assert_eq!(buckets[2], 2); // v in {2,3}
        assert_eq!(buckets[10], 1); // v=1000 (512..1023)
    }

    #[test]
    fn histogram_quantiles() {
        let h = Histogram::default();
        for v in 1..=1000u64 {
            h.observe(v);
        }
        assert_eq!(h.count(), 1000);
        let med = h.quantile(0.5);
        assert!((256..=1024).contains(&med), "{med}");
        assert!(h.quantile(1.0) >= 512);
    }

    #[test]
    fn registry_histograms_share_and_summarize() {
        let reg = Registry::default();
        let h = reg.histogram("serve.batch");
        for v in [1u64, 2, 4, 100] {
            h.observe(v);
        }
        // the registry hands back the same histogram for the same name
        assert_eq!(reg.histogram("serve.batch").count(), 4);
        let s = reg.summary();
        assert!(s.contains("serve.batch") && s.contains("4 obs"), "{s}");
    }

    #[test]
    fn shared_across_threads() {
        let reg = std::sync::Arc::new(Registry::default());
        let c = reg.counter("x");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }
}
