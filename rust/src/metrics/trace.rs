//! Structured tracing spans drained to a JSONL event log.
//!
//! The serving fleet (router → backend → batcher → kernel) and the
//! offline pipeline (reader → parse → encode → sink) both need the same
//! thing the paper's Table 2 needed: *attribution* — which stage a
//! second of wall clock went to.  Counters summarize; spans explain one
//! slow request.  This module is the span side of the telemetry layer:
//!
//! - **Near-zero cost when disabled.**  Tracing is off unless
//!   `--trace-out FILE` initialized it ([`init_file`]).  Every entry
//!   point starts with one relaxed atomic load; a disabled
//!   [`Span`] takes no timestamp, allocates nothing and writes nothing
//!   (`Vec::new()` does not allocate), so instrumented hot paths stay
//!   within the ≤1% bench budget.
//! - **Per-thread buffers.**  Enabled spans serialize into a
//!   thread-local `String` and drain to the shared `BufWriter` under one
//!   short lock — when the thread's span stack empties (end of a
//!   request / pipeline run), when the buffer passes 32 KiB, or when the
//!   thread exits.  The hot path never takes the sink lock per event.
//! - **Parent links + trace IDs.**  A [`TraceCtx`] is `Copy` and travels
//!   across threads and (as the `X-Trace-Id` header, see
//!   [`serve`](crate::serve)) across processes, so one JSONL file
//!   reconstructs a request's full fleet path.  ID helpers
//!   ([`gen_id`]/[`parse_id`]/[`format_id`]) work whether or not tracing
//!   is enabled — header propagation is unconditional, only the event
//!   log is gated.
//!
//! ## JSONL schema
//!
//! One event per line.  Spans:
//!
//! ```text
//! {"kind":"span","name":"serve.kernel","trace":"<16 hex>","span":7,
//!  "parent":3,"t_us":1234,"dur_us":56,"fields":{"docs":4}}
//! ```
//!
//! `span` IDs are process-unique (monotone counter); `parent` is `0` for
//! a root span; `t_us` is microseconds since [`init_file`] (monotonic
//! clock, one epoch per process).  Points (instant events, e.g.
//! `train.epoch`) carry `kind":"point"`, no `span`/`dur_us`, and a
//! `parent` only when emitted under an open span.  Names and field keys
//! are `&'static str` from call sites and must stay JSON-safe
//! (`[a-z0-9._]`); values are finite `f64` (non-finite renders `null`).
//!
//! The span taxonomy (which names exist and how they nest) is documented
//! in the crate root ("Observability" in `lib.rs`).

use std::cell::RefCell;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::{Error, Result};

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: OnceLock<Mutex<BufWriter<File>>> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();
/// Process-unique span ids; 0 is reserved for "no span".
static NEXT_SPAN: AtomicU64 = AtomicU64::new(1);
static ID_SEED: AtomicU64 = AtomicU64::new(0);

/// Drain a thread buffer to the sink once it passes this size even if
/// the span stack is still open (long pipeline runs).
const FLUSH_BYTES: usize = 32 * 1024;

/// Route all subsequently emitted events to `path` (JSONL, truncated).
/// One sink per process: the CLI calls this once, before any work, when
/// `--trace-out` is set.  A second call is an error.
pub fn init_file<P: AsRef<Path>>(path: P) -> Result<()> {
    let file = File::create(path)?;
    let _ = EPOCH.set(Instant::now());
    if SINK.set(Mutex::new(BufWriter::new(file))).is_err() {
        return Err(Error::InvalidArg(
            "trace sink already initialized (--trace-out is once per process)".into(),
        ));
    }
    ENABLED.store(true, Ordering::Release);
    Ok(())
}

/// Is event emission on?  (Header propagation does not check this.)
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Drain the calling thread's buffer and flush the sink to disk.  The
/// CLI calls this before exiting; worker threads drain themselves on
/// exit (thread-local destructor) or when their span stack empties.
pub fn flush() {
    let _ = TLS.try_with(|t| drain(&mut t.borrow_mut().buf));
    if let Some(sink) = SINK.get() {
        if let Ok(mut w) = sink.lock() {
            let _ = w.flush();
        }
    }
}

fn epoch() -> &'static Instant {
    EPOCH.get_or_init(Instant::now)
}

/// A span's coordinates: enough to parent children across threads and
/// to echo the trace id on the wire.  `Copy`, 16 bytes, `Default` is
/// the null context (trace 0 = untraced).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    /// Request/run-scoped id, 64-bit, rendered as 16 hex chars.
    pub trace: u64,
    /// The span itself (0 when tracing is disabled).
    pub span: u64,
}

/// A timed scope.  Emits one `"span"` event on drop when tracing is
/// enabled; a disabled span is inert (no timestamp, no allocation).
pub struct Span {
    name: &'static str,
    ctx: TraceCtx,
    parent: u64,
    start: Option<Instant>,
    fields: Vec<(&'static str, f64)>,
}

impl Span {
    /// Open a span under the calling thread's innermost open span (a
    /// fresh root with a generated trace id if there is none).
    pub fn enter(name: &'static str) -> Span {
        if !enabled() {
            return Span::inert(name, 0);
        }
        let (trace, parent) = TLS
            .try_with(|t| t.borrow().stack.last().copied())
            .ok()
            .flatten()
            .unwrap_or((0, 0));
        let trace = if trace == 0 { gen_id() } else { trace };
        Span::live(name, trace, parent)
    }

    /// Open a root span (parent 0) for an externally supplied trace id —
    /// the serve path, where the id arrives in (or is generated for) the
    /// `X-Trace-Id` header.  The id is carried even when tracing is
    /// disabled so [`ctx`](Self::ctx) keeps working for header echo.
    pub fn root(name: &'static str, trace: u64) -> Span {
        if !enabled() {
            return Span::inert(name, trace);
        }
        Span::live(name, trace, 0)
    }

    /// Open a child of an explicit context — the cross-thread form
    /// (scatter-gather legs, scorer workers, pipeline stages).
    pub fn child(name: &'static str, ctx: TraceCtx) -> Span {
        if !enabled() {
            return Span::inert(name, ctx.trace);
        }
        Span::live(name, ctx.trace, ctx.span)
    }

    fn inert(name: &'static str, trace: u64) -> Span {
        Span { name, ctx: TraceCtx { trace, span: 0 }, parent: 0, start: None, fields: Vec::new() }
    }

    fn live(name: &'static str, trace: u64, parent: u64) -> Span {
        let span = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
        let _ = TLS.try_with(|t| t.borrow_mut().stack.push((trace, span)));
        Span {
            name,
            ctx: TraceCtx { trace, span },
            parent,
            start: Some(Instant::now()),
            fields: Vec::new(),
        }
    }

    /// Coordinates for parenting children (valid even cross-thread).
    pub fn ctx(&self) -> TraceCtx {
        self.ctx
    }

    /// Attach a numeric field to the span's event (no-op when inert).
    pub fn record(&mut self, key: &'static str, value: f64) {
        if self.start.is_some() {
            self.fields.push((key, value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let t_us = start.saturating_duration_since(*epoch()).as_micros() as u64;
        let dur_us = start.elapsed().as_micros() as u64;
        let line = event_json(
            "span",
            self.name,
            self.ctx.trace,
            Some(self.ctx.span),
            Some(self.parent),
            t_us,
            Some(dur_us),
            &self.fields,
        );
        let _ = TLS.try_with(|t| {
            let mut t = t.borrow_mut();
            if t.stack.last() == Some(&(self.ctx.trace, self.ctx.span)) {
                t.stack.pop();
            }
            t.buf.push_str(&line);
            if t.stack.is_empty() || t.buf.len() >= FLUSH_BYTES {
                drain(&mut t.buf);
            }
        });
    }
}

/// Emit a span retroactively from two captured instants — for scopes
/// whose start was measured before the emitting code runs (admission
/// wait measured from enqueue time, pipeline stage timings the report
/// already collects).  Allocates a fresh span id under `ctx`.
pub fn emit_span(
    name: &'static str,
    ctx: TraceCtx,
    start: Instant,
    end: Instant,
    fields: &[(&'static str, f64)],
) {
    if !enabled() {
        return;
    }
    let span = NEXT_SPAN.fetch_add(1, Ordering::Relaxed);
    let t_us = start.saturating_duration_since(*epoch()).as_micros() as u64;
    let dur_us = end.saturating_duration_since(start).as_micros() as u64;
    push_line(event_json("span", name, ctx.trace, Some(span), Some(ctx.span), t_us, Some(dur_us), fields));
}

/// Emit an instant event (no duration) — per-epoch training loss, etc.
/// Parented under the calling thread's innermost open span, if any.
pub fn point(name: &'static str, fields: &[(&'static str, f64)]) {
    if !enabled() {
        return;
    }
    let top = TLS.try_with(|t| t.borrow().stack.last().copied()).ok().flatten();
    let (trace, parent) = top.map_or((0, None), |(tr, sp)| (tr, Some(sp)));
    let t_us = epoch().elapsed().as_micros() as u64;
    push_line(event_json("point", name, trace, None, parent, t_us, None, fields));
}

/// Generate a nonzero 64-bit id (splitmix64 over wall clock ⊕ counter ⊕
/// pid — unique enough for correlating logs, not a security token).
pub fn gen_id() -> u64 {
    let seed = ID_SEED.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let mut z = nanos ^ seed ^ ((std::process::id() as u64) << 32);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)).max(1)
}

/// Parse a wire trace id (1–16 hex chars, nonzero).
pub fn parse_id(s: &str) -> Option<u64> {
    let s = s.trim();
    if s.is_empty() || s.len() > 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok().filter(|&v| v != 0)
}

/// Render a trace id for the wire (16 hex chars, zero-padded).
pub fn format_id(id: u64) -> String {
    format!("{id:016x}")
}

// ---- per-thread buffering ----

struct ThreadBuf {
    buf: String,
    /// Innermost-last stack of (trace, span) open on this thread.
    stack: Vec<(u64, u64)>,
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        drain(&mut self.buf);
    }
}

thread_local! {
    static TLS: RefCell<ThreadBuf> =
        RefCell::new(ThreadBuf { buf: String::new(), stack: Vec::new() });
}

fn drain(buf: &mut String) {
    if buf.is_empty() {
        return;
    }
    if let Some(sink) = SINK.get() {
        if let Ok(mut w) = sink.lock() {
            let _ = w.write_all(buf.as_bytes());
        }
    }
    buf.clear();
}

fn push_line(line: String) {
    let _ = TLS.try_with(|t| {
        let mut t = t.borrow_mut();
        t.buf.push_str(&line);
        if t.stack.is_empty() || t.buf.len() >= FLUSH_BYTES {
            drain(&mut t.buf);
        }
    });
}

/// One JSONL event.  Names/keys are static strings the call sites keep
/// JSON-safe; this renderer does no escaping by design.
#[allow(clippy::too_many_arguments)]
fn event_json(
    kind: &str,
    name: &str,
    trace: u64,
    span: Option<u64>,
    parent: Option<u64>,
    t_us: u64,
    dur_us: Option<u64>,
    fields: &[(&'static str, f64)],
) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(128);
    let _ = write!(
        s,
        "{{\"kind\":\"{kind}\",\"name\":\"{name}\",\"trace\":\"{}\"",
        format_id(trace)
    );
    if let Some(id) = span {
        let _ = write!(s, ",\"span\":{id}");
    }
    if let Some(p) = parent {
        let _ = write!(s, ",\"parent\":{p}");
    }
    let _ = write!(s, ",\"t_us\":{t_us}");
    if let Some(d) = dur_us {
        let _ = write!(s, ",\"dur_us\":{d}");
    }
    if !fields.is_empty() {
        s.push_str(",\"fields\":{");
        for (i, (k, v)) in fields.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            if v.is_finite() {
                let _ = write!(s, "\"{k}\":{v}");
            } else {
                let _ = write!(s, "\"{k}\":null");
            }
        }
        s.push('}');
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // NB: no test here calls init_file — the sink is once-per-process and
    // unit tests share a process.  File-backed emission is covered by
    // tests/telemetry_e2e.rs (its own binary) and the CI trace smoke.

    #[test]
    fn ids_roundtrip_and_reject_junk() {
        let id = gen_id();
        assert_ne!(id, 0);
        assert_ne!(id, gen_id());
        let wire = format_id(id);
        assert_eq!(wire.len(), 16);
        assert_eq!(parse_id(&wire), Some(id));
        assert_eq!(parse_id("00000000000000ff"), Some(255));
        assert_eq!(parse_id("0"), None, "zero is the null trace");
        assert_eq!(parse_id(""), None);
        assert_eq!(parse_id("xyz"), None);
        assert_eq!(parse_id("11112222333344445"), None, "17 chars overflows");
        assert_eq!(parse_id(" ab "), Some(0xab), "surrounding whitespace ok");
    }

    #[test]
    fn disabled_spans_are_inert_but_carry_the_trace_id() {
        assert!(!enabled());
        let mut root = Span::root("test.root", 0xDEAD);
        root.record("x", 1.0);
        let ctx = root.ctx();
        assert_eq!(ctx.trace, 0xDEAD);
        assert_eq!(ctx.span, 0, "disabled spans allocate no span id");
        let child = Span::child("test.child", ctx);
        assert_eq!(child.ctx().trace, 0xDEAD);
        drop(child);
        drop(root); // must not emit or panic
        emit_span("test.retro", ctx, Instant::now(), Instant::now(), &[("n", 2.0)]);
        point("test.point", &[("loss", 0.5)]);
        let entered = Span::enter("test.enter");
        assert_eq!(entered.ctx().span, 0);
    }

    #[test]
    fn event_json_schema() {
        let line = event_json(
            "span",
            "serve.kernel",
            0xABC,
            Some(7),
            Some(3),
            1234,
            Some(56),
            &[("docs", 4.0), ("loss", 0.25), ("bad", f64::NAN)],
        );
        assert_eq!(
            line,
            "{\"kind\":\"span\",\"name\":\"serve.kernel\",\
             \"trace\":\"0000000000000abc\",\"span\":7,\"parent\":3,\
             \"t_us\":1234,\"dur_us\":56,\
             \"fields\":{\"docs\":4,\"loss\":0.25,\"bad\":null}}\n"
        );
        let pt = event_json("point", "train.epoch", 0, None, None, 9, None, &[]);
        assert_eq!(
            pt,
            "{\"kind\":\"point\",\"name\":\"train.epoch\",\
             \"trace\":\"0000000000000000\",\"t_us\":9}\n"
        );
    }
}
