//! Figure 8: true permutations vs 2-universal hashing on webspam-like
//! data, averaged over many runs (paper: 50; scaled by `fig8_runs`).
//!
//! Section 7's claim: the simplest 2-universal family is statistically
//! indistinguishable from true permutations for learning — the curves
//! should overlap within Monte-Carlo noise.  The "true permutation" arm
//! uses the storage-free Feistel bijection (DESIGN.md §5 substitution;
//! exact Fisher–Yates tables are also implemented and used at small D in
//! the unit tests).

use crate::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use crate::data::dataset::SparseDataset;
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::hashing::minwise::{bbit_truncate, MinwiseHasher, PermutationMinwise};
use crate::hashing::permutation::FeistelPermutation;
use crate::report::{fnum, Table};
use crate::util::stats;
use crate::util::Rng;
use crate::Result;

use super::Ctx;

fn hash_with<FH>(ds: &SparseDataset, k: usize, b: u32, mut hash_into: FH) -> BbitDataset
where
    FH: FnMut(&[u32], &mut [u64]),
{
    let mut pc = PackedCodes::new(b, k);
    let mut scratch = vec![0u64; k];
    let mut row = vec![0u16; k];
    for i in 0..ds.len() {
        hash_into(ds.row(i).0, &mut scratch);
        for (j, slot) in row.iter_mut().enumerate() {
            *slot = bbit_truncate(scratch[j], b);
        }
        pc.push_row(&row).unwrap();
    }
    BbitDataset::new(pc, ds.labels.clone())
}

pub fn run(ctx: &mut Ctx) -> Result<Vec<Table>> {
    let scale = ctx.scale.clone();
    let (train, test) = ctx.webspam()?.clone();
    let d = train.dim;
    let b = 8u32;
    let k_list: Vec<usize> = scale.k_grid.iter().copied().take(3).collect();
    let c_list = [0.1, 1.0, 10.0];
    let runs = scale.fig8_runs;
    let sched = Scheduler::new(scale.workers);

    let mut t = Table::new(
        &format!(
            "webspam-like accuracy: permutations vs 2-universal hashing (Figure 8 shape, b={b}, {runs}-run mean±sd)"
        ),
        &["solver", "k", "C", "perm acc %", "2u acc %", "perm sd", "2u sd"],
    );

    for kind in [SolverKind::SvmDcd, SolverKind::LrNewton] {
        for &k in &k_list {
            for &c in &c_list {
                let (mut acc_perm, mut acc_univ) = (Vec::new(), Vec::new());
                for run in 0..runs {
                    let seed = scale.seed ^ (run as u64) << 8 ^ k as u64;
                    // permutation arm
                    let mut rng = Rng::new(seed);
                    let perms: Vec<FeistelPermutation> =
                        (0..k).map(|_| FeistelPermutation::draw(d, &mut rng)).collect();
                    let pm = PermutationMinwise::new(perms);
                    let tr = hash_with(&train, k, b, |s, out| pm.hash_into(s, out));
                    let te = hash_with(&test, k, b, |s, out| pm.hash_into(s, out));
                    let o = sched.run_grid(
                        &tr,
                        &te,
                        &[TrainJob { tag: String::new(), solver: kind, c }],
                    )?;
                    acc_perm.push(100.0 * o[0].test_accuracy);
                    // 2-universal arm (independent draw)
                    let mut rng = Rng::new(seed ^ 0xABCD);
                    let mh = MinwiseHasher::draw(k, d, &mut rng);
                    let tr = hash_with(&train, k, b, |s, out| mh.hash_into(s, out));
                    let te = hash_with(&test, k, b, |s, out| mh.hash_into(s, out));
                    let o = sched.run_grid(
                        &tr,
                        &te,
                        &[TrainJob { tag: String::new(), solver: kind, c }],
                    )?;
                    acc_univ.push(100.0 * o[0].test_accuracy);
                }
                t.row(&[
                    format!("{kind:?}"),
                    k.to_string(),
                    c.to_string(),
                    fnum(stats::mean(&acc_perm)),
                    fnum(stats::mean(&acc_univ)),
                    fnum(stats::stddev(&acc_perm)),
                    fnum(stats::stddev(&acc_univ)),
                ]);
            }
            eprintln!("[fig8] {kind:?} k={k} done");
        }
    }
    ctx.emit(&t, "fig8_perm_vs_universal.csv")?;
    Ok(vec![t])
}
