//! Shared experiment context: scaled-down dataset construction, one-time
//! hashing passes, and per-(b, k) views.
//!
//! Scaling strategy (DESIGN.md §4): the paper's expanded rcv1 is
//! n = 677,399 / D ≈ 1.01e9 / 200 GB; the default scale keeps every
//! *structural* property (binary sparse sets, resemblance-borne labels,
//! r = f/D → 0, the same expansion rule) at laptop size.  `--scale paper`
//! raises the knobs for bigger machines.
//!
//! The 16-bit trick: minwise values are hashed **once** per corpus at
//! `k = kmax`, stored as 16-bit codes; every (b ≤ 16, k ≤ kmax) cell of a
//! figure grid is derived by `truncate_bits`/`truncate_k` — exactly how
//! the paper re-uses one preprocessing pass across its whole grid.

use std::collections::BTreeMap;

use crate::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
use crate::data::dataset::SparseDataset;
use crate::data::expand::{expand_dataset, ExpandConfig};
use crate::data::gen::{CorpusConfig, CorpusGenerator};
use crate::encode::encoder::EncoderSpec;
use crate::encode::expansion::BbitDataset;
use crate::report::Table;
use crate::util::Rng;
use crate::Result;

/// Which solver a comparison uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverSel {
    Svm,
    Lr,
}

impl SolverSel {
    pub fn name(self) -> &'static str {
        match self {
            SolverSel::Svm => "linear SVM",
            SolverSel::Lr => "logistic regression",
        }
    }
}

/// Experiment scale knobs.
#[derive(Clone, Debug)]
pub struct Scale {
    pub n_docs: usize,
    pub vocab: u32,
    pub mean_tokens: f64,
    /// Expanded dimensionality D.
    pub dim: u64,
    /// One-time hashing width; every k in `k_grid` must be ≤ kmax.
    pub kmax: usize,
    pub k_grid: Vec<usize>,
    pub b_grid: Vec<u32>,
    pub c_grid: Vec<f64>,
    /// VW bin grid (paper: 2^5..2^14).
    pub vw_bins_grid: Vec<usize>,
    /// Figure-8 averaging runs (paper: 50).
    pub fig8_runs: usize,
    pub seed: u64,
    pub workers: usize,
    pub results_dir: String,
}

impl Scale {
    /// Laptop scale — `experiments all` in minutes.
    pub fn small() -> Self {
        Scale {
            n_docs: 3000,
            vocab: 3000,
            mean_tokens: 30.0,
            dim: 1 << 30,
            kmax: 256,
            k_grid: vec![30, 64, 128, 256],
            b_grid: vec![1, 2, 4, 8, 12, 16],
            c_grid: crate::coordinator::scheduler::paper_c_grid(),
            vw_bins_grid: vec![32, 64, 128, 256, 512, 1024, 2048, 4096],
            fig8_runs: 10,
            seed: 0xB_B17,
            workers: crate::config::available_workers(),
            results_dir: "results".into(),
        }
    }

    /// Closer to the paper's grid (hours, big RAM).
    pub fn paper() -> Self {
        Scale {
            n_docs: 40_000,
            vocab: 12_000,
            mean_tokens: 40.0,
            kmax: 512,
            k_grid: vec![30, 50, 100, 150, 200, 300, 500],
            vw_bins_grid: (5..=14).map(|e| 1usize << e).collect(),
            fig8_runs: 50,
            ..Scale::small()
        }
    }

    /// CI scale — seconds; used by integration tests.
    pub fn tiny() -> Self {
        Scale {
            n_docs: 400,
            vocab: 800,
            mean_tokens: 15.0,
            dim: 1 << 26,
            kmax: 64,
            k_grid: vec![16, 64],
            b_grid: vec![1, 4, 8],
            c_grid: vec![0.1, 1.0],
            vw_bins_grid: vec![64, 256, 1024],
            fig8_runs: 3,
            seed: 0xB_B17,
            workers: 2,
            results_dir: "results".into(),
        }
    }
}

/// Lazily-built shared state for all experiments.
pub struct Ctx {
    pub scale: Scale,
    /// Expanded rcv1-like split.
    rcv1: Option<(SparseDataset, SparseDataset)>,
    /// 16-bit kmax-wide codes for (train, test).
    codes16: Option<(crate::encode::packed::PackedCodes, crate::encode::packed::PackedCodes)>,
    /// Cache of derived (b, k) views.
    views: BTreeMap<(u32, usize), (BbitDataset, BbitDataset)>,
    /// webspam-like corpus for Figure 8.
    webspam: Option<(SparseDataset, SparseDataset)>,
}

impl Ctx {
    pub fn new(scale: Scale) -> Self {
        Ctx { scale, rcv1: None, codes16: None, views: BTreeMap::new(), webspam: None }
    }

    pub fn pipeline(&self) -> Pipeline {
        Pipeline::new(PipelineConfig {
            workers: self.scale.workers,
            chunk_size: 256,
            queue_depth: 4,
        })
    }

    /// The expanded rcv1-like (train, test) pair, built on first use.
    pub fn rcv1(&mut self) -> Result<&(SparseDataset, SparseDataset)> {
        if self.rcv1.is_none() {
            let s = &self.scale;
            eprintln!(
                "[ctx] generating rcv1-like corpus: n={} vocab={} (expansion to D=2^{})",
                s.n_docs,
                s.vocab,
                s.dim.trailing_zeros()
            );
            let base = CorpusGenerator::new(CorpusConfig {
                n_docs: s.n_docs,
                vocab: s.vocab,
                zipf_alpha: 1.05,
                mean_tokens: s.mean_tokens,
                class_signal: 0.55,
                pos_fraction: 0.47,
                seed: s.seed,
            })
            .generate();
            let cfg = ExpandConfig { vocab: s.vocab, dim: s.dim, three_way_rate: 30, seed: s.seed ^ 0xEE };
            cfg.validate()?;
            let expanded = expand_dataset(&cfg, &base);
            // paper: 50/50 split for rcv1
            let (train, test) = expanded.split(0.5, &mut Rng::new(s.seed ^ 0x51));
            self.rcv1 = Some((train, test));
        }
        Ok(self.rcv1.as_ref().unwrap())
    }

    /// One-time 16-bit × kmax hashing pass over the rcv1 split (through
    /// the production pipeline), cached.
    fn codes16(&mut self) -> Result<&(crate::encode::packed::PackedCodes, crate::encode::packed::PackedCodes)> {
        if self.codes16.is_none() {
            let kmax = self.scale.kmax;
            let seed = self.scale.seed ^ 0x4A5E;
            let dim = self.scale.dim;
            let pipe = self.pipeline();
            let (train, test) = self.rcv1()?.clone();
            eprintln!("[ctx] hashing corpus once at b=16, k={kmax}");
            let job = EncoderSpec::Bbit { b: 16, k: kmax, d: dim, seed };
            let (out_tr, _) = pipe.run(dataset_chunks(&train, 256), &job)?;
            let (out_te, _) = pipe.run(dataset_chunks(&test, 256), &job)?;
            let tr = out_tr.into_bbit()?;
            let te = out_te.into_bbit()?;
            debug_assert_eq!(tr.labels, train.labels);
            self.codes16 = Some((tr.codes, te.codes));
        }
        Ok(self.codes16.as_ref().unwrap())
    }

    /// (train, test) b-bit view for one grid cell, derived from the 16-bit
    /// pass and cached.
    pub fn bbit_view(&mut self, b: u32, k: usize) -> Result<&(BbitDataset, BbitDataset)> {
        if !self.views.contains_key(&(b, k)) {
            let (tr_labels, te_labels) = {
                let (train, test) = self.rcv1()?;
                (train.labels.clone(), test.labels.clone())
            };
            let (c_tr, c_te) = self.codes16()?;
            let tr = c_tr.truncate_k(k)?.truncate_bits(b)?;
            let te = c_te.truncate_k(k)?.truncate_bits(b)?;
            self.views.insert(
                (b, k),
                (BbitDataset::new(tr, tr_labels), BbitDataset::new(te, te_labels)),
            );
        }
        Ok(&self.views[&(b, k)])
    }

    /// VW-hash the rcv1 split into `bins` (not cached — each bins value is
    /// used once per run).
    pub fn vw_view(&mut self, bins: usize) -> Result<(SparseDataset, SparseDataset)> {
        let seed = self.scale.seed ^ 0x77;
        let pipe = self.pipeline();
        let (train, test) = self.rcv1()?.clone();
        let job = EncoderSpec::Vw { bins, seed };
        let (out_tr, _) = pipe.run(dataset_chunks(&train, 256), &job)?;
        let (out_te, _) = pipe.run(dataset_chunks(&test, 256), &job)?;
        Ok((out_tr.into_vw()?, out_te.into_vw()?))
    }

    /// webspam-like (train, test) pair (no expansion; for Figure 8).
    pub fn webspam(&mut self) -> Result<&(SparseDataset, SparseDataset)> {
        if self.webspam.is_none() {
            let s = &self.scale;
            // scale webspam along with the rcv1 preset but keep D feasible
            // for explicit permutation tables
            let ds = CorpusGenerator::new(CorpusConfig {
                n_docs: s.n_docs.min(2000),
                vocab: 1 << 18,
                zipf_alpha: 1.02,
                mean_tokens: 4.0 * s.mean_tokens,
                class_signal: 0.5,
                pos_fraction: 0.61,
                seed: s.seed ^ 0x3B,
            })
            .generate();
            // paper: 80/20 split for webspam
            let (train, test) = ds.split(0.8, &mut Rng::new(s.seed ^ 0x82));
            self.webspam = Some((train, test));
        }
        Ok(self.webspam.as_ref().unwrap())
    }

    /// Print a table and save its CSV under `results/`.
    pub fn emit(&self, t: &Table, csv_name: &str) -> Result<()> {
        println!("{}", t.render());
        let path = std::path::Path::new(&self.scale.results_dir).join(csv_name);
        t.write_csv(&path)?;
        eprintln!("[csv] {}", path.display());
        Ok(())
    }
}
