//! Table 1: dataset statistics (n, D, nonzeros median/mean, split).

use crate::report::{fnum, Table};
use crate::Result;

use super::Ctx;

pub fn run(ctx: &mut Ctx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table 1 — dataset information (paper: webspam 24 GB n=350000 D=16.6M nnz~3889/3728 80/20; rcv1 200 GB n=677399 D=1.01e9 nnz~3051/12062 50/50)",
        &["dataset", "examples (n)", "dims (D)", "nnz median", "nnz mean", "libsvm size", "split"],
    );
    {
        let (tr, te) = ctx.webspam()?;
        let mut all = tr.clone();
        for ex in te.iter() {
            all.push(&ex);
        }
        let s = all.stats();
        t.row(&[
            "webspam-like (gen)".into(),
            s.n.to_string(),
            s.dim.to_string(),
            fnum(s.nnz_median),
            fnum(s.nnz_mean),
            human_bytes(s.bytes_libsvm),
            "80% / 20%".into(),
        ]);
    }
    {
        let (tr, te) = ctx.rcv1()?;
        let mut all = tr.clone();
        for ex in te.iter() {
            all.push(&ex);
        }
        let s = all.stats();
        t.row(&[
            "rcv1-like expanded (gen)".into(),
            s.n.to_string(),
            s.dim.to_string(),
            fnum(s.nnz_median),
            fnum(s.nnz_mean),
            human_bytes(s.bytes_libsvm),
            "50% / 50%".into(),
        ]);
    }
    ctx.emit(&t, "table1.csv")?;
    Ok(vec![t])
}

pub fn human_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.1} GB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.1} MB", b as f64 / (1u64 << 20) as f64)
    } else {
        format!("{:.1} KB", b as f64 / (1u64 << 10) as f64)
    }
}
