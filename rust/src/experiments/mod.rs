//! Experiment harnesses — one per table/figure of the paper (DESIGN.md §4).
//!
//! Every harness prints paper-style rows through [`crate::report::Table`]
//! and writes a CSV under `results/` so the figures can be re-plotted.
//! `bbit-mh experiments all` regenerates everything recorded in
//! EXPERIMENTS.md.
//!
//! | id | paper artifact | module |
//! |----|----------------|--------|
//! | `table1` | Table 1 (dataset stats) | [`table1`] |
//! | `fig1`..`fig4` | b-bit accuracy/time grids (SVM, LR) | [`figs1_4`] |
//! | `fig5`,`fig6` | VW vs b-bit accuracy | [`figs5_7`] |
//! | `fig7` | VW vs 8-bit train time | [`figs5_7`] |
//! | `fig8` | permutations vs 2-universal | [`fig8`] |
//! | `table2` | loading vs preprocessing cost | [`table2`] |
//! | `variance` | Eqs. 2/7/13/16 validation | [`variance`] |
//! | `fig9` | VW-on-top-of-16-bit trick (§5.4) | [`fig9`] |

pub mod context;
pub mod fig8;
pub mod fig9;
pub mod figs1_4;
pub mod figs5_7;
pub mod table1;
pub mod table2;
pub mod variance;

use crate::report::Table;
use crate::Result;

pub use context::{Ctx, Scale};

/// Run one experiment by id; returns the rendered tables.
pub fn run(id: &str, ctx: &mut Ctx) -> Result<Vec<Table>> {
    match id {
        "table1" => table1::run(ctx),
        "fig1" | "fig2" => figs1_4::run_svm(ctx),
        "fig3" | "fig4" => figs1_4::run_lr(ctx),
        "fig5" => figs5_7::run_accuracy(ctx, context::SolverSel::Svm),
        "fig6" => figs5_7::run_accuracy(ctx, context::SolverSel::Lr),
        "fig7" => figs5_7::run_time(ctx),
        "fig8" => fig8::run(ctx),
        "table2" => table2::run(ctx),
        "variance" => variance::run(ctx),
        "fig9" => fig9::run(ctx),
        other => Err(crate::Error::InvalidArg(format!(
            "unknown experiment {other:?} (try: {})",
            ALL_IDS.join(", ")
        ))),
    }
}

/// Every experiment id, in presentation order.
pub const ALL_IDS: [&str; 9] = [
    "table1", "fig1", "fig3", "fig5", "fig6", "fig7", "fig8", "table2", "variance",
];

/// Run everything (the `experiments all` path; fig2/fig4 are emitted by
/// fig1/fig3 runs, fig9 is opt-in because of its memory footprint).
pub fn run_all(ctx: &mut Ctx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();
    for id in ALL_IDS {
        eprintln!("--- experiment {id} ---");
        tables.extend(run(id, ctx)?);
    }
    Ok(tables)
}
