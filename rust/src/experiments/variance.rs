//! Variance validation: the closed forms of Eqs. 2, 7, 13, 16 against
//! Monte-Carlo estimates from the actual hashers.
//!
//! This is the theory underpinning the paper's Section 5.3 storage
//! argument (and the reason VW needs orders of magnitude more space):
//! - minwise:  Var(R̂_M) = R(1−R)/k                          (Eq. 2)
//! - b-bit:    Var(R̂_b) = P_b(1−P_b)/(k(1−C_{2,b})²)         (Eq. 7)
//! - RP:       Var(â)   = (Σu₁²Σu₂² + a² + (s−3)Σu₁²u₂²)/k   (Eq. 13)
//! - VW:       Var(â)   = (s−1)Σu₁²u₂² + (… − 2Σu₁²u₂²)/k    (Eq. 16)
//!
//! The final table runs b-bit minwise and one-permutation hashing through
//! [`FeatureEncoder`](crate::encode::encoder::FeatureEncoder) trait
//! objects — the same dispatch the pipeline workers use — so any new
//! scheme drops into this harness by implementing the trait.

use crate::data::dataset::Example;
use crate::encode::encoder::{EncodedChunk, EncoderSpec};
use crate::hashing::estimators;
use crate::hashing::minwise::{bbit_truncate, resemblance, MinwiseHasher};
use crate::hashing::rp::{estimate_inner_product, RandomProjection};
use crate::hashing::vw::VwHasher;
use crate::report::{fnum, Table};
use crate::util::{stats, Rng};
use crate::{Error, Result};

use super::Ctx;

/// Encode one pair of sets through a spec's trait object and return the
/// two packed code rows (the scheme-agnostic path of the harness).
fn trait_codes_pair(
    spec: &EncoderSpec,
    s1: &[u32],
    s2: &[u32],
) -> Result<(Vec<u16>, Vec<u16>)> {
    let enc = spec.encoder()?;
    let chunk = [Example::binary(1, s1.to_vec()), Example::binary(-1, s2.to_vec())];
    match enc.encode_chunk(&chunk)? {
        EncodedChunk::Packed { codes, .. } => {
            let (mut r0, mut r1) = (vec![0u16; codes.k], vec![0u16; codes.k]);
            codes.row_into(0, &mut r0);
            codes.row_into(1, &mut r1);
            Ok((r0, r1))
        }
        EncodedChunk::Sparse { .. } => Err(Error::InvalidArg(format!(
            "variance harness needs a packed-code scheme, got {}",
            spec.scheme()
        ))),
    }
}

/// A synthetic pair of binary sets with controllable resemblance.
fn make_pair(d: u64, shared: usize, only: usize, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let sh: Vec<u32> = rng.sample_distinct(d / 2, shared).into_iter().map(|x| x as u32).collect();
    let mut s1 = sh.clone();
    let mut s2 = sh;
    s1.extend(rng.sample_distinct(d / 4, only).into_iter().map(|x| x as u32 + (d / 2) as u32));
    s2.extend(rng.sample_distinct(d / 4, only).into_iter().map(|x| x as u32 + (3 * d / 4) as u32));
    s1.sort_unstable();
    s2.sort_unstable();
    (s1, s2)
}

pub fn run(ctx: &mut Ctx) -> Result<Vec<Table>> {
    let trials = if ctx.scale.n_docs <= 500 { 200 } else { 600 };
    let d = 1u64 << 26;
    let mut rng = Rng::new(ctx.scale.seed ^ 0x7A8);
    let (s1, s2) = make_pair(d, 240, 120, &mut rng);
    let r = resemblance(&s1, &s2);
    let (f1, f2) = (s1.len(), s2.len());
    let a = r / (1.0 + r) * (f1 + f2) as f64;

    // ---- minwise + b-bit (Eqs. 2 and 7) ----
    let mut t1 = Table::new(
        &format!(
            "variance of resemblance estimators (R={:.3}, {} trials) — Eq. 2 / Eq. 7",
            r, trials
        ),
        &["estimator", "k", "empirical var", "theory var", "ratio"],
    );
    for &k in &[64usize, 256] {
        let mut est_full = Vec::new();
        let mut est_b: std::collections::BTreeMap<u32, Vec<f64>> = Default::default();
        for _ in 0..trials {
            let mh = MinwiseHasher::draw(k, d, &mut rng);
            let (z1, z2) = (mh.hash(&s1), mh.hash(&s2));
            let matches = z1.iter().zip(&z2).filter(|(a, b)| a == b).count();
            est_full.push(matches as f64 / k as f64);
            for &b in &[1u32, 4, 8] {
                let pb = z1
                    .iter()
                    .zip(&z2)
                    .filter(|(x, y)| bbit_truncate(**x, b) == bbit_truncate(**y, b))
                    .count() as f64
                    / k as f64;
                // Eq. 6 unbiased correction in the sparse limit
                let c = 0.5f64.powi(b as i32);
                est_b.entry(b).or_default().push((pb - c) / (1.0 - c));
            }
        }
        t1.row(&[
            "minwise (Eq. 2)".into(),
            k.to_string(),
            fnum(stats::variance(&est_full)),
            fnum(estimators::var_minwise(r, k)),
            fnum(stats::variance(&est_full) / estimators::var_minwise(r, k)),
        ]);
        for (b, est) in &est_b {
            let theory = estimators::var_bbit(r, 0.0, 0.0, *b, k);
            t1.row(&[
                format!("{b}-bit (Eq. 7)"),
                k.to_string(),
                fnum(stats::variance(est)),
                fnum(theory),
                fnum(stats::variance(est) / theory),
            ]);
        }
    }
    ctx.emit(&t1, "variance_minwise.csv")?;

    // ---- RP and VW (Eqs. 13 and 16), sweep s ----
    let sum_sq1 = f1 as f64;
    let sum_sq2 = f2 as f64;
    let sum_prod_sq = a; // binary data: Σu₁²u₂² = |S1∩S2|
    let mut t2 = Table::new(
        &format!(
            "variance of inner-product estimators (a={a:.0}, {trials} trials) — Eq. 13 / Eq. 16; s=1 makes them equal"
        ),
        &["estimator", "s", "k", "empirical var", "theory var", "ratio"],
    );
    let k = 128usize;
    for &s in &[1.0f64, 3.0] {
        let mut est_rp = Vec::new();
        for _ in 0..trials {
            let rp = RandomProjection::new(k, s, &mut rng);
            let (v1, v2) = (rp.project_set(&s1), rp.project_set(&s2));
            est_rp.push(estimate_inner_product(&v1, &v2));
        }
        let theory = estimators::var_rp(sum_sq1, sum_sq2, a, sum_prod_sq, s, k);
        t2.row(&[
            "RP (Eq. 13)".into(),
            s.to_string(),
            k.to_string(),
            fnum(stats::variance(&est_rp)),
            fnum(theory),
            fnum(stats::variance(&est_rp) / theory),
        ]);
    }
    for &s in &[1.0f64, 3.0] {
        let mut est_vw = Vec::new();
        let items1: Vec<(u32, f32)> = s1.iter().map(|&t| (t, 1.0)).collect();
        let items2: Vec<(u32, f32)> = s2.iter().map(|&t| (t, 1.0)).collect();
        for trial in 0..trials {
            let h = VwHasher::draw(k, &mut rng);
            let seed = trial as u64 ^ 0x5EED;
            let (g1, g2) = (
                h.hash_real_with_s(&items1, s, seed),
                h.hash_real_with_s(&items2, s, seed),
            );
            est_vw.push(g1.iter().zip(&g2).map(|(a, b)| (*a as f64) * (*b as f64)).sum());
        }
        let theory = estimators::var_vw(sum_sq1, sum_sq2, a, sum_prod_sq, s, k);
        t2.row(&[
            "VW (Eq. 16)".into(),
            s.to_string(),
            k.to_string(),
            fnum(stats::variance(&est_vw)),
            fnum(theory),
            fnum(stats::variance(&est_vw) / theory),
        ]);
    }
    ctx.emit(&t2, "variance_rp_vw.csv")?;

    // ---- Section 5.3: storage ratio at equal variance ----
    let mut t3 = Table::new(
        "storage needed by VW (32-bit entries) vs b-bit minwise at equal resemblance variance (§5.3)",
        &["R", "b", "k_bbit", "VW/bbit storage ratio"],
    );
    for &rr in &[0.2f64, 0.5, 0.8] {
        for &b in &[1u32, 4, 8] {
            let ratio =
                estimators::equal_variance_storage_ratio(rr, f1, f2, b, 200, 32);
            t3.row(&[rr.to_string(), b.to_string(), "200".into(), fnum(ratio)]);
        }
    }
    ctx.emit(&t3, "variance_storage_ratio.csv")?;

    // ---- OPH vs b-bit through the FeatureEncoder trait ----
    // One-permutation hashing pays ONE hash pass for all `bins` samples;
    // at equal storage (bins = k, same b) its densified estimator tracks
    // the b-bit variance (Eq. 7 as the reference) at 1/k-th of the
    // hashing cost.  Both arms are driven through `EncoderSpec::encoder()`
    // trait objects — the identical dispatch the pipeline workers run.
    let b = 8u32;
    let c = 0.5f64.powi(b as i32);
    let mut t4 = Table::new(
        &format!(
            "resemblance-estimator variance via FeatureEncoder trait objects \
             (R={r:.3}, b={b}, {trials} trials; theory = Eq. 7)"
        ),
        &["encoder", "k (bins)", "empirical var", "Eq. 7 var", "ratio"],
    );
    for &k in &[64usize, 256] {
        let mut est_bbit = Vec::with_capacity(trials);
        let mut est_oph = Vec::with_capacity(trials);
        for _ in 0..trials {
            let bb_spec = EncoderSpec::Bbit { b, k, d, seed: rng.next_u64() };
            let (c1, c2) = trait_codes_pair(&bb_spec, &s1, &s2)?;
            let pb = c1.iter().zip(&c2).filter(|(x, y)| x == y).count() as f64 / k as f64;
            est_bbit.push((pb - c) / (1.0 - c));
            let oph_spec = EncoderSpec::Oph { bins: k, b, seed: rng.next_u64() };
            let (c1, c2) = trait_codes_pair(&oph_spec, &s1, &s2)?;
            let pb = c1.iter().zip(&c2).filter(|(x, y)| x == y).count() as f64 / k as f64;
            est_oph.push((pb - c) / (1.0 - c));
        }
        let theory = estimators::var_bbit(r, 0.0, 0.0, b, k);
        for (name, est) in [("bbit (trait)", &est_bbit), ("oph (trait)", &est_oph)] {
            t4.row(&[
                name.into(),
                k.to_string(),
                fnum(stats::variance(est)),
                fnum(theory),
                fnum(stats::variance(est) / theory),
            ]);
        }
    }
    ctx.emit(&t4, "variance_trait_oph.csv")?;
    Ok(vec![t1, t2, t3, t4])
}
