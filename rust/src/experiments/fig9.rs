//! §5.4 extension ("fig9"): VW *on top of* 16-bit minwise hashing.
//!
//! The paper notes that for b = 16 the expanded dimensionality 2^16·k is
//! much larger than the number of nonzeros (k), so an additional VW pass
//! gives *compact indexing* and cuts training time by 2–3× at essentially
//! unchanged accuracy.  We reproduce that: expand 16-bit codes to their
//! implicit 2^16·k column space, VW-hash those columns into 2^m bins, and
//! compare training time + accuracy against direct 16-bit training.

use crate::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use crate::data::dataset::{Example, SparseDataset};
use crate::encode::expansion::BbitDataset;
use crate::hashing::vw::VwHasher;
use crate::report::{fnum, Table};
use crate::util::Rng;
use crate::Result;

use super::Ctx;

/// VW-hash the implicit expansion columns of a b-bit dataset.
fn vw_over_codes(ds: &BbitDataset, bins: usize, seed: u64) -> SparseDataset {
    let hasher = VwHasher::draw(bins, &mut Rng::new(seed));
    let mut out = SparseDataset::new(bins as u64);
    out.values = Some(Vec::new());
    let mut cols = vec![0u32; ds.codes.k];
    for i in 0..ds.len() {
        ds.cols_into(i, &mut cols);
        let pairs = hasher.hash_sparse(&cols);
        out.push(&Example {
            label: ds.labels[i],
            indices: pairs.iter().map(|p| p.0).collect(),
            values: Some(pairs.iter().map(|p| p.1).collect()),
        });
    }
    out
}

pub fn run(ctx: &mut Ctx) -> Result<Vec<Table>> {
    let scale = ctx.scale.clone();
    let k = *scale.k_grid.last().unwrap();
    let b = 16u32;
    let c = 1.0;
    let (train16, test16) = ctx.bbit_view(b, k)?.clone();
    let dim16 = train16.dim();
    let sched = Scheduler::new(1); // timing comparison → single thread

    let mut t = Table::new(
        &format!(
            "VW on top of 16-bit minwise hashing (§5.4): direct dim=2^16·{k}={dim16} vs VW-compacted"
        ),
        &["representation", "dim", "solver", "test acc %", "train seconds"],
    );

    for kind in [SolverKind::SvmDcd, SolverKind::LrNewton] {
        let o = sched.run_grid(
            &train16,
            &test16,
            &[TrainJob { tag: String::new(), solver: kind, c }],
        )?;
        t.row(&[
            "16-bit direct".into(),
            dim16.to_string(),
            format!("{kind:?}"),
            fnum(100.0 * o[0].test_accuracy),
            fnum(o[0].train_seconds),
        ]);
    }
    for &bins in &[dim16 / 16, dim16 / 64] {
        let vw_train = vw_over_codes(&train16, bins, scale.seed ^ 0x94);
        let vw_test = vw_over_codes(&test16, bins, scale.seed ^ 0x94);
        for kind in [SolverKind::SvmDcd, SolverKind::LrNewton] {
            let o = sched.run_grid(
                &vw_train,
                &vw_test,
                &[TrainJob { tag: String::new(), solver: kind, c }],
            )?;
            t.row(&[
                format!("16-bit + VW/{}", dim16 / bins),
                bins.to_string(),
                format!("{kind:?}"),
                fnum(100.0 * o[0].test_accuracy),
                fnum(o[0].train_seconds),
            ]);
        }
    }
    ctx.emit(&t, "fig9_vw_on_bbit.csv")?;
    Ok(vec![t])
}
