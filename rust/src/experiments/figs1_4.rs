//! Figures 1–4: b-bit minwise hashing accuracy and training time on the
//! expanded rcv1-like dataset, across the (b, k, C) grid.
//!
//! Figure 1/3: test accuracy vs C, one curve per (b, k) — SVM / LR.
//! Figure 2/4: training time vs C for the same grid.
//! Paper headline to reproduce: k = 30, b = 12 already exceeds 90%
//! accuracy; k ≥ 200–300 approaches the full-data accuracy, and larger b
//! (more bits) dominates smaller b at equal k.

use crate::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use crate::report::{fnum, Table};
use crate::Result;

use super::context::SolverSel;
use super::Ctx;

pub fn run_svm(ctx: &mut Ctx) -> Result<Vec<Table>> {
    run_grid(ctx, SolverSel::Svm, "fig1_svm_accuracy", "fig2_svm_time")
}

pub fn run_lr(ctx: &mut Ctx) -> Result<Vec<Table>> {
    run_grid(ctx, SolverSel::Lr, "fig3_lr_accuracy", "fig4_lr_time")
}

fn run_grid(
    ctx: &mut Ctx,
    solver: SolverSel,
    acc_name: &str,
    time_name: &str,
) -> Result<Vec<Table>> {
    let scale = ctx.scale.clone();
    let kind = match solver {
        SolverSel::Svm => SolverKind::SvmDcd,
        SolverSel::Lr => SolverKind::LrNewton,
    };
    let mut acc_t = Table::new(
        &format!("{} test accuracy on rcv1-like (Figures 1/3 shape)", solver.name()),
        &["b", "k", "C", "test acc %", "train acc %"],
    );
    let mut time_t = Table::new(
        &format!("{} training time on rcv1-like (Figures 2/4 shape)", solver.name()),
        &["b", "k", "C", "train seconds", "iterations"],
    );
    let sched = Scheduler::new(scale.workers);
    for &b in &scale.b_grid {
        for &k in &scale.k_grid {
            let (train, test) = ctx.bbit_view(b, k)?;
            let jobs: Vec<TrainJob> = scale
                .c_grid
                .iter()
                .map(|&c| TrainJob { tag: format!("b={b} k={k}"), solver: kind, c })
                .collect();
            let outcomes = sched.run_grid(train, test, &jobs)?;
            for o in outcomes {
                acc_t.row(&[
                    b.to_string(),
                    k.to_string(),
                    o.c.to_string(),
                    fnum(100.0 * o.test_accuracy),
                    fnum(100.0 * o.train_accuracy),
                ]);
                time_t.row(&[
                    b.to_string(),
                    k.to_string(),
                    o.c.to_string(),
                    fnum(o.train_seconds),
                    o.iterations.to_string(),
                ]);
            }
            eprintln!("[{}] b={b} k={k} done", acc_name);
        }
    }
    ctx.emit(&acc_t, &format!("{acc_name}.csv"))?;
    ctx.emit(&time_t, &format!("{time_name}.csv"))?;

    // headline check rows (what EXPERIMENTS.md quotes)
    let mut headline = Table::new(
        "headline: best test accuracy per (b, k) over the C grid",
        &["b", "k", "best test acc %"],
    );
    summarize_best(&acc_t, &mut headline);
    println!("{}", headline.render());
    Ok(vec![acc_t, time_t, headline])
}

/// Group accuracy rows by (b, k) and keep the best over C.
fn summarize_best(acc: &Table, out: &mut Table) {
    use std::collections::BTreeMap;
    let mut best: BTreeMap<(u32, usize), f64> = BTreeMap::new();
    for row in acc_rows(acc) {
        let key = (row.0, row.1);
        let e = best.entry(key).or_insert(f64::MIN);
        *e = e.max(row.3);
    }
    for ((b, k), v) in best {
        out.row(&[b.to_string(), k.to_string(), fnum(v)]);
    }
}

/// Parse back the string rows (cheap + keeps Table the single source).
fn acc_rows(t: &Table) -> impl Iterator<Item = (u32, usize, f64, f64)> + '_ {
    t.rows_raw().iter().map(|r| {
        (
            r[0].parse().unwrap(),
            r[1].parse().unwrap(),
            r[2].parse().unwrap(),
            r[3].parse().unwrap(),
        )
    })
}
