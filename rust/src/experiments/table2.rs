//! Table 2: data-loading time vs preprocessing time vs accelerated
//! preprocessing.
//!
//! Paper (k = 500): loading 1.0e4 s, CPU preprocessing 3.0e4 s (~3×
//! loading), GPU preprocessing 0.14e4 s (~1/7 of loading).  Reproduction
//! target is the *ratio structure*: single-thread hashing a small multiple
//! of loading; the parallel pipeline and the batched PJRT kernel bringing
//! it down to a fraction.
//!
//! Method: write the expanded corpus to an actual LibSVM file, then time
//! (1) a full parse through the byte-block reader (the default ingest
//! path every production command runs), (2) single-worker block-parallel
//! pipeline hashing, (3) all-core pipeline hashing, (4) the PJRT minhash
//! artifact (the paper's GPU column; interpret-mode Pallas on CPU — see
//! DESIGN.md §6 for the real-TPU estimate).

use std::time::Instant;

use crate::coordinator::pipeline::{Pipeline, PipelineConfig};
use crate::coordinator::sink::CollectSink;
use crate::data::libsvm::{parse_block, BlockReader, LibsvmWriter, ParsedChunk};
use crate::encode::encoder::EncoderSpec;
use crate::hashing::universal::UniversalFamily;
use crate::report::{fnum, Table};
use crate::runtime::{PjrtRuntime, RoutedMinhash};
use crate::util::Rng;
use crate::Result;

use super::table1::human_bytes;
use super::Ctx;

pub fn run(ctx: &mut Ctx) -> Result<Vec<Table>> {
    let scale = ctx.scale.clone();
    let k = scale.kmax.min(512);
    let (train, _) = ctx.rcv1()?;

    // --- materialize the LibSVM file (the paper's on-disk format) ---
    let dir = std::env::temp_dir().join("bbit_mh_table2");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("rcv1_like_train.svm");
    {
        let mut w = LibsvmWriter::create(&path)?;
        w.write_dataset(train)?;
        w.finish()?;
    }
    let bytes = std::fs::metadata(&path)?.len();
    let n_docs = train.len();

    // --- (1) data loading: full parse through the byte-block reader ---
    let t0 = Instant::now();
    let mut parsed = 0usize;
    let mut scratch = ParsedChunk::default();
    for block in BlockReader::open(&path)? {
        let block = block?;
        scratch.clear();
        parse_block(&block.bytes, block.first_line, true, &mut scratch)?;
        for (_, set, _) in scratch.rows() {
            parsed += set.len();
        }
    }
    let load_s = t0.elapsed().as_secs_f64();
    assert!(parsed > 0);

    // --- (2) preprocessing, 1 worker (the paper's "Preprocessing") ---
    let hash_1w = time_pipeline(&path, k, scale.dim, 1)?;

    // --- (3) preprocessing, all cores (trivially parallelizable claim) ---
    let hash_nw = time_pipeline(&path, k, scale.dim, scale.workers)?;

    // --- (4) PJRT minhash artifact (the "GPU" column analogue) ---
    let pjrt_s = time_pjrt(&path, scale.dim, ctx)?;

    let mut t = Table::new(
        &format!(
            "Table 2 — loading vs preprocessing, k={k}, {} docs, {} on disk (paper rcv1: load 1.0e4s, prep 3.0e4s, GPU prep 0.14e4s)",
            n_docs,
            human_bytes(bytes)
        ),
        &["stage", "seconds", "ratio vs loading"],
    );
    t.row(&["data loading (stream parse)".into(), fnum(load_s), "1.00".into()]);
    t.row(&[
        "preprocessing, 1 thread".into(),
        fnum(hash_1w),
        fnum(hash_1w / load_s),
    ]);
    t.row(&[
        format!("preprocessing, {} threads", scale.workers),
        fnum(hash_nw),
        fnum(hash_nw / load_s),
    ]);
    match pjrt_s {
        Some(s) => t.row(&[
            "preprocessing, PJRT kernel (k=512)".into(),
            fnum(s),
            fnum(s / load_s),
        ]),
        None => t.row(&[
            "preprocessing, PJRT kernel".into(),
            "skipped (no artifacts)".into(),
            "-".into(),
        ]),
    }
    ctx.emit(&t, "table2_preprocessing.csv")?;
    std::fs::remove_dir_all(&dir).ok();
    Ok(vec![t])
}

fn time_pipeline(path: &std::path::Path, k: usize, dim: u64, workers: usize) -> Result<f64> {
    let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 256, queue_depth: 4 });
    let spec = EncoderSpec::Bbit { b: 16, k, d: dim, seed: 7 };
    let mut sink = CollectSink::for_spec(&spec)?;
    let t0 = Instant::now();
    pipe.run_sink_blocks(BlockReader::open(path)?, true, &spec, &mut sink)?;
    let total = t0.elapsed().as_secs_f64();
    assert!(!sink.into_output().is_empty());
    Ok(total)
}

fn time_pjrt(path: &std::path::Path, dim: u64, ctx: &Ctx) -> Result<Option<f64>> {
    let rt = match PjrtRuntime::cpu(std::path::Path::new("artifacts")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("[table2] PJRT column skipped: {e}");
            return Ok(None);
        }
    };
    // size-routed: short documents go to the nnz=512 artifact (§Perf)
    let engine = RoutedMinhash::from_names(&rt, &["minhash_k512_nnz512", "minhash_k512_nnz1024", "minhash_k512"])?;
    let mut rng = Rng::new(ctx.scale.seed ^ 0x6B);
    let family = UniversalFamily::draw(engine.k(), dim.min(engine.d_space()), &mut rng);
    // big slabs ≈ the old 8192-doc chunks, so the engine still sees
    // batch-sized calls
    let blocks = BlockReader::open(path)?.with_block_bytes(4 << 20);
    let mut scratch = ParsedChunk::default();
    let t0 = Instant::now();
    let mut rows = 0usize;
    for block in blocks {
        let block = block?;
        scratch.clear();
        parse_block(&block.bytes, block.first_line, true, &mut scratch)?;
        let sets: Vec<&[u32]> = (0..scratch.len()).map(|i| scratch.row(i).0).collect();
        let z = engine.minhash_all(&sets, &family)?;
        rows += z.len() / engine.k();
    }
    let total = t0.elapsed().as_secs_f64();
    assert!(rows > 0);
    Ok(Some(total))
}
