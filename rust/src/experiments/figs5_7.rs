//! Figures 5–7: VW vs b-bit minwise hashing at matched k.
//!
//! Figure 5/6: test accuracy against k — VW at k = 2^5..2^14 bins (solid
//! curves in the paper) vs b-bit at k = 30..500 samples (dashed), for
//! C ∈ {0.01, 0.1, 1, 10}.  The reproduction target is the *gap*: b-bit
//! reaches VW's k=2^14 accuracy with k ≈ 30–200 samples.
//! Figure 7: training time against k for VW vs 8-bit minwise.

use crate::coordinator::scheduler::{small_c_grid, Scheduler, SolverKind, TrainJob};
use crate::report::{fnum, Table};
use crate::Result;

use super::context::SolverSel;
use super::Ctx;

pub fn run_accuracy(ctx: &mut Ctx, solver: SolverSel) -> Result<Vec<Table>> {
    let scale = ctx.scale.clone();
    let kind = match solver {
        SolverSel::Svm => SolverKind::SvmDcd,
        SolverSel::Lr => SolverKind::LrNewton,
    };
    let figname = match solver {
        SolverSel::Svm => "fig5_svm_vw_vs_bbit",
        SolverSel::Lr => "fig6_lr_vw_vs_bbit",
    };
    let c_grid = small_c_grid();
    let sched = Scheduler::new(scale.workers);
    let mut t = Table::new(
        &format!(
            "{} accuracy: VW (k bins) vs b-bit minwise (k samples) — Figures 5/6 shape",
            solver.name()
        ),
        &["method", "k", "C", "test acc %", "storage bits/doc"],
    );

    // --- VW arm ---
    for &bins in &scale.vw_bins_grid {
        let (train, test) = ctx.vw_view(bins)?;
        let jobs: Vec<TrainJob> = c_grid
            .iter()
            .map(|&c| TrainJob { tag: format!("vw {bins}"), solver: kind, c })
            .collect();
        for o in sched.run_grid(&train, &test, &jobs)? {
            t.row(&[
                "VW".into(),
                bins.to_string(),
                o.c.to_string(),
                fnum(100.0 * o.test_accuracy),
                // the paper budgets 32 bits per stored VW entry (§5.3)
                (bins as u64 * 32).to_string(),
            ]);
        }
        eprintln!("[{figname}] vw bins={bins} done");
    }

    // --- b-bit arm (b = 8 like Figure 7, plus b from the grid midpoint) ---
    for &b in &[4u32, 8] {
        for &k in &scale.k_grid {
            let (train, test) = ctx.bbit_view(b, k)?;
            let jobs: Vec<TrainJob> = c_grid
                .iter()
                .map(|&c| TrainJob { tag: format!("b{b} k{k}"), solver: kind, c })
                .collect();
            for o in sched.run_grid(train, test, &jobs)? {
                t.row(&[
                    format!("{b}-bit mh"),
                    k.to_string(),
                    o.c.to_string(),
                    fnum(100.0 * o.test_accuracy),
                    (b as u64 * k as u64).to_string(),
                ]);
            }
        }
        eprintln!("[{figname}] b={b} arm done");
    }
    ctx.emit(&t, &format!("{figname}.csv"))?;
    Ok(vec![t])
}

pub fn run_time(ctx: &mut Ctx) -> Result<Vec<Table>> {
    let scale = ctx.scale.clone();
    let c = 1.0;
    let mut t = Table::new(
        "training time: VW vs 8-bit minwise at the same k (Figure 7 shape, SVM left / LR right)",
        &["method", "k", "svm seconds", "lr seconds"],
    );
    for &bins in &scale.vw_bins_grid {
        let (train, test) = ctx.vw_view(bins)?;
        let svm = Scheduler::new(1).run_grid(
            &train,
            &test,
            &[TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c }],
        )?;
        let lr = Scheduler::new(1).run_grid(
            &train,
            &test,
            &[TrainJob { tag: String::new(), solver: SolverKind::LrNewton, c }],
        )?;
        t.row(&[
            "VW".into(),
            bins.to_string(),
            fnum(svm[0].train_seconds),
            fnum(lr[0].train_seconds),
        ]);
    }
    for &k in &scale.k_grid {
        let (train, test) = ctx.bbit_view(8, k)?;
        let svm = Scheduler::new(1).run_grid(
            train,
            test,
            &[TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c }],
        )?;
        let lr = Scheduler::new(1).run_grid(
            train,
            test,
            &[TrainJob { tag: String::new(), solver: SolverKind::LrNewton, c }],
        )?;
        t.row(&[
            "8-bit mh".into(),
            k.to_string(),
            fnum(svm[0].train_seconds),
            fnum(lr[0].train_seconds),
        ]);
    }
    ctx.emit(&t, "fig7_train_time.csv")?;
    Ok(vec![t])
}
