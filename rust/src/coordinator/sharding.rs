//! Shard planning for the preprocessing pipeline.
//!
//! Chunks flow to workers through a shared bounded queue (pull model =
//! natural load balancing); the *plan* here assigns each chunk a stable
//! shard id and output row range so workers can write their results into
//! disjoint regions of the packed output without synchronization, and the
//! collector can verify nothing was lost or duplicated — the pipeline's
//! integrity invariant (proptested in `rust/tests/prop_coordinator.rs`).

/// A contiguous range of example rows assigned to one chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkAssignment {
    pub chunk_id: usize,
    /// First global row of this chunk.
    pub row0: usize,
    /// Rows in this chunk.
    pub rows: usize,
}

/// Deterministic chunk → row-range plan for a dataset of `n` rows split
/// into `chunk_size` chunks.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    pub n: usize,
    pub chunk_size: usize,
}

impl ShardPlan {
    pub fn new(n: usize, chunk_size: usize) -> Self {
        assert!(chunk_size > 0);
        ShardPlan { n, chunk_size }
    }

    pub fn n_chunks(&self) -> usize {
        self.n.div_ceil(self.chunk_size)
    }

    pub fn assignment(&self, chunk_id: usize) -> ChunkAssignment {
        let row0 = chunk_id * self.chunk_size;
        debug_assert!(row0 < self.n || self.n == 0);
        ChunkAssignment {
            chunk_id,
            row0,
            rows: self.chunk_size.min(self.n - row0),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = ChunkAssignment> + '_ {
        (0..self.n_chunks()).map(|c| self.assignment(c))
    }

    /// True iff the assignments tile `[0, n)` exactly once (the invariant
    /// the collector re-checks at runtime).
    pub fn covers_exactly(&self) -> bool {
        let mut next = 0usize;
        for a in self.iter() {
            if a.row0 != next || a.rows == 0 {
                return false;
            }
            next += a.rows;
        }
        next == self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_tiling() {
        for n in [0usize, 1, 9, 10, 11, 100, 4097] {
            for cs in [1usize, 3, 10, 256] {
                let p = ShardPlan::new(n, cs);
                assert!(p.covers_exactly(), "n={n} cs={cs}");
                assert_eq!(
                    p.iter().map(|a| a.rows).sum::<usize>(),
                    n,
                    "n={n} cs={cs}"
                );
            }
        }
    }

    #[test]
    fn last_chunk_is_short() {
        let p = ShardPlan::new(25, 10);
        assert_eq!(p.n_chunks(), 3);
        assert_eq!(p.assignment(2), ChunkAssignment { chunk_id: 2, row0: 20, rows: 5 });
    }
}
