//! Parallel cache replay: a reader pool over the v3 chunk index.
//!
//! The forward pipeline ([`Pipeline`](crate::coordinator::pipeline))
//! parallelizes *hashing*; once a corpus lives in the on-disk cache the
//! dominant workload flips to *re-reading* it — the paper's "many cheap
//! training runs over one cache" loop (C-sweeps, model search), which the
//! follow-up "b-Bit Minwise Hashing in Practice" (arXiv:1205.2958) shows
//! is bottlenecked by replay speed, not hashing.  This module makes replay
//! scale with cores while keeping the chunk stream *identical* to the
//! sequential reader:
//!
//! - workers each own a seekable [`IndexedCacheReader`] and claim records
//!   off a shared cursor (pull model — natural load balancing);
//! - records decode (read + FNV verify + unpack) into recycled
//!   `(PackedCodes, Vec<i8>)` buffers drawn from a bounded pool, so the
//!   hot path allocates nothing per record *and* the pool doubles as the
//!   admission-credit loop from the forward pipeline: at most
//!   `2·threads + 2` decoded chunks exist at once, no matter how far ahead
//!   the fast workers run;
//! - the collector re-emits chunks through the same reorder-window design
//!   the forward pipeline uses — strictly in record order — so
//!   order-sensitive consumers (holdout splitting, progressive loss,
//!   streaming SGD, and the [`similarity`](crate::similarity) index
//!   builder, whose shard snapshots must be byte-identical for every
//!   thread count) observe bit-for-bit the sequence a sequential scan
//!   would have produced.
//!
//! Workers grab a buffer *before* claiming a record id, which is what
//! makes the bounded pool deadlock-free: the lowest unemitted record is
//! always held by a worker that already owns a buffer, so the collector
//! can always make progress.
//!
//! Caches without a usable index (pre-v3 files, truncated footers) fall
//! back to the sequential scan with a warning instead of failing — the
//! paranoia twin of [`ChunkIndex::load`] returning `Ok(None)`.

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::pipeline::PipelineReport;
use crate::coordinator::sharding::ShardPlan;
use crate::encode::cache::{CacheReader, ChunkIndex, IndexedCacheReader};
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::metrics::trace;
use crate::{Error, Result};

/// One recycled decode buffer.
type ChunkBuf = (PackedCodes, Vec<i8>);

/// Replay every record of a hashed cache through `emit(record_id, row0,
/// codes, labels)` — called strictly in record order on the calling
/// thread, exactly once per record (`row0` is the record's global first
/// row).  `threads <= 1` runs the sequential scan; `threads > 1` decodes
/// across a reader pool when the cache carries a chunk index, falling back
/// to the sequential scan (with a warning) when it does not.  Either way
/// the emitted chunk sequence is identical.
pub fn replay_cache<P, F>(path: P, threads: usize, emit: F) -> Result<PipelineReport>
where
    P: AsRef<Path>,
    F: FnMut(usize, u64, &PackedCodes, &[i8]) -> Result<()>,
{
    let path = path.as_ref();
    let index = if threads > 1 { load_index_or_warn(path)? } else { None };
    replay_cache_with(path, index.as_ref(), threads, emit)
}

/// Process-wide count of replays that wanted the pooled reader but had to
/// fall back to the sequential scan (pre-v3 cache or damaged footer).
/// The stderr warning in [`load_index_or_warn`] is easy to lose in fleet
/// logs; this counter is rendered as `replay_index_fallback_total` on the
/// serve tier's `/metrics` so a degraded cache shows up on a dashboard.
static INDEX_FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Read the [`INDEX_FALLBACKS`] counter (monotonic since process start).
pub fn index_fallbacks() -> u64 {
    INDEX_FALLBACKS.load(Ordering::Relaxed)
}

/// Load a cache's chunk index for pooled replay, downgrading "no usable
/// index" to `None` with the standard one-line warning.  Callers that
/// replay the same cache repeatedly (multi-epoch training) load once and
/// pass the result to [`replay_cache_with`] each pass, instead of
/// re-reading and re-verifying the footer — and re-warning — per epoch.
pub fn load_index_or_warn(path: &Path) -> Result<Option<ChunkIndex>> {
    match ChunkIndex::load(path)? {
        Some(index) => Ok(Some(index)),
        None => {
            INDEX_FALLBACKS.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "warning: cache {} has no chunk index (pre-v3 file or damaged footer); \
                 replaying on one thread",
                path.display()
            );
            Ok(None)
        }
    }
}

/// [`replay_cache`] with a caller-held index: `Some` + `threads > 1` runs
/// the reader pool; anything else is the sequential scan.
pub fn replay_cache_with<F>(
    path: &Path,
    index: Option<&ChunkIndex>,
    threads: usize,
    emit: F,
) -> Result<PipelineReport>
where
    F: FnMut(usize, u64, &PackedCodes, &[i8]) -> Result<()>,
{
    let mut report = match index {
        Some(index) if threads > 1 => replay_pool(path, index, threads, emit)?,
        // threads > 1 without an index means the pooled reader was
        // requested but degraded — flag it on the replay.run span
        _ => replay_sequential(path, threads > 1, emit)?,
    };
    report.replay_bytes = std::fs::metadata(path)?.len();
    Ok(report)
}

/// The single-threaded scan: one reader, one pair of scratch buffers.
/// `index_fallback` marks a scan that was *meant* to be pooled (threads
/// requested, no usable index) — recorded on the `replay.run` span so a
/// trace-side degradation is visible next to the `/metrics` counter.
fn replay_sequential<F>(path: &Path, index_fallback: bool, mut emit: F) -> Result<PipelineReport>
where
    F: FnMut(usize, u64, &PackedCodes, &[i8]) -> Result<()>,
{
    let wall0 = Instant::now();
    let mut root = trace::Span::enter("replay.run");
    if index_fallback {
        root.record("index_fallback", 1.0);
    }
    let rctx = root.ctx();
    let mut reader = CacheReader::open(path)?;
    let meta = reader.meta();
    let (b, k) = meta.spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!("cache scheme {} is not packed", meta.spec.scheme()))
    })?;
    let mut codes = PackedCodes::new(b, k);
    let mut labels: Vec<i8> = Vec::new();
    let mut report = PipelineReport {
        replay_threads: 1,
        per_worker_chunks: vec![0],
        ..Default::default()
    };
    let mut row0 = 0u64;
    let mut record = 0usize;
    loop {
        let t0 = Instant::now();
        if !reader.next_chunk_into(&mut codes, &mut labels)? {
            break;
        }
        let t1 = Instant::now();
        report.hash_cpu_seconds += (t1 - t0).as_secs_f64();
        trace::emit_span("replay.read", rctx, t0, t1, &[("record", record as f64)]);
        let t0 = Instant::now();
        emit(record, row0, &codes, &labels)?;
        let t1 = Instant::now();
        report.sink_seconds += (t1 - t0).as_secs_f64();
        trace::emit_span("replay.emit", rctx, t0, t1, &[("record", record as f64)]);
        row0 += codes.n as u64;
        record += 1;
    }
    root.record("records", record as f64);
    report.docs = row0 as usize;
    report.chunks = record;
    report.per_worker_chunks[0] = record;
    report.reorder_peak = if record > 0 { 1 } else { 0 };
    report.wall_seconds = wall0.elapsed().as_secs_f64();
    Ok(report)
}

/// The reader pool: `threads` decode workers, recycled buffers, in-order
/// emission on the calling thread.
fn replay_pool<F>(
    path: &Path,
    index: &ChunkIndex,
    threads: usize,
    mut emit: F,
) -> Result<PipelineReport>
where
    F: FnMut(usize, u64, &PackedCodes, &[i8]) -> Result<()>,
{
    let wall0 = Instant::now();
    let mut root = trace::Span::enter("replay.run");
    let rctx = root.ctx();
    let n_rec = index.entries.len();
    let starts = index.row_starts();
    let threads = threads.min(n_rec.max(1));
    root.record("records", n_rec as f64);
    root.record("threads", threads as f64);
    let mut report = PipelineReport {
        replay_threads: threads,
        per_worker_chunks: vec![0; threads],
        ..Default::default()
    };
    if n_rec == 0 {
        report.wall_seconds = wall0.elapsed().as_secs_f64();
        return Ok(report);
    }
    // geometry for the buffer pool
    let meta = IndexedCacheReader::open(path)?.meta();
    let (b, k) = meta.spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!("cache scheme {} is not packed", meta.spec.scheme()))
    })?;
    // per-thread readers opened up front so IO errors surface before any
    // thread spawns
    let mut readers = Vec::with_capacity(threads);
    for _ in 0..threads {
        readers.push(IndexedCacheReader::open(path)?);
    }
    // the buffer pool IS the credit loop: `window` buffers exist in total,
    // so at most `window` decoded records are in flight or parked in the
    // reorder map at once
    let window = 2 * threads + 2;
    let (free_tx, free_rx) = sync_channel::<ChunkBuf>(window);
    for _ in 0..window {
        free_tx
            .try_send((PackedCodes::new(b, k), Vec::new()))
            .expect("buffer prefill cannot overflow");
    }
    let free_rx = Mutex::new(free_rx);
    let next_record = AtomicUsize::new(0);
    // worker → collector: (record id, decoded buffer, decode seconds, wid)
    type Decoded = (usize, ChunkBuf, f64, usize);
    let (full_tx, full_rx) = sync_channel::<Result<Decoded>>(window);

    std::thread::scope(|scope| -> Result<()> {
        for (wid, mut reader) in readers.into_iter().enumerate() {
            let full_tx = full_tx.clone();
            let free_rx = &free_rx;
            let next_record = &next_record;
            let entries = &index.entries;
            let starts = &starts;
            scope.spawn(move || {
                loop {
                    // buffer first, record second — guarantees the lowest
                    // unemitted record is held by a buffer-owning worker
                    let buf = free_rx.lock().unwrap().recv();
                    let Ok((mut codes, mut labels)) = buf else {
                        break; // collector done or bailed
                    };
                    let rec = next_record.fetch_add(1, Ordering::Relaxed);
                    if rec >= entries.len() {
                        break; // all records claimed; buffer retires
                    }
                    let t0 = Instant::now();
                    // a panicking decode must still produce a message: a
                    // silently lost record would wedge the collector, which
                    // waits for every id in order
                    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        reader.read_into(&entries[rec], starts[rec], &mut codes, &mut labels)
                    }))
                    .unwrap_or_else(|_| {
                        Err(Error::Pipeline(format!("replay worker {wid} panicked")))
                    })
                    .map(|()| {
                        let t1 = Instant::now();
                        trace::emit_span(
                            "replay.read",
                            rctx,
                            t0,
                            t1,
                            &[("record", rec as f64), ("worker", wid as f64)],
                        );
                        (rec, (codes, labels), (t1 - t0).as_secs_f64(), wid)
                    });
                    if full_tx.send(out).is_err() {
                        break; // collector bailed on an earlier error
                    }
                }
            });
        }
        drop(full_tx);

        // ---- collector (this thread): bounded reorder window ----
        let mut reorder: std::collections::BTreeMap<usize, ChunkBuf> =
            std::collections::BTreeMap::new();
        let mut next_emit = 0usize;
        for msg in full_rx {
            let (rec, buf, decode_secs, wid) = msg?;
            report.hash_cpu_seconds += decode_secs;
            report.per_worker_chunks[wid] += 1;
            reorder.insert(rec, buf);
            report.reorder_peak = report.reorder_peak.max(reorder.len());
            while let Some((codes, labels)) = reorder.remove(&next_emit) {
                let t0 = Instant::now();
                emit(next_emit, starts[next_emit], &codes, &labels)?;
                let t1 = Instant::now();
                report.sink_seconds += (t1 - t0).as_secs_f64();
                trace::emit_span("replay.emit", rctx, t0, t1, &[("record", next_emit as f64)]);
                report.docs += codes.n;
                next_emit += 1;
                // recycle the buffer (never blocks: in-channel buffers ≤
                // capacity by conservation; workers-gone is fine)
                let _ = free_tx.try_send((codes, labels));
            }
            if next_emit == n_rec {
                break; // all emitted; stop before waiting on idle workers
            }
        }
        // unblock any workers still parked on the buffer pool
        drop(free_tx);
        if next_emit != n_rec {
            return Err(Error::Pipeline(format!(
                "cache replay lost records: emitted {next_emit} of {n_rec}"
            )));
        }
        Ok(())
    })?;
    report.chunks = n_rec;
    report.wall_seconds = wall0.elapsed().as_secs_f64();
    Ok(report)
}

/// Materialize a whole cache as a [`BbitDataset`], fanning record decode
/// out across `threads` when the file carries a chunk index — the batch
/// solvers' parallel loading path.  Output is bit-identical to
/// [`CacheReader::read_all`] regardless of thread count (records land at
/// their exact row offsets).  Falls back to the sequential scan (with a
/// warning) when no usable index exists.
pub fn materialize_cache<P: AsRef<Path>>(path: P, threads: usize) -> Result<BbitDataset> {
    let path = path.as_ref();
    if threads > 1 {
        if let Some(index) = load_index_or_warn(path)? {
            return materialize_indexed(path, &index, threads);
        }
    }
    CacheReader::open(path)?.read_all()
}

fn materialize_indexed(path: &Path, index: &ChunkIndex, threads: usize) -> Result<BbitDataset> {
    let meta = IndexedCacheReader::open(path)?.meta();
    let (b, k) = meta.spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!("cache scheme {} is not packed", meta.spec.scheme()))
    })?;
    let stride = PackedCodes::new(b, k).stride();
    let n = meta.n as usize;
    let n_rec = index.entries.len();
    let starts = index.row_starts();
    let mut words = vec![0u64; stride * n];
    let mut labels = vec![0i8; n];
    // contiguous record ranges per worker → disjoint output regions
    let plan = ShardPlan::new(n_rec, n_rec.div_ceil(threads.max(1)).max(1));
    let mut shards = Vec::with_capacity(plan.n_chunks());
    let mut rest_w = words.as_mut_slice();
    let mut rest_l = labels.as_mut_slice();
    for a in plan.iter() {
        let rows: usize = index.entries[a.row0..a.row0 + a.rows]
            .iter()
            .map(|e| e.rows as usize)
            .sum();
        let (w_shard, w_rest) = std::mem::take(&mut rest_w).split_at_mut(rows * stride);
        let (l_shard, l_rest) = std::mem::take(&mut rest_l).split_at_mut(rows);
        rest_w = w_rest;
        rest_l = l_rest;
        shards.push((a, w_shard, l_shard));
    }
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(shards.len());
        for (a, w_shard, l_shard) in shards {
            let starts = &starts;
            let entries = &index.entries;
            handles.push(scope.spawn(move || -> Result<()> {
                let mut reader = IndexedCacheReader::open(path)?;
                let mut codes = PackedCodes::new(b, k);
                let mut ls: Vec<i8> = Vec::new();
                let (mut woff, mut loff) = (0usize, 0usize);
                for rec in a.row0..a.row0 + a.rows {
                    reader.read_into(&entries[rec], starts[rec], &mut codes, &mut ls)?;
                    let w = codes.words();
                    w_shard[woff..woff + w.len()].copy_from_slice(w);
                    woff += w.len();
                    l_shard[loff..loff + ls.len()].copy_from_slice(&ls);
                    loff += ls.len();
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join()
                .map_err(|_| Error::Pipeline("cache materialize worker panicked".into()))??;
        }
        Ok(())
    })?;
    let codes = PackedCodes::from_words(b, k, n, words)?;
    Ok(BbitDataset::new(codes, labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encode::cache::CacheWriter;
    use crate::encode::encoder::EncoderSpec;
    use crate::util::Rng;

    /// Write a little cache to a temp file; returns (path, chunks).
    fn build_cache(tag: &str, sizes: &[usize]) -> (std::path::PathBuf, Vec<(PackedCodes, Vec<i8>)>) {
        let dir = std::env::temp_dir().join(format!("bbit_replay_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("replay.cache");
        let spec = EncoderSpec::Bbit { b: 6, k: 17, d: 1 << 20, seed: 5 };
        let mut w = CacheWriter::create(&path, &spec).unwrap();
        let mut rng = Rng::new(0x9E9);
        let mut chunks = Vec::new();
        for &rows in sizes {
            let mut pc = PackedCodes::new(6, 17);
            let mut ls = Vec::new();
            for _ in 0..rows {
                let row: Vec<u16> = (0..17).map(|_| rng.below(1 << 6) as u16).collect();
                pc.push_row(&row).unwrap();
                ls.push(if rng.bool() { 1 } else { -1 });
            }
            w.write_chunk(&pc, &ls).unwrap();
            chunks.push((pc, ls));
        }
        w.finalize().unwrap();
        (path, chunks)
    }

    fn collect_replay(
        path: &std::path::Path,
        threads: usize,
    ) -> (Vec<(usize, u64, PackedCodes, Vec<i8>)>, PipelineReport) {
        let mut seen = Vec::new();
        let report = replay_cache(path, threads, |rec, row0, codes, labels| {
            seen.push((rec, row0, codes.clone(), labels.to_vec()));
            Ok(())
        })
        .unwrap();
        (seen, report)
    }

    #[test]
    fn pool_emits_in_order_and_matches_sequential() {
        let sizes = [13usize, 64, 1, 40, 27, 64, 9, 30, 30, 5];
        let (path, chunks) = build_cache("order", &sizes);
        let (seq, seq_report) = collect_replay(&path, 1);
        assert_eq!(seq_report.replay_threads, 1);
        assert_eq!(seq.len(), sizes.len());
        for threads in [2usize, 4, 7] {
            let (par, report) = collect_replay(&path, threads);
            assert_eq!(par, seq, "threads={threads}");
            assert_eq!(report.chunks, sizes.len());
            assert_eq!(report.docs, sizes.iter().sum::<usize>());
            assert_eq!(report.per_worker_chunks.iter().sum::<usize>(), sizes.len());
            assert!(report.replay_bytes > 0);
        }
        // emitted ids/rows are the exact record map
        for (i, (rec, row0, codes, labels)) in seq.iter().enumerate() {
            assert_eq!(*rec, i);
            assert_eq!(*row0, sizes[..i].iter().sum::<usize>() as u64);
            assert_eq!(codes, &chunks[i].0);
            assert_eq!(labels, &chunks[i].1);
        }
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn more_threads_than_records_is_fine() {
        let (path, chunks) = build_cache("tiny", &[5, 3]);
        let (par, report) = collect_replay(&path, 16);
        assert_eq!(par.len(), 2);
        assert_eq!(par[1].2, chunks[1].0);
        assert!(report.replay_threads <= 2, "pool must clamp to record count");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn missing_footer_falls_back_to_sequential() {
        let (path, _) = build_cache("fallback", &[20, 20, 20]);
        // tear the trailer off: index unusable, records intact
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 7]).unwrap();
        let (seq, _) = collect_replay(&path, 1);
        let before = index_fallbacks();
        let (par, report) = collect_replay(&path, 4);
        assert_eq!(par, seq, "fallback must replay the identical stream");
        assert_eq!(report.replay_threads, 1, "fallback runs sequentially");
        assert!(index_fallbacks() > before, "fallback must bump the process counter");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn emit_errors_stop_the_pool() {
        let (path, _) = build_cache("emit_err", &[10, 10, 10, 10, 10, 10]);
        let mut emitted = 0usize;
        let err = replay_cache(&path, 4, |_, _, _, _| {
            emitted += 1;
            Err(Error::Pipeline("sink full".into()))
        });
        assert!(err.is_err());
        assert_eq!(emitted, 1, "emit must stop at the first error");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn corrupt_record_errors_propagate_from_workers() {
        let (path, _) = build_cache("corrupt", &[32, 32, 32, 32]);
        let index = ChunkIndex::load(&path).unwrap().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let target = index.entries[2].offset as usize + 12 + 3; // payload of record 2
        bytes[target] ^= 0x20;
        // keep the footer valid so the pool path actually runs: the entry
        // checksum now disagrees with the payload, which is the point
        std::fs::write(&path, &bytes).unwrap();
        let err = replay_cache(&path, 4, |_, _, _, _| Ok(()));
        assert!(err.is_err(), "flipped payload byte must fail the pool");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
