//! Training-job scheduler: fans a (method, solver, b, k, C) grid across
//! threads.
//!
//! The paper's workflow trains the *same* hashed dataset many times ("for
//! example, for many different C values in SVM cross-validation") — the
//! reason preprocessing amortizes to a one-time cost.  The scheduler owns
//! that sweep: jobs are pulled from a shared queue by a small thread pool,
//! each trains on a shared immutable dataset reference, and outcomes are
//! collected with the job's grid coordinates attached so experiment
//! harnesses can print figure rows directly.

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use crate::solver::dcd_svm::{train_svm, SvmConfig};
use crate::solver::linear::{accuracy, FeatureMatrix, LinearModel};
use crate::solver::lr_newton::{train_lr, LrConfig};
use crate::Result;

/// Which solver a job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    SvmDcd,
    LrNewton,
}

/// One training job in a sweep.
#[derive(Clone, Debug)]
pub struct TrainJob {
    /// Free-form grid coordinates echoed into the outcome (e.g. b, k).
    pub tag: String,
    pub solver: SolverKind,
    pub c: f64,
}

/// A finished job.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub tag: String,
    pub solver: SolverKind,
    pub c: f64,
    pub train_accuracy: f64,
    pub test_accuracy: f64,
    pub train_seconds: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Thread-pool scheduler over a fixed train/test pair.
pub struct Scheduler {
    pub threads: usize,
}

impl Scheduler {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        Scheduler { threads }
    }

    /// Run all jobs; outcomes are returned in job order.
    pub fn run_grid<F: FeatureMatrix>(
        &self,
        train: &F,
        test: &F,
        jobs: &[TrainJob],
    ) -> Result<Vec<TrainOutcome>> {
        let queue: Arc<Mutex<std::vec::IntoIter<(usize, TrainJob)>>> = Arc::new(Mutex::new(
            jobs.iter().cloned().enumerate().collect::<Vec<_>>().into_iter(),
        ));
        let (tx, rx) = channel::<(usize, TrainOutcome)>();
        std::thread::scope(|scope| {
            for _ in 0..self.threads.min(jobs.len().max(1)) {
                let queue = queue.clone();
                let tx = tx.clone();
                scope.spawn(move || loop {
                    let next = queue.lock().unwrap().next();
                    let Some((pos, job)) = next else { break };
                    let outcome = run_one(train, test, &job);
                    if tx.send((pos, outcome)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<TrainOutcome>> = vec![None; jobs.len()];
            for (pos, outcome) in rx {
                out[pos] = Some(outcome);
            }
            Ok(out.into_iter().map(|o| o.expect("job lost")).collect())
        })
    }
}

fn run_one<F: FeatureMatrix>(train: &F, test: &F, job: &TrainJob) -> TrainOutcome {
    let (model, stats): (LinearModel, _) = match job.solver {
        SolverKind::SvmDcd => train_svm(train, &SvmConfig::with_c(job.c)),
        SolverKind::LrNewton => train_lr(train, &LrConfig::with_c(job.c)),
    };
    TrainOutcome {
        tag: job.tag.clone(),
        solver: job.solver,
        c: job.c,
        train_accuracy: accuracy(&model, train),
        test_accuracy: accuracy(&model, test),
        train_seconds: stats.train_seconds,
        iterations: stats.iterations,
        converged: stats.converged,
    }
}

/// The paper's C grid (Section 4.1: 10^-3..10^2 with finer spacing in
/// [0.1, 10]).
pub fn paper_c_grid() -> Vec<f64> {
    vec![0.001, 0.01, 0.03, 0.1, 0.3, 0.5, 1.0, 3.0, 5.0, 10.0, 30.0, 100.0]
}

/// The reduced C grid used by the figure-5/6 style comparisons.
pub fn small_c_grid() -> Vec<f64> {
    vec![0.01, 0.1, 1.0, 10.0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Example, SparseDataset};
    use crate::util::Rng;

    fn separable(n: usize, seed: u64) -> SparseDataset {
        let mut rng = Rng::new(seed);
        let mut ex = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let base = if pos { 0 } else { 10 };
            ex.push(Example::binary(
                if pos { 1 } else { -1 },
                (0..4).map(|_| base + rng.below(10) as u32).collect(),
            ));
        }
        SparseDataset::from_examples(20, &ex)
    }

    #[test]
    fn grid_runs_all_jobs_in_order() {
        let train = separable(200, 1);
        let test = separable(100, 2);
        let jobs: Vec<TrainJob> = [0.01, 0.1, 1.0]
            .iter()
            .flat_map(|&c| {
                [SolverKind::SvmDcd, SolverKind::LrNewton].map(|solver| TrainJob {
                    tag: format!("c={c}"),
                    solver,
                    c,
                })
            })
            .collect();
        let outcomes = Scheduler::new(3).run_grid(&train, &test, &jobs).unwrap();
        assert_eq!(outcomes.len(), 6);
        for (job, out) in jobs.iter().zip(&outcomes) {
            assert_eq!(job.tag, out.tag);
            assert_eq!(job.solver, out.solver);
            assert!(out.test_accuracy > 0.9, "{out:?}");
        }
    }

    #[test]
    fn single_thread_equals_many_threads() {
        let train = separable(150, 3);
        let test = separable(80, 4);
        let jobs: Vec<TrainJob> = paper_c_grid()
            .into_iter()
            .take(4)
            .map(|c| TrainJob { tag: String::new(), solver: SolverKind::SvmDcd, c })
            .collect();
        let a = Scheduler::new(1).run_grid(&train, &test, &jobs).unwrap();
        let b = Scheduler::new(4).run_grid(&train, &test, &jobs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            // solvers are deterministic given C, so accuracies must agree
            assert_eq!(x.test_accuracy, y.test_accuracy);
            assert_eq!(x.train_accuracy, y.train_accuracy);
        }
    }

    #[test]
    fn grids_are_sane() {
        let g = paper_c_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(g.first().copied(), Some(0.001));
        assert_eq!(g.last().copied(), Some(100.0));
        assert!(small_c_grid().len() == 4);
    }
}
