//! The streaming preprocessing pipeline.
//!
//! Topology (all std threads, bounded channels = backpressure):
//!
//! ```text
//!   reader ──sync_channel(queue_depth)──▶ worker×W ──channel──▶ collector
//!   (LibSVM parse / generator)   (minwise+b-bit pack, or VW)   (reorder +
//!                                                               splice)
//! ```
//!
//! - The reader is the paper's "data loading" stage (Table 2 column 1);
//!   workers are the "preprocessing" stage (column 2); swapping the worker
//!   body for the PJRT [`MinhashEngine`](crate::runtime::MinhashEngine)
//!   gives column 3 (the accelerated path).
//! - Workers pull from one shared queue — natural load balancing (a slow
//!   chunk doesn't stall siblings), with chunk ids restoring deterministic
//!   output order in the collector regardless of completion order.
//! - `try_send`-then-`send` on the reader side counts backpressure stalls:
//!   if the hashing stage cannot keep up with parsing, stalls > 0 and the
//!   bounded queue caps memory at `queue_depth · chunk_size` examples.
//!
//! The pipeline's integrity invariant — every input example appears in the
//! output exactly once, in input order — is enforced by construction
//! (chunk-id reordering) and property-tested in
//! `rust/tests/prop_coordinator.rs`.

use std::sync::mpsc::{channel, sync_channel, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::data::dataset::{Example, SparseDataset};
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::hashing::minwise::BbitMinHash;
use crate::hashing::vw::VwHasher;
use crate::util::Rng;
use crate::{Error, Result};

/// What the hash workers compute.
#[derive(Clone, Debug)]
pub enum HashJob {
    /// k-way minwise hashing truncated to b bits, packed (the paper's
    /// method, Sections 2–3).
    Bbit { b: u32, k: usize, d: u64, seed: u64 },
    /// VW signed feature hashing into `bins` bins (Section 5).
    Vw { bins: usize, seed: u64 },
}

/// Pipeline tuning knobs (a view of [`crate::config::Config`]).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub workers: usize,
    pub chunk_size: usize,
    pub queue_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            workers: crate::config::available_workers(),
            chunk_size: 256,
            queue_depth: 4,
        }
    }
}

/// Hashed output: packed b-bit codes or a VW CSR dataset.
pub enum PipelineOutput {
    Bbit(BbitDataset),
    Vw(SparseDataset),
}

impl PipelineOutput {
    pub fn len(&self) -> usize {
        match self {
            PipelineOutput::Bbit(d) => d.len(),
            PipelineOutput::Vw(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn into_bbit(self) -> Result<BbitDataset> {
        match self {
            PipelineOutput::Bbit(d) => Ok(d),
            _ => Err(Error::Pipeline("expected b-bit output".into())),
        }
    }

    pub fn into_vw(self) -> Result<SparseDataset> {
        match self {
            PipelineOutput::Vw(d) => Ok(d),
            _ => Err(Error::Pipeline("expected VW output".into())),
        }
    }
}

/// Timing/health report (feeds Table 2 and the pipeline bench).
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub docs: usize,
    pub chunks: usize,
    /// Seconds the reader spent producing chunks (parse/generate).
    pub read_seconds: f64,
    /// CPU-seconds summed across hash workers.
    pub hash_cpu_seconds: f64,
    /// End-to-end wall-clock.
    pub wall_seconds: f64,
    /// Times the reader hit a full queue (backpressure events).
    pub backpressure_stalls: u64,
    /// Chunks processed per worker (load-balance visibility).
    pub per_worker_chunks: Vec<usize>,
}

/// The streaming orchestrator.
pub struct Pipeline {
    pub cfg: PipelineConfig,
}

type ChunkResult<O> = (usize, O);

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        assert!(cfg.workers > 0 && cfg.chunk_size > 0 && cfg.queue_depth > 0);
        Pipeline { cfg }
    }

    /// Generic fan-out/fan-in over chunks; returns per-chunk outputs in
    /// chunk order plus the report.  `work(chunk, worker_id)` runs on
    /// worker threads.
    pub fn run_chunks<O, W>(
        &self,
        source: impl Iterator<Item = Result<Vec<Example>>> + Send,
        work: W,
    ) -> Result<(Vec<O>, PipelineReport)>
    where
        O: Send,
        W: Fn(&[Example], usize) -> Result<O> + Send + Sync,
    {
        let wall0 = Instant::now();
        let mut report = PipelineReport {
            per_worker_chunks: vec![0; self.cfg.workers],
            ..Default::default()
        };

        std::thread::scope(|scope| -> Result<(Vec<O>, PipelineReport)> {
            let (chunk_tx, chunk_rx) = sync_channel::<(usize, Vec<Example>)>(self.cfg.queue_depth);
            let chunk_rx = Arc::new(Mutex::new(chunk_rx));
            let (out_tx, out_rx) = channel::<Result<ChunkResult<(O, usize, f64)>>>();

            // ---- reader (this scope's own thread) ----
            let reader = scope.spawn(move || -> Result<(usize, usize, f64, u64)> {
                let t0 = Instant::now();
                let mut docs = 0usize;
                let mut chunks = 0usize;
                let mut stalls = 0u64;
                for (chunk_id, chunk) in source.enumerate() {
                    let chunk = chunk?;
                    docs += chunk.len();
                    chunks += 1;
                    match chunk_tx.try_send((chunk_id, chunk)) {
                        Ok(()) => {}
                        Err(TrySendError::Full(v)) => {
                            stalls += 1;
                            chunk_tx.send(v).map_err(|_| {
                                Error::Pipeline("workers hung up".into())
                            })?;
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            return Err(Error::Pipeline("workers hung up".into()));
                        }
                    }
                }
                Ok((docs, chunks, t0.elapsed().as_secs_f64(), stalls))
            });

            // ---- workers ----
            let work = &work;
            for wid in 0..self.cfg.workers {
                let rx = chunk_rx.clone();
                let tx = out_tx.clone();
                scope.spawn(move || {
                    loop {
                        let msg = rx.lock().unwrap().recv();
                        let (chunk_id, chunk) = match msg {
                            Ok(v) => v,
                            Err(_) => break, // reader done, queue drained
                        };
                        let t0 = Instant::now();
                        let out = work(&chunk, wid)
                            .map(|o| (chunk_id, (o, wid, t0.elapsed().as_secs_f64())));
                        if tx.send(out).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(out_tx);
            drop(chunk_rx);

            // ---- collector (current thread): reorder by chunk id ----
            let mut pending: std::collections::BTreeMap<usize, O> =
                std::collections::BTreeMap::new();
            for msg in out_rx {
                let (chunk_id, (out, wid, secs)) = msg?;
                report.hash_cpu_seconds += secs;
                report.per_worker_chunks[wid] += 1;
                pending.insert(chunk_id, out);
            }
            let (docs, chunks, read_secs, stalls) = reader
                .join()
                .map_err(|_| Error::Pipeline("reader panicked".into()))??;
            report.docs = docs;
            report.chunks = chunks;
            report.read_seconds = read_secs;
            report.backpressure_stalls = stalls;
            if pending.len() != chunks {
                return Err(Error::Pipeline(format!(
                    "lost chunks: got {} of {}",
                    pending.len(),
                    chunks
                )));
            }
            // BTreeMap iterates in ascending chunk order
            let ordered: Vec<O> = pending.into_values().collect();
            report.wall_seconds = wall0.elapsed().as_secs_f64();
            Ok((ordered, report))
        })
    }

    /// Run a [`HashJob`] over a chunk stream, assembling the hashed dataset.
    pub fn run(
        &self,
        source: impl Iterator<Item = Result<Vec<Example>>> + Send,
        job: &HashJob,
    ) -> Result<(PipelineOutput, PipelineReport)> {
        match job {
            HashJob::Bbit { b, k, d, seed } => {
                let hasher = Arc::new(BbitMinHash::draw(*k, *b, *d, &mut Rng::new(*seed)));
                let (chunks, report) = self.run_chunks(source, {
                    let hasher = hasher.clone();
                    move |chunk: &[Example], _wid| {
                        let mut codes = PackedCodes::new(hasher.b, hasher.k());
                        let mut labels = Vec::with_capacity(chunk.len());
                        let mut scratch = vec![0u64; hasher.k()];
                        let mut row = vec![0u16; hasher.k()];
                        for ex in chunk {
                            hasher.codes_into(&ex.indices, &mut scratch, &mut row);
                            codes.push_row(&row)?;
                            labels.push(ex.label);
                        }
                        Ok((codes, labels))
                    }
                })?;
                let mut all = PackedCodes::new(*b, *k);
                let mut labels = Vec::new();
                for (codes, ls) in chunks {
                    all.extend(&codes)?;
                    labels.extend(ls);
                }
                Ok((PipelineOutput::Bbit(BbitDataset::new(all, labels)), report))
            }
            HashJob::Vw { bins, seed } => {
                let hasher = Arc::new(VwHasher::draw(*bins, &mut Rng::new(*seed)));
                let (chunks, report) = self.run_chunks(source, {
                    let hasher = hasher.clone();
                    move |chunk: &[Example], _wid| {
                        let mut rows = Vec::with_capacity(chunk.len());
                        for ex in chunk {
                            let pairs = hasher.hash_sparse(&ex.indices);
                            rows.push((ex.label, pairs));
                        }
                        Ok(rows)
                    }
                })?;
                let mut ds = SparseDataset::new(*bins as u64);
                ds.values = Some(Vec::new());
                for rows in chunks {
                    for (label, pairs) in rows {
                        ds.push(&Example {
                            label,
                            indices: pairs.iter().map(|p| p.0).collect(),
                            values: Some(pairs.iter().map(|p| p.1).collect()),
                        });
                    }
                }
                Ok((PipelineOutput::Vw(ds), report))
            }
        }
    }
}

/// Turn an in-memory dataset into the chunk stream the pipeline consumes
/// (tests and benches; production path streams from LibSVM files).
pub fn dataset_chunks(
    ds: &SparseDataset,
    chunk_size: usize,
) -> impl Iterator<Item = Result<Vec<Example>>> + '_ {
    let plan = crate::coordinator::sharding::ShardPlan::new(ds.len(), chunk_size);
    let assignments: Vec<_> = plan.iter().collect();
    assignments.into_iter().map(move |a| {
        Ok((a.row0..a.row0 + a.rows)
            .map(|i| {
                let (idx, vals) = ds.row(i);
                Example {
                    label: ds.labels[i],
                    indices: idx.to_vec(),
                    values: vals.map(|v| v.to_vec()),
                }
            })
            .collect())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen::{CorpusConfig, CorpusGenerator};

    fn corpus(n: usize) -> SparseDataset {
        CorpusGenerator::new(CorpusConfig {
            n_docs: n,
            vocab: 1000,
            zipf_alpha: 1.05,
            mean_tokens: 20.0,
            class_signal: 0.5,
            pos_fraction: 0.5,
            seed: 99,
        })
        .generate()
    }

    #[test]
    fn bbit_pipeline_matches_sequential() {
        let ds = corpus(300);
        let job = HashJob::Bbit { b: 8, k: 32, d: 1 << 20, seed: 5 };
        let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 32, queue_depth: 2 });
        let (out, report) = pipe.run(dataset_chunks(&ds, 32), &job).unwrap();
        let bb = out.into_bbit().unwrap();
        assert_eq!(bb.len(), 300);
        assert_eq!(report.docs, 300);
        assert_eq!(report.chunks, 10);
        // sequential reference
        let hasher = BbitMinHash::draw(32, 8, 1 << 20, &mut Rng::new(5));
        for i in 0..ds.len() {
            assert_eq!(bb.codes.row(i), hasher.codes(ds.row(i).0), "row {i}");
            assert_eq!(bb.labels[i], ds.labels[i]);
        }
    }

    #[test]
    fn vw_pipeline_matches_sequential() {
        let ds = corpus(100);
        let job = HashJob::Vw { bins: 64, seed: 7 };
        let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 17, queue_depth: 2 });
        let (out, _) = pipe.run(dataset_chunks(&ds, 17), &job).unwrap();
        let vw = out.into_vw().unwrap();
        vw.validate().unwrap();
        assert_eq!(vw.len(), 100);
        let hasher = VwHasher::draw(64, &mut Rng::new(7));
        for i in 0..ds.len() {
            let mut dense = vec![0.0f32; 64];
            hasher.hash_into(ds.row(i).0, &mut dense);
            let (idx, vals) = vw.row(i);
            let mut got = vec![0.0f32; 64];
            for (t, v) in idx.iter().zip(vals.unwrap()) {
                got[*t as usize] = *v;
            }
            assert_eq!(got, dense, "row {i}");
        }
    }

    #[test]
    fn single_worker_and_tiny_queue() {
        let ds = corpus(50);
        let job = HashJob::Bbit { b: 4, k: 8, d: 1 << 16, seed: 1 };
        let pipe = Pipeline::new(PipelineConfig { workers: 1, chunk_size: 7, queue_depth: 1 });
        let (out, report) = pipe.run(dataset_chunks(&ds, 7), &job).unwrap();
        assert_eq!(out.len(), 50);
        assert_eq!(report.per_worker_chunks, vec![8]);
    }

    #[test]
    fn worker_errors_propagate() {
        let ds = corpus(40);
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 8, queue_depth: 2 });
        let result: Result<(Vec<()>, _)> =
            pipe.run_chunks(dataset_chunks(&ds, 8), |chunk, _| {
                if chunk[0].indices.len() < 10_000 {
                    Err(Error::Pipeline("injected".into()))
                } else {
                    Ok(())
                }
            });
        assert!(result.is_err());
    }

    #[test]
    fn reader_errors_propagate() {
        let source = vec![
            Ok(vec![Example::binary(1, vec![1])]),
            Err(Error::Io(std::io::Error::other("disk gone"))),
        ];
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 1, queue_depth: 1 });
        let out = pipe.run(source.into_iter(), &HashJob::Bbit { b: 1, k: 4, d: 16, seed: 0 });
        assert!(out.is_err());
    }

    #[test]
    fn order_is_deterministic_across_worker_counts() {
        let ds = corpus(200);
        let job = HashJob::Bbit { b: 2, k: 16, d: 1 << 18, seed: 3 };
        let run = |workers| {
            let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 13, queue_depth: 3 });
            let (out, _) = pipe.run(dataset_chunks(&ds, 13), &job).unwrap();
            out.into_bbit().unwrap()
        };
        let a = run(1);
        let b = run(7);
        assert_eq!(a.labels, b.labels);
        for i in 0..a.len() {
            assert_eq!(a.codes.row(i), b.codes.row(i));
        }
    }
}
