//! The streaming preprocessing pipeline.
//!
//! Topology (all std threads, bounded channels = backpressure).  Two
//! source shapes share one fan-out/fan-in core:
//!
//! ```text
//!   chunk source (legacy / in-memory):
//!   reader ──sync_channel(queue_depth)──▶ worker×W ──sync_channel──▶ collector ──▶ sink
//!   (LibSVM parse / generator)    (FeatureEncoder::encode_chunk:   (bounded     (collect |
//!                                  bbit / vw / rp / oph)            reorder      cache |
//!                                                                   window)      train)
//!
//!   block source (byte-block ingest, the default raw-input path):
//!   reader ──────────────────────▶ worker×W ──────────────────────▶ collector ──▶ sink
//!   (carve newline-aligned         (parse_block into per-worker
//!    RawBlocks; recycled            ParsedChunk scratch, then
//!    buffers, no parsing)           FeatureEncoder::encode_parsed)
//! ```
//!
//! - The reader is the paper's "data loading" stage (Table 2 column 1);
//!   workers are the "preprocessing" stage (column 2) and run a shared
//!   [`FeatureEncoder`] trait object — the scheme (b-bit minwise, VW,
//!   random projections, OPH, ...) is decided by the [`EncoderSpec`] and
//!   never by the pipeline itself.  Swapping the worker body for the PJRT
//!   [`MinhashEngine`](crate::runtime::MinhashEngine) gives column 3 (the
//!   accelerated path).
//! - In the block topology ([`run_blocks_each`](Pipeline::run_blocks_each))
//!   the reader stops parsing entirely: it carves the input into
//!   newline-aligned byte slabs ([`BlockReader`]) whose buffers the parse
//!   workers hand back for reuse, so *parsing scales with `--workers`*
//!   instead of bottlenecking on one thread — and the per-byte reader work
//!   drops to a `read` plus a newline count, i.e. the raw-load bound the
//!   paper compares preprocessing against.  Workers parse into recycled
//!   per-worker [`ParsedChunk`] scratch and encode in place; the reorder
//!   window keeps block order, so output is deterministic for every worker
//!   count.
//! - Workers pull from one shared queue — natural load balancing (a slow
//!   chunk doesn't stall siblings), with chunk ids restoring deterministic
//!   output order in the collector regardless of completion order.
//! - The collector holds only the *reorder window*: chunks that completed
//!   ahead of the next-in-order chunk.  Each chunk is re-emitted into the
//!   [`PipelineSink`](crate::coordinator::sink) the moment its predecessors
//!   have been, then dropped.  An admission-credit loop (collector returns
//!   one token per emitted chunk; the reader blocks without a token) hard-
//!   bounds chunks in flight at `2·(workers + queue_depth)`, so peak
//!   collector memory — reported as [`PipelineReport::reorder_peak`] — is
//!   set by the window, never by corpus size.  The old end-of-run
//!   buffer-the-whole-dataset behavior survives only inside
//!   [`CollectSink`](crate::coordinator::sink::CollectSink).
//! - `try_send`-then-`send` on the reader side counts backpressure stalls
//!   *and* the seconds spent blocked ([`PipelineReport::stall_seconds`]):
//!   if hashing cannot keep up with parsing, stalls > 0 and the bounded
//!   queues cap memory at roughly
//!   `(queue_depth + workers + out-queue) · chunk_size` examples.
//!
//! The pipeline's integrity invariant — every input example appears in the
//! output exactly once, in input order — is enforced by construction
//! (chunk-id reordering, emitted-count check) and property-tested in
//! `rust/tests/prop_invariants.rs`.

use std::sync::mpsc::{sync_channel, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::sink::{CollectSink, PipelineSink};
use crate::data::dataset::{Example, SparseDataset};
use crate::data::libsvm::{
    parse_block, parse_block_lossy, BadLine, BlockReader, ParsedChunk, RawBlock,
};
use crate::encode::encoder::{EncodedChunk, EncoderSpec, FeatureEncoder};
use crate::encode::expansion::BbitDataset;
use crate::metrics::trace::{self, TraceCtx};
use crate::{Error, Result};

/// What the hash workers compute — legacy name for [`EncoderSpec`].
///
/// The closed two-variant `HashJob` enum became the open scheme space of
/// [`EncoderSpec`]; the old `HashJob::Bbit { .. }` / `HashJob::Vw { .. }`
/// constructors are the same variants with the same fields.
#[deprecated(note = "use EncoderSpec (encode::encoder); HashJob is a thin alias")]
pub type HashJob = EncoderSpec;

/// Pipeline tuning knobs (a view of [`crate::config::Config`]).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub workers: usize,
    pub chunk_size: usize,
    pub queue_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            workers: crate::config::available_workers(),
            chunk_size: 256,
            queue_depth: 4,
        }
    }
}

/// Materialized encoded output: packed b-bit codes (b-bit minwise, OPH)
/// or a sparse CSR dataset (VW, RP).
pub enum PipelineOutput {
    Packed(BbitDataset),
    Sparse(SparseDataset),
}

impl PipelineOutput {
    pub fn len(&self) -> usize {
        match self {
            PipelineOutput::Packed(d) => d.len(),
            PipelineOutput::Sparse(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn into_packed(self) -> Result<BbitDataset> {
        match self {
            PipelineOutput::Packed(d) => Ok(d),
            _ => Err(Error::Pipeline("expected packed-code output".into())),
        }
    }

    pub fn into_sparse(self) -> Result<SparseDataset> {
        match self {
            PipelineOutput::Sparse(d) => Ok(d),
            _ => Err(Error::Pipeline("expected sparse output".into())),
        }
    }

    /// Legacy spelling of [`into_packed`](Self::into_packed).
    pub fn into_bbit(self) -> Result<BbitDataset> {
        self.into_packed()
    }

    /// Legacy spelling of [`into_sparse`](Self::into_sparse).
    pub fn into_vw(self) -> Result<SparseDataset> {
        self.into_sparse()
    }
}

/// Timing/health report (feeds Table 2 and the pipeline bench).
#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub docs: usize,
    pub chunks: usize,
    /// Seconds the reader spent *producing* chunks (parse/generate) —
    /// excludes time blocked on a full worker queue, which lands in
    /// [`stall_seconds`](Self::stall_seconds).  This is the paper's
    /// Table-2 "data loading" column; folding backpressure waits into it
    /// would overstate loading cost whenever hashing is the bottleneck.
    pub read_seconds: f64,
    /// Seconds the reader spent blocked on backpressure — waiting for an
    /// admission credit or handing a chunk to a full worker queue (the
    /// wall-clock cost of the events counted by
    /// [`backpressure_stalls`](Self::backpressure_stalls)).
    pub stall_seconds: f64,
    /// CPU-seconds summed across hash workers.
    pub hash_cpu_seconds: f64,
    /// Seconds the collector spent inside the sink (`consume` + `finish`)
    /// — disk time for a cache sink, solver time for a train sink.
    pub sink_seconds: f64,
    /// End-to-end wall-clock.
    pub wall_seconds: f64,
    /// Backpressure events: each time the reader blocked waiting for an
    /// admission credit or for space in the worker queue.  A single chunk
    /// can count both (credit wait, then full queue), so this is an event
    /// count, not a chunk count; [`stall_seconds`](Self::stall_seconds)
    /// carries the wall-clock cost.
    pub backpressure_stalls: u64,
    /// High-water mark of the collector's reorder window in chunks: the
    /// most chunks ever held waiting for an earlier chunk to complete.
    /// Hard-bounded at `2·(workers + queue_depth)` by the admission-credit
    /// loop — never grows with corpus size.
    pub reorder_peak: usize,
    /// Chunks processed per worker (load-balance visibility).
    pub per_worker_chunks: Vec<usize>,
    /// Reader-pool threads used by a cache replay (0 for the forward
    /// hash pipeline; 1 means the sequential replay path).  Filled by
    /// [`replay`](crate::coordinator::replay).
    pub replay_threads: usize,
    /// Cache file bytes behind a replay run (header + records + footer) —
    /// the MB/s numerator of the `replay` bench scenario.
    pub replay_bytes: u64,
    /// Raw input bytes carved by the block reader (0 for chunk sources) —
    /// the MB/s numerator of the `ingest` bench scenario.
    pub input_bytes: u64,
    /// Worker CPU-seconds spent parsing raw byte blocks into rows
    /// (block-parallel ingest only; on the legacy line-reader path parsing
    /// happens on the reader thread and lands in
    /// [`read_seconds`](Self::read_seconds)).  Disjoint from
    /// [`hash_cpu_seconds`](Self::hash_cpu_seconds), which keeps meaning
    /// encode-only time.
    pub parse_cpu_seconds: f64,
    /// Wall-clock seconds spent inside device (`--device xla`) chunk
    /// encodes, summed across workers (a slice of
    /// [`hash_cpu_seconds`](Self::hash_cpu_seconds): the workers block on
    /// the device driver for this long).  0 when no device encoder ran.
    pub encode_device_seconds: f64,
    /// Chunks encoded on the device path.
    pub device_chunks: u64,
    /// Chunks a device encoder fell back to CPU for (device unavailable
    /// or a mid-run launch failure).  `device_chunks + device_fallbacks`
    /// equals total chunks when a [`DeviceEncoder`](crate::encode::DeviceEncoder)
    /// drove the run.
    pub device_fallbacks: u64,
    /// Malformed input lines skipped under `--on-error skip`
    /// ([`IngestOptions::skip_errors`]) — 0 on the default fail-fast path,
    /// where the first bad line aborts the run instead.
    pub parse_errors: u64,
}

impl PipelineReport {
    /// Replayed rows per wall-clock second (0 when nothing ran).
    pub fn rows_per_sec(&self) -> f64 {
        self.docs as f64 / self.wall_seconds.max(1e-9)
    }

    /// Documents parsed per parse-CPU-second (block-parallel ingest; 0
    /// when no in-worker parsing ran).
    pub fn parse_rows_per_sec(&self) -> f64 {
        if self.parse_cpu_seconds <= 0.0 {
            0.0
        } else {
            self.docs as f64 / self.parse_cpu_seconds
        }
    }

    /// Raw input megabytes ingested per wall-clock second (0 for non-block
    /// sources).
    pub fn ingest_mb_per_sec(&self) -> f64 {
        self.input_bytes as f64 / 1e6 / self.wall_seconds.max(1e-9)
    }

    /// Machine-readable dump of every counter plus the derived rates —
    /// the `--report-json FILE` flag on `preprocess` and `train --stream`,
    /// so bench/trend tooling consumes this instead of scraping the human
    /// summary.  Hand-rolled JSON, same as the BENCH_*.json writers (the
    /// crate has no serde).
    pub fn to_json(&self) -> String {
        let per_worker = self
            .per_worker_chunks
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"docs\":{},\"chunks\":{},\"read_seconds\":{:.6},\"stall_seconds\":{:.6},\
             \"hash_cpu_seconds\":{:.6},\"parse_cpu_seconds\":{:.6},\"sink_seconds\":{:.6},\
             \"wall_seconds\":{:.6},\"backpressure_stalls\":{},\"reorder_peak\":{},\
             \"per_worker_chunks\":[{}],\"replay_threads\":{},\"replay_bytes\":{},\
             \"input_bytes\":{},\"encode_device_seconds\":{:.6},\"device_chunks\":{},\
             \"device_fallbacks\":{},\"parse_errors\":{},\"rows_per_sec\":{:.1},\
             \"parse_rows_per_sec\":{:.1},\"ingest_mb_per_sec\":{:.3}}}",
            self.docs,
            self.chunks,
            self.read_seconds,
            self.stall_seconds,
            self.hash_cpu_seconds,
            self.parse_cpu_seconds,
            self.sink_seconds,
            self.wall_seconds,
            self.backpressure_stalls,
            self.reorder_peak,
            per_worker,
            self.replay_threads,
            self.replay_bytes,
            self.input_bytes,
            self.encode_device_seconds,
            self.device_chunks,
            self.device_fallbacks,
            self.parse_errors,
            self.rows_per_sec(),
            self.parse_rows_per_sec(),
            self.ingest_mb_per_sec(),
        )
    }
}

/// Copy a device-capable encoder's counters into the report after a run —
/// a no-op for plain CPU encoders, whose
/// [`device_stats`](FeatureEncoder::device_stats) is `None`.
fn fold_device_stats(report: &mut PipelineReport, encoder: &dyn FeatureEncoder) {
    if let Some(ds) = encoder.device_stats() {
        report.encode_device_seconds = ds.device_seconds;
        report.device_chunks = ds.device_chunks;
        report.device_fallbacks = ds.device_fallbacks;
    }
}

/// Ingest policy for the block pipeline
/// ([`run_encoder_blocks_opts`](Pipeline::run_encoder_blocks_opts)):
/// what to do with malformed LibSVM lines.
///
/// Default is fail-fast (the first bad line aborts the run with its line
/// number, exactly as before).  With `skip_errors` the parse continues
/// past bad lines: each one is counted in
/// [`PipelineReport::parse_errors`] and handed — in input order, on the
/// collector thread — to `on_bad_line`, which is where `preprocess
/// --quarantine FILE` appends the raw bytes for later inspection.
#[derive(Default)]
pub struct IngestOptions<'a> {
    /// Continue past malformed lines instead of failing the run.
    pub skip_errors: bool,
    /// In-order receiver for skipped lines (ignored unless
    /// `skip_errors`); an error here aborts the run.
    pub on_bad_line: Option<&'a mut dyn FnMut(&BadLine) -> Result<()>>,
}

/// The streaming orchestrator.
pub struct Pipeline {
    pub cfg: PipelineConfig,
}

type ChunkResult<O> = (usize, O);

impl Pipeline {
    pub fn new(cfg: PipelineConfig) -> Self {
        assert!(cfg.workers > 0 && cfg.chunk_size > 0 && cfg.queue_depth > 0);
        Pipeline { cfg }
    }

    /// Generic fan-out/fan-in over chunks with *incremental in-order
    /// delivery*: `work(chunk, worker_id)` runs on worker threads, and
    /// `emit(chunk_id, output)` runs on the collector (calling) thread,
    /// called exactly once per chunk in ascending chunk order, as soon as
    /// all predecessors have been emitted.  Completed-but-early chunks
    /// wait in a reorder window whose high-water mark is reported.
    pub fn run_chunks_each<O, W, E>(
        &self,
        source: impl Iterator<Item = Result<Vec<Example>>> + Send,
        work: W,
        emit: E,
    ) -> Result<PipelineReport>
    where
        O: Send,
        W: Fn(&[Example], usize) -> Result<O> + Send + Sync,
        E: FnMut(usize, O) -> Result<()>,
    {
        let mut root = trace::Span::enter("pipeline.run");
        let rctx = root.ctx();
        let report = self.run_core(
            source,
            rctx,
            |chunk: &Vec<Example>| (chunk.len(), 0),
            || (),
            |chunk, (), wid| {
                let mut span = trace::Span::child("pipeline.encode", rctx);
                span.record("worker", wid as f64);
                span.record("rows", chunk.len() as f64);
                let out = work(&chunk, wid);
                span.record(
                    "device",
                    if crate::encode::encoder::take_encode_used_device() { 1.0 } else { 0.0 },
                );
                out
            },
            emit,
        )?;
        root.record("docs", report.docs as f64);
        root.record("chunks", report.chunks as f64);
        Ok(report)
    }

    /// The fan-out/fan-in engine behind every source shape: generic over
    /// the item the reader produces (`Vec<Example>` chunks, raw byte
    /// blocks, ...) and over per-worker mutable state (`make_state` runs
    /// once per worker; the block path parks its parse scratch there).
    /// `size_of` is the reader-side accounting hook returning
    /// `(docs, input_bytes)` for an item before it is dispatched.
    /// `rctx` is the caller's root trace context: read and sink stage
    /// spans parent under it (worker-stage spans are the caller's job —
    /// the block path splits them into parse + encode).
    fn run_core<I, O, ST, SZ, MK, W, E>(
        &self,
        source: impl Iterator<Item = Result<I>> + Send,
        rctx: TraceCtx,
        size_of: SZ,
        mut make_state: MK,
        work: W,
        mut emit: E,
    ) -> Result<PipelineReport>
    where
        I: Send,
        O: Send,
        ST: Send,
        SZ: Fn(&I) -> (usize, u64) + Send,
        MK: FnMut() -> ST,
        W: Fn(I, &mut ST, usize) -> Result<O> + Send + Sync,
        E: FnMut(usize, O) -> Result<()>,
    {
        let wall0 = Instant::now();
        let mut report = PipelineReport {
            per_worker_chunks: vec![0; self.cfg.workers],
            ..Default::default()
        };

        // In-flight admission window: the reader consumes one credit per
        // chunk and the collector returns it once the chunk is emitted to
        // the sink, so at most `window` chunks exist anywhere in the
        // pipeline (queues + workers + reorder buffer) at once.
        let window = 2 * (self.cfg.workers + self.cfg.queue_depth);

        // Per-worker state built up front on this thread, moved into the
        // worker threads below.
        let states: Vec<ST> = (0..self.cfg.workers).map(|_| make_state()).collect();

        std::thread::scope(|scope| -> Result<PipelineReport> {
            let (chunk_tx, chunk_rx) = sync_channel::<(usize, I)>(self.cfg.queue_depth);
            let chunk_rx = Arc::new(Mutex::new(chunk_rx));
            // Bounded so a slow sink backpressures workers (and through
            // them the reader) instead of letting finished chunks pile up.
            // The chunk id rides outside the Result so a failure is
            // attributable to its chunk: the collector fails on the
            // *earliest* bad chunk, not the first failure to finish.
            let (out_tx, out_rx) = sync_channel::<ChunkResult<Result<(O, usize, f64)>>>(
                self.cfg.workers + self.cfg.queue_depth,
            );
            let (credit_tx, credit_rx) = sync_channel::<()>(window);
            for _ in 0..window {
                credit_tx.try_send(()).expect("credit prefill cannot overflow");
            }

            // ---- reader (this scope's own thread) ----
            let reader = scope.spawn(move || -> Result<(usize, usize, u64, f64, u64, f64)> {
                let t0 = Instant::now();
                let mut docs = 0usize;
                let mut chunks = 0usize;
                let mut bytes = 0u64;
                let mut stalls = 0u64;
                let mut stall_secs = 0.0f64;
                let trace_on = trace::enabled();
                let mut source = source.enumerate();
                loop {
                    // per-chunk read span: times the pull itself (parse /
                    // generate / disk) — queue waits below are excluded,
                    // mirroring the read_seconds/stall_seconds split
                    let t_read = if trace_on { Some(Instant::now()) } else { None };
                    let Some((chunk_id, chunk)) = source.next() else {
                        break;
                    };
                    let chunk = chunk?;
                    if let Some(start) = t_read {
                        trace::emit_span(
                            "pipeline.read",
                            rctx,
                            start,
                            Instant::now(),
                            &[("chunk", chunk_id as f64)],
                        );
                    }
                    let (n, b) = size_of(&chunk);
                    docs += n;
                    bytes += b;
                    chunks += 1;
                    // admission credit: blocks once `window` chunks are in
                    // flight, bounding collector memory structurally
                    match credit_rx.try_recv() {
                        Ok(()) => {}
                        Err(TryRecvError::Empty) => {
                            stalls += 1;
                            let blocked = Instant::now();
                            credit_rx.recv().map_err(|_| {
                                Error::Pipeline("collector hung up".into())
                            })?;
                            stall_secs += blocked.elapsed().as_secs_f64();
                        }
                        Err(TryRecvError::Disconnected) => {
                            return Err(Error::Pipeline("collector hung up".into()));
                        }
                    }
                    match chunk_tx.try_send((chunk_id, chunk)) {
                        Ok(()) => {}
                        Err(TrySendError::Full(v)) => {
                            stalls += 1;
                            let blocked = Instant::now();
                            chunk_tx.send(v).map_err(|_| {
                                Error::Pipeline("workers hung up".into())
                            })?;
                            stall_secs += blocked.elapsed().as_secs_f64();
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            return Err(Error::Pipeline("workers hung up".into()));
                        }
                    }
                }
                let read_secs = t0.elapsed().as_secs_f64() - stall_secs;
                Ok((docs, chunks, bytes, read_secs, stalls, stall_secs))
            });

            // ---- workers ----
            let work = &work;
            for (wid, mut state) in states.into_iter().enumerate() {
                let rx = chunk_rx.clone();
                let tx = out_tx.clone();
                scope.spawn(move || {
                    loop {
                        let msg = rx.lock().unwrap().recv();
                        let (chunk_id, chunk) = match msg {
                            Ok(v) => v,
                            Err(_) => break, // reader done, queue drained
                        };
                        let t0 = Instant::now();
                        // a panicking chunk must still produce a message:
                        // with admission credits, a silently lost chunk
                        // would wedge the reader instead of failing the run
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || work(chunk, &mut state, wid),
                        ))
                        .unwrap_or_else(|_| {
                            Err(Error::Pipeline(format!("worker {wid} panicked")))
                        })
                        .map(|o| (o, wid, t0.elapsed().as_secs_f64()));
                        if tx.send((chunk_id, out)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(out_tx);
            drop(chunk_rx);

            // ---- collector (current thread): bounded reorder window ----
            // Chunks that completed ahead of order wait here; everything
            // in order is emitted immediately and dropped.  Failures park
            // under their chunk id too, and only surface once every
            // earlier chunk has been emitted — so a multi-error input
            // reports the earliest bad chunk deterministically, exactly
            // like the sequential reader, regardless of worker scheduling.
            let mut reorder: std::collections::BTreeMap<usize, O> =
                std::collections::BTreeMap::new();
            let mut failed: std::collections::BTreeMap<usize, Error> =
                std::collections::BTreeMap::new();
            let mut next_chunk = 0usize;
            for (chunk_id, res) in out_rx {
                match res {
                    Ok((out, wid, secs)) => {
                        report.hash_cpu_seconds += secs;
                        report.per_worker_chunks[wid] += 1;
                        reorder.insert(chunk_id, out);
                        report.reorder_peak = report.reorder_peak.max(reorder.len());
                    }
                    Err(e) => {
                        failed.insert(chunk_id, e);
                    }
                }
                loop {
                    if let Some(e) = failed.remove(&next_chunk) {
                        return Err(e);
                    }
                    let Some(out) = reorder.remove(&next_chunk) else {
                        break;
                    };
                    let t0 = Instant::now();
                    emit(next_chunk, out)?;
                    let t1 = Instant::now();
                    report.sink_seconds += (t1 - t0).as_secs_f64();
                    trace::emit_span(
                        "pipeline.sink",
                        rctx,
                        t0,
                        t1,
                        &[("chunk", next_chunk as f64)],
                    );
                    next_chunk += 1;
                    // return the admission credit (never blocks: in-channel
                    // credits ≤ capacity by conservation; reader-gone is fine)
                    let _ = credit_tx.try_send(());
                }
            }
            let (docs, chunks, bytes, read_secs, stalls, stall_secs) = reader
                .join()
                .map_err(|_| Error::Pipeline("reader panicked".into()))??;
            // unreachable in practice (every dispatched chunk sends exactly
            // one message), kept so a parked failure can never be swallowed
            if let Some((_, e)) = failed.into_iter().next() {
                return Err(e);
            }
            report.docs = docs;
            report.chunks = chunks;
            report.input_bytes = bytes;
            report.read_seconds = read_secs;
            report.stall_seconds = stall_secs;
            report.backpressure_stalls = stalls;
            if next_chunk != chunks || !reorder.is_empty() {
                return Err(Error::Pipeline(format!(
                    "lost chunks: emitted {} of {}",
                    next_chunk, chunks
                )));
            }
            report.wall_seconds = wall0.elapsed().as_secs_f64();
            Ok(report)
        })
    }

    /// Fan-out/fan-in returning per-chunk outputs in chunk order plus the
    /// report (materializing form of [`run_chunks_each`](Self::run_chunks_each)).
    pub fn run_chunks<O, W>(
        &self,
        source: impl Iterator<Item = Result<Vec<Example>>> + Send,
        work: W,
    ) -> Result<(Vec<O>, PipelineReport)>
    where
        O: Send,
        W: Fn(&[Example], usize) -> Result<O> + Send + Sync,
    {
        let mut outputs = Vec::new();
        let report = self.run_chunks_each(source, work, |_, o| {
            outputs.push(o);
            Ok(())
        })?;
        Ok((outputs, report))
    }

    /// Run an already-drawn [`FeatureEncoder`] over a chunk stream,
    /// pushing encoded chunks into `sink` incrementally in input order —
    /// the out-of-core entry point.  The encoder is shared by reference
    /// across all workers; the sink's `finish` is called (and timed)
    /// before returning.
    pub fn run_encoder<S: PipelineSink>(
        &self,
        source: impl Iterator<Item = Result<Vec<Example>>> + Send,
        encoder: &dyn FeatureEncoder,
        sink: &mut S,
    ) -> Result<PipelineReport> {
        let mut report = self.run_chunks_each(
            source,
            |chunk: &[Example], _wid| encoder.encode_chunk(chunk),
            |_, chunk| sink.consume(chunk),
        )?;
        let t0 = Instant::now();
        sink.finish()?;
        report.sink_seconds += t0.elapsed().as_secs_f64();
        fold_device_stats(&mut report, encoder);
        Ok(report)
    }

    /// Draw the encoder an [`EncoderSpec`] describes and run it into
    /// `sink` (see [`run_encoder`](Self::run_encoder)).
    pub fn run_sink<S: PipelineSink>(
        &self,
        source: impl Iterator<Item = Result<Vec<Example>>> + Send,
        spec: &EncoderSpec,
        sink: &mut S,
    ) -> Result<PipelineReport> {
        let encoder = spec.encoder()?;
        self.run_encoder(source, encoder.as_ref(), sink)
    }

    /// Run an [`EncoderSpec`] over a chunk stream, assembling the encoded
    /// dataset in memory (a [`run_sink`](Self::run_sink) with a
    /// [`CollectSink`] — the materializing path tests and experiments use).
    pub fn run(
        &self,
        source: impl Iterator<Item = Result<Vec<Example>>> + Send,
        spec: &EncoderSpec,
    ) -> Result<(PipelineOutput, PipelineReport)> {
        let mut sink = CollectSink::for_spec(spec)?;
        let report = self.run_sink(source, spec, &mut sink)?;
        Ok((sink.into_output(), report))
    }

    /// Block-parallel fan-out with parse-in-worker: the reader carves raw
    /// newline-aligned byte blocks, each worker parses them into its own
    /// recycled [`ParsedChunk`] scratch and runs `work(&parsed, wid)`, and
    /// `emit(block_id, output)` fires strictly in block order on the
    /// calling thread.  Block buffers are handed back to the reader after
    /// parsing, so steady-state ingest allocates nothing per document (the
    /// admission-credit loop bounds how many buffers circulate).  The
    /// report's [`parse_cpu_seconds`](PipelineReport::parse_cpu_seconds) /
    /// [`input_bytes`](PipelineReport::input_bytes) counters come from
    /// this path; `hash_cpu_seconds` keeps meaning encode-only time.
    pub fn run_blocks_each<R, O, W, E>(
        &self,
        mut blocks: BlockReader<R>,
        binary: bool,
        work: W,
        mut emit: E,
    ) -> Result<PipelineReport>
    where
        R: std::io::Read + Send,
        O: Send,
        W: Fn(&ParsedChunk, usize) -> Result<O> + Send + Sync,
        E: FnMut(usize, O) -> Result<()>,
    {
        let (pool_tx, pool_rx) = std::sync::mpsc::channel::<Vec<u8>>();
        blocks.set_recycle(pool_rx);
        let mut root = trace::Span::enter("pipeline.run");
        let rctx = root.ctx();
        let mut docs = 0usize;
        let mut parse_cpu = 0.0f64;
        let mut report = self.run_core(
            blocks,
            rctx,
            |b: &RawBlock| (0, b.bytes.len() as u64),
            || (ParsedChunk::default(), pool_tx.clone()),
            |block: RawBlock, (parsed, recycle), wid| {
                parsed.clear();
                let t0 = Instant::now();
                parse_block(&block.bytes, block.first_line, binary, parsed)?;
                let t1 = Instant::now();
                let parse_secs = (t1 - t0).as_secs_f64();
                trace::emit_span(
                    "pipeline.parse",
                    rctx,
                    t0,
                    t1,
                    &[("worker", wid as f64), ("rows", parsed.len() as f64)],
                );
                // hand the raw buffer back to the reader (reader gone at
                // end-of-input is fine)
                let _ = recycle.send(block.bytes);
                let mut span = trace::Span::child("pipeline.encode", rctx);
                span.record("worker", wid as f64);
                span.record("rows", parsed.len() as f64);
                let out = work(parsed, wid)?;
                span.record(
                    "device",
                    if crate::encode::encoder::take_encode_used_device() { 1.0 } else { 0.0 },
                );
                drop(span);
                Ok((out, parsed.len(), parse_secs))
            },
            |id, (out, n, parse_secs)| {
                docs += n;
                parse_cpu += parse_secs;
                emit(id, out)
            },
        )?;
        report.docs = docs; // blocks carry an unknown doc count at read time
        report.parse_cpu_seconds = parse_cpu;
        report.hash_cpu_seconds = (report.hash_cpu_seconds - parse_cpu).max(0.0);
        root.record("docs", report.docs as f64);
        root.record("chunks", report.chunks as f64);
        Ok(report)
    }

    /// Run an already-drawn [`FeatureEncoder`] over raw LibSVM blocks —
    /// the byte-block twin of [`run_encoder`](Self::run_encoder) and the
    /// default `preprocess`/`train --stream` ingest path.  Workers parse
    /// *and* encode ([`FeatureEncoder::encode_parsed`]); empty blocks
    /// (all comments/blanks) are skipped rather than written as zero-row
    /// sink chunks, but still advance the sink's
    /// [`mark_progress`](PipelineSink::mark_progress) cursor.
    pub fn run_encoder_blocks<R, S>(
        &self,
        blocks: BlockReader<R>,
        binary: bool,
        encoder: &dyn FeatureEncoder,
        sink: &mut S,
    ) -> Result<PipelineReport>
    where
        R: std::io::Read + Send,
        S: PipelineSink,
    {
        self.run_encoder_blocks_opts(blocks, binary, encoder, sink, IngestOptions::default())
    }

    /// [`run_encoder_blocks`](Self::run_encoder_blocks) with ingest
    /// policy: error skipping/quarantine ([`IngestOptions`]) and per-block
    /// input-progress notification.  After every block's rows reach the
    /// sink — in block order, including blocks that produced no rows —
    /// the sink's [`mark_progress`](PipelineSink::mark_progress) receives
    /// the raw-input byte offset and line number ingest would restart
    /// from, which is what lets a durable [`CacheSink`] journal a resume
    /// point that is always consistent with the records it has consumed.
    pub fn run_encoder_blocks_opts<R, S>(
        &self,
        mut blocks: BlockReader<R>,
        binary: bool,
        encoder: &dyn FeatureEncoder,
        sink: &mut S,
        mut ingest: IngestOptions<'_>,
    ) -> Result<PipelineReport>
    where
        R: std::io::Read + Send,
        S: PipelineSink,
    {
        let (pool_tx, pool_rx) = std::sync::mpsc::channel::<Vec<u8>>();
        blocks.set_recycle(pool_rx);
        let mut root = trace::Span::enter("pipeline.run");
        let rctx = root.ctx();
        let mut docs = 0usize;
        let mut parse_cpu = 0.0f64;
        let mut parse_errors = 0u64;
        let skip = ingest.skip_errors;
        let mut report = self.run_core(
            blocks,
            rctx,
            |b: &RawBlock| (0, b.bytes.len() as u64),
            || (ParsedChunk::default(), pool_tx.clone()),
            |block: RawBlock, (parsed, recycle), wid| {
                parsed.clear();
                let mut bad = Vec::new();
                let t0 = Instant::now();
                if skip {
                    parse_block_lossy(&block.bytes, block.first_line, binary, parsed, &mut bad);
                } else {
                    parse_block(&block.bytes, block.first_line, binary, parsed)?;
                }
                let t1 = Instant::now();
                let parse_secs = (t1 - t0).as_secs_f64();
                trace::emit_span(
                    "pipeline.parse",
                    rctx,
                    t0,
                    t1,
                    &[("worker", wid as f64), ("rows", parsed.len() as f64)],
                );
                let _ = recycle.send(block.bytes);
                let mut span = trace::Span::child("pipeline.encode", rctx);
                span.record("worker", wid as f64);
                span.record("rows", parsed.len() as f64);
                let out = encoder.encode_parsed(parsed)?;
                span.record(
                    "device",
                    if crate::encode::encoder::take_encode_used_device() { 1.0 } else { 0.0 },
                );
                drop(span);
                Ok((out, parsed.len(), parse_secs, block.end_offset, block.next_line, bad))
            },
            |_, (chunk, n, parse_secs, end_offset, next_line, bad): (
                EncodedChunk,
                usize,
                f64,
                u64,
                usize,
                Vec<BadLine>,
            )| {
                docs += n;
                parse_cpu += parse_secs;
                parse_errors += bad.len() as u64;
                if let Some(cb) = ingest.on_bad_line.as_mut() {
                    for b in &bad {
                        cb(b)?;
                    }
                }
                if !chunk.is_empty() {
                    sink.consume(chunk)?;
                }
                sink.mark_progress(end_offset, next_line as u64)
            },
        )?;
        report.docs = docs;
        report.parse_cpu_seconds = parse_cpu;
        report.parse_errors = parse_errors;
        report.hash_cpu_seconds = (report.hash_cpu_seconds - parse_cpu).max(0.0);
        let t0 = Instant::now();
        sink.finish()?;
        report.sink_seconds += t0.elapsed().as_secs_f64();
        fold_device_stats(&mut report, encoder);
        root.record("docs", report.docs as f64);
        root.record("chunks", report.chunks as f64);
        Ok(report)
    }

    /// Draw the encoder an [`EncoderSpec`] describes and run it over raw
    /// LibSVM blocks into `sink` (the byte-block twin of
    /// [`run_sink`](Self::run_sink)).
    pub fn run_sink_blocks<R, S>(
        &self,
        blocks: BlockReader<R>,
        binary: bool,
        spec: &EncoderSpec,
        sink: &mut S,
    ) -> Result<PipelineReport>
    where
        R: std::io::Read + Send,
        S: PipelineSink,
    {
        let encoder = spec.encoder()?;
        self.run_encoder_blocks(blocks, binary, encoder.as_ref(), sink)
    }
}

/// Turn an in-memory dataset into the chunk stream the pipeline consumes
/// (tests and benches; production path streams from LibSVM files).
pub fn dataset_chunks(
    ds: &SparseDataset,
    chunk_size: usize,
) -> impl Iterator<Item = Result<Vec<Example>>> + '_ {
    let plan = crate::coordinator::sharding::ShardPlan::new(ds.len(), chunk_size);
    let assignments: Vec<_> = plan.iter().collect();
    assignments.into_iter().map(move |a| {
        Ok((a.row0..a.row0 + a.rows)
            .map(|i| {
                let (idx, vals) = ds.row(i);
                Example {
                    label: ds.labels[i],
                    indices: idx.to_vec(),
                    values: vals.map(|v| v.to_vec()),
                }
            })
            .collect())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen::{CorpusConfig, CorpusGenerator};
    use crate::hashing::minwise::BbitMinHash;
    use crate::hashing::vw::VwHasher;
    use crate::util::Rng;

    fn corpus(n: usize) -> SparseDataset {
        CorpusGenerator::new(CorpusConfig {
            n_docs: n,
            vocab: 1000,
            zipf_alpha: 1.05,
            mean_tokens: 20.0,
            class_signal: 0.5,
            pos_fraction: 0.5,
            seed: 99,
        })
        .generate()
    }

    #[test]
    fn bbit_pipeline_matches_sequential() {
        let ds = corpus(300);
        let spec = EncoderSpec::Bbit { b: 8, k: 32, d: 1 << 20, seed: 5 };
        let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 32, queue_depth: 2 });
        let (out, report) = pipe.run(dataset_chunks(&ds, 32), &spec).unwrap();
        let bb = out.into_packed().unwrap();
        assert_eq!(bb.len(), 300);
        assert_eq!(report.docs, 300);
        assert_eq!(report.chunks, 10);
        assert!(report.reorder_peak >= 1);
        // sequential reference: the trait path must match the direct
        // hasher draw bit-for-bit (the pre-redesign worker body)
        let hasher = BbitMinHash::draw(32, 8, 1 << 20, &mut Rng::new(5));
        for i in 0..ds.len() {
            assert_eq!(bb.codes.row(i), hasher.codes(ds.row(i).0), "row {i}");
            assert_eq!(bb.labels[i], ds.labels[i]);
        }
    }

    #[test]
    fn vw_pipeline_matches_sequential() {
        let ds = corpus(100);
        let spec = EncoderSpec::Vw { bins: 64, seed: 7 };
        let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 17, queue_depth: 2 });
        let (out, _) = pipe.run(dataset_chunks(&ds, 17), &spec).unwrap();
        let vw = out.into_sparse().unwrap();
        vw.validate().unwrap();
        assert_eq!(vw.len(), 100);
        let hasher = VwHasher::draw(64, &mut Rng::new(7));
        for i in 0..ds.len() {
            let mut dense = vec![0.0f32; 64];
            hasher.hash_into(ds.row(i).0, &mut dense);
            let (idx, vals) = vw.row(i);
            let mut got = vec![0.0f32; 64];
            for (t, v) in idx.iter().zip(vals.unwrap()) {
                got[*t as usize] = *v;
            }
            assert_eq!(got, dense, "row {i}");
        }
    }

    #[test]
    fn oph_pipeline_matches_sequential() {
        // the proof-of-openness scheme goes through the identical
        // trait-object worker path as bbit/vw
        let ds = corpus(150);
        let spec = EncoderSpec::Oph { bins: 48, b: 6, seed: 13 };
        let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 19, queue_depth: 2 });
        let (out, report) = pipe.run(dataset_chunks(&ds, 19), &spec).unwrap();
        let bb = out.into_packed().unwrap();
        assert_eq!(report.docs, 150);
        assert_eq!(bb.codes.k, 48);
        let hasher =
            crate::hashing::oph::OnePermutationHasher::draw(48, 6, &mut Rng::new(13));
        for i in 0..ds.len() {
            assert_eq!(bb.codes.row(i), hasher.codes(ds.row(i).0), "row {i}");
        }
    }

    #[test]
    fn rp_pipeline_collects_sparse_projections() {
        let ds = corpus(60);
        let spec = EncoderSpec::Rp { proj: 24, s: 3.0, seed: 3 };
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 16, queue_depth: 2 });
        let (out, _) = pipe.run(dataset_chunks(&ds, 16), &spec).unwrap();
        let rp = out.into_sparse().unwrap();
        rp.validate().unwrap();
        assert_eq!(rp.len(), 60);
        assert_eq!(rp.dim, 24);
    }

    #[test]
    fn single_worker_and_tiny_queue() {
        let ds = corpus(50);
        let spec = EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 16, seed: 1 };
        let pipe = Pipeline::new(PipelineConfig { workers: 1, chunk_size: 7, queue_depth: 1 });
        let (out, report) = pipe.run(dataset_chunks(&ds, 7), &spec).unwrap();
        assert_eq!(out.len(), 50);
        assert_eq!(report.per_worker_chunks, vec![8]);
        // one worker completes chunks strictly in order, so the reorder
        // window never holds more than the chunk being emitted
        assert_eq!(report.reorder_peak, 1);
    }

    #[test]
    fn worker_errors_propagate() {
        let ds = corpus(40);
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 8, queue_depth: 2 });
        let result: Result<(Vec<()>, _)> =
            pipe.run_chunks(dataset_chunks(&ds, 8), |chunk, _| {
                if chunk[0].indices.len() < 10_000 {
                    Err(Error::Pipeline("injected".into()))
                } else {
                    Ok(())
                }
            });
        assert!(result.is_err());
    }

    #[test]
    fn sink_errors_propagate() {
        let ds = corpus(40);
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 8, queue_depth: 2 });
        let mut emitted = 0usize;
        let result = pipe.run_chunks_each(
            dataset_chunks(&ds, 8),
            |_, _| Ok(()),
            |_, ()| {
                emitted += 1;
                Err(Error::Pipeline("sink full".into()))
            },
        );
        assert!(result.is_err());
        assert_eq!(emitted, 1, "emit must stop at the first sink error");
    }

    #[test]
    fn reader_errors_propagate() {
        let source = vec![
            Ok(vec![Example::binary(1, vec![1])]),
            Err(Error::Io(std::io::Error::other("disk gone"))),
        ];
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 1, queue_depth: 1 });
        let out =
            pipe.run(source.into_iter(), &EncoderSpec::Bbit { b: 1, k: 4, d: 16, seed: 0 });
        assert!(out.is_err());
    }

    #[test]
    fn empty_source_yields_empty_output() {
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 4, queue_depth: 1 });
        let source = std::iter::empty::<Result<Vec<Example>>>();
        let (out, report) = pipe
            .run(source, &EncoderSpec::Bbit { b: 8, k: 16, d: 1 << 20, seed: 0 })
            .unwrap();
        assert!(out.is_empty());
        assert_eq!(report.chunks, 0);
        assert_eq!(report.reorder_peak, 0);
    }

    #[test]
    fn order_is_deterministic_across_worker_counts() {
        let ds = corpus(200);
        let spec = EncoderSpec::Bbit { b: 2, k: 16, d: 1 << 18, seed: 3 };
        let run = |workers| {
            let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 13, queue_depth: 3 });
            let (out, _) = pipe.run(dataset_chunks(&ds, 13), &spec).unwrap();
            out.into_packed().unwrap()
        };
        let a = run(1);
        let b = run(7);
        assert_eq!(a.labels, b.labels);
        for i in 0..a.len() {
            assert_eq!(a.codes.row(i), b.codes.row(i));
        }
    }

    #[test]
    fn block_pipeline_matches_chunk_pipeline_for_every_worker_count() {
        // serialize a corpus to LibSVM text, then hash it through (a) the
        // legacy chunk source and (b) the byte-block parse-in-worker
        // source: packed output must be bit-identical, for 1 and many
        // workers and for slabs much smaller than the text
        let ds = corpus(240);
        let mut text = Vec::new();
        {
            let mut w = crate::data::libsvm::LibsvmWriter::new(&mut text);
            w.write_dataset(&ds).unwrap();
            w.finish().unwrap();
        }
        let spec = EncoderSpec::Bbit { b: 6, k: 24, d: 1 << 20, seed: 9 };
        let reference = {
            let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 32, queue_depth: 2 });
            pipe.run(dataset_chunks(&ds, 32), &spec).unwrap().0.into_packed().unwrap()
        };
        for workers in [1usize, 4] {
            let pipe = Pipeline::new(PipelineConfig { workers, chunk_size: 32, queue_depth: 2 });
            let blocks = BlockReader::new(&text[..]).with_block_bytes(192);
            let mut sink = CollectSink::for_spec(&spec).unwrap();
            let report = pipe.run_sink_blocks(blocks, true, &spec, &mut sink).unwrap();
            let got = sink.into_output().into_packed().unwrap();
            assert_eq!(report.docs, 240, "workers={workers}");
            assert_eq!(report.input_bytes, text.len() as u64);
            assert!(report.parse_cpu_seconds >= 0.0);
            assert!(report.chunks > 1, "slab size must produce many blocks");
            assert_eq!(got.labels, reference.labels, "workers={workers}");
            for i in 0..got.len() {
                assert_eq!(got.codes.row(i), reference.codes.row(i), "row {i}");
            }
        }
    }

    #[test]
    fn block_pipeline_propagates_parse_errors_with_line_numbers() {
        let text = b"+1 1:1\n-1 2:1\nbogus line\n+1 3:1\n";
        let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 8, queue_depth: 2 });
        let spec = EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 16, seed: 1 };
        let mut sink = CollectSink::for_spec(&spec).unwrap();
        let blocks = BlockReader::new(&text[..]).with_block_bytes(8);
        let err = pipe.run_sink_blocks(blocks, true, &spec, &mut sink).unwrap_err();
        match err {
            Error::LibsvmParse { line, .. } => assert_eq!(line, 3),
            other => panic!("wrong error {other:?}"),
        }
    }

    #[test]
    fn earliest_failing_chunk_wins_regardless_of_scheduling() {
        // two failing chunks where the later one finishes first: the run
        // must still report the earlier chunk's error, every time
        let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 1, queue_depth: 2 });
        for _ in 0..5 {
            let source =
                (0..20u32).map(|i| Ok(vec![Example::binary(1, vec![i + 1])]));
            let err = pipe
                .run_chunks_each(
                    source,
                    |chunk: &[Example], _| -> Result<()> {
                        match chunk[0].indices[0] {
                            5 => {
                                // the early bad chunk is slow...
                                std::thread::sleep(std::time::Duration::from_millis(20));
                                Err(Error::Pipeline("bad chunk 4".into()))
                            }
                            // ...the late bad chunk fails instantly
                            16 => Err(Error::Pipeline("bad chunk 15".into())),
                            _ => Ok(()),
                        }
                    },
                    |_, ()| Ok(()),
                )
                .unwrap_err();
            assert_eq!(err.to_string(), "pipeline error: bad chunk 4");
        }
    }

    #[test]
    fn block_pipeline_reports_the_first_bad_line_of_many() {
        // several malformed lines spread across many tiny blocks parsed by
        // racing workers: the surfaced line number must be the first one
        let mut text = String::new();
        for i in 0..60 {
            if i == 17 || i == 40 || i == 55 {
                text.push_str("broken record\n");
            } else {
                text.push_str(&format!("+1 {}:1\n", i + 1));
            }
        }
        let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 4, queue_depth: 2 });
        for _ in 0..5 {
            let blocks = BlockReader::new(text.as_bytes()).with_block_bytes(8);
            let err = pipe
                .run_blocks_each(blocks, true, |parsed, _| Ok(parsed.len()), |_, _| Ok(()))
                .unwrap_err();
            match err {
                Error::LibsvmParse { line, .. } => assert_eq!(line, 18),
                other => panic!("wrong error {other:?}"),
            }
        }
    }

    #[test]
    fn block_pipeline_skips_empty_blocks() {
        // slabs of pure comments/blanks must not reach the sink as
        // zero-row chunks (a cache sink would happily write them)
        struct CountingSink {
            chunks: usize,
            rows: usize,
        }
        impl crate::coordinator::sink::PipelineSink for CountingSink {
            fn consume(&mut self, chunk: EncodedChunk) -> Result<()> {
                self.chunks += 1;
                self.rows += chunk.len();
                Ok(())
            }
        }
        let text = b"# a\n# b\n\n\n+1 1:1\n# c\n\n-1 2:1\n# d\n\n";
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 8, queue_depth: 2 });
        let blocks = BlockReader::new(&text[..]).with_block_bytes(4);
        let spec = EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 16, seed: 1 };
        let mut sink = CountingSink { chunks: 0, rows: 0 };
        let report = pipe.run_sink_blocks(blocks, true, &spec, &mut sink).unwrap();
        assert_eq!(report.docs, 2);
        assert_eq!(sink.rows, 2);
        assert!(
            sink.chunks <= 2,
            "empty blocks must be skipped, got {} sink chunks",
            sink.chunks
        );
        assert!(report.chunks > sink.chunks, "tiny slabs produce empty blocks");
    }

    #[test]
    fn report_json_carries_every_counter() {
        let ds = corpus(120);
        let spec = EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 16, seed: 2 };
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 16, queue_depth: 2 });
        let (_, report) = pipe.run(dataset_chunks(&ds, 16), &spec).unwrap();
        let j = report.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"docs\":120"), "{j}");
        assert!(j.contains("\"chunks\":8"), "{j}");
        assert!(j.contains("\"per_worker_chunks\":["), "{j}");
        for key in [
            "read_seconds",
            "stall_seconds",
            "hash_cpu_seconds",
            "parse_cpu_seconds",
            "sink_seconds",
            "wall_seconds",
            "backpressure_stalls",
            "reorder_peak",
            "replay_threads",
            "replay_bytes",
            "input_bytes",
            "encode_device_seconds",
            "device_chunks",
            "device_fallbacks",
            "parse_errors",
            "rows_per_sec",
            "parse_rows_per_sec",
            "ingest_mb_per_sec",
        ] {
            assert!(j.contains(&format!("\"{key}\":")), "missing {key} in {j}");
        }
    }

    #[test]
    fn skip_mode_quarantines_bad_lines_and_counts_them() {
        // the same 3-bad-lines corpus that fail-fast aborts on (test
        // above): with skip_errors the run completes, every good row is
        // encoded, and the bad lines arrive at the quarantine callback in
        // input order with their original bytes
        let mut text = String::new();
        for i in 0..60 {
            if i == 17 || i == 40 || i == 55 {
                text.push_str("broken record\n");
            } else {
                text.push_str(&format!("+1 {}:1\n", i + 1));
            }
        }
        let spec = EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 16, seed: 1 };
        let encoder = spec.encoder().unwrap();
        let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 4, queue_depth: 2 });
        for _ in 0..3 {
            let mut sink = CollectSink::for_spec(&spec).unwrap();
            let mut bad = Vec::new();
            let mut on_bad = |b: &BadLine| {
                bad.push((b.line, b.bytes.clone()));
                Ok(())
            };
            let blocks = BlockReader::new(text.as_bytes()).with_block_bytes(8);
            let report = pipe
                .run_encoder_blocks_opts(
                    blocks,
                    true,
                    encoder.as_ref(),
                    &mut sink,
                    IngestOptions { skip_errors: true, on_bad_line: Some(&mut on_bad) },
                )
                .unwrap();
            assert_eq!(report.docs, 57);
            assert_eq!(report.parse_errors, 3);
            assert!(report.to_json().contains("\"parse_errors\":3"));
            assert_eq!(
                bad.iter().map(|(l, _)| *l).collect::<Vec<_>>(),
                vec![18, 41, 56],
                "quarantine order must be input order"
            );
            assert!(bad.iter().all(|(_, b)| b == b"broken record"));
            let out = sink.into_output().into_packed().unwrap();
            assert_eq!(out.len(), 57);
        }
    }

    #[test]
    fn mark_progress_fires_in_order_for_every_block() {
        struct ProgressSink {
            rows: usize,
            marks: Vec<(u64, u64)>,
        }
        impl crate::coordinator::sink::PipelineSink for ProgressSink {
            fn consume(&mut self, chunk: EncodedChunk) -> Result<()> {
                self.rows += chunk.len();
                Ok(())
            }
            fn mark_progress(&mut self, off: u64, line: u64) -> Result<()> {
                self.marks.push((off, line));
                Ok(())
            }
        }
        // comment/blank lines force empty blocks: those must still mark
        // progress (a durable cache journals the input cursor off this)
        let text = b"# c\n\n+1 1:1\n-1 2:1\n# d\n+1 3:1\n";
        let pipe = Pipeline::new(PipelineConfig { workers: 3, chunk_size: 8, queue_depth: 2 });
        let spec = EncoderSpec::Bbit { b: 4, k: 8, d: 1 << 16, seed: 1 };
        let mut sink = ProgressSink { rows: 0, marks: Vec::new() };
        let blocks = BlockReader::new(&text[..]).with_block_bytes(4);
        let report = pipe.run_sink_blocks(blocks, true, &spec, &mut sink).unwrap();
        assert_eq!(sink.rows, 3);
        assert_eq!(sink.marks.len(), report.chunks, "every block marks progress");
        assert!(
            sink.marks.windows(2).all(|w| w[0].0 < w[1].0 && w[0].1 <= w[1].1),
            "marks must advance monotonically: {:?}",
            sink.marks
        );
        let last = sink.marks.last().unwrap();
        assert_eq!(last.0, text.len() as u64);
        assert_eq!(last.1, 7, "6 input lines consumed, cursor on line 7");
    }

    #[test]
    fn emit_order_is_ascending_and_complete() {
        let ds = corpus(230);
        let pipe = Pipeline::new(PipelineConfig { workers: 4, chunk_size: 9, queue_depth: 2 });
        let mut seen = Vec::new();
        let report = pipe
            .run_chunks_each(
                dataset_chunks(&ds, 9),
                |chunk, _| Ok(chunk.len()),
                |id, len| {
                    seen.push((id, len));
                    Ok(())
                },
            )
            .unwrap();
        assert_eq!(seen.len(), report.chunks);
        assert!(seen.iter().enumerate().all(|(i, &(id, _))| i == id));
        assert_eq!(seen.iter().map(|&(_, l)| l).sum::<usize>(), 230);
    }
}
