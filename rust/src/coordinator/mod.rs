//! Layer-3 coordination: the streaming preprocessing pipeline (reader →
//! sharded encode workers → collector → sink, with bounded-queue
//! backpressure; on raw LibSVM input the reader carves newline-aligned
//! byte blocks and the workers parse *and* encode, so ingest scales with
//! `--workers`), the pluggable sinks behind the out-of-core workflow
//! (collect in memory / write the on-disk hashed cache / train as chunks
//! arrive), the parallel cache-replay reader pool ([`replay`]: decode the
//! hashed cache across cores, re-emitting chunks strictly in record
//! order), and the training-job scheduler that fans a (method, b, k, C)
//! grid across threads — the "re-use the hashed data for many C values"
//! workflow the paper's preprocessing-cost argument is built on
//! (Sections 1 and 6).
//!
//! The workers are scheme-agnostic: they run whatever
//! [`FeatureEncoder`](crate::encode::encoder::FeatureEncoder) the
//! caller's [`EncoderSpec`](crate::encode::encoder::EncoderSpec) draws.

pub mod pipeline;
pub mod replay;
pub mod scheduler;
pub mod sharding;
pub mod sink;

pub use pipeline::{Pipeline, PipelineConfig, PipelineOutput, PipelineReport};
pub use replay::{load_index_or_warn, materialize_cache, replay_cache, replay_cache_with};
pub use scheduler::{Scheduler, TrainJob, TrainOutcome};
pub use sharding::ShardPlan;
pub use sink::{CacheSink, CollectSink, PipelineSink, TrainSink};
