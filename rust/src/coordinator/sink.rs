//! Pipeline sinks: where in-order encoded chunks go.
//!
//! The collector stage of [`Pipeline`](crate::coordinator::pipeline) used
//! to buffer every chunk until end-of-run and assemble one giant in-memory
//! dataset — fine for the paper's figures at toy scale, fatal for its
//! headline 200GB workload.  Sinks invert that: the collector re-emits
//! chunks *incrementally in input order* and pushes each one into a
//! [`PipelineSink`], after which the chunk is dropped.  Three sinks cover
//! the out-of-core workflow:
//!
//! - [`CollectSink`] — accumulate in memory (the old behavior; every
//!   existing caller and experiment goes through it unchanged);
//! - [`CacheSink`] — append to the on-disk hashed cache
//!   ([`encode::cache`](crate::encode::cache)): hash once, train many
//!   times;
//! - [`TrainSink`] — feed a streaming SGD trainer
//!   ([`SgdStream`](crate::solver::SgdStream)) directly: one-pass
//!   hash-and-train with nothing materialized at all.
//!
//! Sinks consume [`EncodedChunk`]s and are scheme-agnostic up to chunk
//! *shape*: any packed-code encoder (b-bit minwise, OPH) can feed the
//! cache and the streaming trainer; any sparse encoder (VW, RP) collects
//! into a CSR dataset.  Sinks run on the collector thread, strictly in
//! chunk order, so a sink never needs internal synchronization or
//! reordering of its own.

use std::fs::File;
use std::io::{BufWriter, Seek, Write};
use std::path::Path;

use crate::coordinator::pipeline::PipelineOutput;
use crate::data::dataset::SparseDataset;
use crate::encode::cache::CacheWriter;
use crate::encode::encoder::{EncodedChunk, EncoderSpec};
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::solver::{LinearModel, SgdConfig, SgdStream, TrainStats};
use crate::{Error, Result};

/// Consumer of in-order encoded chunks.
///
/// `consume` is called once per chunk, in input order, on the collector
/// thread; `finish` exactly once after the last chunk (flush buffers,
/// patch headers, apply the tail minibatch, ...).
pub trait PipelineSink {
    fn consume(&mut self, chunk: EncodedChunk) -> Result<()>;

    fn finish(&mut self) -> Result<()> {
        Ok(())
    }

    /// Input-progress notification from the block pipeline: every raw
    /// block — including blocks that produced no rows — reports, in block
    /// order and after its rows reached [`consume`](Self::consume), the
    /// input byte offset and 1-based line number ingest would restart
    /// from.  Most sinks ignore it; a durable [`CacheSink`] journals it
    /// so `preprocess --resume` can restart a killed run from the last
    /// consistent (cache prefix, input cursor) pair.
    fn mark_progress(&mut self, _input_offset: u64, _next_line: u64) -> Result<()> {
        Ok(())
    }
}

/// In-memory accumulation — preserves the original `Pipeline::run`
/// contract ([`PipelineOutput`] with rows in input order).
pub struct CollectSink {
    out: PipelineOutput,
}

impl CollectSink {
    /// Collect packed-code chunks into a [`BbitDataset`] of geometry
    /// `(b, k)` — b-bit minwise and OPH land here.
    pub fn packed(b: u32, k: usize) -> Self {
        CollectSink {
            out: PipelineOutput::Packed(BbitDataset::new(PackedCodes::new(b, k), Vec::new())),
        }
    }

    /// Collect sparse chunks into a valued [`SparseDataset`] over `dim`
    /// hashed dimensions — VW and RP land here.
    pub fn sparse(dim: usize) -> Self {
        let mut ds = SparseDataset::new(dim as u64);
        ds.values = Some(Vec::new());
        CollectSink { out: PipelineOutput::Sparse(ds) }
    }

    /// The right collector for a spec (packed vs. sparse output).
    pub fn for_spec(spec: &EncoderSpec) -> Result<Self> {
        spec.validate()?;
        Ok(match spec.packed_geometry() {
            Some((b, k)) => CollectSink::packed(b, k),
            None => CollectSink::sparse(spec.output_dim()),
        })
    }

    pub fn into_output(self) -> PipelineOutput {
        self.out
    }
}

impl PipelineSink for CollectSink {
    fn consume(&mut self, chunk: EncodedChunk) -> Result<()> {
        match (&mut self.out, chunk) {
            (PipelineOutput::Packed(ds), EncodedChunk::Packed { codes, labels }) => {
                ds.codes.extend(&codes)?;
                ds.labels.extend(labels);
                Ok(())
            }
            (PipelineOutput::Sparse(ds), EncodedChunk::Sparse { rows }) => {
                for (label, pairs) in rows {
                    ds.push_parts(label, &pairs);
                }
                Ok(())
            }
            _ => Err(Error::Pipeline("sink/chunk kind mismatch".into())),
        }
    }
}

/// Stream packed-code chunks into the on-disk hashed cache.
pub struct CacheSink<W: Write + Seek> {
    writer: CacheWriter<W>,
}

impl CacheSink<BufWriter<File>> {
    /// Create a cache file recording the encoder spec (must be a
    /// packed-code scheme; the cache stores [`PackedCodes`] records).
    pub fn create<P: AsRef<Path>>(path: P, spec: &EncoderSpec) -> Result<Self> {
        Ok(CacheSink { writer: CacheWriter::create(path, spec)? })
    }

    /// [`create`](Self::create) with explicit write options
    /// (`preprocess --cache-compress` sets
    /// [`CacheWriteOptions::compress`](crate::encode::cache::CacheWriteOptions)).
    pub fn create_opts<P: AsRef<Path>>(
        path: P,
        spec: &EncoderSpec,
        opts: crate::encode::cache::CacheWriteOptions,
    ) -> Result<Self> {
        Ok(CacheSink { writer: CacheWriter::create_opts(path, spec, opts)? })
    }

    /// Crash-safe [`create_opts`](Self::create_opts): writes to
    /// `<path>.tmp` beside a resume journal, fsyncs every `sync_chunks`
    /// progress marks, and atomically renames onto `path` in `finish` —
    /// so `path` only ever names a complete, finalized cache.
    pub fn create_durable<P: AsRef<Path>>(
        path: P,
        spec: &EncoderSpec,
        opts: crate::encode::cache::CacheWriteOptions,
        sync_chunks: usize,
    ) -> Result<Self> {
        Ok(CacheSink { writer: CacheWriter::create_durable(path, spec, opts, sync_chunks)? })
    }

    /// Resume a durable write that died before `finish`: validates the
    /// partial `<path>.tmp` against its journal, truncates any torn
    /// tail, and returns the reopened sink plus the input cursor
    /// (`ResumePoint`) ingest must restart from.  `Ok(None)` when there
    /// is nothing to resume (no partial output on disk).
    pub fn resume_durable<P: AsRef<Path>>(
        path: P,
        spec: &EncoderSpec,
        opts: crate::encode::cache::CacheWriteOptions,
        sync_chunks: usize,
    ) -> Result<Option<(Self, crate::encode::cache::ResumePoint)>> {
        Ok(CacheWriter::resume_durable(path, spec, opts, sync_chunks)?
            .map(|(writer, point)| (CacheSink { writer }, point)))
    }
}

impl<W: Write + Seek> CacheSink<W> {
    pub fn new(writer: CacheWriter<W>) -> Self {
        CacheSink { writer }
    }

    /// Rows written so far.
    pub fn rows_written(&self) -> u64 {
        self.writer.rows_written()
    }

    /// Header metadata accumulated so far (row count + raw/stored payload
    /// byte totals — the CLI's compression report).
    pub fn meta(&self) -> crate::encode::cache::CacheMeta {
        self.writer.meta()
    }
}

impl<W: Write + Seek> PipelineSink for CacheSink<W> {
    fn consume(&mut self, chunk: EncodedChunk) -> Result<()> {
        match chunk {
            EncodedChunk::Packed { codes, labels } => self.writer.write_chunk(&codes, &labels),
            EncodedChunk::Sparse { .. } => {
                Err(Error::Pipeline("cache sink only stores packed-code chunks".into()))
            }
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.writer.finalize()
    }

    fn mark_progress(&mut self, input_offset: u64, next_line: u64) -> Result<()> {
        self.writer.mark_progress(input_offset, next_line)
    }
}

/// One-pass hash-and-train: chunks go straight into a streaming SGD
/// update; nothing is materialized.  `finish` applies the tail minibatch,
/// so after the pipeline returns, [`into_result`](Self::into_result) holds
/// exactly the weights materialize-then-`train_sgd` (1 epoch) would have
/// produced on the same chunk stream.  `finish` closes the epoch through
/// [`SgdStream::end_epoch`], which emits a `train.epoch` trace point
/// (epoch/rows/loss) when `--trace-out` is active — so even the one-pass
/// path leaves a training-curve event in the JSONL log.
pub struct TrainSink {
    stream: SgdStream,
}

impl TrainSink {
    pub fn new(cfg: SgdConfig, b: u32, k: usize) -> Self {
        TrainSink { stream: SgdStream::new(cfg, b, k) }
    }

    /// A trainer sized for a packed-code encoder spec (errors for sparse
    /// schemes — streaming SGD consumes [`PackedCodes`] chunks).
    pub fn for_spec(cfg: SgdConfig, spec: &EncoderSpec) -> Result<Self> {
        let (b, k) = spec.packed_geometry().ok_or_else(|| {
            Error::InvalidArg(format!(
                "streaming SGD needs a packed-code encoder; {} emits sparse rows",
                spec.scheme()
            ))
        })?;
        Ok(TrainSink::new(cfg, b, k))
    }

    /// Rows trained on so far.
    pub fn rows_seen(&self) -> u64 {
        self.stream.rows_seen()
    }

    pub fn into_result(self) -> (LinearModel, TrainStats) {
        self.stream.finalize()
    }
}

impl PipelineSink for TrainSink {
    fn consume(&mut self, chunk: EncodedChunk) -> Result<()> {
        match chunk {
            EncodedChunk::Packed { codes, labels } => self.stream.push_chunk(codes, labels),
            EncodedChunk::Sparse { .. } => {
                Err(Error::Pipeline("train sink only accepts packed-code chunks".into()))
            }
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.stream.end_epoch();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn packed_chunk(b: u32, k: usize, rows: &[(u16, i8)]) -> EncodedChunk {
        let mut codes = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for &(c, l) in rows {
            codes.push_row(&vec![c; k]).unwrap();
            labels.push(l);
        }
        EncodedChunk::Packed { codes, labels }
    }

    #[test]
    fn collect_sink_accumulates_in_order() {
        let mut sink = CollectSink::packed(4, 3);
        sink.consume(packed_chunk(4, 3, &[(1, 1), (2, -1)])).unwrap();
        sink.consume(packed_chunk(4, 3, &[(3, 1)])).unwrap();
        sink.finish().unwrap();
        let ds = sink.into_output().into_bbit().unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.labels, vec![1, -1, 1]);
        assert_eq!(ds.codes.row(2), vec![3, 3, 3]);
    }

    #[test]
    fn kind_mismatch_is_an_error() {
        let mut sink = CollectSink::packed(4, 3);
        assert!(sink.consume(EncodedChunk::Sparse { rows: vec![] }).is_err());
        let mut sink = CollectSink::sparse(8);
        assert!(sink.consume(packed_chunk(4, 3, &[(1, 1)])).is_err());
        let spec = EncoderSpec::Bbit { b: 4, k: 3, d: 16, seed: 0 };
        let mut cache = CacheSink::new(
            CacheWriter::new(std::io::Cursor::new(Vec::new()), &spec).unwrap(),
        );
        assert!(cache.consume(EncodedChunk::Sparse { rows: vec![] }).is_err());
        let mut train = TrainSink::new(SgdConfig::default(), 4, 3);
        assert!(train.consume(EncodedChunk::Sparse { rows: vec![] }).is_err());
    }

    #[test]
    fn for_spec_picks_the_matching_collector() {
        let packed = CollectSink::for_spec(&EncoderSpec::Oph { bins: 6, b: 2, seed: 1 }).unwrap();
        assert!(matches!(packed.into_output(), PipelineOutput::Packed(_)));
        let sparse = CollectSink::for_spec(&EncoderSpec::Rp { proj: 5, s: 1.0, seed: 1 }).unwrap();
        match sparse.into_output() {
            PipelineOutput::Sparse(ds) => assert_eq!(ds.dim, 5),
            _ => panic!("rp must collect sparse"),
        }
    }

    #[test]
    fn train_sink_for_spec_rejects_sparse_schemes() {
        assert!(TrainSink::for_spec(SgdConfig::default(), &EncoderSpec::Vw { bins: 8, seed: 0 })
            .is_err());
        assert!(TrainSink::for_spec(
            SgdConfig::default(),
            &EncoderSpec::Oph { bins: 8, b: 4, seed: 0 }
        )
        .is_ok());
    }

    #[test]
    fn sparse_collect_uses_push_parts() {
        let mut sink = CollectSink::sparse(8);
        sink.consume(EncodedChunk::Sparse {
            rows: vec![(1, vec![(0, 1.5), (3, -1.0)]), (-1, vec![(2, 1.0)])],
        })
        .unwrap();
        let ds = sink.into_output().into_vw().unwrap();
        ds.validate().unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.row(0).0, &[0, 3]);
        assert_eq!(ds.row(0).1.unwrap(), &[1.5, -1.0]);
    }
}
