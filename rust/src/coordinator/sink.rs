//! Pipeline sinks: where in-order hashed chunks go.
//!
//! The collector stage of [`Pipeline`](crate::coordinator::pipeline) used
//! to buffer every chunk until end-of-run and assemble one giant in-memory
//! dataset — fine for the paper's figures at toy scale, fatal for its
//! headline 200GB workload.  Sinks invert that: the collector re-emits
//! chunks *incrementally in input order* and pushes each one into a
//! [`PipelineSink`], after which the chunk is dropped.  Three sinks cover
//! the out-of-core workflow:
//!
//! - [`CollectSink`] — accumulate in memory (the old behavior; every
//!   existing caller and experiment goes through it unchanged);
//! - [`CacheSink`] — append to the on-disk hashed cache
//!   ([`encode::cache`](crate::encode::cache)): hash once, train many
//!   times;
//! - [`TrainSink`] — feed a streaming SGD trainer
//!   ([`SgdStream`](crate::solver::SgdStream)) directly: one-pass
//!   hash-and-train with nothing materialized at all.
//!
//! Sinks run on the collector thread, strictly in chunk order, so a sink
//! never needs internal synchronization or reordering of its own.

use std::fs::File;
use std::io::{BufWriter, Seek, Write};
use std::path::Path;

use crate::coordinator::pipeline::PipelineOutput;
use crate::data::dataset::SparseDataset;
use crate::encode::cache::CacheWriter;
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::solver::{LinearModel, SgdConfig, SgdStream, TrainStats};
use crate::{Error, Result};

/// One hashed chunk, as produced by the workers and re-ordered by the
/// collector.
pub enum HashedChunk {
    /// Packed b-bit codes + labels for a run of consecutive input rows.
    Bbit { codes: PackedCodes, labels: Vec<i8> },
    /// VW-hashed rows as (label, sorted sparse pairs).
    Vw { rows: Vec<(i8, Vec<(u32, f32)>)> },
}

impl HashedChunk {
    pub fn len(&self) -> usize {
        match self {
            HashedChunk::Bbit { labels, .. } => labels.len(),
            HashedChunk::Vw { rows } => rows.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Consumer of in-order hashed chunks.
///
/// `consume` is called once per chunk, in input order, on the collector
/// thread; `finish` exactly once after the last chunk (flush buffers,
/// patch headers, apply the tail minibatch, ...).
pub trait PipelineSink {
    fn consume(&mut self, chunk: HashedChunk) -> Result<()>;

    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// In-memory accumulation — preserves the original `Pipeline::run`
/// contract ([`PipelineOutput`] with rows in input order).
pub struct CollectSink {
    out: PipelineOutput,
}

impl CollectSink {
    /// Collect b-bit chunks into a [`BbitDataset`].
    pub fn bbit(b: u32, k: usize) -> Self {
        CollectSink {
            out: PipelineOutput::Bbit(BbitDataset::new(PackedCodes::new(b, k), Vec::new())),
        }
    }

    /// Collect VW chunks into a valued [`SparseDataset`] over `bins` bins.
    pub fn vw(bins: usize) -> Self {
        let mut ds = SparseDataset::new(bins as u64);
        ds.values = Some(Vec::new());
        CollectSink { out: PipelineOutput::Vw(ds) }
    }

    pub fn into_output(self) -> PipelineOutput {
        self.out
    }
}

impl PipelineSink for CollectSink {
    fn consume(&mut self, chunk: HashedChunk) -> Result<()> {
        match (&mut self.out, chunk) {
            (PipelineOutput::Bbit(ds), HashedChunk::Bbit { codes, labels }) => {
                ds.codes.extend(&codes)?;
                ds.labels.extend(labels);
                Ok(())
            }
            (PipelineOutput::Vw(ds), HashedChunk::Vw { rows }) => {
                for (label, pairs) in rows {
                    ds.push_parts(label, &pairs);
                }
                Ok(())
            }
            _ => Err(Error::Pipeline("sink/chunk kind mismatch".into())),
        }
    }
}

/// Stream chunks into the on-disk hashed cache.
pub struct CacheSink<W: Write + Seek> {
    writer: CacheWriter<W>,
}

impl CacheSink<BufWriter<File>> {
    /// Create a cache file recording the hashing recipe `(b, k, d, seed)`.
    pub fn create<P: AsRef<Path>>(path: P, b: u32, k: usize, d: u64, seed: u64) -> Result<Self> {
        Ok(CacheSink { writer: CacheWriter::create(path, b, k, d, seed)? })
    }
}

impl<W: Write + Seek> CacheSink<W> {
    pub fn new(writer: CacheWriter<W>) -> Self {
        CacheSink { writer }
    }

    /// Rows written so far.
    pub fn rows_written(&self) -> u64 {
        self.writer.rows_written()
    }
}

impl<W: Write + Seek> PipelineSink for CacheSink<W> {
    fn consume(&mut self, chunk: HashedChunk) -> Result<()> {
        match chunk {
            HashedChunk::Bbit { codes, labels } => self.writer.write_chunk(&codes, &labels),
            HashedChunk::Vw { .. } => {
                Err(Error::Pipeline("cache sink only stores b-bit chunks".into()))
            }
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.writer.finalize()
    }
}

/// One-pass hash-and-train: chunks go straight into a streaming SGD
/// update; nothing is materialized.  `finish` applies the tail minibatch,
/// so after the pipeline returns, [`into_result`](Self::into_result) holds
/// exactly the weights materialize-then-`train_sgd` (1 epoch) would have
/// produced on the same chunk stream.
pub struct TrainSink {
    stream: SgdStream,
}

impl TrainSink {
    pub fn new(cfg: SgdConfig, b: u32, k: usize) -> Self {
        TrainSink { stream: SgdStream::new(cfg, b, k) }
    }

    /// Rows trained on so far.
    pub fn rows_seen(&self) -> u64 {
        self.stream.rows_seen()
    }

    pub fn into_result(self) -> (LinearModel, TrainStats) {
        self.stream.finalize()
    }
}

impl PipelineSink for TrainSink {
    fn consume(&mut self, chunk: HashedChunk) -> Result<()> {
        match chunk {
            HashedChunk::Bbit { codes, labels } => self.stream.push_chunk(codes, labels),
            HashedChunk::Vw { .. } => {
                Err(Error::Pipeline("train sink only accepts b-bit chunks".into()))
            }
        }
    }

    fn finish(&mut self) -> Result<()> {
        self.stream.end_epoch();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bbit_chunk(b: u32, k: usize, rows: &[(u16, i8)]) -> HashedChunk {
        let mut codes = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for &(c, l) in rows {
            codes.push_row(&vec![c; k]).unwrap();
            labels.push(l);
        }
        HashedChunk::Bbit { codes, labels }
    }

    #[test]
    fn collect_sink_accumulates_in_order() {
        let mut sink = CollectSink::bbit(4, 3);
        sink.consume(bbit_chunk(4, 3, &[(1, 1), (2, -1)])).unwrap();
        sink.consume(bbit_chunk(4, 3, &[(3, 1)])).unwrap();
        sink.finish().unwrap();
        let ds = sink.into_output().into_bbit().unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.labels, vec![1, -1, 1]);
        assert_eq!(ds.codes.row(2), vec![3, 3, 3]);
    }

    #[test]
    fn kind_mismatch_is_an_error() {
        let mut sink = CollectSink::bbit(4, 3);
        assert!(sink.consume(HashedChunk::Vw { rows: vec![] }).is_err());
        let mut sink = CollectSink::vw(8);
        assert!(sink.consume(bbit_chunk(4, 3, &[(1, 1)])).is_err());
        let mut cache = CacheSink::new(
            CacheWriter::new(std::io::Cursor::new(Vec::new()), 4, 3, 16, 0).unwrap(),
        );
        assert!(cache.consume(HashedChunk::Vw { rows: vec![] }).is_err());
        let mut train = TrainSink::new(SgdConfig::default(), 4, 3);
        assert!(train.consume(HashedChunk::Vw { rows: vec![] }).is_err());
    }

    #[test]
    fn vw_collect_uses_push_parts() {
        let mut sink = CollectSink::vw(8);
        sink.consume(HashedChunk::Vw {
            rows: vec![(1, vec![(0, 1.5), (3, -1.0)]), (-1, vec![(2, 1.0)])],
        })
        .unwrap();
        let ds = sink.into_output().into_vw().unwrap();
        ds.validate().unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.row(0).0, &[0, 3]);
        assert_eq!(ds.row(0).1.unwrap(), &[1.5, -1.0]);
    }
}
