//! # bbit-mh — b-bit minwise hashing for large-scale linear learning
//!
//! A production-shaped reproduction of Li, Shrivastava & König (2011),
//! *"Training Logistic Regression and SVM on 200GB Data Using b-Bit Minwise
//! Hashing and Comparisons with Vowpal Wabbit (VW)"*.
//!
//! The crate is the **layer-3 coordinator** of a three-layer stack:
//!
//! - **L1** (build-time python): Pallas kernels for k-way minwise hashing,
//!   VW feature hashing and b-bit gather margins (`python/compile/kernels/`).
//! - **L2** (build-time python): jax train/predict graphs composing the
//!   kernels, AOT-lowered to HLO text (`python/compile/model.py`, `aot.py`).
//! - **L3** (this crate): streaming data pipeline, hashing substrates,
//!   LIBLINEAR-style solvers, the experiment harness for every table and
//!   figure of the paper, and a PJRT runtime executing the AOT artifacts.
//!
//! Python is never on the request path: `make artifacts` runs once, after
//! which the `bbit-mh` binary is self-contained.
//!
//! ## Module map (see DESIGN.md for the full system inventory)
//!
//! | module | paper dependency |
//! |---|---|
//! | [`data`] | LibSVM streaming IO (zero-copy byte-block parser + legacy line reader), rcv1-like generator, feature expansion |
//! | [`hashing`] | minwise / b-bit / VW / RP / OPH substrates (register-blocked 4-wide minwise kernel) + estimator variance theory |
//! | [`encode`] | the scheme-agnostic [`FeatureEncoder`](encode::encoder::FeatureEncoder) API ([`EncoderSpec`](encode::encoder::EncoderSpec)), `n·b·k`-bit packed codes, 2^b×k expansion (Section 3), spec-tagged on-disk cache (v3: chunk-index footer for parallel replay + optional RLE record compression), and the `--device xla` [`DeviceEncoder`](encode::DeviceEncoder) batching minwise/VW hashing onto the PJRT runtime |
//! | [`kernels`] | the train/score inner loops: whole-row b-bit decode, 8-wide unrolled dot/axpy, weight prefetch, scalar reference twins |
//! | [`solver`] | dual-CD SVM, Newton-CG LR, SGD incl. streaming/out-of-core form; models persist their `EncoderSpec`; cache eval/holdout/SGD all replay across threads |
//! | [`coordinator`] | streaming pipeline (reader → encoder workers → collector → sink; raw input is carved into byte blocks and *parsed in the workers*, so ingest scales with `--workers`), parallel cache-replay reader pool, + scheduler |
//! | [`serve`] | online scoring: micro-batched HTTP model server with hot reload, admission control, a load generator, and the consistent-hash `route` fleet tier scatter-gathering `/similar` over shard servers (the paper's "used in industry / search" request path) |
//! | [`similarity`] | online near-neighbor search: sharded, snapshottable LSH index over b-bit signatures, built out-of-core from the hashed cache (the paper's Section 6 "re-use the hashed data" workflow, made a serving subsystem) |
//! | [`metrics`] | the unified telemetry layer: counters/gauges/histograms, one Prometheus text renderer + format validator ([`metrics::prom`]), and structured JSONL tracing spans with fleet-wide trace-id propagation ([`metrics::trace`]) |
//! | [`faults`] | env-armed failpoints (`BBMH_FAILPOINTS`) on the crash-critical sites — cache write/finalize, replay decode, batch scoring, router forward, device launch — one relaxed atomic when disarmed |
//! | [`runtime`] | PJRT CPU client executing `artifacts/*.hlo.txt` (typed input-geometry validation before every launch); feeds the `--device xla` encode path |
//! | [`experiments`] | one harness per table/figure (Table 1–2, Fig 1–8, …) |
//!
//! ## The encoder seam
//!
//! Every hashing scheme is described by a serializable
//! [`EncoderSpec`](encode::encoder::EncoderSpec) (`Bbit`/`Vw`/`Rp`/`Oph`)
//! and executed through the
//! [`FeatureEncoder`](encode::encoder::FeatureEncoder) trait.  The
//! pipeline workers, the cache header, the saved-model format and the CLI
//! all speak spec — adding a scheme means one spec variant (with its
//! serializations beside it in `encode/encoder.rs`) plus one trait impl;
//! no coordinator, solver or CLI surgery.  One-permutation hashing
//! ([`hashing::oph`]) is the existence proof.
//!
//! ## Out-of-core workflow (the paper's 200GB story)
//!
//! The pipeline's collector re-emits encoded chunks incrementally, in
//! input order, into a pluggable [`coordinator::sink::PipelineSink`]:
//!
//! 1. `preprocess --encoder bbit|oph --cache-out` streams packed-code
//!    chunks to the checksummed on-disk cache ([`encode::cache`]) — hash
//!    the corpus once, spec recorded in the header.  Raw input runs the
//!    byte-block fast path by default (zero-copy parse in the workers,
//!    recycled buffers; `--legacy-reader` keeps the old line reader for
//!    one release), tracking the paper's "preprocessing ≈ loading" bound;
//! 2. `train --cache` replays that cache through batch solvers or the
//!    streaming SGD trainer ([`solver::SgdStream`]) for as many
//!    (solver, C, epoch) sweeps as needed — and because the v3 cache is
//!    indexed, `--replay-threads N` fans replay across a reader pool
//!    ([`coordinator::replay`]): eval and batch materialization shard
//!    with a merge reduce, `--holdout` decodes in parallel with
//!    bit-identical results, and SGD runs per-shard workers synchronized
//!    by iterate averaging at epoch boundaries;
//! 3. `train --stream` skips the cache entirely: one pass, hash-and-train,
//!    nothing materialized;
//! 4. `serve --model m --port p` keeps the trained model resident behind a
//!    micro-batched HTTP scoring endpoint ([`serve`]) — and because the
//!    registry hot-reloads the model file, the cache→train loop retrains
//!    into production without a restart;
//! 5. the same cache feeds the *similarity* side of the paper's re-use
//!    story: `similar-index --cache c --out idx --shards N` builds a
//!    banded LSH index ([`similarity`]) through the replay reader pool
//!    and snapshots it, `serve --similar-index idx` answers
//!    `POST /similar` (top-K near neighbors with b-bit resemblance
//!    estimates) through the same batcher/deadline machinery, and
//!    `route --backends h:p,...` consistent-hashes doc lookups across a
//!    fleet of shard servers with health-checked scatter-gather.
//!
//! ## Performance (where cycles go, and how it's tracked)
//!
//! With ingest and replay parallelized (PRs 4–5), train/score time lives
//! in the per-row gather/scatter against the weight vector.  The
//! [`kernels`] module documents that hot path — whole-row decode, 8-wide
//! unrolled accumulators, one-row-ahead weight prefetch — including which
//! kernels are bit-exact vs tolerance-bounded against their scalar
//! reference twins.  The standing benchmark matrix
//! (`cargo bench --bench bench_pipeline -- matrix`) measures
//! train-no-cache / train-from-cache / predict / serve (runtime, rows/s,
//! peak RSS, and the scalar-vs-unrolled `kernel_speedup`) into
//! `BENCH_matrix.json`; CI gates every bench artifact against the
//! committed baselines in `benches/baselines/` via
//! `scripts/bench_gate.sh` and appends history with
//! `scripts/bench_trend.sh`.
//!
//! The preprocessing side has a device column: `preprocess --device xla`
//! swaps the workers' per-row hashing for the
//! [`DeviceEncoder`](encode::DeviceEncoder), which pads parsed CSR chunks
//! to the compiled `[batch, nnz]` geometry of the AOT minwise/VW
//! artifacts and double-buffers host→device staging against execution on
//! a dedicated driver thread.  Output is bit-identical to the CPU path
//! (same draws, same mod-reduce, same truncation — asserted row-for-row
//! and cache-byte-for-byte in `tests/device_encoder.rs`), rows that
//! exceed the compiled `nnz` fall back to the scalar twin per row, and a
//! missing/broken PJRT stack degrades to pure CPU with a logged reason —
//! never an error.  `bench_pipeline -- ingest` records the device column
//! (`device_preprocess_seconds`, `device_over_load`) next to the CPU
//! ingest numbers, and `--report-json` carries
//! `encode_device_seconds` / `device_chunks` / `device_fallbacks`.
//!
//! ## Observability
//!
//! The [`metrics`] module is the one telemetry layer every tier speaks:
//!
//! - **Prometheus exposition.**  Both `GET /metrics` bodies (server and
//!   router) render through [`metrics::prom::Exposition`] with canonical
//!   naming — counters end `_total`, durations are `_seconds` in base
//!   units, histograms emit cumulative `_bucket{le=...}`/`_sum`/`_count`.
//!   [`metrics::prom::validate`] is a promtool-style format checker; CI
//!   scrapes both live endpoints and validates them
//!   (`scripts/check_metrics.sh`).  [`metrics::Gauge`] tracks
//!   point-in-time state: queue depth, loaded shards, model epoch.
//! - **Tracing spans.**  `--trace-out FILE` (on `preprocess`, `train`,
//!   `serve`, `route`) streams JSONL span events ([`metrics::trace`]).
//!   The span taxonomy: `pipeline.run` > `pipeline.read` /
//!   `pipeline.parse` / `pipeline.encode` / `pipeline.sink`;
//!   `replay.run` > `replay.read` / `replay.emit`; a `train.epoch` point
//!   per epoch; on the serve path `serve.score` / `serve.similar` roots
//!   over `serve.admission_wait` (queue wait), `serve.batch_assembly`,
//!   and `serve.kernel` (service time); on the router `route.score` /
//!   `route.similar` roots over per-backend `route.forward` /
//!   `route.scatter_leg` legs.
//! - **Trace-id propagation.**  Every request gets a trace id at the
//!   edge (client-supplied `X-Trace-Id` or minted), echoed on every
//!   response and forwarded on every backend leg — so one grep by trace
//!   id over the fleet's trace files reconstructs a request's full path,
//!   with queue wait separated from service time.  `--slow-ms N` logs
//!   slow requests (with their trace id) to stderr on both tiers.
//! - **Machine-readable reports.**  `--report-json FILE` (on
//!   `preprocess` and `train --stream`) dumps the
//!   [`PipelineReport`](coordinator::PipelineReport) as JSON.
//!
//! ## Fault tolerance (crash-safe pipelines)
//!
//! A 200GB preprocess or a long SGD sweep must survive `kill -9`,
//! torn writes and rolling restarts.  Every durable artifact in the
//! crate commits atomically, and every long-running pass can resume:
//!
//! - **Crash-safe cache commits.**  `preprocess --cache-out` writes
//!   through `<cache>.tmp` plus an fsync'd sidecar journal recording,
//!   every `--sync-chunks` chunks, the validated record prefix and the
//!   exact input byte offset/line that produced it.  Finalize writes the
//!   footer and publishes with one atomic rename — a crash at *any*
//!   point leaves either the complete old artifact or no artifact, never
//!   a torn cache.  `preprocess --resume` salvages the checksummed
//!   prefix of the tmp file, seeks raw input to the journaled offset and
//!   continues; the resumed cache is byte-identical to an uninterrupted
//!   run (asserted in `tests/crash_recovery.rs` by SIGKILLing a live
//!   preprocess at varying depths).
//! - **Malformed-input policy.**  `--on-error skip` (on `preprocess`
//!   and `train --stream`) skips unparseable lines instead of aborting
//!   mid-corpus, counts them in the report (`parse_errors`), and
//!   `--quarantine FILE` preserves the raw bytes with line numbers for
//!   offline triage.
//! - **Training checkpoints.**  `train --cache --checkpoint FILE
//!   [--checkpoint-every N]` snapshots the streaming SGD state (weights
//!   + optimizer position) atomically between epochs; `--resume` picks
//!   up from the snapshot and reaches **bit-identical** final weights
//!   versus the uninterrupted run.  A checkpoint is a valid saved model
//!   — `serve`'s hot-reload registry can load it mid-train.
//! - **Online-tier drain.**  On SIGTERM the server fails `/healthz`
//!   first (so load balancers stop routing), finishes in-flight
//!   requests, then exits within `--drain-ms`.  The `route` tier
//!   retries transient backend failures with backoff, so a draining
//!   shard is invisible to fleet callers.
//! - **Failpoints.**  [`faults`] is a std-only failpoint facility:
//!   `BBMH_FAILPOINTS=site=action[:prob][:count]` arms error / panic /
//!   partial-write / delay injection at the crash-critical sites
//!   (`cache.write_record`, `cache.finalize`, `replay.decode`,
//!   `serve.batch`, `route.forward`, `device.launch`).  Disarmed cost is
//!   one relaxed atomic load.  `tests/crash_recovery.rs` drives the
//!   recovery guarantees through these sites, and CI's `fault-injection`
//!   job runs the suite under a failpoint matrix (delays everywhere,
//!   forced torn writes, forced finalize crashes).

pub mod config;
pub mod coordinator;
pub mod data;
pub mod encode;
pub mod error;
pub mod experiments;
pub mod faults;
pub mod hashing;
pub mod kernels;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod similarity;
pub mod solver;
pub mod util;

pub use error::{Error, Result};
