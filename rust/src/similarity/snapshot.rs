//! The `BBMHSIM1` on-disk snapshot: build an [`LshIndex`] once, load it
//! fast on every serve restart.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! 8B  magic  b"BBMHSIM1"
//! --- FNV-1a checksummed region ---
//! 4+4+8+8+8  EncoderSpec::header_fields  (tag, p0, p1, p2, seed)
//! 8   bands            8   rows_per_band
//! 8   num_shards       8   shard_count (shards stored in THIS file)
//! per shard, ascending by shard id:
//!   8   shard_id       8   rows
//!   rows × 8           row ids, ascending
//!   PackedCodes::save  payload ("BBMH" + b, k, n + packed words)
//! --- end checksummed region ---
//! 8B  FNV-1a 64 of the region above
//! ```
//!
//! Only the signatures and row ids are stored — the per-band bucket
//! tables are derived data, rebuilt at load in the same local-row order
//! the build path uses, so a loaded index answers queries identically to
//! the one that was saved while the file stays at signature size.  A
//! multi-shard build can be written as one file ([`save`]) or split one
//! shard per file ([`save_shard`]) for a serve fleet; [`load_many`]
//! merges any consistent set of shard files back into one index.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::encode::packed::PackedCodes;
use crate::encode::EncoderSpec;
use crate::hashing::lsh::LshConfig;
use crate::similarity::index::{IndexShard, LshIndex};
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"BBMHSIM1";
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

/// `Write` adapter that folds every byte into a running FNV-1a 64.
struct HashingWriter<W: Write> {
    inner: W,
    hash: u64,
}

impl<W: Write> Write for HashingWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.inner.write(buf)?;
        for &byte in &buf[..n] {
            self.hash = (self.hash ^ byte as u64).wrapping_mul(FNV_PRIME);
        }
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// `Read` adapter mirroring [`HashingWriter`] on the load side.
struct HashingReader<R: Read> {
    inner: R,
    hash: u64,
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        for &byte in &buf[..n] {
            self.hash = (self.hash ^ byte as u64).wrapping_mul(FNV_PRIME);
        }
        Ok(n)
    }
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn write_shards<W: Write>(w: &mut W, index: &LshIndex, shards: &[&IndexShard]) -> Result<()> {
    let (tag, p0, p1, p2, seed) = index.spec().header_fields();
    w.write_all(&tag.to_le_bytes())?;
    w.write_all(&p0.to_le_bytes())?;
    for v in [p1, p2, seed] {
        write_u64(w, v)?;
    }
    let cfg = index.config();
    for v in [
        cfg.bands as u64,
        cfg.rows_per_band as u64,
        index.num_shards() as u64,
        shards.len() as u64,
    ] {
        write_u64(w, v)?;
    }
    for shard in shards {
        write_u64(w, shard.shard_id as u64)?;
        write_u64(w, shard.row_ids.len() as u64)?;
        for &id in &shard.row_ids {
            write_u64(w, id)?;
        }
        shard.codes.save(&mut *w)?;
    }
    Ok(())
}

fn save_to<P: AsRef<Path>>(index: &LshIndex, shards: &[&IndexShard], path: P) -> Result<()> {
    let file = File::create(path)?;
    let mut w = HashingWriter { inner: BufWriter::new(file), hash: FNV_OFFSET };
    w.inner.write_all(MAGIC)?; // magic sits outside the checksummed region
    write_shards(&mut w, index, shards)?;
    let hash = w.hash;
    write_u64(&mut w.inner, hash)?;
    w.inner.flush()?;
    Ok(())
}

/// Write every resident shard of `index` into one snapshot file.
pub fn save<P: AsRef<Path>>(index: &LshIndex, path: P) -> Result<()> {
    let shards: Vec<&IndexShard> = index.shards().iter().collect();
    save_to(index, &shards, path)
}

/// Write one resident shard into its own snapshot file — the fleet
/// layout, one file per shard server.
pub fn save_shard<P: AsRef<Path>>(index: &LshIndex, shard: usize, path: P) -> Result<()> {
    let found = index
        .shards()
        .iter()
        .find(|s| s.shard_id == shard)
        .ok_or_else(|| Error::InvalidArg(format!("shard {shard} not resident in index")))?;
    save_to(index, &[found], path)
}

/// Parsed file contents, pre-assembly: consistency across files is
/// checked by [`load_many`], intra-file invariants here.
struct SnapshotFile {
    spec: EncoderSpec,
    cfg: LshConfig,
    num_shards: usize,
    shards: Vec<IndexShard>,
}

fn read_file(path: &Path) -> Result<SnapshotFile> {
    let display = path.display().to_string();
    let bad = |msg: String| Error::InvalidArg(format!("{display}: {msg}"));
    let file = File::open(path)?;
    let mut r = HashingReader { inner: BufReader::new(file), hash: FNV_OFFSET };
    let mut magic = [0u8; 8];
    r.inner.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("not a BBMHSIM1 similarity snapshot".into()));
    }
    let tag = read_u32(&mut r)?;
    let p0 = read_u32(&mut r)?;
    let p1 = read_u64(&mut r)?;
    let p2 = read_u64(&mut r)?;
    let seed = read_u64(&mut r)?;
    let spec = EncoderSpec::from_header_fields(tag, p0, p1, p2, seed)?;
    let (b, k) = spec
        .packed_geometry()
        .ok_or_else(|| bad(format!("snapshot spec {} is not packed", spec.scheme())))?;
    let cfg = LshConfig {
        bands: read_u64(&mut r)? as usize,
        rows_per_band: read_u64(&mut r)? as usize,
    };
    let num_shards = read_u64(&mut r)? as usize;
    let shard_count = read_u64(&mut r)? as usize;
    if num_shards == 0 || shard_count == 0 || shard_count > num_shards {
        return Err(bad(format!(
            "bad shard header: {shard_count} stored of {num_shards} total"
        )));
    }
    let mut shards = Vec::with_capacity(shard_count);
    for _ in 0..shard_count {
        let shard_id = read_u64(&mut r)? as usize;
        if shard_id >= num_shards {
            return Err(bad(format!("shard id {shard_id} out of range ({num_shards})")));
        }
        let rows = read_u64(&mut r)? as usize;
        let mut row_ids = Vec::with_capacity(rows);
        for _ in 0..rows {
            row_ids.push(read_u64(&mut r)?);
        }
        for pair in row_ids.windows(2) {
            if pair[0] >= pair[1] {
                return Err(bad(format!("shard {shard_id} row ids not ascending")));
            }
        }
        if let Some(&id) = row_ids.iter().find(|&&id| id % num_shards as u64 != shard_id as u64)
        {
            return Err(bad(format!("row {id} does not belong to shard {shard_id}")));
        }
        let codes = PackedCodes::load(&mut r)?;
        if (codes.b, codes.k) != (b, k) || codes.n != rows {
            return Err(bad(format!(
                "shard {shard_id} geometry (b={}, k={}, n={}) does not match header \
                 (b={b}, k={k}, rows={rows})",
                codes.b, codes.k, codes.n
            )));
        }
        shards.push(IndexShard::from_loaded(shard_id, codes, row_ids, &cfg));
    }
    let computed = r.hash;
    let stored = read_u64(&mut r.inner)?;
    if computed != stored {
        return Err(bad(format!(
            "checksum mismatch (stored {stored:#018x}, computed {computed:#018x})"
        )));
    }
    Ok(SnapshotFile { spec, cfg, num_shards, shards })
}

/// Load one snapshot file back into a queryable [`LshIndex`].
pub fn load<P: AsRef<Path>>(path: P) -> Result<LshIndex> {
    let f = read_file(path.as_ref())?;
    LshIndex::from_parts(f.spec, f.cfg, f.num_shards, f.shards)
}

/// Load and merge several shard files into one index.  Every file must
/// agree on the encoder spec, banding config, and total shard count, and
/// no shard may appear twice.
pub fn load_many<P: AsRef<Path>>(paths: &[P]) -> Result<LshIndex> {
    let Some((first, rest)) = paths.split_first() else {
        return Err(Error::InvalidArg("no snapshot files given".into()));
    };
    let mut merged = read_file(first.as_ref())?;
    for path in rest {
        let f = read_file(path.as_ref())?;
        if f.spec != merged.spec {
            return Err(Error::InvalidArg(format!(
                "{}: encoder spec differs from {}",
                path.as_ref().display(),
                first.as_ref().display()
            )));
        }
        if f.cfg != merged.cfg || f.num_shards != merged.num_shards {
            return Err(Error::InvalidArg(format!(
                "{}: banding/shard layout differs from {}",
                path.as_ref().display(),
                first.as_ref().display()
            )));
        }
        merged.shards.extend(f.shards);
    }
    // from_parts rejects duplicate shard ids across the merged set
    LshIndex::from_parts(merged.spec, merged.cfg, merged.num_shards, merged.shards)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::minwise::BbitMinHash;
    use crate::util::Rng;
    use std::path::PathBuf;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bbmh_sim_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn spec() -> EncoderSpec {
        EncoderSpec::Bbit { b: 8, k: 64, d: 1 << 24, seed: 0xBEE }
    }

    fn cfg() -> LshConfig {
        LshConfig { bands: 16, rows_per_band: 4 }
    }

    fn corpus(n: usize) -> PackedCodes {
        let EncoderSpec::Bbit { b, k, d, seed } = spec() else { unreachable!() };
        let bb = BbitMinHash::draw(k, b, d, &mut Rng::new(seed));
        let mut rng = Rng::new(0xD1CE);
        let mut pc = PackedCodes::new(b, k);
        for _ in 0..n {
            let set: Vec<u32> =
                rng.sample_distinct(d, 250).into_iter().map(|x| x as u32).collect();
            pc.push_row(&bb.codes(&set)).unwrap();
        }
        pc
    }

    fn assert_same_answers(a: &LshIndex, b: &LshIndex, rows: usize) {
        assert_eq!(a.shard_ids(), b.shard_ids());
        assert_eq!(a.rows(), b.rows());
        for row in 0..rows {
            let id = row as u64;
            if !a.has_shard(a.owner_shard(id)) {
                continue;
            }
            let (ha, sa) = a.query_doc(id, rows).unwrap();
            let (hb, sb) = b.query_doc(id, rows).unwrap();
            assert_eq!(ha, hb, "row {row}: neighbors drifted across save/load");
            assert_eq!(sa, sb, "row {row}: query stats drifted across save/load");
        }
    }

    #[test]
    fn snapshot_round_trips_bit_for_bit() {
        let dir = temp_dir("round_trip");
        let pc = corpus(60);
        let built = LshIndex::from_codes(&pc, spec(), cfg(), 3).unwrap();
        let path = dir.join("all.sim");
        save(&built, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.spec(), spec());
        assert_eq!(loaded.config(), cfg());
        assert_eq!(loaded.num_shards(), 3);
        assert_same_answers(&built, &loaded, pc.n);
        // derived band tables must rebuild identically too
        let (a, b) = (built.band_stats(), loaded.band_stats());
        assert_eq!(a, b);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_files_merge_back_into_the_full_index() {
        let dir = temp_dir("merge");
        let pc = corpus(40);
        let built = LshIndex::from_codes(&pc, spec(), cfg(), 2).unwrap();
        let p0 = dir.join("s0.sim");
        let p1 = dir.join("s1.sim");
        save_shard(&built, 0, &p0).unwrap();
        save_shard(&built, 1, &p1).unwrap();

        // one shard alone serves its own rows and knows what is missing
        let half = load(&p0).unwrap();
        assert_eq!(half.shard_ids(), vec![0]);
        assert!(half.has_shard(0) && !half.has_shard(1));
        assert!(half.query_doc(1, 5).is_err(), "row 1 lives in the absent shard");

        // merged shard files answer exactly like the original build
        let merged = load_many(&[&p1, &p0]).unwrap();
        assert_same_answers(&built, &merged, pc.n);

        // the same shard twice must be rejected
        assert!(load_many(&[&p0, &p0]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_and_truncation_are_detected() {
        let dir = temp_dir("corrupt");
        let pc = corpus(20);
        let built = LshIndex::from_codes(&pc, spec(), cfg(), 1).unwrap();
        let path = dir.join("good.sim");
        save(&built, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        // flip one payload byte: checksum (or a structural check) trips
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x40;
        let bad = dir.join("flipped.sim");
        std::fs::write(&bad, &flipped).unwrap();
        assert!(load(&bad).is_err(), "bit flip must not load cleanly");

        // truncation: short read surfaces as an error
        let cut = dir.join("cut.sim");
        std::fs::write(&cut, &bytes[..bytes.len() - 9]).unwrap();
        assert!(load(&cut).is_err());

        // foreign magic
        let alien = dir.join("alien.sim");
        std::fs::write(&alien, b"NOTASNAP00000000").unwrap();
        assert!(load(&alien).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
