//! The owned, sharded LSH index (see the module docs in
//! [`crate::similarity`]).
//!
//! Build paths: [`LshIndex::build_from_cache`] (out-of-core, through the
//! replay reader pool) and [`LshIndex::from_codes`] (in-memory — the
//! near-duplicates example and the offline/online parity tests).  Query
//! paths: [`LshIndex::query`] over a hashed signature and
//! [`LshIndex::query_doc`] over an indexed record id.

use std::collections::HashMap;
use std::path::Path;

use crate::encode::encoder::{EncodeScratch, FeatureEncoder};
use crate::encode::packed::PackedCodes;
use crate::encode::EncoderSpec;
use crate::hashing::lsh::{band_key_codes, LshConfig};
use crate::{Error, Result};

/// One ranked near-neighbor: the record's global id (its row number in
/// the cache the index was built from) and its P̂_b code-agreement
/// estimate in `[0, 1]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: u64,
    pub estimate: f64,
}

/// Work accounting for one query (drives the serve-path histograms).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Bucket hits across all bands and shards, before deduplication.
    pub candidates: usize,
    /// Distinct rows re-ranked (post-dedup) — the verify-step depth.
    pub reranked: usize,
}

/// Per-band bucket occupancy, aggregated across the local shards — the
/// skew signal (`max_bucket` ≫ `mean_bucket` means one key is hot and
/// that band contributes little selectivity).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandStats {
    pub band: usize,
    /// Distinct keys in this band's tables.
    pub buckets: usize,
    /// Largest single bucket.
    pub max_bucket: usize,
    /// Rows per bucket on average.
    pub mean_bucket: f64,
}

/// One resident shard: the rows whose `id % num_shards == shard_id`.
pub(crate) struct IndexShard {
    pub(crate) shard_id: usize,
    /// Packed signatures, one row per indexed record row.
    pub(crate) codes: PackedCodes,
    /// Global record id per local row, ascending (build emits in order).
    pub(crate) row_ids: Vec<u64>,
    /// One table per band: band key → local row ids (derived data —
    /// rebuilt from `codes` on snapshot load, never serialized).
    tables: Vec<HashMap<u64, Vec<u32>>>,
}

impl IndexShard {
    fn new(shard_id: usize, b: u32, k: usize, bands: usize) -> Self {
        IndexShard {
            shard_id,
            codes: PackedCodes::new(b, k),
            row_ids: Vec::new(),
            tables: vec![HashMap::new(); bands],
        }
    }

    /// Append one signature and bucket it into every band table.
    fn push(&mut self, id: u64, sig: &[u16], cfg: &LshConfig) -> Result<()> {
        let local = self.codes.n as u32;
        self.codes.push_row(sig)?;
        self.row_ids.push(id);
        for (band, table) in self.tables.iter_mut().enumerate() {
            let key = band_key_codes(sig, band, cfg.rows_per_band);
            table.entry(key).or_default().push(local);
        }
        Ok(())
    }

    /// Reassemble a shard from snapshot parts: band tables are derived
    /// data, rebuilt here in local-row order — the same insertion order
    /// the build path uses, so loaded and built shards query identically.
    pub(crate) fn from_loaded(
        shard_id: usize,
        codes: PackedCodes,
        row_ids: Vec<u64>,
        cfg: &LshConfig,
    ) -> Self {
        let mut shard = IndexShard { shard_id, codes, row_ids, tables: Vec::new() };
        shard.rebuild_tables(cfg);
        shard
    }

    /// Rebuild the band tables from the signatures (snapshot load).
    fn rebuild_tables(&mut self, cfg: &LshConfig) {
        self.tables = vec![HashMap::new(); cfg.bands];
        let mut sig = vec![0u16; self.codes.k];
        for row in 0..self.codes.n {
            self.codes.row_into(row, &mut sig);
            for (band, table) in self.tables.iter_mut().enumerate() {
                let key = band_key_codes(&sig, band, cfg.rows_per_band);
                table.entry(key).or_default().push(row as u32);
            }
        }
    }
}

/// The owned, sharded LSH index (module docs: [`crate::similarity`]).
pub struct LshIndex {
    spec: EncoderSpec,
    cfg: LshConfig,
    /// Total sharding factor chosen at build time (`id % num_shards`
    /// places a record); this process may hold any subset of the shards.
    num_shards: usize,
    /// Resident shards, ascending by `shard_id`.
    shards: Vec<IndexShard>,
    /// Query-side hasher, drawn from `spec` — the exact family that
    /// produced the indexed signatures.
    encoder: Box<dyn FeatureEncoder>,
}

impl LshIndex {
    fn validate_geometry(spec: &EncoderSpec, cfg: &LshConfig) -> Result<(u32, usize)> {
        let (b, k) = spec.packed_geometry().ok_or_else(|| {
            Error::InvalidArg(format!(
                "similarity index needs packed codes; encoder {} emits sparse rows",
                spec.scheme()
            ))
        })?;
        if cfg.bands == 0 || cfg.rows_per_band == 0 {
            return Err(Error::InvalidArg("bands and rows-per-band must be >= 1".into()));
        }
        if k < cfg.signature_width() {
            return Err(Error::InvalidArg(format!(
                "signature needs {} codes, have k={k}",
                cfg.signature_width()
            )));
        }
        Ok((b, k))
    }

    /// The documented banding caveat (hashing/lsh.rs): at b < 4 a band
    /// chance-collides at ≈ 2^-br and candidate sets flood.  Warn at
    /// build time only — snapshot loads stay quiet.
    fn warn_low_b(b: u32) {
        if b < 4 {
            eprintln!(
                "warning: building LSH index over b={b} codes; b >= 4 recommended for \
                 banding (chance band collisions ≈ 2^-(b*rows))"
            );
        }
    }

    pub(crate) fn from_parts(
        spec: EncoderSpec,
        cfg: LshConfig,
        num_shards: usize,
        mut shards: Vec<IndexShard>,
    ) -> Result<Self> {
        Self::validate_geometry(&spec, &cfg)?;
        if num_shards == 0 {
            return Err(Error::InvalidArg("num_shards must be >= 1".into()));
        }
        shards.sort_by_key(|s| s.shard_id);
        for pair in shards.windows(2) {
            if pair[0].shard_id == pair[1].shard_id {
                return Err(Error::InvalidArg(format!(
                    "duplicate shard {} in index",
                    pair[0].shard_id
                )));
            }
        }
        for s in &shards {
            if s.shard_id >= num_shards {
                return Err(Error::InvalidArg(format!(
                    "shard id {} out of range (num_shards {num_shards})",
                    s.shard_id
                )));
            }
        }
        let encoder = spec.encoder()?;
        Ok(LshIndex { spec, cfg, num_shards, shards, encoder })
    }

    /// Build from an in-memory code matrix (row id == row number) — the
    /// offline form the near-duplicates example uses.  `shards = 1` keeps
    /// every pair co-resident and reproduces the
    /// [`crate::hashing::lsh::LshIndex`] results exactly.
    pub fn from_codes(
        codes: &PackedCodes,
        spec: EncoderSpec,
        cfg: LshConfig,
        shards: usize,
    ) -> Result<Self> {
        let (b, k) = Self::validate_geometry(&spec, &cfg)?;
        if (codes.b, codes.k) != (b, k) {
            return Err(Error::InvalidArg(format!(
                "codes geometry (b={}, k={}) does not match encoder {} (b={b}, k={k})",
                codes.b,
                codes.k,
                spec.scheme()
            )));
        }
        if shards == 0 {
            return Err(Error::InvalidArg("--shards must be >= 1".into()));
        }
        Self::warn_low_b(b);
        let mut parts: Vec<IndexShard> =
            (0..shards).map(|s| IndexShard::new(s, b, k, cfg.bands)).collect();
        let mut sig = vec![0u16; k];
        for row in 0..codes.n {
            codes.row_into(row, &mut sig);
            let id = row as u64;
            parts[(id % shards as u64) as usize].push(id, &sig, &cfg)?;
        }
        Self::from_parts(spec, cfg, shards, parts)
    }

    /// Build out-of-core from a v3 hashed cache through the
    /// [`replay_cache`](crate::coordinator::replay::replay_cache) reader
    /// pool.  The pool emits records strictly in order for every thread
    /// count, so the built shards — row ids, signature order, bucket
    /// contents — are identical for every `replay_threads`.
    pub fn build_from_cache<P: AsRef<Path>>(
        cache: P,
        cfg: LshConfig,
        shards: usize,
        replay_threads: usize,
    ) -> Result<Self> {
        let cache = cache.as_ref();
        if shards == 0 {
            return Err(Error::InvalidArg("--shards must be >= 1".into()));
        }
        let meta = crate::encode::cache::CacheReader::open(cache)?.meta();
        let spec = meta.spec;
        let (b, k) = Self::validate_geometry(&spec, &cfg)?;
        Self::warn_low_b(b);
        let mut parts: Vec<IndexShard> =
            (0..shards).map(|s| IndexShard::new(s, b, k, cfg.bands)).collect();
        let mut sig = vec![0u16; k];
        crate::coordinator::replay::replay_cache(
            cache,
            replay_threads,
            |_record, row0, codes, _labels| {
                for row in 0..codes.n {
                    codes.row_into(row, &mut sig);
                    let id = row0 + row as u64;
                    parts[(id % shards as u64) as usize].push(id, &sig, &cfg)?;
                }
                Ok(())
            },
        )?;
        Self::from_parts(spec, cfg, shards, parts)
    }

    pub fn spec(&self) -> EncoderSpec {
        self.spec
    }

    pub fn config(&self) -> LshConfig {
        self.cfg
    }

    /// Total sharding factor chosen at build time.
    pub fn num_shards(&self) -> usize {
        self.num_shards
    }

    /// The shards resident in this index, ascending.
    pub fn shard_ids(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.shard_id).collect()
    }

    pub fn has_shard(&self, shard: usize) -> bool {
        self.shards.iter().any(|s| s.shard_id == shard)
    }

    /// Which shard a record id lives in (the build-time placement rule).
    pub fn owner_shard(&self, id: u64) -> usize {
        (id % self.num_shards as u64) as usize
    }

    /// Rows resident across the local shards.
    pub fn rows(&self) -> usize {
        self.shards.iter().map(|s| s.codes.n).sum()
    }

    /// Signature width in codes (`k` of the underlying scheme).
    pub fn signature_len(&self) -> usize {
        self.spec.packed_geometry().map(|(_, k)| k).unwrap_or(0)
    }

    /// Resident signature bytes (the b-bit storage story: this is what a
    /// serve replica actually holds per row).
    pub fn storage_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.codes.storage_bytes()).sum()
    }

    /// Fresh scratch for [`hash_query`](Self::hash_query).
    pub fn scratch(&self) -> EncodeScratch {
        self.encoder.scratch()
    }

    /// Hash one raw document (sorted feature indices) into the signature
    /// family this index was built from; the codes land in
    /// `scratch.codes`.
    pub fn hash_query(&self, set: &[u32], scratch: &mut EncodeScratch) -> Result<()> {
        if !self.encoder.signature_into(set, scratch) {
            // unreachable for any spec that passed validate_geometry
            return Err(Error::InvalidArg(format!(
                "encoder {} emits no packed signature",
                self.spec.scheme()
            )));
        }
        Ok(())
    }

    /// Top-K near neighbors of a hashed signature across the local
    /// shards: banded candidate lookup, then a P̂_b re-rank through the
    /// whole-row decode kernel.  Ties break toward the smaller id, so a
    /// scatter-gather merge over disjoint shard subsets reproduces the
    /// single-process ranking exactly.
    pub fn query(&self, sig: &[u16], top_k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        let k = self.signature_len();
        if sig.len() != k {
            return Err(Error::InvalidArg(format!(
                "query signature has {} codes, index expects {k}",
                sig.len()
            )));
        }
        let b = self.spec.packed_geometry().map(|(b, _)| b).unwrap_or(0);
        // expand the query once: (j << b) | code — the same row-index form
        // row_indices_into decodes candidates into
        let query_idx: Vec<u32> =
            sig.iter().enumerate().map(|(j, &c)| ((j as u32) << b) | c as u32).collect();
        let mut stats = QueryStats::default();
        let mut hits: Vec<Neighbor> = Vec::new();
        let mut cand: Vec<u32> = Vec::new();
        let mut row_idx = vec![0u32; k];
        for shard in &self.shards {
            cand.clear();
            for (band, table) in shard.tables.iter().enumerate() {
                let key = band_key_codes(sig, band, self.cfg.rows_per_band);
                if let Some(ids) = table.get(&key) {
                    cand.extend_from_slice(ids);
                }
            }
            stats.candidates += cand.len();
            cand.sort_unstable();
            cand.dedup();
            stats.reranked += cand.len();
            for &local in &cand {
                // verify step: whole-row decode + agreement count — hits/k
                // is bit-for-bit the offline code_agreement estimate
                shard.codes.row_indices_into(local as usize, &mut row_idx);
                let agree = query_idx.iter().zip(&row_idx).filter(|(a, b)| a == b).count();
                hits.push(Neighbor {
                    id: shard.row_ids[local as usize],
                    estimate: agree as f64 / k as f64,
                });
            }
        }
        rank_neighbors(&mut hits, top_k);
        Ok((hits, stats))
    }

    /// [`query`](Self::query) by indexed record id.  Errors if the owning
    /// shard is not resident (fleet callers route to the owner) or the id
    /// was never indexed.
    pub fn query_doc(&self, id: u64, top_k: usize) -> Result<(Vec<Neighbor>, QueryStats)> {
        let owner = self.owner_shard(id);
        let shard = self
            .shards
            .iter()
            .find(|s| s.shard_id == owner)
            .ok_or_else(|| Error::InvalidArg(format!("shard {owner} not resident here")))?;
        let local = shard
            .row_ids
            .binary_search(&id)
            .map_err(|_| Error::InvalidArg(format!("doc {id} is not in the index")))?;
        let mut sig = vec![0u16; shard.codes.k];
        shard.codes.row_into(local, &mut sig);
        self.query(&sig, top_k)
    }

    /// All near-duplicate pairs `(i < j, estimate)` with code agreement ≥
    /// `min_code_agreement`, over the *resident* shards (pairs never span
    /// shards — with `shards = 1` this is exactly the offline
    /// [`crate::hashing::lsh::LshIndex::near_duplicate_pairs`]).
    pub fn near_duplicate_pairs(&self, min_code_agreement: f64) -> Vec<(u64, u64, f64)> {
        let k = self.signature_len();
        let mut out = Vec::new();
        let mut a_idx = vec![0u32; k];
        let mut b_idx = vec![0u32; k];
        for shard in &self.shards {
            let mut seen = std::collections::HashSet::new();
            for table in &shard.tables {
                for ids in table.values() {
                    if ids.len() < 2 {
                        continue;
                    }
                    for (a_pos, &i) in ids.iter().enumerate() {
                        for &j in &ids[a_pos + 1..] {
                            let key = ((i as u64) << 32) | j as u64;
                            if !seen.insert(key) {
                                continue;
                            }
                            shard.codes.row_indices_into(i as usize, &mut a_idx);
                            shard.codes.row_indices_into(j as usize, &mut b_idx);
                            let agree =
                                a_idx.iter().zip(&b_idx).filter(|(a, b)| a == b).count();
                            let est = agree as f64 / k as f64;
                            if est >= min_code_agreement {
                                out.push((shard.row_ids[i as usize], shard.row_ids[j as usize], est));
                            }
                        }
                    }
                }
            }
        }
        out.sort_unstable_by_key(|p| (p.0, p.1));
        out
    }

    /// Per-band bucket occupancy aggregated across resident shards.
    pub fn band_stats(&self) -> Vec<BandStats> {
        (0..self.cfg.bands)
            .map(|band| {
                let mut buckets = 0usize;
                let mut max_bucket = 0usize;
                let mut entries = 0usize;
                for shard in &self.shards {
                    for ids in shard.tables[band].values() {
                        buckets += 1;
                        entries += ids.len();
                        max_bucket = max_bucket.max(ids.len());
                    }
                }
                BandStats {
                    band,
                    buckets,
                    max_bucket,
                    mean_bucket: entries as f64 / buckets.max(1) as f64,
                }
            })
            .collect()
    }

    pub(crate) fn shards(&self) -> &[IndexShard] {
        &self.shards
    }
}

/// Rank in place: estimate descending, id ascending on ties, truncate to
/// `top_k`.  Shared by the in-process query and the router's
/// scatter-gather merge so both rankings agree bit-for-bit.
pub fn rank_neighbors(hits: &mut Vec<Neighbor>, top_k: usize) {
    hits.sort_unstable_by(|a, b| {
        b.estimate
            .partial_cmp(&a.estimate)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| a.id.cmp(&b.id))
    });
    hits.truncate(top_k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hashing::lsh;
    use crate::hashing::minwise::BbitMinHash;
    use crate::util::Rng;

    fn spec() -> EncoderSpec {
        EncoderSpec::Bbit { b: 8, k: 64, d: 1 << 24, seed: 0x51A }
    }

    fn corpus_codes(n_pairs: usize) -> PackedCodes {
        // planted near-duplicate pairs (2i, 2i+1), same idiom as the
        // offline lsh.rs tests
        let EncoderSpec::Bbit { b, k, d, seed } = spec() else { unreachable!() };
        let bb = BbitMinHash::draw(k, b, d, &mut Rng::new(seed));
        let mut rng = Rng::new(0xC0FFEE);
        let mut pc = PackedCodes::new(b, k);
        for _ in 0..n_pairs {
            let base: Vec<u32> =
                rng.sample_distinct(d, 300).into_iter().map(|x| x as u32).collect();
            let mut near = base.clone();
            for _ in 0..15 {
                let pos = rng.below_usize(near.len());
                near[pos] = rng.below(d) as u32;
            }
            near.sort_unstable();
            near.dedup();
            pc.push_row(&bb.codes(&base)).unwrap();
            pc.push_row(&bb.codes(&near)).unwrap();
        }
        pc
    }

    #[test]
    fn single_shard_matches_offline_index_bit_for_bit() {
        let pc = corpus_codes(20);
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        let offline = lsh::LshIndex::build(&pc, cfg).unwrap();
        let online = LshIndex::from_codes(&pc, spec(), cfg, 1).unwrap();

        // pair sweep: identical pairs, bitwise-identical estimates
        let off_pairs = offline.near_duplicate_pairs(0.5);
        let on_pairs = online.near_duplicate_pairs(0.5);
        assert_eq!(off_pairs.len(), on_pairs.len());
        for (&(i, j, a), &(gi, gj, ga)) in off_pairs.iter().zip(&on_pairs) {
            assert_eq!((i as u64, j as u64), (gi, gj));
            assert!(a.to_bits() == ga.to_bits(), "estimate drifted: {a} vs {ga}");
        }

        // per-row query: candidates and estimates line up with the
        // offline candidate + code_agreement walk
        for row in 0..pc.n {
            let (hits, stats) = online.query_doc(row as u64, pc.n).unwrap();
            let offline_cands = offline.candidates_for_row(row);
            assert_eq!(stats.reranked, offline_cands.len(), "row {row}");
            for h in &hits {
                let a = lsh::code_agreement(&pc, row, h.id as usize);
                assert!(a.to_bits() == h.estimate.to_bits(), "row {row} id {}", h.id);
            }
        }
    }

    #[test]
    fn sharded_union_covers_all_rows_and_merges_like_one_index() {
        let pc = corpus_codes(20);
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        let whole = LshIndex::from_codes(&pc, spec(), cfg, 1).unwrap();
        let sharded = LshIndex::from_codes(&pc, spec(), cfg, 4).unwrap();
        assert_eq!(sharded.shard_ids(), vec![0, 1, 2, 3]);
        assert_eq!(sharded.rows(), pc.n);
        let mut sig = vec![0u16; pc.k];
        for row in 0..pc.n {
            pc.row_into(row, &mut sig);
            let (a, _) = whole.query(&sig, 5).unwrap();
            let (b, _) = sharded.query(&sig, 5).unwrap();
            assert_eq!(a, b, "row {row}: sharded query must rank identically");
        }
    }

    #[test]
    fn hash_query_matches_indexed_signature() {
        // a raw doc hashed at query time lands on its own indexed row with
        // estimate exactly 1.0
        let EncoderSpec::Bbit { d, .. } = spec() else { unreachable!() };
        let mut rng = Rng::new(7);
        let docs: Vec<Vec<u32>> = (0..10)
            .map(|_| rng.sample_distinct(d, 200).into_iter().map(|x| x as u32).collect())
            .collect();
        let enc = spec().encoder().unwrap();
        let chunk: Vec<crate::data::dataset::Example> =
            docs.iter().map(|s| crate::data::dataset::Example::binary(1, s.clone())).collect();
        let codes = match enc.encode_chunk(&chunk).unwrap() {
            crate::encode::EncodedChunk::Packed { codes, .. } => codes,
            _ => unreachable!(),
        };
        let idx = LshIndex::from_codes(&codes, spec(), LshConfig { bands: 16, rows_per_band: 4 }, 2)
            .unwrap();
        let mut scratch = idx.scratch();
        for (i, doc) in docs.iter().enumerate() {
            idx.hash_query(doc, &mut scratch).unwrap();
            let sig = scratch.codes.clone();
            let (hits, _) = idx.query(&sig, 1).unwrap();
            assert_eq!(hits[0].id, i as u64, "self must rank first");
            assert_eq!(hits[0].estimate, 1.0);
        }
    }

    #[test]
    fn rejects_bad_geometry_and_bad_queries() {
        let pc = corpus_codes(2);
        // too-narrow signature
        let cfg = LshConfig { bands: 32, rows_per_band: 4 };
        assert!(LshIndex::from_codes(&pc, spec(), cfg, 1).is_err());
        // zero shards
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        assert!(LshIndex::from_codes(&pc, spec(), cfg, 0).is_err());
        // sparse scheme
        let vw = EncoderSpec::Vw { bins: 64, seed: 1 };
        assert!(LshIndex::from_codes(&pc, vw, cfg, 1).is_err());
        let idx = LshIndex::from_codes(&pc, spec(), cfg, 2).unwrap();
        // wrong signature width
        assert!(idx.query(&[0u16; 3], 5).is_err());
        // unknown doc id
        assert!(idx.query_doc(1 << 40, 5).is_err());
    }

    #[test]
    fn band_stats_account_every_row_per_band() {
        let pc = corpus_codes(10);
        let cfg = LshConfig { bands: 16, rows_per_band: 4 };
        let idx = LshIndex::from_codes(&pc, spec(), cfg, 3).unwrap();
        let stats = idx.band_stats();
        assert_eq!(stats.len(), 16);
        for s in &stats {
            // every row lands in exactly one bucket per band (per shard)
            let entries = (s.mean_bucket * s.buckets as f64).round() as usize;
            assert_eq!(entries, pc.n, "band {}", s.band);
            assert!(s.max_bucket >= 1 && s.max_bucket <= pc.n);
        }
    }
}
