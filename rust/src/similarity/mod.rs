//! The online near-neighbor subsystem: an owned, sharded, snapshottable
//! LSH index over b-bit signatures, served behind `POST /similar`.
//!
//! Section 6 of the paper argues the hashed data "can be used and re-used
//! for many tasks such as supervised learning, clustering, duplicate
//! detections, near-neighbor search"; the follow-up "b-Bit Minwise Hashing
//! in Practice" (arXiv:1205.2958) makes that re-use the headline workflow.
//! [`crate::hashing::lsh`] is the offline half (borrowed codes, built
//! per call); this module is the production half, layered on the cache and
//! serve stacks the earlier PRs built:
//!
//! - [`index`] — [`LshIndex`]: banded buckets over minwise/OPH signatures,
//!   built **out-of-core** from a v3 hashed cache through the
//!   [`replay_cache`](crate::coordinator::replay::replay_cache) reader
//!   pool (deterministic for every `--replay-threads` count, because the
//!   pool emits records strictly in order).  Signatures stay in
//!   [`PackedCodes`](crate::encode::packed::PackedCodes), so resident
//!   memory matches the paper's b-bit storage story; candidate re-rank
//!   goes through the PR 6 whole-row decode kernel
//!   (`PackedCodes::row_indices_into`) and produces P̂_b estimates
//!   bit-for-bit equal to the offline
//!   [`code_agreement`](crate::hashing::lsh::code_agreement) path.
//!   Rows are sharded by record id (`id % shards`) at build time so a
//!   fleet of servers can each hold a disjoint slice.
//! - [`snapshot`] — the compact on-disk format (`BBMHSIM1`): encoder spec
//!   + banding config + per-shard row ids and packed signatures, FNV-1a
//!   checksummed.  Build once, load fast on restart; band tables are
//!   rebuilt deterministically at load (they are derived data), so the
//!   file stays at signature size.
//!
//! Serving: `bbit-mh serve --similar-index idx` routes `POST /similar`
//! (LibSVM line or `doc:<id>`) through the existing batcher admission /
//! deadline / 503-shed machinery; `bbit-mh route` scatter-gathers a fleet
//! of shard servers behind consistent hashing (see
//! [`crate::serve::router`]).  `bbit-mh similar-index` builds snapshots
//! from a cache.

pub mod index;
pub mod snapshot;

pub use index::{BandStats, LshIndex, Neighbor, QueryStats};
