//! The online scoring subsystem — the request path the paper's closing
//! argument points at ("b-bit minwise hashing has been widely used in
//! industry ... in the context of search"): keep one trained model
//! resident and serve margins over raw documents at traffic, instead of
//! the one-shot `classify` CLI's load-score-exit loop.
//!
//! Four cooperating pieces, each its own module:
//!
//! - [`server`] — a dependency-free TCP/HTTP-1.1 front end
//!   ([`ModelServer`]): `POST /score` LibSVM lines, `GET /metrics`,
//!   `GET /healthz`; thread-per-connection with keep-alive.
//! - [`batcher`] — the micro-batching admission queue ([`Batcher`]):
//!   bounded (overload sheds with `503 Retry-After`, it never queues
//!   unboundedly), with scorer workers draining up to `batch_max`
//!   documents per `batch_wait` window and fanning margins back through
//!   per-job channels.
//! - [`registry`] — epoch-versioned hot reload ([`ModelRegistry`]): an
//!   `Arc<SavedModel>` swap driven by watching the model file, so the
//!   cache→train loop's retrained models go live without dropping a
//!   connection.
//! - [`loadgen`] — the measurement side: a paced loopback load generator
//!   reporting achieved QPS and exact latency percentiles (the `serve`
//!   scenario of `benches/bench_pipeline.rs`).
//!
//! Scoring reuses the [`FeatureEncoder`](crate::encode::encoder) seam end
//! to end: the server is scheme-agnostic because
//! [`SavedModel::margin`](crate::solver::SavedModel::margin) is, and each
//! scorer worker keeps one `EncodeScratch` per model epoch — the same
//! buffer-reuse discipline as the offline classify path.
//!
//! CLI: `bbit-mh serve --model m --port p` (see `main.rs`).

pub mod batcher;
pub mod http;
pub mod loadgen;
pub mod registry;
pub mod server;

pub use batcher::{Batcher, ScoreJob, ScoreOutcome};
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use registry::{EpochModel, ModelRegistry};
pub use server::{ModelServer, ServeConfig, ServeMetrics};
