//! The online scoring subsystem — the request path the paper's closing
//! argument points at ("b-bit minwise hashing has been widely used in
//! industry ... in the context of search"): keep one trained model
//! resident and serve margins over raw documents at traffic, instead of
//! the one-shot `classify` CLI's load-score-exit loop.
//!
//! Five cooperating pieces, each its own module:
//!
//! - [`server`] — a dependency-free TCP/HTTP-1.1 front end
//!   ([`ModelServer`]): `POST /score` LibSVM lines, `POST /similar`
//!   near-neighbor queries (when started with a
//!   [`similarity::LshIndex`](crate::similarity::LshIndex)),
//!   `GET /metrics`, `GET /healthz`; thread-per-connection with
//!   keep-alive.
//! - [`batcher`] — the micro-batching admission queue ([`Batcher`]):
//!   bounded (overload sheds with `503 Retry-After`, it never queues
//!   unboundedly), with scorer workers draining up to `batch_max`
//!   jobs per `batch_wait` window and fanning results back through
//!   per-job channels.  `/score` and `/similar` share the queue, so
//!   admission and deadline semantics are uniform across endpoints.
//! - [`registry`] — epoch-versioned hot reload ([`ModelRegistry`]): an
//!   `Arc<SavedModel>` swap driven by watching the model file, so the
//!   cache→train loop's retrained models go live without dropping a
//!   connection.
//! - [`router`] — the fleet tier ([`Router`]): consistent-hash shard
//!   placement over backend servers ([`shard_assignment`]),
//!   `/healthz`-driven per-backend health with retry/backoff, per-shard
//!   degradation and scatter-gather `/similar` merges with partial-result
//!   flagging.
//! - [`loadgen`] — the measurement side: a paced load generator for any
//!   POST path (`/score` against one server, `/similar` through the
//!   router for fleet-level QPS/p99), reporting achieved QPS, drift
//!   against the requested rate, shed-rate and exact latency percentiles
//!   (the `serve` scenario of `benches/bench_pipeline.rs`).
//!
//! Scoring reuses the [`FeatureEncoder`](crate::encode::encoder) seam end
//! to end: the server is scheme-agnostic because
//! [`SavedModel::margin`](crate::solver::SavedModel::margin) is, and each
//! scorer worker keeps one `EncodeScratch` per model epoch — the same
//! buffer-reuse discipline as the offline classify path.
//!
//! CLI: `bbit-mh serve --model m --port p [--similar-index idx]` for one
//! server, `bbit-mh route --backends h:p,h:p --shards N` for the fleet
//! (see `main.rs`).

pub mod batcher;
pub mod http;
pub mod loadgen;
pub mod registry;
pub mod router;
pub mod server;

pub use batcher::{Batcher, JobTask, ScoreJob, ScoreOutcome};
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use registry::{EpochModel, ModelRegistry};
pub use router::{shard_assignment, Router, RouterConfig, RouterMetrics};
pub use server::{ModelServer, ServeConfig, ServeMetrics};
