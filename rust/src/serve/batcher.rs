//! The micro-batching request queue: admission control at the front,
//! batch formation at the back.
//!
//! Connection handlers [`try_enqueue`](Batcher::try_enqueue) one
//! [`ScoreJob`] per document; scorer workers call
//! [`next_batch`](Batcher::next_batch), which blocks for the first job and
//! then keeps collecting until `batch_max` jobs are in hand or
//! `batch_wait` has elapsed — the classic latency/throughput dial
//! (batch_wait=0 degenerates to per-request scoring, large values to full
//! batches).  Margins flow back through each job's single-slot response
//! channel, so a worker never blocks on a slow or departed client.
//!
//! Admission control is structural, mirroring the pipeline's
//! admission-credit loop (`coordinator/pipeline.rs`): the queue is
//! hard-bounded at `cap`, and a full queue *rejects* (`try_enqueue`
//! returns the job back, the handler answers `503 Retry-After`) instead of
//! blocking — under overload the server sheds load in O(1) rather than
//! accumulating an unbounded backlog whose every entry would miss its
//! deadline anyway.

use std::collections::VecDeque;
use std::sync::mpsc::SyncSender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::trace::{self, TraceCtx};

/// What the scorer sends back for one document.
#[derive(Debug, Clone, PartialEq)]
pub enum ScoreOutcome {
    /// The margin, plus the epoch of the model that produced it (bumped on
    /// every hot reload — lets clients observe swaps).
    Margin { margin: f32, epoch: u64 },
    /// Top-K near neighbors for a `/similar` job, with the work the query
    /// did (bucket hits pre-dedup, rows re-ranked) for the histograms.
    Neighbors { hits: Vec<crate::similarity::Neighbor>, candidates: u64, reranked: u64 },
    /// A `/similar` doc-id lookup for a record this index does not hold
    /// (absent shard or never-indexed id) — the handler answers 404.
    NotFound,
    /// The job's deadline passed while it sat in the queue; it was never
    /// scored.
    Expired,
}

/// What the workers should do with one admitted job.  `/score` and
/// `/similar` share the queue, so admission control, micro-batching and
/// deadline shedding behave identically across both endpoints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobTask {
    /// Score `indices` against the resident model.
    Score,
    /// Hash `indices` and run a top-K near-neighbor query.
    SimilarRaw { top_k: usize },
    /// Top-K near-neighbor query for an already-indexed record.
    SimilarDoc { id: u64, top_k: usize },
}

/// One admitted request.
pub struct ScoreJob {
    /// What to do with the job.
    pub task: JobTask,
    /// Sorted, deduplicated feature indices of the raw document (empty for
    /// [`JobTask::SimilarDoc`] lookups).
    pub indices: Vec<u32>,
    /// When the job entered the queue (queue-wait accounting).
    pub enqueued: Instant,
    /// Absolute deadline; a worker drops the job unscored past this.
    pub deadline: Instant,
    /// Single-slot rendezvous back to the connection handler.  Capacity 1
    /// and exactly one send per job, so the worker never blocks here even
    /// if the handler has timed out and gone away (the send just fails).
    pub resp: SyncSender<ScoreOutcome>,
    /// Trace context of the request's root span (`serve.score` /
    /// `serve.similar`).  Workers parent their `serve.admission_wait` and
    /// `serve.kernel` spans on this, so one request stays one trace even
    /// though it crosses the handler/worker thread boundary.
    pub trace: TraceCtx,
}

struct QueueState {
    q: VecDeque<ScoreJob>,
    closed: bool,
}

/// Bounded micro-batching queue (see module docs).
pub struct Batcher {
    cap: usize,
    state: Mutex<QueueState>,
    notify: Condvar,
}

impl Batcher {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "admission queue capacity must be positive");
        Batcher {
            cap,
            state: Mutex::new(QueueState { q: VecDeque::new(), closed: false }),
            notify: Condvar::new(),
        }
    }

    /// Admit one job, or hand it back if the queue is full or the server
    /// is shutting down — the caller turns `Err` into `503 Retry-After`.
    /// Never blocks.
    pub fn try_enqueue(&self, job: ScoreJob) -> std::result::Result<(), ScoreJob> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.q.len() >= self.cap {
            return Err(job);
        }
        st.q.push_back(job);
        drop(st);
        self.notify.notify_one();
        Ok(())
    }

    /// Collect the next micro-batch into `out` (cleared first): block for
    /// the first job, then keep taking jobs until `max` are in hand or
    /// `wait` has elapsed since the first one.  Returns `false` when the
    /// batcher is closed and drained — the worker's signal to exit.
    pub fn next_batch(&self, max: usize, wait: Duration, out: &mut Vec<ScoreJob>) -> bool {
        out.clear();
        debug_assert!(max > 0);
        let mut st = self.state.lock().unwrap();
        // phase 1: block until a first job (or close)
        loop {
            if let Some(job) = st.q.pop_front() {
                out.push(job);
                break;
            }
            if st.closed {
                return false;
            }
            st = self.notify.wait(st).unwrap();
        }
        // batch assembly starts the moment the first job is in hand; the
        // span is a child of that job's request trace
        let assembly_start = if trace::enabled() { Some(Instant::now()) } else { None };
        // phase 2: fill up to `max` within the batching window
        let window_ends = Instant::now() + wait;
        while out.len() < max {
            if let Some(job) = st.q.pop_front() {
                out.push(job);
                continue;
            }
            if st.closed {
                break;
            }
            let now = Instant::now();
            if now >= window_ends {
                break;
            }
            let (guard, timeout) = self.notify.wait_timeout(st, window_ends - now).unwrap();
            st = guard;
            if timeout.timed_out() {
                // take whatever raced in with the timeout, then ship
                while out.len() < max {
                    match st.q.pop_front() {
                        Some(job) => out.push(job),
                        None => break,
                    }
                }
                break;
            }
        }
        drop(st);
        if let Some(start) = assembly_start {
            trace::emit_span(
                "serve.batch_assembly",
                out[0].trace,
                start,
                Instant::now(),
                &[("batch", out.len() as f64)],
            );
        }
        true
    }

    /// Jobs currently waiting (observability; racy by nature).
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    /// Stop admitting; wake every worker so they drain and exit.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.notify.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::sync::Arc;

    fn job(idx: u32) -> (ScoreJob, std::sync::mpsc::Receiver<ScoreOutcome>) {
        let (tx, rx) = sync_channel(1);
        let now = Instant::now();
        (
            ScoreJob {
                task: JobTask::Score,
                indices: vec![idx],
                enqueued: now,
                deadline: now + Duration::from_secs(5),
                resp: tx,
                trace: TraceCtx::default(),
            },
            rx,
        )
    }

    #[test]
    fn full_queue_sheds_instead_of_blocking() {
        let b = Batcher::new(2);
        let (j1, _r1) = job(1);
        let (j2, _r2) = job(2);
        let (j3, _r3) = job(3);
        assert!(b.try_enqueue(j1).is_ok());
        assert!(b.try_enqueue(j2).is_ok());
        // third must come straight back — the hard admission bound
        let back = b.try_enqueue(j3).unwrap_err();
        assert_eq!(back.indices, vec![3]);
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn batch_respects_max_and_preserves_fifo_order() {
        let b = Batcher::new(16);
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (j, r) = job(i);
            b.try_enqueue(j).unwrap();
            rxs.push(r);
        }
        let mut out = Vec::new();
        assert!(b.next_batch(3, Duration::ZERO, &mut out));
        assert_eq!(out.iter().map(|j| j.indices[0]).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(b.next_batch(3, Duration::ZERO, &mut out));
        assert_eq!(out.iter().map(|j| j.indices[0]).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn close_wakes_blocked_workers_and_drains() {
        let b = Arc::new(Batcher::new(4));
        let (j, _r) = job(9);
        b.try_enqueue(j).unwrap();
        let worker = {
            let b = b.clone();
            std::thread::spawn(move || {
                let mut out = Vec::new();
                let mut batches = 0;
                while b.next_batch(8, Duration::from_micros(50), &mut out) {
                    batches += out.len();
                }
                batches
            })
        };
        // give the worker a moment to take the queued job and block again
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert_eq!(worker.join().unwrap(), 1);
        // post-close admissions shed
        let (j, _r) = job(10);
        assert!(b.try_enqueue(j).is_err());
    }

    #[test]
    fn batching_window_collects_late_arrivals() {
        let b = Arc::new(Batcher::new(16));
        let producer = {
            let b = b.clone();
            std::thread::spawn(move || {
                let mut rxs = Vec::new();
                for i in 0..4 {
                    let (j, r) = job(i);
                    b.try_enqueue(j).unwrap();
                    rxs.push(r);
                    std::thread::sleep(Duration::from_millis(2));
                }
                rxs
            })
        };
        let mut out = Vec::new();
        assert!(b.next_batch(4, Duration::from_millis(500), &mut out));
        let _rxs = producer.join().unwrap();
        // first job unblocks the worker; the window should sweep up the
        // stragglers into one batch (all 4 — the window far exceeds the
        // 2ms production gaps)
        assert_eq!(out.len(), 4);
    }
}
