//! The micro-batched model server.
//!
//! Thread topology (all std, no async runtime — consistent with the
//! pipeline in `coordinator/pipeline.rs`):
//!
//! ```text
//!   accept ──spawn──▶ conn handler×N ──try_enqueue──▶ Batcher ──▶ scorer×W
//!                         ▲                  (bounded:     (micro-batch:
//!                         │                   503 on full)  batch_max /
//!                         └──────── margins via per-job ◀── batch_wait)
//!                                   single-slot channels
//!   watcher: polls the model file, swaps Arc<SavedModel>, bumps epoch
//! ```
//!
//! Routes:
//! - `POST /score` — body: LibSVM lines (label optional, ignored), one
//!   document per line; response: `<pred> <margin>` per document, margins
//!   printed with `f32`'s round-tripping `Display`, plus an
//!   `X-Model-Epoch` header.  `503 Retry-After: 1` when admission sheds,
//!   `504` when the per-request deadline expires, `400` on parse errors.
//! - `POST /similar` — only when started with a similarity index
//!   ([`ModelServer::start_with_index`] / `serve --similar-index`).  Body:
//!   one query — either `doc:<id>` for an already-indexed record or a
//!   LibSVM line hashed at query time; optional `X-Top-K` header (default
//!   10).  Response: `<id> <estimate>` per neighbor plus `X-Candidates` /
//!   `X-Reranked` work headers.  The job flows through the *same* batcher,
//!   so admission shedding (503) and deadline expiry (504) behave exactly
//!   like `/score`; `404` for unknown doc ids or when no index is loaded.
//! - `GET /metrics` — counter/histogram exposition ([`ServeMetrics`]).
//! - `GET /healthz` — liveness + current model epoch/spec (+ resident
//!   similarity shards when an index is attached — the router's health
//!   poller reads this).
//!
//! Admission control, batching and hot reload live in their own modules
//! ([`batcher`](crate::serve::batcher), [`registry`](crate::serve::registry));
//! this one owns the sockets, the HTTP routing and the thread lifecycle.
//! Connection handling is thread-per-connection: acceptable because the
//! load generator and real deployments both use keep-alive connection
//! pools (connections ≈ clients, not requests), and the *request* path is
//! guarded by the bounded queue regardless of connection count.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::prom::Exposition;
use crate::metrics::trace;
use crate::metrics::{Counter, Gauge, Histogram};
use crate::serve::batcher::{Batcher, JobTask, ScoreJob, ScoreOutcome};
use crate::serve::http;
use crate::serve::registry::ModelRegistry;
use crate::similarity::LshIndex;
use crate::{Error, Result};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind host (loopback by default; expose deliberately).
    pub host: String,
    /// Bind port; 0 asks the OS for an ephemeral port (tests, CI).
    pub port: u16,
    /// Scorer worker threads draining the batch queue.
    pub scorer_workers: usize,
    /// Largest micro-batch a scorer takes in one drain.
    pub batch_max: usize,
    /// How long a scorer waits for stragglers after the first job of a
    /// batch — the latency/throughput dial (0 = per-request scoring).
    pub batch_wait: Duration,
    /// Admission bound: queued-but-unscored documents beyond this are shed
    /// with `503 Retry-After`.
    pub queue_cap: usize,
    /// Per-request deadline; documents still queued past it are dropped
    /// unscored and the request answers `504`.
    pub deadline: Duration,
    /// Model-file poll interval for hot reload.
    pub reload_poll: Duration,
    /// Idle keep-alive connections are closed after this long.
    pub idle_timeout: Duration,
    /// Log any request slower than this (milliseconds, with its trace id)
    /// to stderr; `None` disables the slow-request log.
    pub slow_ms: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 0,
            scorer_workers: 2,
            batch_max: 64,
            batch_wait: Duration::from_micros(200),
            queue_cap: 1024,
            deadline: Duration::from_millis(50),
            reload_poll: Duration::from_millis(200),
            idle_timeout: Duration::from_secs(10),
            slow_ms: None,
        }
    }
}

/// Serving-path observability, built on [`crate::metrics`] primitives and
/// rendered at `/metrics` and in the shutdown report.
#[derive(Default)]
pub struct ServeMetrics {
    /// Documents received on the score path (pre-admission).
    pub docs_received: Counter,
    /// Documents scored by a worker.
    pub docs_scored: Counter,
    /// Documents rejected by admission control (each one a 503).
    pub docs_shed: Counter,
    /// Documents dropped unscored because their deadline passed in queue.
    pub docs_expired: Counter,
    /// HTTP requests handled (all routes).
    pub http_requests: Counter,
    /// Malformed HTTP requests / unparseable score bodies.
    pub http_errors: Counter,
    /// Successful model hot reloads.
    pub reloads: Counter,
    /// Failed reload attempts (file changed but would not load).
    pub reload_errors: Counter,
    /// `/similar` queries received (pre-admission).
    pub similar_received: Counter,
    /// `/similar` queries answered by a worker.
    pub similar_served: Counter,
    /// Scored micro-batch sizes.
    pub batch_size: Histogram,
    /// Per-document queue wait, microseconds.
    pub queue_wait_us: Histogram,
    /// Per-score-request wall latency inside the handler, microseconds.
    pub latency_us: Histogram,
    /// Bucket hits per `/similar` query, pre-dedup (candidate volume).
    pub similar_candidates: Histogram,
    /// Distinct rows re-ranked per `/similar` query (verify depth).
    pub similar_rerank_depth: Histogram,
    /// Largest bucket per band, observed once at index attach — the
    /// bucket-skew signal (a huge max against a small mean means one hot
    /// key dominates that band).
    pub similar_bucket_max: Histogram,
    /// Current model reload generation (mirrors the registry epoch).
    pub model_epoch: Gauge,
    /// Jobs sitting in the admission queue at the last scrape.
    pub queue_depth: Gauge,
    /// Similarity shards resident in this replica (0 without an index).
    pub similar_shards: Gauge,
    /// 1 while the server is draining (SIGTERM received, `/healthz`
    /// failing, in-flight work finishing), 0 otherwise.
    pub draining: Gauge,
}

impl ServeMetrics {
    /// Prometheus text exposition (also the shutdown report).  The
    /// liveness gauges are refreshed from the caller's current values so
    /// every scrape reflects the moment it was taken.
    pub fn render(&self, epoch: u64, queue_depth: usize) -> String {
        self.model_epoch.set(epoch);
        self.queue_depth.set(queue_depth as u64);
        let mut exp = Exposition::new();
        exp.gauge(
            "serve_model_epoch",
            "Reload generation of the resident model.",
            self.model_epoch.get(),
        )
        .gauge(
            "serve_queue_depth",
            "Jobs sitting in the admission queue right now.",
            self.queue_depth.get(),
        )
        .gauge(
            "serve_similar_shards",
            "Similarity shards resident in this replica.",
            self.similar_shards.get(),
        )
        .gauge(
            "serve_draining",
            "1 while the server is draining after SIGTERM, 0 otherwise.",
            self.draining.get(),
        )
        .counter(
            "serve_docs_received_total",
            "Documents received on the score path (pre-admission).",
            self.docs_received.get(),
        )
        .counter(
            "serve_docs_scored_total",
            "Documents scored by a worker.",
            self.docs_scored.get(),
        )
        .counter(
            "serve_docs_shed_total",
            "Documents rejected by admission control (each one a 503).",
            self.docs_shed.get(),
        )
        .counter(
            "serve_docs_expired_total",
            "Documents dropped unscored because their deadline passed in queue.",
            self.docs_expired.get(),
        )
        .counter(
            "serve_http_requests_total",
            "HTTP requests handled (all routes).",
            self.http_requests.get(),
        )
        .counter(
            "serve_http_errors_total",
            "Malformed HTTP requests and unparseable bodies.",
            self.http_errors.get(),
        )
        .counter(
            "serve_model_reloads_total",
            "Successful model hot reloads.",
            self.reloads.get(),
        )
        .counter(
            "serve_model_reload_errors_total",
            "Reload attempts that failed to load.",
            self.reload_errors.get(),
        )
        .counter(
            "serve_similar_received_total",
            "/similar queries received (pre-admission).",
            self.similar_received.get(),
        )
        .counter(
            "serve_similar_served_total",
            "/similar queries answered by a worker.",
            self.similar_served.get(),
        )
        .counter(
            "replay_index_fallback_total",
            "Pooled cache replays that degraded to sequential because the index footer was missing or corrupt.",
            crate::coordinator::replay::index_fallbacks(),
        )
        .histogram("serve_batch_size", "Documents per scored micro-batch.", &self.batch_size, 1.0)
        .histogram(
            "serve_queue_wait_seconds",
            "Per-document admission-queue wait.",
            &self.queue_wait_us,
            1e-6,
        )
        .histogram(
            "serve_request_latency_seconds",
            "Request wall latency inside the handler.",
            &self.latency_us,
            1e-6,
        )
        .histogram(
            "serve_similar_candidates",
            "Bucket hits per /similar query, pre-dedup.",
            &self.similar_candidates,
            1.0,
        )
        .histogram(
            "serve_similar_rerank_depth",
            "Distinct rows re-ranked per /similar query.",
            &self.similar_rerank_depth,
            1.0,
        )
        .histogram(
            "serve_similar_bucket_max",
            "Largest bucket per band, observed once at index attach.",
            &self.similar_bucket_max,
            1.0,
        );
        exp.finish()
    }
}

/// Everything the accept/handler/scorer/watcher threads share.
struct ServerCtx {
    cfg: ServeConfig,
    batcher: Batcher,
    registry: ModelRegistry,
    metrics: ServeMetrics,
    /// The similarity index behind `POST /similar`, when one was attached
    /// at startup.  Immutable once loaded (rebuild + restart to refresh).
    similar: Option<Arc<LshIndex>>,
    shutdown: AtomicBool,
    /// Set by [`ModelServer::begin_drain`]: `/healthz` answers 503 (load
    /// balancers stop routing here) while in-flight requests finish.
    draining: AtomicBool,
    /// Requests currently inside a handler (parsed but not yet answered).
    /// [`ModelServer::drain`] waits for this to reach zero.
    inflight: AtomicU64,
}

/// Decrements the in-flight gauge when a request handler finishes, even on
/// an early return or panic.
struct InflightGuard<'a>(&'a AtomicU64);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running server; dropping it without [`shutdown`](Self::shutdown)
/// leaves the threads serving until process exit.
pub struct ModelServer {
    ctx: Arc<ServerCtx>,
    addr: SocketAddr,
    threads: Vec<JoinHandle<()>>,
}

impl ModelServer {
    /// Load the model at `path`, bind, and start the accept / scorer /
    /// reload-watcher threads.
    pub fn start<P: AsRef<Path>>(model_path: P, cfg: ServeConfig) -> Result<Self> {
        Self::start_with_index(model_path, cfg, None)
    }

    /// [`start`](Self::start), plus a similarity index enabling
    /// `POST /similar` on this server.
    pub fn start_with_index<P: AsRef<Path>>(
        model_path: P,
        cfg: ServeConfig,
        similar: Option<Arc<LshIndex>>,
    ) -> Result<Self> {
        if cfg.scorer_workers == 0 || cfg.batch_max == 0 || cfg.queue_cap == 0 {
            return Err(Error::InvalidArg(
                "serve: workers, batch-max and queue must all be positive".into(),
            ));
        }
        let registry = ModelRegistry::open(model_path)?;
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let addr = listener.local_addr()?;
        let metrics = ServeMetrics::default();
        metrics.model_epoch.set(registry.epoch());
        if let Some(idx) = &similar {
            // one-shot skew snapshot: per-band max bucket sizes
            for band in idx.band_stats() {
                metrics.similar_bucket_max.observe(band.max_bucket as u64);
            }
            metrics.similar_shards.set(idx.shard_ids().len() as u64);
        }
        let ctx = Arc::new(ServerCtx {
            batcher: Batcher::new(cfg.queue_cap),
            registry,
            metrics,
            similar,
            shutdown: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            inflight: AtomicU64::new(0),
            cfg,
        });
        let mut threads = Vec::new();

        for _ in 0..ctx.cfg.scorer_workers {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || scorer_loop(&ctx)));
        }
        {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || watcher_loop(&ctx)));
        }
        {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || accept_loop(&ctx, listener)));
        }
        Ok(ModelServer { ctx, addr, threads })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.ctx.metrics
    }

    pub fn registry(&self) -> &ModelRegistry {
        &self.ctx.registry
    }

    /// Flip the server into draining mode: `/healthz` starts answering
    /// `503 draining` immediately so load balancers (and the fleet
    /// router's health poller) stop routing new work here, while score
    /// and similar traffic already inside a handler keeps being served.
    /// New `POST` work arriving after this point is refused with 503.
    pub fn begin_drain(&self) {
        self.ctx.draining.store(true, Ordering::SeqCst);
        self.ctx.metrics.draining.set(1);
    }

    /// Graceful SIGTERM sequence: [`begin_drain`](Self::begin_drain), wait
    /// (bounded by `bound`) for every in-flight request to finish, then
    /// [`shutdown`](Self::shutdown).  Requests still in flight when the
    /// bound expires are abandoned to the normal shutdown path, which
    /// still scores whatever is already in the admission queue.
    pub fn drain(self, bound: Duration) -> String {
        self.begin_drain();
        let give_up = Instant::now() + bound;
        while self.ctx.inflight.load(Ordering::SeqCst) > 0 && Instant::now() < give_up {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.shutdown()
    }

    /// Graceful stop: close admission (in-queue jobs still get scored),
    /// join the scorer/watcher/accept threads, and return the final
    /// metrics exposition as the shutdown report.
    pub fn shutdown(mut self) -> String {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        self.ctx.batcher.close();
        // wake the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.ctx
            .metrics
            .render(self.ctx.registry.epoch(), self.ctx.batcher.depth())
    }
}

fn accept_loop(ctx: &Arc<ServerCtx>, listener: TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if ctx.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let ctx2 = ctx.clone();
                // handlers are detached: they exit on connection close,
                // idle timeout, or the shutdown flag at the next request.
                // Builder::spawn (unlike thread::spawn) reports thread
                // exhaustion as an Err instead of panicking the accept
                // loop — drop the connection and keep serving
                let spawned = std::thread::Builder::new()
                    .name("bbmh-conn".into())
                    .spawn(move || handle_conn(&ctx2, stream));
                if spawned.is_err() {
                    ctx.metrics.http_errors.inc();
                }
            }
            Err(_) => {
                if ctx.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                // persistent accept failures (e.g. fd exhaustion) must
                // not busy-spin a core; back off briefly and retry
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn watcher_loop(ctx: &Arc<ServerCtx>) {
    while !ctx.shutdown.load(Ordering::Relaxed) {
        std::thread::sleep(ctx.cfg.reload_poll);
        if ctx.shutdown.load(Ordering::Relaxed) {
            break;
        }
        match ctx.registry.poll_reload() {
            Ok(true) => {
                ctx.metrics.reloads.inc();
                ctx.metrics.model_epoch.set(ctx.registry.epoch());
            }
            Ok(false) => {}
            // mid-write or corrupt file: keep the old model, retry next poll
            Err(_) => ctx.metrics.reload_errors.inc(),
        }
    }
}

fn scorer_loop(ctx: &Arc<ServerCtx>) {
    let mut batch: Vec<ScoreJob> = Vec::with_capacity(ctx.cfg.batch_max);
    // per-worker scratch, re-drawn only when a hot reload changes the model
    let mut scratch = None;
    // per-worker signature scratch for /similar (the index never reloads)
    let mut sim_scratch = None;
    while ctx.batcher.next_batch(ctx.cfg.batch_max, ctx.cfg.batch_wait, &mut batch) {
        // failpoint: `delay-ms` stretches the scoring window (the drain
        // tests widen their race window with it); an injected error drops
        // the whole batch unscored — every job answers Expired, exactly
        // what a handler sees from a worker that died mid-batch
        if crate::faults::trigger(crate::faults::site::SERVE_BATCH).is_some() {
            for job in batch.drain(..) {
                ctx.metrics.docs_expired.inc();
                let _ = job.resp.send(ScoreOutcome::Expired);
            }
            continue;
        }
        ctx.metrics.batch_size.observe(batch.len() as u64);
        let em = ctx.registry.current();
        let stale = match &scratch {
            Some((epoch, _)) => *epoch != em.epoch,
            None => true,
        };
        if stale {
            scratch = Some((em.epoch, em.model.scratch()));
        }
        let (_, sc) = scratch.as_mut().expect("scratch initialized above");
        for job in batch.drain(..) {
            let picked_up = Instant::now();
            ctx.metrics
                .queue_wait_us
                .observe(picked_up.saturating_duration_since(job.enqueued).as_micros() as u64);
            // queue-wait vs service-time, separated per request: the wait
            // span covers enqueue → pickup, the kernel span the scoring
            trace::emit_span("serve.admission_wait", job.trace, job.enqueued, picked_up, &[]);
            if picked_up > job.deadline {
                ctx.metrics.docs_expired.inc();
                let _ = job.resp.send(ScoreOutcome::Expired);
                continue;
            }
            match job.task {
                JobTask::Score => {
                    let _kernel = trace::Span::child("serve.kernel", job.trace);
                    let margin = em.model.margin(&job.indices, sc);
                    ctx.metrics.docs_scored.inc();
                    // a handler that timed out and left is fine — send
                    // just fails
                    let _ =
                        job.resp.send(ScoreOutcome::Margin { margin, epoch: em.epoch });
                }
                JobTask::SimilarRaw { top_k } | JobTask::SimilarDoc { top_k, .. } => {
                    let mut kernel = trace::Span::child("serve.kernel", job.trace);
                    // /similar is only routable with an index attached
                    let idx = ctx.similar.as_ref().expect("similar job without index");
                    let answered = match job.task {
                        JobTask::SimilarRaw { .. } => {
                            let ss = sim_scratch.get_or_insert_with(|| idx.scratch());
                            match idx.hash_query(&job.indices, &mut *ss) {
                                Ok(()) => idx.query(&ss.codes, top_k),
                                Err(e) => Err(e),
                            }
                        }
                        JobTask::SimilarDoc { id, .. } => idx.query_doc(id, top_k),
                        JobTask::Score => unreachable!(),
                    };
                    let outcome = match answered {
                        Ok((hits, stats)) => {
                            ctx.metrics.similar_served.inc();
                            ctx.metrics
                                .similar_candidates
                                .observe(stats.candidates as u64);
                            ctx.metrics
                                .similar_rerank_depth
                                .observe(stats.reranked as u64);
                            kernel.record("candidates", stats.candidates as f64);
                            kernel.record("reranked", stats.reranked as f64);
                            ScoreOutcome::Neighbors {
                                hits,
                                candidates: stats.candidates as u64,
                                reranked: stats.reranked as u64,
                            }
                        }
                        // absent shard / unknown id / bad width → 404
                        Err(_) => ScoreOutcome::NotFound,
                    };
                    let _ = job.resp.send(outcome);
                }
            }
        }
    }
}

fn handle_conn(ctx: &Arc<ServerCtx>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(ctx.cfg.idle_timeout));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // client closed between requests
            // an idle keep-alive connection hitting the read timeout is
            // normal pool behavior, not a malformed request: close
            // silently — no error counter, and no 400 that a client
            // racing the timeout could misread as its response
            Err(Error::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(_) => {
                // actual garbage on the wire — best-effort close notice
                ctx.metrics.http_errors.inc();
                let _ = http::write_response(
                    &mut stream,
                    400,
                    "Bad Request",
                    &[],
                    b"bad request\n",
                );
                break;
            }
        };
        ctx.metrics.http_requests.inc();
        ctx.inflight.fetch_add(1, Ordering::SeqCst);
        let _inflight = InflightGuard(&ctx.inflight);
        // the request's correlation id: taken from the client's
        // X-Trace-Id when it sent a valid one, minted here otherwise —
        // either way it is echoed on every response this server writes
        let trace_id =
            req.trace_id().and_then(trace::parse_id).unwrap_or_else(trace::gen_id);
        let tid = (http::TRACE_HEADER, trace::format_id(trace_id));
        let draining = ctx.draining.load(Ordering::SeqCst);
        let keep =
            req.keep_alive() && !ctx.shutdown.load(Ordering::Relaxed) && !draining;
        let io_ok = match (req.method.as_str(), req.path.as_str()) {
            // work arriving *after* the drain began is refused; requests
            // already inside a handler when SIGTERM landed complete
            ("POST", "/score") | ("POST", "/similar") if draining => http::write_response(
                &mut stream,
                503,
                "Service Unavailable",
                &[("Retry-After", "1".to_string()), tid],
                b"draining\n",
            )
            .is_ok(),
            ("POST", "/score") => handle_score(ctx, &req.body, &mut stream, trace_id),
            ("POST", "/similar") => handle_similar(ctx, &req, &mut stream, trace_id),
            ("GET", "/metrics") => {
                let body = ctx
                    .metrics
                    .render(ctx.registry.epoch(), ctx.batcher.depth());
                http::write_response(&mut stream, 200, "OK", &[tid], body.as_bytes()).is_ok()
            }
            // the drain sequence fails health *first*: pollers see the
            // 503 and stop routing before any capacity disappears
            ("GET", "/healthz") if draining => http::write_response(
                &mut stream,
                503,
                "Service Unavailable",
                &[tid],
                b"draining\n",
            )
            .is_ok(),
            ("GET", "/healthz") => {
                let em = ctx.registry.current();
                let mut body = format!(
                    "ok epoch={} scheme={} dim={}",
                    em.epoch,
                    em.model.spec.scheme(),
                    em.model.model.w.len()
                );
                if let Some(idx) = &ctx.similar {
                    // "similar_shards=0,2/4": resident shard ids / total —
                    // the router's health poller parses this
                    let ids: Vec<String> =
                        idx.shard_ids().iter().map(|s| s.to_string()).collect();
                    body.push_str(&format!(
                        " similar_shards={}/{}",
                        ids.join(","),
                        idx.num_shards()
                    ));
                }
                body.push('\n');
                http::write_response(&mut stream, 200, "OK", &[tid], body.as_bytes()).is_ok()
            }
            _ => http::write_response(&mut stream, 404, "Not Found", &[tid], b"not found\n")
                .is_ok(),
        };
        if !io_ok || !keep {
            break;
        }
    }
}

/// Parse one request-body line into sorted/deduped feature indices.
/// `Ok(None)` for blank/comment lines; the label token (any first token
/// without a `:`) is accepted and ignored so both raw `idx:val` streams
/// and full LibSVM lines score as-is.
fn parse_doc_line(line: &str) -> std::result::Result<Option<Vec<u32>>, String> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut indices = Vec::new();
    for (pos, tok) in line.split_ascii_whitespace().enumerate() {
        match tok.split_once(':') {
            Some((idx, _)) => {
                indices.push(idx.parse::<u32>().map_err(|_| {
                    format!("bad feature index {idx:?} in {tok:?}")
                })?);
            }
            None if pos == 0 => {} // label token — scoring ignores it
            None => return Err(format!("bad feature token {tok:?}")),
        }
    }
    if indices.is_empty() {
        return Err("empty document (no features)".to_string());
    }
    indices.sort_unstable();
    indices.dedup();
    Ok(Some(indices))
}

/// `--slow-ms`: one stderr line per request slower than the threshold,
/// keyed by trace id so the JSONL span log (when enabled) carries the
/// breakdown the summary line cannot.
fn slow_log(slow_ms: Option<u64>, path: &str, trace_id: u64, status: u16, t0: Instant) {
    let Some(ms) = slow_ms else { return };
    let elapsed = t0.elapsed();
    if elapsed.as_millis() as u64 >= ms {
        eprintln!(
            "slow-request path={path} status={status} dur_ms={} trace={}",
            elapsed.as_millis(),
            trace::format_id(trace_id)
        );
    }
}

/// The score route: admit every body line, drain the margins, answer.
/// Returns whether the response was written (socket still healthy).
fn handle_score(ctx: &Arc<ServerCtx>, body: &[u8], stream: &mut TcpStream, trace_id: u64) -> bool {
    let t0 = Instant::now();
    let mut root = trace::Span::root("serve.score", trace_id);
    let rctx = root.ctx();
    let tid = (http::TRACE_HEADER, trace::format_id(trace_id));
    let Ok(text) = std::str::from_utf8(body) else {
        ctx.metrics.http_errors.inc();
        return http::write_response(stream, 400, "Bad Request", &[tid], b"body is not utf-8\n")
            .is_ok();
    };
    let deadline = Instant::now() + ctx.cfg.deadline;
    let mut pending = Vec::new();
    let mut shed = false;
    let mut bad: Option<String> = None;
    for line in text.lines() {
        match parse_doc_line(line) {
            Ok(None) => continue,
            Ok(Some(indices)) => {
                ctx.metrics.docs_received.inc();
                let (tx, rx) = sync_channel(1);
                let job = ScoreJob {
                    task: JobTask::Score,
                    indices,
                    enqueued: Instant::now(),
                    deadline,
                    resp: tx,
                    trace: rctx,
                };
                match ctx.batcher.try_enqueue(job) {
                    Ok(()) => pending.push(rx),
                    Err(_) => {
                        ctx.metrics.docs_shed.inc();
                        shed = true;
                        break;
                    }
                }
            }
            Err(msg) => {
                bad = Some(msg);
                break;
            }
        }
    }
    // drain everything already admitted, even when the request as a whole
    // fails — the jobs are in flight and the workers will answer them
    let grace = ctx.cfg.batch_wait * 2 + Duration::from_millis(100);
    let mut lines = String::new();
    let mut max_epoch = 0u64;
    let mut expired = false;
    let admitted = pending.len();
    for rx in pending {
        let budget = deadline.saturating_duration_since(Instant::now()) + grace;
        match rx.recv_timeout(budget) {
            Ok(ScoreOutcome::Margin { margin, epoch }) => {
                max_epoch = max_epoch.max(epoch);
                let pred: i8 = if margin >= 0.0 { 1 } else { -1 };
                // Display of f32 round-trips exactly — clients can compare
                // margins bit-for-bit against a local SavedModel::margin
                lines.push_str(&format!("{pred} {margin}\n"));
            }
            // Expired from the worker, or the worker never got to it
            // within our budget (it will count the doc itself either way)
            Ok(ScoreOutcome::Expired) | Err(_) => expired = true,
        }
    }
    ctx.metrics.latency_us.observe(t0.elapsed().as_micros() as u64);
    let (status, reason, mut headers, body): (u16, &str, Vec<(&str, String)>, Vec<u8>) =
        if let Some(msg) = bad {
            ctx.metrics.http_errors.inc();
            (400, "Bad Request", Vec::new(), format!("bad document: {msg}\n").into_bytes())
        } else if shed {
            (
                503,
                "Service Unavailable",
                vec![("Retry-After", "1".to_string())],
                b"shed: admission queue full\n".to_vec(),
            )
        } else if expired {
            (504, "Gateway Timeout", Vec::new(), b"deadline expired\n".to_vec())
        } else {
            (200, "OK", vec![("X-Model-Epoch", max_epoch.to_string())], lines.into_bytes())
        };
    headers.push(tid);
    root.record("docs", admitted as f64);
    root.record("status", status as f64);
    slow_log(ctx.cfg.slow_ms, "/score", trace_id, status, t0);
    http::write_response(stream, status, reason, &headers, &body).is_ok()
}

/// The `/similar` route: one query per request (first non-blank body
/// line), admitted through the same batcher as `/score` so overload and
/// deadline semantics are identical across endpoints.
fn handle_similar(
    ctx: &Arc<ServerCtx>,
    req: &http::Request,
    stream: &mut TcpStream,
    trace_id: u64,
) -> bool {
    let t0 = Instant::now();
    let mut root = trace::Span::root("serve.similar", trace_id);
    let rctx = root.ctx();
    let tid = || (http::TRACE_HEADER, trace::format_id(trace_id));
    if ctx.similar.is_none() {
        return http::write_response(
            stream,
            404,
            "Not Found",
            &[tid()],
            b"no similarity index loaded (serve --similar-index)\n",
        )
        .is_ok();
    }
    let Ok(text) = std::str::from_utf8(&req.body) else {
        ctx.metrics.http_errors.inc();
        return http::write_response(stream, 400, "Bad Request", &[tid()], b"body is not utf-8\n")
            .is_ok();
    };
    let top_k = match req.header("x-top-k") {
        None => 10,
        Some(v) => match v.trim().parse::<usize>() {
            Ok(k) => k.clamp(1, 1000),
            Err(_) => {
                ctx.metrics.http_errors.inc();
                let body = format!("bad X-Top-K header {v:?}\n");
                return http::write_response(stream, 400, "Bad Request", &[tid()], body.as_bytes())
                    .is_ok();
            }
        },
    };
    // first meaningful line is the query; either doc:<id> or a LibSVM line
    let line = text.lines().map(str::trim).find(|l| !l.is_empty() && !l.starts_with('#'));
    let parsed = match line {
        None => Err("empty query body".to_string()),
        Some(l) => match l.strip_prefix("doc:") {
            Some(id) => id
                .trim()
                .parse::<u64>()
                .map(|id| (JobTask::SimilarDoc { id, top_k }, Vec::new()))
                .map_err(|_| format!("bad doc id {id:?}")),
            None => match parse_doc_line(l) {
                Ok(Some(indices)) => Ok((JobTask::SimilarRaw { top_k }, indices)),
                Ok(None) => Err("empty query body".to_string()),
                Err(msg) => Err(msg),
            },
        },
    };
    let (task, indices) = match parsed {
        Ok(x) => x,
        Err(msg) => {
            ctx.metrics.http_errors.inc();
            let body = format!("bad query: {msg}\n");
            return http::write_response(stream, 400, "Bad Request", &[tid()], body.as_bytes())
                .is_ok();
        }
    };
    ctx.metrics.similar_received.inc();
    let deadline = Instant::now() + ctx.cfg.deadline;
    let (tx, rx) = sync_channel(1);
    let job =
        ScoreJob { task, indices, enqueued: Instant::now(), deadline, resp: tx, trace: rctx };
    if ctx.batcher.try_enqueue(job).is_err() {
        ctx.metrics.docs_shed.inc();
        return http::write_response(
            stream,
            503,
            "Service Unavailable",
            &[("Retry-After", "1".to_string()), tid()],
            b"shed: admission queue full\n",
        )
        .is_ok();
    }
    let grace = ctx.cfg.batch_wait * 2 + Duration::from_millis(100);
    let budget = deadline.saturating_duration_since(Instant::now()) + grace;
    let outcome = rx.recv_timeout(budget);
    ctx.metrics.latency_us.observe(t0.elapsed().as_micros() as u64);
    let (status, reason, mut headers, body): (u16, &str, Vec<(&str, String)>, Vec<u8>) =
        match outcome {
            Ok(ScoreOutcome::Neighbors { hits, candidates, reranked }) => {
                let mut lines = String::new();
                for h in &hits {
                    // f64 Display round-trips: clients can compare estimates
                    // bit-for-bit against the offline near_duplicates path
                    lines.push_str(&format!("{} {}\n", h.id, h.estimate));
                }
                root.record("candidates", candidates as f64);
                root.record("reranked", reranked as f64);
                (
                    200,
                    "OK",
                    vec![
                        ("X-Candidates", candidates.to_string()),
                        ("X-Reranked", reranked.to_string()),
                    ],
                    lines.into_bytes(),
                )
            }
            Ok(ScoreOutcome::NotFound) => (
                404,
                "Not Found",
                Vec::new(),
                b"doc not in this index's resident shards\n".to_vec(),
            ),
            // Expired from the worker, or the worker never got to it within
            // the budget (the worker counts the expiry itself either way)
            Ok(ScoreOutcome::Expired) | Err(_) => {
                (504, "Gateway Timeout", Vec::new(), b"deadline expired\n".to_vec())
            }
            Ok(ScoreOutcome::Margin { .. }) => unreachable!("similar job answered with a margin"),
        };
    headers.push(tid());
    root.record("status", status as f64);
    slow_log(ctx.cfg.slow_ms, "/similar", trace_id, status, t0);
    http::write_response(stream, status, reason, &headers, &body).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_line_parsing() {
        assert_eq!(parse_doc_line("").unwrap(), None);
        assert_eq!(parse_doc_line("# comment").unwrap(), None);
        assert_eq!(parse_doc_line("+1 5:1 3:1 5:1").unwrap(), Some(vec![3, 5]));
        // labelless documents score too
        assert_eq!(parse_doc_line("7:1 2:0.5").unwrap(), Some(vec![2, 7]));
        // a bare non-label token is malformed, as is a bad index
        assert!(parse_doc_line("+1 5:1 bogus").is_err());
        assert!(parse_doc_line("+1 notanum:1").is_err());
        assert!(parse_doc_line("+1").is_err(), "empty documents are rejected");
    }

    #[test]
    fn metrics_render_is_valid_prometheus_and_contains_every_series() {
        let m = ServeMetrics::default();
        m.docs_received.add(3);
        m.batch_size.observe(4);
        m.queue_wait_us.observe(150);
        let text = m.render(2, 1);
        crate::metrics::prom::validate(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
        for needle in [
            "# TYPE serve_model_epoch gauge",
            "serve_model_epoch 2",
            "serve_queue_depth 1",
            "serve_similar_shards 0",
            "serve_draining 0",
            // value elided: the fallback counter is process-global and
            // sibling tests may bump it concurrently
            "# TYPE replay_index_fallback_total counter",
            "# TYPE serve_docs_received_total counter",
            "serve_docs_received_total 3",
            "serve_docs_shed_total 0",
            "# TYPE serve_batch_size histogram",
            "serve_batch_size_bucket{le=\"+Inf\"} 1",
            "serve_batch_size_sum 4",
            "serve_batch_size_count 1",
            "serve_queue_wait_seconds_sum 0.00015",
            "serve_request_latency_seconds_count 0",
            "serve_model_reloads_total 0",
            "serve_similar_received_total 0",
            "serve_similar_served_total 0",
            "serve_similar_candidates_count 0",
            "serve_similar_rerank_depth_count 0",
            "serve_similar_bucket_max_count 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn start_rejects_degenerate_configs() {
        let cfg = ServeConfig { scorer_workers: 0, ..Default::default() };
        assert!(ModelServer::start("/nonexistent.bbmh", cfg).is_err());
        // a missing model file is a typed error, not a panic
        assert!(ModelServer::start("/nonexistent.bbmh", ServeConfig::default()).is_err());
    }
}
