//! The micro-batched model server.
//!
//! Thread topology (all std, no async runtime — consistent with the
//! pipeline in `coordinator/pipeline.rs`):
//!
//! ```text
//!   accept ──spawn──▶ conn handler×N ──try_enqueue──▶ Batcher ──▶ scorer×W
//!                         ▲                  (bounded:     (micro-batch:
//!                         │                   503 on full)  batch_max /
//!                         └──────── margins via per-job ◀── batch_wait)
//!                                   single-slot channels
//!   watcher: polls the model file, swaps Arc<SavedModel>, bumps epoch
//! ```
//!
//! Routes:
//! - `POST /score` — body: LibSVM lines (label optional, ignored), one
//!   document per line; response: `<pred> <margin>` per document, margins
//!   printed with `f32`'s round-tripping `Display`, plus an
//!   `X-Model-Epoch` header.  `503 Retry-After: 1` when admission sheds,
//!   `504` when the per-request deadline expires, `400` on parse errors.
//! - `GET /metrics` — counter/histogram exposition ([`ServeMetrics`]).
//! - `GET /healthz` — liveness + current model epoch/spec.
//!
//! Admission control, batching and hot reload live in their own modules
//! ([`batcher`](crate::serve::batcher), [`registry`](crate::serve::registry));
//! this one owns the sockets, the HTTP routing and the thread lifecycle.
//! Connection handling is thread-per-connection: acceptable because the
//! load generator and real deployments both use keep-alive connection
//! pools (connections ≈ clients, not requests), and the *request* path is
//! guarded by the bounded queue regardless of connection count.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::{Counter, Histogram};
use crate::serve::batcher::{Batcher, ScoreJob, ScoreOutcome};
use crate::serve::http;
use crate::serve::registry::ModelRegistry;
use crate::{Error, Result};

/// Server tuning knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind host (loopback by default; expose deliberately).
    pub host: String,
    /// Bind port; 0 asks the OS for an ephemeral port (tests, CI).
    pub port: u16,
    /// Scorer worker threads draining the batch queue.
    pub scorer_workers: usize,
    /// Largest micro-batch a scorer takes in one drain.
    pub batch_max: usize,
    /// How long a scorer waits for stragglers after the first job of a
    /// batch — the latency/throughput dial (0 = per-request scoring).
    pub batch_wait: Duration,
    /// Admission bound: queued-but-unscored documents beyond this are shed
    /// with `503 Retry-After`.
    pub queue_cap: usize,
    /// Per-request deadline; documents still queued past it are dropped
    /// unscored and the request answers `504`.
    pub deadline: Duration,
    /// Model-file poll interval for hot reload.
    pub reload_poll: Duration,
    /// Idle keep-alive connections are closed after this long.
    pub idle_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 0,
            scorer_workers: 2,
            batch_max: 64,
            batch_wait: Duration::from_micros(200),
            queue_cap: 1024,
            deadline: Duration::from_millis(50),
            reload_poll: Duration::from_millis(200),
            idle_timeout: Duration::from_secs(10),
        }
    }
}

/// Serving-path observability, built on [`crate::metrics`] primitives and
/// rendered at `/metrics` and in the shutdown report.
#[derive(Default)]
pub struct ServeMetrics {
    /// Documents received on the score path (pre-admission).
    pub docs_received: Counter,
    /// Documents scored by a worker.
    pub docs_scored: Counter,
    /// Documents rejected by admission control (each one a 503).
    pub docs_shed: Counter,
    /// Documents dropped unscored because their deadline passed in queue.
    pub docs_expired: Counter,
    /// HTTP requests handled (all routes).
    pub http_requests: Counter,
    /// Malformed HTTP requests / unparseable score bodies.
    pub http_errors: Counter,
    /// Successful model hot reloads.
    pub reloads: Counter,
    /// Failed reload attempts (file changed but would not load).
    pub reload_errors: Counter,
    /// Scored micro-batch sizes.
    pub batch_size: Histogram,
    /// Per-document queue wait, microseconds.
    pub queue_wait_us: Histogram,
    /// Per-score-request wall latency inside the handler, microseconds.
    pub latency_us: Histogram,
}

impl ServeMetrics {
    /// Text exposition (also the shutdown report).
    pub fn render(&self, epoch: u64, queue_depth: usize) -> String {
        let mut s = String::new();
        s.push_str(&format!("serve_model_epoch {epoch}\n"));
        s.push_str(&format!("serve_queue_depth {queue_depth}\n"));
        for (name, c) in [
            ("serve_docs_received_total", &self.docs_received),
            ("serve_docs_scored_total", &self.docs_scored),
            ("serve_docs_shed_total", &self.docs_shed),
            ("serve_docs_expired_total", &self.docs_expired),
            ("serve_http_requests_total", &self.http_requests),
            ("serve_http_errors_total", &self.http_errors),
            ("serve_model_reloads_total", &self.reloads),
            ("serve_model_reload_errors_total", &self.reload_errors),
        ] {
            s.push_str(&format!("{name} {}\n", c.get()));
        }
        for (name, h) in [
            ("serve_batch_size", &self.batch_size),
            ("serve_queue_wait_us", &self.queue_wait_us),
            ("serve_request_latency_us", &self.latency_us),
        ] {
            s.push_str(&format!(
                "{name}_count {}\n{name}_p50 {}\n{name}_p99 {}\n",
                h.count(),
                h.quantile(0.5),
                h.quantile(0.99),
            ));
        }
        s
    }
}

/// Everything the accept/handler/scorer/watcher threads share.
struct ServerCtx {
    cfg: ServeConfig,
    batcher: Batcher,
    registry: ModelRegistry,
    metrics: ServeMetrics,
    shutdown: AtomicBool,
}

/// A running server; dropping it without [`shutdown`](Self::shutdown)
/// leaves the threads serving until process exit.
pub struct ModelServer {
    ctx: Arc<ServerCtx>,
    addr: SocketAddr,
    threads: Vec<JoinHandle<()>>,
}

impl ModelServer {
    /// Load the model at `path`, bind, and start the accept / scorer /
    /// reload-watcher threads.
    pub fn start<P: AsRef<Path>>(model_path: P, cfg: ServeConfig) -> Result<Self> {
        if cfg.scorer_workers == 0 || cfg.batch_max == 0 || cfg.queue_cap == 0 {
            return Err(Error::InvalidArg(
                "serve: workers, batch-max and queue must all be positive".into(),
            ));
        }
        let registry = ModelRegistry::open(model_path)?;
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let addr = listener.local_addr()?;
        let ctx = Arc::new(ServerCtx {
            batcher: Batcher::new(cfg.queue_cap),
            registry,
            metrics: ServeMetrics::default(),
            shutdown: AtomicBool::new(false),
            cfg,
        });
        let mut threads = Vec::new();

        for _ in 0..ctx.cfg.scorer_workers {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || scorer_loop(&ctx)));
        }
        {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || watcher_loop(&ctx)));
        }
        {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || accept_loop(&ctx, listener)));
        }
        Ok(ModelServer { ctx, addr, threads })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.ctx.metrics
    }

    pub fn registry(&self) -> &ModelRegistry {
        &self.ctx.registry
    }

    /// Graceful stop: close admission (in-queue jobs still get scored),
    /// join the scorer/watcher/accept threads, and return the final
    /// metrics exposition as the shutdown report.
    pub fn shutdown(mut self) -> String {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        self.ctx.batcher.close();
        // wake the accept loop with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.ctx
            .metrics
            .render(self.ctx.registry.epoch(), self.ctx.batcher.depth())
    }
}

fn accept_loop(ctx: &Arc<ServerCtx>, listener: TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if ctx.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let ctx2 = ctx.clone();
                // handlers are detached: they exit on connection close,
                // idle timeout, or the shutdown flag at the next request.
                // Builder::spawn (unlike thread::spawn) reports thread
                // exhaustion as an Err instead of panicking the accept
                // loop — drop the connection and keep serving
                let spawned = std::thread::Builder::new()
                    .name("bbmh-conn".into())
                    .spawn(move || handle_conn(&ctx2, stream));
                if spawned.is_err() {
                    ctx.metrics.http_errors.inc();
                }
            }
            Err(_) => {
                if ctx.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                // persistent accept failures (e.g. fd exhaustion) must
                // not busy-spin a core; back off briefly and retry
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn watcher_loop(ctx: &Arc<ServerCtx>) {
    while !ctx.shutdown.load(Ordering::Relaxed) {
        std::thread::sleep(ctx.cfg.reload_poll);
        if ctx.shutdown.load(Ordering::Relaxed) {
            break;
        }
        match ctx.registry.poll_reload() {
            Ok(true) => ctx.metrics.reloads.inc(),
            Ok(false) => {}
            // mid-write or corrupt file: keep the old model, retry next poll
            Err(_) => ctx.metrics.reload_errors.inc(),
        }
    }
}

fn scorer_loop(ctx: &Arc<ServerCtx>) {
    let mut batch: Vec<ScoreJob> = Vec::with_capacity(ctx.cfg.batch_max);
    // per-worker scratch, re-drawn only when a hot reload changes the model
    let mut scratch = None;
    while ctx.batcher.next_batch(ctx.cfg.batch_max, ctx.cfg.batch_wait, &mut batch) {
        ctx.metrics.batch_size.observe(batch.len() as u64);
        let em = ctx.registry.current();
        let stale = match &scratch {
            Some((epoch, _)) => *epoch != em.epoch,
            None => true,
        };
        if stale {
            scratch = Some((em.epoch, em.model.scratch()));
        }
        let (_, sc) = scratch.as_mut().expect("scratch initialized above");
        for job in batch.drain(..) {
            ctx.metrics
                .queue_wait_us
                .observe(job.enqueued.elapsed().as_micros() as u64);
            if Instant::now() > job.deadline {
                ctx.metrics.docs_expired.inc();
                let _ = job.resp.send(ScoreOutcome::Expired);
                continue;
            }
            let margin = em.model.margin(&job.indices, sc);
            ctx.metrics.docs_scored.inc();
            // a handler that timed out and left is fine — send just fails
            let _ = job.resp.send(ScoreOutcome::Margin { margin, epoch: em.epoch });
        }
    }
}

fn handle_conn(ctx: &Arc<ServerCtx>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(ctx.cfg.idle_timeout));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break, // client closed between requests
            // an idle keep-alive connection hitting the read timeout is
            // normal pool behavior, not a malformed request: close
            // silently — no error counter, and no 400 that a client
            // racing the timeout could misread as its response
            Err(Error::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(_) => {
                // actual garbage on the wire — best-effort close notice
                ctx.metrics.http_errors.inc();
                let _ = http::write_response(
                    &mut stream,
                    400,
                    "Bad Request",
                    &[],
                    b"bad request\n",
                );
                break;
            }
        };
        ctx.metrics.http_requests.inc();
        let keep = req.keep_alive() && !ctx.shutdown.load(Ordering::Relaxed);
        let io_ok = match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/score") => handle_score(ctx, &req.body, &mut stream),
            ("GET", "/metrics") => {
                let body = ctx
                    .metrics
                    .render(ctx.registry.epoch(), ctx.batcher.depth());
                http::write_response(&mut stream, 200, "OK", &[], body.as_bytes()).is_ok()
            }
            ("GET", "/healthz") => {
                let em = ctx.registry.current();
                let body = format!(
                    "ok epoch={} scheme={} dim={}\n",
                    em.epoch,
                    em.model.spec.scheme(),
                    em.model.model.w.len()
                );
                http::write_response(&mut stream, 200, "OK", &[], body.as_bytes()).is_ok()
            }
            _ => http::write_response(&mut stream, 404, "Not Found", &[], b"not found\n")
                .is_ok(),
        };
        if !io_ok || !keep {
            break;
        }
    }
}

/// Parse one request-body line into sorted/deduped feature indices.
/// `Ok(None)` for blank/comment lines; the label token (any first token
/// without a `:`) is accepted and ignored so both raw `idx:val` streams
/// and full LibSVM lines score as-is.
fn parse_doc_line(line: &str) -> std::result::Result<Option<Vec<u32>>, String> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(None);
    }
    let mut indices = Vec::new();
    for (pos, tok) in line.split_ascii_whitespace().enumerate() {
        match tok.split_once(':') {
            Some((idx, _)) => {
                indices.push(idx.parse::<u32>().map_err(|_| {
                    format!("bad feature index {idx:?} in {tok:?}")
                })?);
            }
            None if pos == 0 => {} // label token — scoring ignores it
            None => return Err(format!("bad feature token {tok:?}")),
        }
    }
    if indices.is_empty() {
        return Err("empty document (no features)".to_string());
    }
    indices.sort_unstable();
    indices.dedup();
    Ok(Some(indices))
}

/// The score route: admit every body line, drain the margins, answer.
/// Returns whether the response was written (socket still healthy).
fn handle_score(ctx: &Arc<ServerCtx>, body: &[u8], stream: &mut TcpStream) -> bool {
    let t0 = Instant::now();
    let Ok(text) = std::str::from_utf8(body) else {
        ctx.metrics.http_errors.inc();
        return http::write_response(stream, 400, "Bad Request", &[], b"body is not utf-8\n")
            .is_ok();
    };
    let deadline = Instant::now() + ctx.cfg.deadline;
    let mut pending = Vec::new();
    let mut shed = false;
    let mut bad: Option<String> = None;
    for line in text.lines() {
        match parse_doc_line(line) {
            Ok(None) => continue,
            Ok(Some(indices)) => {
                ctx.metrics.docs_received.inc();
                let (tx, rx) = sync_channel(1);
                let job = ScoreJob { indices, enqueued: Instant::now(), deadline, resp: tx };
                match ctx.batcher.try_enqueue(job) {
                    Ok(()) => pending.push(rx),
                    Err(_) => {
                        ctx.metrics.docs_shed.inc();
                        shed = true;
                        break;
                    }
                }
            }
            Err(msg) => {
                bad = Some(msg);
                break;
            }
        }
    }
    // drain everything already admitted, even when the request as a whole
    // fails — the jobs are in flight and the workers will answer them
    let grace = ctx.cfg.batch_wait * 2 + Duration::from_millis(100);
    let mut lines = String::new();
    let mut max_epoch = 0u64;
    let mut expired = false;
    for rx in pending {
        let budget = deadline.saturating_duration_since(Instant::now()) + grace;
        match rx.recv_timeout(budget) {
            Ok(ScoreOutcome::Margin { margin, epoch }) => {
                max_epoch = max_epoch.max(epoch);
                let pred: i8 = if margin >= 0.0 { 1 } else { -1 };
                // Display of f32 round-trips exactly — clients can compare
                // margins bit-for-bit against a local SavedModel::margin
                lines.push_str(&format!("{pred} {margin}\n"));
            }
            // Expired from the worker, or the worker never got to it
            // within our budget (it will count the doc itself either way)
            Ok(ScoreOutcome::Expired) | Err(_) => expired = true,
        }
    }
    ctx.metrics.latency_us.observe(t0.elapsed().as_micros() as u64);
    if let Some(msg) = bad {
        ctx.metrics.http_errors.inc();
        let body = format!("bad document: {msg}\n");
        return http::write_response(stream, 400, "Bad Request", &[], body.as_bytes()).is_ok();
    }
    if shed {
        return http::write_response(
            stream,
            503,
            "Service Unavailable",
            &[("Retry-After", "1".to_string())],
            b"shed: admission queue full\n",
        )
        .is_ok();
    }
    if expired {
        return http::write_response(stream, 504, "Gateway Timeout", &[], b"deadline expired\n")
            .is_ok();
    }
    http::write_response(
        stream,
        200,
        "OK",
        &[("X-Model-Epoch", max_epoch.to_string())],
        lines.as_bytes(),
    )
    .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_line_parsing() {
        assert_eq!(parse_doc_line("").unwrap(), None);
        assert_eq!(parse_doc_line("# comment").unwrap(), None);
        assert_eq!(parse_doc_line("+1 5:1 3:1 5:1").unwrap(), Some(vec![3, 5]));
        // labelless documents score too
        assert_eq!(parse_doc_line("7:1 2:0.5").unwrap(), Some(vec![2, 7]));
        // a bare non-label token is malformed, as is a bad index
        assert!(parse_doc_line("+1 5:1 bogus").is_err());
        assert!(parse_doc_line("+1 notanum:1").is_err());
        assert!(parse_doc_line("+1").is_err(), "empty documents are rejected");
    }

    #[test]
    fn metrics_render_contains_every_series() {
        let m = ServeMetrics::default();
        m.docs_received.add(3);
        m.batch_size.observe(4);
        let text = m.render(2, 1);
        for needle in [
            "serve_model_epoch 2",
            "serve_queue_depth 1",
            "serve_docs_received_total 3",
            "serve_docs_shed_total 0",
            "serve_batch_size_count 1",
            "serve_request_latency_us_p99",
            "serve_model_reloads_total 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn start_rejects_degenerate_configs() {
        let cfg = ServeConfig { scorer_workers: 0, ..Default::default() };
        assert!(ModelServer::start("/nonexistent.bbmh", cfg).is_err());
        // a missing model file is a typed error, not a panic
        assert!(ModelServer::start("/nonexistent.bbmh", ServeConfig::default()).is_err());
    }
}
