//! Epoch-versioned model registry with file-watch hot reload.
//!
//! The serving story needs the cache→train loop (PR 1) to feed production
//! without restarts: retrain writes a new model file, the server picks it
//! up, in-flight requests finish on the model they started with.  The
//! mechanism is an `Arc` swap: every scorer grabs
//! [`current()`](ModelRegistry::current) per batch — an `RwLock` read plus
//! an `Arc` clone, no model copy — and a watcher thread polls the file's
//! (mtime, len) fingerprint, loading and swapping on change.  Each
//! successful swap bumps the **epoch**, which rides along in every
//! [`ScoreOutcome`](crate::serve::batcher::ScoreOutcome) and in `/healthz`,
//! so clients (and the e2e test) can observe a reload land.
//!
//! A failed reload — typically the trainer caught mid-write — keeps the
//! old model serving and is retried on the next poll; the server counts
//! these as `reload_errors`.  Note the fingerprint is (mtime, len): on a
//! filesystem with coarse mtime granularity, a same-length rewrite within
//! the same timestamp tick is missed until the next real change (writers
//! that care should write-new-then-rename, which changes the inode mtime).

use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};
use std::time::SystemTime;

use crate::solver::SavedModel;
use crate::Result;

/// One loaded model plus its reload generation.
pub struct EpochModel {
    pub model: SavedModel,
    /// 1 for the model the server started with; +1 per successful reload.
    pub epoch: u64,
}

/// (mtime, len) identity of the file contents last loaded.
type Fingerprint = (SystemTime, u64);

/// See module docs.
pub struct ModelRegistry {
    path: PathBuf,
    slot: RwLock<Slot>,
}

struct Slot {
    current: Arc<EpochModel>,
    fingerprint: Option<Fingerprint>,
}

fn fingerprint_of(path: &Path) -> Result<Fingerprint> {
    let meta = std::fs::metadata(path)?;
    Ok((meta.modified()?, meta.len()))
}

impl ModelRegistry {
    /// Load the initial model (epoch 1).
    pub fn open<P: AsRef<Path>>(path: P) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let model = SavedModel::load(&path)?;
        // fingerprint read *after* the load: if the file changed in
        // between, the next poll sees a newer fingerprint and reloads —
        // at worst one redundant reload, never a missed one
        let fingerprint = fingerprint_of(&path).ok();
        Ok(ModelRegistry {
            path,
            slot: RwLock::new(Slot {
                current: Arc::new(EpochModel { model, epoch: 1 }),
                fingerprint,
            }),
        })
    }

    /// The model file being watched.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The live model — cheap (lock + `Arc` clone); scorers call this once
    /// per batch so a swap lands at the next batch boundary.
    pub fn current(&self) -> Arc<EpochModel> {
        self.slot.read().unwrap().current.clone()
    }

    /// Current reload generation.
    pub fn epoch(&self) -> u64 {
        self.slot.read().unwrap().current.epoch
    }

    /// Check the file fingerprint; load and swap if it changed.  Returns
    /// `Ok(true)` on a swap, `Ok(false)` if the file is unchanged, and
    /// `Err` if it changed but could not be loaded (old model keeps
    /// serving; the caller counts the error and retries next poll).
    pub fn poll_reload(&self) -> Result<bool> {
        let fp = fingerprint_of(&self.path)?;
        if self.slot.read().unwrap().fingerprint == Some(fp) {
            return Ok(false);
        }
        // load outside the write lock: scorers keep reading the old model
        // for however long the parse takes
        let model = SavedModel::load(&self.path)?;
        let mut slot = self.slot.write().unwrap();
        let epoch = slot.current.epoch + 1;
        slot.current = Arc::new(EpochModel { model, epoch });
        slot.fingerprint = Some(fp);
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encode::EncoderSpec;
    use crate::solver::LinearModel;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bbmh_registry_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_model(path: &Path, spec: EncoderSpec, bias: f32) {
        let w: Vec<f32> = (0..spec.output_dim()).map(|j| j as f32 * 0.5 + bias).collect();
        SavedModel::new(spec, LinearModel { w }).unwrap().save(path).unwrap();
    }

    #[test]
    fn open_reload_and_epoch_bump() {
        let dir = temp_dir("reload");
        let path = dir.join("m.bbmh");
        let spec = EncoderSpec::Oph { bins: 4, b: 2, seed: 3 };
        write_model(&path, spec, 0.0);
        let reg = ModelRegistry::open(&path).unwrap();
        assert_eq!(reg.epoch(), 1);
        assert!(!reg.poll_reload().unwrap(), "unchanged file must not reload");

        // in-flight handle survives the swap
        let old = reg.current();
        // ensure a new fingerprint even on coarse-mtime filesystems: the
        // weight change keeps the byte length identical, so nudge mtime
        std::thread::sleep(std::time::Duration::from_millis(20));
        write_model(&path, spec, 1.0);
        let bumped = filetime_changed(&path, &reg);
        assert!(bumped, "rewrite must be observed as a reload");
        assert_eq!(reg.epoch(), 2);
        assert_eq!(old.epoch, 1, "old Arc keeps serving its epoch");
        assert_ne!(old.model.model.w[0], reg.current().model.model.w[0]);
        std::fs::remove_dir_all(dir).ok();
    }

    /// Poll until the rewrite is visible (coarse-mtime guard: if the first
    /// poll misses because mtime+len are identical, touch the file again).
    fn filetime_changed(path: &Path, reg: &ModelRegistry) -> bool {
        for _ in 0..50 {
            if reg.poll_reload().unwrap() {
                return true;
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
            // re-touch by appending nothing: rewrite the file wholesale
            let bytes = std::fs::read(path).unwrap();
            std::fs::write(path, bytes).unwrap();
        }
        false
    }

    #[test]
    fn corrupt_rewrite_keeps_old_model() {
        let dir = temp_dir("corrupt");
        let path = dir.join("m.bbmh");
        let spec = EncoderSpec::Oph { bins: 4, b: 2, seed: 3 };
        write_model(&path, spec, 0.0);
        let reg = ModelRegistry::open(&path).unwrap();
        std::fs::write(&path, b"BBMH-MODEL v9 garbage\nweights\n").unwrap();
        // changed fingerprint + unloadable file = typed error, old model up
        let mut saw_error = false;
        for _ in 0..50 {
            match reg.poll_reload() {
                Err(_) => {
                    saw_error = true;
                    break;
                }
                Ok(true) => panic!("garbage must not swap in"),
                Ok(false) => std::thread::sleep(std::time::Duration::from_millis(25)),
            }
        }
        assert!(saw_error, "corrupt rewrite never surfaced as an error");
        assert_eq!(reg.epoch(), 1);
        assert_eq!(reg.current().model.spec, spec);

        // a good rewrite afterwards recovers
        std::thread::sleep(std::time::Duration::from_millis(20));
        write_model(&path, spec, 2.0);
        assert!(filetime_changed(&path, &reg));
        assert_eq!(reg.epoch(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    /// The crash regression behind the atomic-save protocol: a model file
    /// torn mid-write (a valid prefix, truncated inside the weight block)
    /// must never swap in over the live model.  Checkpoint saves now go
    /// through tmp+rename so the watcher never sees this state from our
    /// own trainer, but anything else writing the path can still tear.
    #[test]
    fn torn_model_file_never_poisons_live_server() {
        let dir = temp_dir("torn");
        let path = dir.join("m.bbmh");
        let spec = EncoderSpec::Oph { bins: 4, b: 2, seed: 3 };
        write_model(&path, spec, 0.0);
        let reg = ModelRegistry::open(&path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // tear the file at several depths inside the weight block
        for cut in [good.len() - 1, good.len() - 7, good.len() / 2] {
            std::thread::sleep(std::time::Duration::from_millis(20));
            std::fs::write(&path, &good[..cut]).unwrap();
            let mut saw_error = false;
            for _ in 0..50 {
                match reg.poll_reload() {
                    Err(_) => {
                        saw_error = true;
                        break;
                    }
                    Ok(true) => panic!("torn file (cut at {cut}) must not swap in"),
                    Ok(false) => std::thread::sleep(std::time::Duration::from_millis(25)),
                }
            }
            assert!(saw_error, "torn file (cut at {cut}) never surfaced as an error");
            assert_eq!(reg.epoch(), 1, "old model must keep serving");
            assert_eq!(reg.current().model.model.w.len(), spec.output_dim());
        }

        // the atomic rewrite that follows a torn interval recovers
        std::thread::sleep(std::time::Duration::from_millis(20));
        write_model(&path, spec, 3.0);
        assert!(filetime_changed(&path, &reg));
        assert_eq!(reg.epoch(), 2);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_file_is_a_typed_error() {
        let dir = temp_dir("missing");
        assert!(ModelRegistry::open(dir.join("nope.bbmh")).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
