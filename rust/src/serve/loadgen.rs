//! Loopback load generator for the model server and the router fleet.
//!
//! Drives a POST path (`/score` against one server, or `/similar` through
//! `bbit-mh route` for fleet-level numbers) at a target aggregate QPS from
//! a small pool of keep-alive connections and reports what the serving
//! path actually delivered: achieved QPS plus its drift against the
//! requested rate, outcome counts (ok / shed / expired / error), the
//! shed-rate, and exact latency percentiles (every sample kept and sorted
//! — no histogram bucketing, this is the measurement side).  Pacing is
//! open-loop per connection (`next_fire += interval`, sleep until then):
//! a slow response delays subsequent sends on that connection but the
//! schedule catches up, so sustained server slowness shows up as missed
//! QPS *and* fat tails rather than being silently absorbed — the usual
//! closed-loop coordinated-omission trap.
//!
//! Wired into `benches/bench_pipeline.rs` as the `serve` scenario (which
//! also dumps `BENCH_serve.json`) and used by the e2e tests; `qps` is the
//! aggregate target across all connections.

use std::io::BufReader;
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::metrics::trace;
use crate::serve::http;
use crate::{Error, Result};

/// Load profile.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// POST path to drive: `/score` for margins, `/similar` for
    /// near-neighbor queries (entries in `docs` may then be `doc:<id>`
    /// lines as well as LibSVM lines).
    pub path: String,
    /// Target aggregate requests/second across all connections.
    pub qps: f64,
    /// How long to drive load.
    pub duration: Duration,
    /// Concurrent keep-alive connections (client threads).
    pub connections: usize,
    /// Document pool, one line per entry, cycled round-robin.
    pub docs: Vec<String>,
}

/// What the run delivered.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    pub sent: u64,
    pub ok: u64,
    pub shed: u64,
    pub expired: u64,
    pub errors: u64,
    pub wall_seconds: f64,
    pub achieved_qps: f64,
    /// The rate the run asked for (`cfg.qps`) — kept in the report so the
    /// drift below is interpretable on its own.
    pub requested_qps: f64,
    /// `(achieved − requested) / requested`: ≈0 when the server kept up,
    /// negative when pacing fell behind (the open-loop schedule slipped).
    pub qps_drift: f64,
    /// `shed / sent`: the fraction of requests admission control rejected
    /// — a fleet bench at high shed-rate has meaningless percentiles.
    pub shed_rate: f64,
    /// Latency percentiles over successful responses, microseconds.
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Responses whose echoed `X-Trace-Id` did not match the one the
    /// request carried — the loadgen doubles as a standing propagation
    /// check, so this should always read 0.
    pub trace_echo_failures: u64,
}

impl LoadgenReport {
    /// One-line human summary (the bench scenario prints this).
    pub fn summary(&self) -> String {
        format!(
            "sent {} in {:.2}s ({:.0} qps achieved, {:+.1}% vs requested): ok {} shed {} \
             ({:.1}% shed) expired {} errors {}; \
             latency p50 {}µs p95 {}µs p99 {}µs max {}µs; trace-echo failures {}",
            self.sent,
            self.wall_seconds,
            self.achieved_qps,
            self.qps_drift * 100.0,
            self.ok,
            self.shed,
            self.shed_rate * 100.0,
            self.expired,
            self.errors,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.max_us,
            self.trace_echo_failures,
        )
    }

    /// Hand-rolled JSON object (the crate has no serde; BENCH_*.json
    /// tracking for the serving path).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"sent\":{},\"ok\":{},\"shed\":{},\"expired\":{},\"errors\":{},\
             \"wall_seconds\":{:.4},\"achieved_qps\":{:.1},\"requested_qps\":{:.1},\
             \"qps_drift\":{:.4},\"shed_rate\":{:.4},\
             \"p50_us\":{},\"p95_us\":{},\"p99_us\":{},\"max_us\":{},\
             \"trace_echo_failures\":{}}}",
            self.sent,
            self.ok,
            self.shed,
            self.expired,
            self.errors,
            self.wall_seconds,
            self.achieved_qps,
            self.requested_qps,
            self.qps_drift,
            self.shed_rate,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.max_us,
            self.trace_echo_failures,
        )
    }
}

struct ThreadTally {
    sent: u64,
    ok: u64,
    shed: u64,
    expired: u64,
    errors: u64,
    trace_echo_failures: u64,
    latencies_us: Vec<u64>,
}

/// Exact percentile over a sorted sample (nearest-rank on n−1).
fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Drive the server at `addr`; blocks for `cfg.duration` (plus drain).
pub fn run(addr: SocketAddr, cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.connections == 0 || cfg.docs.is_empty() || cfg.qps <= 0.0 || cfg.qps.is_nan() {
        return Err(Error::InvalidArg(
            "loadgen: needs connections > 0, qps > 0 and a non-empty doc pool".into(),
        ));
    }
    let interval = Duration::from_secs_f64(cfg.connections as f64 / cfg.qps);
    let wall0 = Instant::now();
    let tallies: Vec<ThreadTally> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(cfg.connections);
        for t in 0..cfg.connections {
            handles.push(scope.spawn(move || drive_one(addr, cfg, t, interval)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen thread panicked"))
            .collect()
    });
    let wall_seconds = wall0.elapsed().as_secs_f64();

    let mut report = LoadgenReport { wall_seconds, ..Default::default() };
    let mut lat: Vec<u64> = Vec::new();
    for t in tallies {
        report.sent += t.sent;
        report.ok += t.ok;
        report.shed += t.shed;
        report.expired += t.expired;
        report.errors += t.errors;
        report.trace_echo_failures += t.trace_echo_failures;
        lat.extend(t.latencies_us);
    }
    lat.sort_unstable();
    report.achieved_qps = report.sent as f64 / wall_seconds.max(1e-9);
    report.requested_qps = cfg.qps;
    report.qps_drift = (report.achieved_qps - cfg.qps) / cfg.qps;
    report.shed_rate = report.shed as f64 / (report.sent.max(1)) as f64;
    report.p50_us = percentile(&lat, 0.50);
    report.p95_us = percentile(&lat, 0.95);
    report.p99_us = percentile(&lat, 0.99);
    report.max_us = lat.last().copied().unwrap_or(0);
    Ok(report)
}

/// One connection's paced request loop.
fn drive_one(
    addr: SocketAddr,
    cfg: &LoadgenConfig,
    thread_idx: usize,
    interval: Duration,
) -> ThreadTally {
    let mut tally = ThreadTally {
        sent: 0,
        ok: 0,
        shed: 0,
        expired: 0,
        errors: 0,
        trace_echo_failures: 0,
        latencies_us: Vec::new(),
    };
    let connect = || -> Option<(TcpStream, BufReader<TcpStream>)> {
        let stream = TcpStream::connect(addr).ok()?;
        stream.set_nodelay(true).ok()?;
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .ok()?;
        let reader = BufReader::new(stream.try_clone().ok()?);
        Some((stream, reader))
    };
    let Some((mut stream, mut reader)) = connect() else {
        tally.errors += 1;
        return tally;
    };
    let start = Instant::now();
    // stagger thread start phases so the aggregate is smooth, not bursty
    let mut next_fire = start + interval.mul_f64(thread_idx as f64 / cfg.connections as f64);
    let mut doc_idx = thread_idx; // decorrelate doc choice across threads
    while start.elapsed() < cfg.duration {
        let now = Instant::now();
        if next_fire > now {
            std::thread::sleep(next_fire - now);
        }
        next_fire += interval;
        let doc = &cfg.docs[doc_idx % cfg.docs.len()];
        doc_idx += 1;
        let mut body = Vec::with_capacity(doc.len() + 1);
        body.extend_from_slice(doc.as_bytes());
        body.push(b'\n');
        tally.sent += 1;
        // every request carries a fresh trace id; the serving tier must
        // echo it back verbatim (propagation is load-bearing for the
        // fleet's observability, so the loadgen checks it on every hit)
        let tid = trace::format_id(trace::gen_id());
        let hdrs = [(http::TRACE_HEADER, tid.clone())];
        let t0 = Instant::now();
        let resp = http::write_post_with(&mut stream, &cfg.path, &hdrs, &body)
            .and_then(|()| http::read_response(&mut reader));
        match resp {
            Ok(r) => {
                if r.trace_id() != Some(tid.as_str()) {
                    tally.trace_echo_failures += 1;
                }
                match r.status {
                    200 => {
                        tally.ok += 1;
                        tally.latencies_us.push(t0.elapsed().as_micros() as u64);
                    }
                    503 => tally.shed += 1,
                    504 => tally.expired += 1,
                    _ => tally.errors += 1,
                }
            }
            Err(_) => {
                tally.errors += 1;
                // the server (or a timeout) dropped us — reconnect and
                // carry on with the schedule
                match connect() {
                    Some((s, r)) => {
                        stream = s;
                        reader = r;
                    }
                    None => break,
                }
            }
        }
    }
    tally
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.0), 1);
        assert_eq!(percentile(&v, 0.5), 51); // rank round(0.5*99)=50 → v[50]
        assert_eq!(percentile(&v, 1.0), 100);
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.99), 7);
    }

    #[test]
    fn config_validation() {
        let bad = LoadgenConfig {
            path: "/score".into(),
            qps: 0.0,
            duration: Duration::from_millis(1),
            connections: 1,
            docs: vec!["+1 1:1".into()],
        };
        let addr: SocketAddr = "127.0.0.1:1".parse().unwrap();
        assert!(run(addr, &bad).is_err());
        let bad = LoadgenConfig {
            path: "/score".into(),
            qps: 10.0,
            duration: Duration::from_millis(1),
            connections: 0,
            docs: vec!["+1 1:1".into()],
        };
        assert!(run(addr, &bad).is_err());
        let bad = LoadgenConfig {
            path: "/similar".into(),
            qps: 10.0,
            duration: Duration::from_millis(1),
            connections: 1,
            docs: vec![],
        };
        assert!(run(addr, &bad).is_err());
    }

    #[test]
    fn json_report_is_well_formed_enough() {
        let r = LoadgenReport {
            sent: 10,
            ok: 9,
            shed: 1,
            wall_seconds: 1.5,
            achieved_qps: 6.7,
            requested_qps: 10.0,
            qps_drift: -0.33,
            shed_rate: 0.1,
            p50_us: 120,
            p95_us: 300,
            p99_us: 400,
            max_us: 500,
            ..Default::default()
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"sent\":10") && j.contains("\"p99_us\":400"));
        assert!(j.contains("\"requested_qps\":10.0"));
        assert!(j.contains("\"qps_drift\":-0.3300"));
        assert!(j.contains("\"shed_rate\":0.1000"));
        assert!(j.contains("\"trace_echo_failures\":0"));
        assert!(r.summary().contains("trace-echo failures 0"));
        assert!(r.summary().contains("p99 400µs"));
        assert!(r.summary().contains("-33.0% vs requested"));
        assert!(r.summary().contains("(10.0% shed)"));
    }
}
