//! The fleet tier: a std-only router that consistent-hashes similarity
//! shards across backend model servers (`bbit-mh route`).
//!
//! ```text
//!   client ──▶ router ──/similar doc:<id>──▶ owner backend (shard = id % N)
//!                 │──/similar <libsvm>────▶ scatter to every assigned
//!                 │                          backend, merge + re-rank
//!                 │──/score ──────────────▶ any healthy backend (RR)
//!                 └── health thread: GET /healthz per backend,
//!                     consecutive-failure threshold, exp. backoff
//! ```
//!
//! Shard placement is [`shard_assignment`]: an FNV-1a hash ring with 64
//! virtual points per backend — deterministic for a given backend list
//! (every router instance, test, and bench derives the identical map), and
//! stable in the consistent-hashing sense (removing one backend only moves
//! the shards it owned).  Each backend is expected to serve the index
//! shards the assignment gives it (`similar-index --shards N` writes one
//! snapshot file per shard).
//!
//! Degradation is per-shard: a doc lookup whose owner backend is down
//! answers `503 Retry-After` for that shard only; a raw-query
//! scatter-gather over a partly-down fleet still answers `200` from the
//! healthy shards, flagged with `X-Partial-Results: true` +
//! `X-Shards-Missing` so callers can tell a full ranking from a partial
//! one.  Backend connections are per-request and closed by the router
//! (client side) first, which keeps `TIME_WAIT` off the backends and lets
//! a restarted backend rebind its port immediately — the recovery path the
//! e2e test exercises.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::metrics::prom::Exposition;
use crate::metrics::trace;
use crate::metrics::{Counter, Gauge};
use crate::serve::http;
use crate::similarity::index::rank_neighbors;
use crate::similarity::Neighbor;
use crate::{Error, Result};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;
/// Virtual points per backend on the hash ring — enough to spread shards
/// evenly across small fleets without making ring construction costly.
const VNODES: usize = 64;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Consistent-hash shard placement: which backend (index into `backends`)
/// owns each shard `0..shards`.  Pure — the router, the CLI, the tests
/// and the bench all call this to agree on placement.
pub fn shard_assignment(backends: &[String], shards: usize) -> Vec<usize> {
    assert!(!backends.is_empty(), "shard_assignment needs at least one backend");
    // the ring: 64 virtual points per backend, sorted by hash
    let mut ring: Vec<(u64, usize)> = Vec::with_capacity(backends.len() * VNODES);
    for (i, b) in backends.iter().enumerate() {
        for v in 0..VNODES {
            ring.push((fnv1a(format!("{b}#{v}").as_bytes()), i));
        }
    }
    ring.sort_unstable();
    (0..shards)
        .map(|s| {
            let key = fnv1a(format!("shard-{s}").as_bytes());
            // first point clockwise from the shard's key, wrapping
            match ring.binary_search_by(|&(h, _)| h.cmp(&key)) {
                Ok(i) => ring[i].1,
                Err(i) => ring[i % ring.len()].1,
            }
        })
        .collect()
}

/// Router tuning knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Bind host.
    pub host: String,
    /// Bind port; 0 for ephemeral (tests).
    pub port: u16,
    /// Backend `host:port` list (each a running `bbit-mh serve`).
    pub backends: Vec<String>,
    /// Total shard count of the fleet's index build.
    pub shards: usize,
    /// Health poll interval for healthy backends.
    pub health_poll: Duration,
    /// Per-probe / per-forward connect+read timeout.
    pub health_timeout: Duration,
    /// Consecutive probe failures before a backend is marked down.
    pub fail_threshold: u32,
    /// Backoff ceiling for probing a down backend.
    pub max_backoff: Duration,
    /// Pause before retrying a failed forward on another (or, for pinned
    /// doc lookups, the same) backend — long enough for a crashed backend
    /// to finish dying, short enough to stay inside client deadlines.
    pub retry_backoff: Duration,
    /// Idle keep-alive client connections close after this long.
    pub idle_timeout: Duration,
    /// Log any request slower than this (milliseconds, with its trace id)
    /// to stderr; `None` disables the slow-request log.
    pub slow_ms: Option<u64>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            host: "127.0.0.1".to_string(),
            port: 0,
            backends: Vec::new(),
            shards: 0,
            health_poll: Duration::from_millis(200),
            health_timeout: Duration::from_secs(2),
            fail_threshold: 2,
            max_backoff: Duration::from_secs(2),
            retry_backoff: Duration::from_millis(20),
            idle_timeout: Duration::from_secs(10),
            slow_ms: None,
        }
    }
}

/// Router-side observability, rendered at `GET /metrics`.
#[derive(Default)]
pub struct RouterMetrics {
    pub requests: Counter,
    /// Requests answered 4xx/5xx for router-side reasons (bad query, all
    /// backends down, owner shard down).
    pub errors: Counter,
    /// Per-shard 503s (owner backend down at lookup time).
    pub shard_unavailable: Counter,
    /// Scatter-gather responses that were partial.
    pub partial_results: Counter,
    /// Backend forwards that failed at the socket level.
    pub forward_failures: Counter,
    /// Forwards re-attempted (alternate backend for `/score`, same owner
    /// for `doc:<id>`) after a socket-level failure, post-backoff.
    pub forward_retries: Counter,
    /// Up→down and down→up health transitions.
    pub health_transitions: Counter,
    /// Backends currently passing health probes.
    pub backends_up: Gauge,
    /// Backends in the configured fleet (fixed for the router's life).
    pub backends_configured: Gauge,
}

impl RouterMetrics {
    /// Prometheus text exposition (also the shutdown report).
    pub fn render(&self, up: usize, total: usize) -> String {
        self.backends_up.set(up as u64);
        self.backends_configured.set(total as u64);
        let mut exp = Exposition::new();
        exp.gauge(
            "route_backends_up",
            "Backends currently passing health probes.",
            self.backends_up.get(),
        )
        .gauge(
            "route_backends_configured",
            "Backends in the configured fleet.",
            self.backends_configured.get(),
        )
        .counter("route_requests_total", "Requests handled (all routes).", self.requests.get())
        .counter(
            "route_errors_total",
            "Requests answered 4xx/5xx for router-side reasons.",
            self.errors.get(),
        )
        .counter(
            "route_shard_unavailable_total",
            "Per-shard 503s (owner backend down at lookup time).",
            self.shard_unavailable.get(),
        )
        .counter(
            "route_partial_results_total",
            "Scatter-gather responses that were partial.",
            self.partial_results.get(),
        )
        .counter(
            "route_forward_failures_total",
            "Backend forwards that failed at the socket level.",
            self.forward_failures.get(),
        )
        .counter(
            "route_forward_retries_total",
            "Forwards re-attempted on another (or the owning) backend after a failure.",
            self.forward_retries.get(),
        )
        .counter(
            "route_health_transitions_total",
            "Up-down and down-up health transitions.",
            self.health_transitions.get(),
        );
        exp.finish()
    }
}

/// Mutable per-backend health state (driven by the poller and by forward
/// failures).
struct BackendHealth {
    healthy: bool,
    consecutive_fails: u32,
    next_probe: Instant,
    backoff: Duration,
}

struct RouterCtx {
    cfg: RouterConfig,
    /// `assignment[shard] == backend index` — fixed for the router's life.
    assignment: Vec<usize>,
    health: Mutex<Vec<BackendHealth>>,
    metrics: RouterMetrics,
    rr: AtomicUsize,
    shutdown: AtomicBool,
}

impl RouterCtx {
    fn is_healthy(&self, backend: usize) -> bool {
        self.health.lock().unwrap()[backend].healthy
    }

    fn healthy_count(&self) -> usize {
        self.health.lock().unwrap().iter().filter(|b| b.healthy).count()
    }

    /// A forward just failed at the socket level: treat it as a failed
    /// probe so traffic stops hitting the backend before the next poll.
    fn note_forward_failure(&self, backend: usize) {
        self.metrics.forward_failures.inc();
        let mut health = self.health.lock().unwrap();
        let h = &mut health[backend];
        h.consecutive_fails += 1;
        if h.healthy && h.consecutive_fails >= self.cfg.fail_threshold {
            h.healthy = false;
            h.backoff = self.cfg.health_poll;
            h.next_probe = Instant::now() + h.backoff;
            self.metrics.health_transitions.inc();
        }
    }
}

/// A running router; [`shutdown`](Self::shutdown) for a graceful stop.
pub struct Router {
    ctx: Arc<RouterCtx>,
    addr: SocketAddr,
    threads: Vec<JoinHandle<()>>,
}

impl Router {
    pub fn start(cfg: RouterConfig) -> Result<Self> {
        if cfg.backends.is_empty() {
            return Err(Error::InvalidArg("route: --backends must list at least one".into()));
        }
        if cfg.shards == 0 {
            return Err(Error::InvalidArg("route: --shards must be >= 1".into()));
        }
        if cfg.fail_threshold == 0 {
            return Err(Error::InvalidArg("route: fail threshold must be >= 1".into()));
        }
        let assignment = shard_assignment(&cfg.backends, cfg.shards);
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let addr = listener.local_addr()?;
        let now = Instant::now();
        let health = (0..cfg.backends.len())
            .map(|_| BackendHealth {
                // optimistic start: the first failed probe/forward flips it
                healthy: true,
                consecutive_fails: 0,
                next_probe: now,
                backoff: cfg.health_poll,
            })
            .collect();
        let ctx = Arc::new(RouterCtx {
            assignment,
            health: Mutex::new(health),
            metrics: RouterMetrics::default(),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            cfg,
        });
        let mut threads = Vec::new();
        {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || health_loop(&ctx)));
        }
        {
            let ctx = ctx.clone();
            threads.push(std::thread::spawn(move || accept_loop(&ctx, listener)));
        }
        Ok(Router { ctx, addr, threads })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &RouterMetrics {
        &self.ctx.metrics
    }

    /// The fixed shard→backend map this router serves with.
    pub fn assignment(&self) -> &[usize] {
        &self.ctx.assignment
    }

    /// Graceful stop; returns the final metrics exposition.
    pub fn shutdown(mut self) -> String {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        self.ctx.metrics.render(self.ctx.healthy_count(), self.ctx.cfg.backends.len())
    }
}

/// One GET probe against a backend's `/healthz`; body must start `ok`.
fn probe_backend(backend: &str, timeout: Duration) -> bool {
    let Ok(mut addrs) = backend.to_socket_addrs() else {
        return false;
    };
    let Some(addr) = addrs.next() else {
        return false;
    };
    let Ok(mut stream) = TcpStream::connect_timeout(&addr, timeout) else {
        return false;
    };
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_nodelay(true);
    if http::write_get(&mut stream, "/healthz").is_err() {
        return false;
    }
    let Ok(clone) = stream.try_clone() else {
        return false;
    };
    match http::read_response(&mut BufReader::new(clone)) {
        Ok(resp) => resp.status == 200 && resp.body.starts_with(b"ok"),
        Err(_) => false,
    }
}

fn health_loop(ctx: &Arc<RouterCtx>) {
    while !ctx.shutdown.load(Ordering::Relaxed) {
        // collect due probes under the lock, probe outside it
        let due: Vec<(usize, String)> = {
            let health = ctx.health.lock().unwrap();
            let now = Instant::now();
            health
                .iter()
                .enumerate()
                .filter(|(_, h)| now >= h.next_probe)
                .map(|(i, _)| (i, ctx.cfg.backends[i].clone()))
                .collect()
        };
        for (i, backend) in due {
            let up = probe_backend(&backend, ctx.cfg.health_timeout);
            let mut health = ctx.health.lock().unwrap();
            let h = &mut health[i];
            let now = Instant::now();
            if up {
                if !h.healthy {
                    ctx.metrics.health_transitions.inc();
                }
                h.healthy = true;
                h.consecutive_fails = 0;
                h.backoff = ctx.cfg.health_poll;
                h.next_probe = now + ctx.cfg.health_poll;
            } else {
                h.consecutive_fails += 1;
                if h.healthy && h.consecutive_fails >= ctx.cfg.fail_threshold {
                    h.healthy = false;
                    ctx.metrics.health_transitions.inc();
                }
                // exponential backoff while down, capped
                h.backoff = (h.backoff * 2).min(ctx.cfg.max_backoff);
                h.next_probe = now + if h.healthy { ctx.cfg.health_poll } else { h.backoff };
            }
        }
        std::thread::sleep(ctx.cfg.health_poll.min(Duration::from_millis(50)));
    }
}

fn accept_loop(ctx: &Arc<RouterCtx>, listener: TcpListener) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if ctx.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let ctx2 = ctx.clone();
                let spawned = std::thread::Builder::new()
                    .name("bbmh-route".into())
                    .spawn(move || handle_conn(&ctx2, stream));
                if spawned.is_err() {
                    ctx.metrics.errors.inc();
                }
            }
            Err(_) => {
                if ctx.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn handle_conn(ctx: &Arc<RouterCtx>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(ctx.cfg.idle_timeout));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => break,
            Err(Error::Io(e))
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                break;
            }
            Err(_) => {
                ctx.metrics.errors.inc();
                let _ =
                    http::write_response(&mut stream, 400, "Bad Request", &[], b"bad request\n");
                break;
            }
        };
        ctx.metrics.requests.inc();
        // correlation id for the whole fleet hop: taken from the client
        // when valid, minted at this edge otherwise; forwarded to every
        // backend leg and echoed on every response
        let trace_id =
            req.trace_id().and_then(trace::parse_id).unwrap_or_else(trace::gen_id);
        let tid = (http::TRACE_HEADER, trace::format_id(trace_id));
        let keep = req.keep_alive() && !ctx.shutdown.load(Ordering::Relaxed);
        let io_ok = match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/similar") => handle_similar(ctx, &req, &mut stream, trace_id),
            ("POST", "/score") => handle_score(ctx, &req, &mut stream, trace_id),
            ("GET", "/metrics") => {
                let body =
                    ctx.metrics.render(ctx.healthy_count(), ctx.cfg.backends.len());
                http::write_response(&mut stream, 200, "OK", &[tid], body.as_bytes()).is_ok()
            }
            ("GET", "/healthz") => {
                let health = ctx.health.lock().unwrap();
                let up = health.iter().filter(|h| h.healthy).count();
                let mut body =
                    format!("ok backends={up}/{} shards={}\n", health.len(), ctx.cfg.shards);
                for (i, h) in health.iter().enumerate() {
                    let shards: Vec<String> = ctx
                        .assignment
                        .iter()
                        .enumerate()
                        .filter(|(_, &b)| b == i)
                        .map(|(s, _)| s.to_string())
                        .collect();
                    body.push_str(&format!(
                        "backend {} {} shards={}\n",
                        ctx.cfg.backends[i],
                        if h.healthy { "up" } else { "down" },
                        shards.join(",")
                    ));
                }
                drop(health);
                http::write_response(&mut stream, 200, "OK", &[tid], body.as_bytes()).is_ok()
            }
            _ => http::write_response(&mut stream, 404, "Not Found", &[tid], b"not found\n")
                .is_ok(),
        };
        if !io_ok || !keep {
            break;
        }
    }
}

/// Forward one POST to a backend over a fresh connection; the router
/// closes its side first, so backend sockets never linger in `TIME_WAIT`.
fn forward_post(
    ctx: &Arc<RouterCtx>,
    backend: usize,
    path: &str,
    headers: &[(&str, String)],
    body: &[u8],
) -> Option<http::Response> {
    let name = &ctx.cfg.backends[backend];
    let result = (|| -> Result<http::Response> {
        // failpoint: an injected error is indistinguishable from a
        // connect-refused here — it feeds the same failure accounting,
        // health demotion and retry machinery the real fault would
        crate::faults::fail(crate::faults::site::ROUTE_FORWARD)?;
        let addr = name
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| Error::InvalidArg(format!("backend {name} does not resolve")))?;
        let mut stream = TcpStream::connect_timeout(&addr, ctx.cfg.health_timeout)?;
        let _ = stream.set_nodelay(true);
        let _ = stream.set_read_timeout(Some(ctx.cfg.health_timeout));
        http::write_post_with(&mut stream, path, headers, body)?;
        let clone = stream.try_clone()?;
        http::read_response(&mut BufReader::new(clone))
    })();
    match result {
        Ok(resp) => Some(resp),
        Err(_) => {
            ctx.note_forward_failure(backend);
            None
        }
    }
}

/// `--slow-ms` for the router tier (same stderr format as the server's,
/// so one grep collects both tiers by trace id).
fn slow_log(slow_ms: Option<u64>, path: &str, trace_id: u64, status: u16, t0: Instant) {
    let Some(ms) = slow_ms else { return };
    let elapsed = t0.elapsed();
    if elapsed.as_millis() as u64 >= ms {
        eprintln!(
            "slow-request path={path} status={status} dur_ms={} trace={}",
            elapsed.as_millis(),
            trace::format_id(trace_id)
        );
    }
}

/// `/score` just needs *a* healthy backend: round-robin over the fleet.
fn handle_score(
    ctx: &Arc<RouterCtx>,
    req: &http::Request,
    stream: &mut TcpStream,
    trace_id: u64,
) -> bool {
    let t0 = Instant::now();
    let mut root = trace::Span::root("route.score", trace_id);
    let rctx = root.ctx();
    let tid = || (http::TRACE_HEADER, trace::format_id(trace_id));
    let fwd_hdrs = [tid()];
    let n = ctx.cfg.backends.len();
    let start = ctx.rr.fetch_add(1, Ordering::Relaxed);
    let mut failed_before = false;
    for probe in 0..n {
        let backend = (start + probe) % n;
        if !ctx.is_healthy(backend) {
            continue;
        }
        // every attempt after a socket-level failure is a retry: pause one
        // backoff beat first so a backend crashing under us finishes dying
        // before the alternate takes the request
        if failed_before {
            ctx.metrics.forward_retries.inc();
            std::thread::sleep(ctx.cfg.retry_backoff);
        }
        let mut leg = trace::Span::child("route.forward", rctx);
        leg.record("backend", backend as f64);
        let forwarded = forward_post(ctx, backend, "/score", &fwd_hdrs, &req.body);
        if let Some(resp) = forwarded {
            leg.record("status", resp.status as f64);
            drop(leg);
            let mut headers = relay_headers(&resp);
            headers.push(tid());
            let reason = reason_for(resp.status);
            root.record("status", resp.status as f64);
            slow_log(ctx.cfg.slow_ms, "/score", trace_id, resp.status, t0);
            return http::write_response(stream, resp.status, reason, &headers, &resp.body)
                .is_ok();
        }
        failed_before = true;
    }
    ctx.metrics.errors.inc();
    root.record("status", 503.0);
    slow_log(ctx.cfg.slow_ms, "/score", trace_id, 503, t0);
    http::write_response(
        stream,
        503,
        "Service Unavailable",
        &[("Retry-After", "1".to_string()), tid()],
        b"no healthy backend\n",
    )
    .is_ok()
}

/// Headers safe to relay from a backend response (`write_response` frames
/// the body itself, so length/type/connection must not be duplicated; the
/// backend's trace echo is dropped because this router appends its own —
/// same id, one copy).
fn relay_headers(resp: &http::Response) -> Vec<(&str, String)> {
    resp.headers
        .iter()
        .filter(|(k, _)| {
            !matches!(
                k.as_str(),
                "content-length" | "content-type" | "connection" | "x-trace-id"
            )
        })
        .map(|(k, v)| (k.as_str(), v.clone()))
        .collect()
}

fn reason_for(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Response",
    }
}

/// `/similar`: doc lookups route to the owner shard's backend; raw queries
/// scatter to every assigned backend and merge.
fn handle_similar(
    ctx: &Arc<RouterCtx>,
    req: &http::Request,
    stream: &mut TcpStream,
    trace_id: u64,
) -> bool {
    let t0 = Instant::now();
    let mut root = trace::Span::root("route.similar", trace_id);
    let rctx = root.ctx();
    let tid = || (http::TRACE_HEADER, trace::format_id(trace_id));
    let text = String::from_utf8_lossy(&req.body);
    let line = text.lines().map(str::trim).find(|l| !l.is_empty() && !l.starts_with('#'));
    // headers every backend leg carries: the client's X-Top-K (when set)
    // plus this request's trace id, so backend spans join the same trace
    let mut fwd_hdrs: Vec<(&str, String)> = match req.header("x-top-k") {
        Some(v) => vec![("X-Top-K", v.to_string())],
        None => Vec::new(),
    };
    fwd_hdrs.push(tid());
    let top_k = req
        .header("x-top-k")
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|k| k.clamp(1, 1000))
        .unwrap_or(10);
    let Some(line) = line else {
        ctx.metrics.errors.inc();
        return http::write_response(stream, 400, "Bad Request", &[tid()], b"empty query body\n")
            .is_ok();
    };

    // ---- doc:<id>: single-shard routed lookup --------------------------
    if let Some(id) = line.strip_prefix("doc:") {
        let Ok(id) = id.trim().parse::<u64>() else {
            ctx.metrics.errors.inc();
            let body = format!("bad doc id {:?}\n", id.trim());
            return http::write_response(stream, 400, "Bad Request", &[tid()], body.as_bytes())
                .is_ok();
        };
        let shard = (id % ctx.cfg.shards as u64) as usize;
        let backend = ctx.assignment[shard];
        // the shard is pinned to its owner, so there is no alternate to
        // fail over to — instead one retry against the same owner after a
        // backoff beat, covering the transient-refusal window (backend
        // restarting, accept queue momentarily full)
        for attempt in 0..2 {
            if !ctx.is_healthy(backend) {
                break;
            }
            if attempt > 0 {
                ctx.metrics.forward_retries.inc();
                std::thread::sleep(ctx.cfg.retry_backoff);
            }
            let mut leg = trace::Span::child("route.forward", rctx);
            leg.record("backend", backend as f64);
            leg.record("shard", shard as f64);
            let forwarded = forward_post(ctx, backend, "/similar", &fwd_hdrs, &req.body);
            if let Some(resp) = forwarded {
                leg.record("status", resp.status as f64);
                drop(leg);
                let mut headers = relay_headers(&resp);
                headers.push(tid());
                let reason = reason_for(resp.status);
                root.record("status", resp.status as f64);
                slow_log(ctx.cfg.slow_ms, "/similar", trace_id, resp.status, t0);
                return http::write_response(
                    stream,
                    resp.status,
                    reason,
                    &headers,
                    &resp.body,
                )
                .is_ok();
            }
        }
        // owner backend down (or the forward just failed): that shard —
        // and only that shard — is unavailable
        ctx.metrics.shard_unavailable.inc();
        ctx.metrics.errors.inc();
        root.record("status", 503.0);
        slow_log(ctx.cfg.slow_ms, "/similar", trace_id, 503, t0);
        let body = format!("shard {shard} unavailable\n");
        return http::write_response(
            stream,
            503,
            "Service Unavailable",
            &[("Retry-After", "1".to_string()), tid()],
            body.as_bytes(),
        )
        .is_ok();
    }

    // ---- raw query: scatter to every assigned backend, merge -----------
    // distinct backends that own at least one shard
    let mut targets: Vec<usize> = ctx.assignment.clone();
    targets.sort_unstable();
    targets.dedup();
    let mut merged: Vec<Neighbor> = Vec::new();
    let mut candidates = 0u64;
    let mut reranked = 0u64;
    let mut missing: Vec<usize> = Vec::new();
    let results: Vec<(usize, Option<http::Response>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = targets
            .iter()
            .map(|&backend| {
                let hdr = &fwd_hdrs;
                let body = req.body.as_slice();
                scope.spawn(move || {
                    // one child span per fan-out leg, parented on the
                    // request root across the thread boundary
                    let mut leg = trace::Span::child("route.scatter_leg", rctx);
                    leg.record("backend", backend as f64);
                    if !ctx.is_healthy(backend) {
                        leg.record("skipped", 1.0);
                        return (backend, None);
                    }
                    let resp = forward_post(ctx, backend, "/similar", hdr, body);
                    if let Some(r) = &resp {
                        leg.record("status", r.status as f64);
                    }
                    (backend, resp)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (backend, resp) in results {
        let ok = match resp {
            Some(resp) if resp.status == 200 => {
                for l in resp.body_text().lines() {
                    let mut parts = l.split_ascii_whitespace();
                    if let (Some(id), Some(est)) = (parts.next(), parts.next()) {
                        if let (Ok(id), Ok(estimate)) = (id.parse(), est.parse()) {
                            merged.push(Neighbor { id, estimate });
                        }
                    }
                }
                candidates += resp
                    .header("x-candidates")
                    .and_then(|v| v.parse::<u64>().ok())
                    .unwrap_or(0);
                reranked += resp
                    .header("x-reranked")
                    .and_then(|v| v.parse::<u64>().ok())
                    .unwrap_or(0);
                true
            }
            // a 4xx/5xx or socket failure from one backend degrades that
            // backend's shards only
            _ => false,
        };
        if !ok {
            missing.extend(
                ctx.assignment
                    .iter()
                    .enumerate()
                    .filter(|(_, &b)| b == backend)
                    .map(|(s, _)| s),
            );
        }
    }
    if missing.len() == ctx.cfg.shards {
        ctx.metrics.errors.inc();
        root.record("status", 503.0);
        slow_log(ctx.cfg.slow_ms, "/similar", trace_id, 503, t0);
        return http::write_response(
            stream,
            503,
            "Service Unavailable",
            &[("Retry-After", "1".to_string()), tid()],
            b"no healthy shard\n",
        )
        .is_ok();
    }
    // same ranking rule as the in-process query, so a fleet merge over
    // disjoint shards reproduces the single-index top-K exactly
    rank_neighbors(&mut merged, top_k);
    let mut lines = String::new();
    for h in &merged {
        lines.push_str(&format!("{} {}\n", h.id, h.estimate));
    }
    let mut headers = vec![
        ("X-Candidates", candidates.to_string()),
        ("X-Reranked", reranked.to_string()),
    ];
    if !missing.is_empty() {
        ctx.metrics.partial_results.inc();
        missing.sort_unstable();
        let list: Vec<String> = missing.iter().map(|s| s.to_string()).collect();
        headers.push(("X-Partial-Results", "true".to_string()));
        headers.push(("X-Shards-Missing", list.join(",")));
    }
    headers.push(tid());
    root.record("status", 200.0);
    root.record("candidates", candidates as f64);
    root.record("reranked", reranked as f64);
    root.record("shards_missing", missing.len() as f64);
    slow_log(ctx.cfg.slow_ms, "/similar", trace_id, 200, t0);
    http::write_response(stream, 200, "OK", &headers, lines.as_bytes()).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("10.0.0.{i}:7000")).collect()
    }

    #[test]
    fn assignment_is_deterministic_and_total() {
        let backends = fleet(3);
        let a = shard_assignment(&backends, 16);
        let b = shard_assignment(&backends, 16);
        assert_eq!(a, b, "same fleet must always map the same");
        assert_eq!(a.len(), 16);
        assert!(a.iter().all(|&i| i < 3));
        // with 64 vnodes per backend, a 3-way fleet should use everyone
        let mut used = a.clone();
        used.sort_unstable();
        used.dedup();
        assert_eq!(used.len(), 3, "every backend should own some shard: {a:?}");
    }

    #[test]
    fn removing_a_backend_only_moves_its_own_shards() {
        let full = fleet(4);
        let a = shard_assignment(&full, 32);
        // drop backend 2; survivors must keep every shard they had
        let reduced: Vec<String> =
            full.iter().enumerate().filter(|(i, _)| *i != 2).map(|(_, b)| b.clone()).collect();
        let b = shard_assignment(&reduced, 32);
        for s in 0..32 {
            if a[s] != 2 {
                // map old index → reduced index (2 removed shifts later ones)
                let expect = if a[s] < 2 { a[s] } else { a[s] - 1 };
                assert_eq!(
                    b[s], expect,
                    "shard {s} moved off a surviving backend — not consistent"
                );
            }
        }
    }

    #[test]
    fn metrics_render_is_valid_prometheus() {
        let m = RouterMetrics::default();
        m.requests.add(5);
        let text = m.render(1, 2);
        crate::metrics::prom::validate(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
        assert!(text.contains("route_backends_up 1"), "{text}");
        assert!(text.contains("route_backends_configured 2"), "{text}");
        assert!(text.contains("route_requests_total 5"), "{text}");
        assert!(text.contains("route_forward_retries_total 0"), "{text}");
        assert!(text.contains("route_health_transitions_total 0"), "{text}");
    }

    #[test]
    fn start_rejects_degenerate_configs() {
        let cfg = RouterConfig { backends: Vec::new(), shards: 2, ..Default::default() };
        assert!(Router::start(cfg).is_err());
        let cfg =
            RouterConfig { backends: fleet(2), shards: 0, ..Default::default() };
        assert!(Router::start(cfg).is_err());
        let cfg = RouterConfig {
            backends: fleet(2),
            shards: 2,
            fail_threshold: 0,
            ..Default::default()
        };
        assert!(Router::start(cfg).is_err());
    }
}
