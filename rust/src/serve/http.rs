//! Minimal HTTP/1.1 framing for the model server and its load generator.
//!
//! The crate's dependency policy (std + `thiserror` + `xla` only — no
//! hyper, no tokio) means the serving layer carries its own wire format.
//! This module is deliberately tiny: request/response heads are CRLF
//! lines, bodies are `Content-Length`-framed (no chunked transfer
//! encoding, no trailers), connections default to keep-alive as HTTP/1.1
//! prescribes.  That subset is exactly what the server
//! ([`serve::server`](crate::serve::server)), the load generator
//! ([`serve::loadgen`](crate::serve::loadgen)) and the e2e tests speak to
//! each other; it is not a general-purpose HTTP implementation.

use std::io::{BufRead, Read, Write};

use crate::{Error, Result};

/// Upper bound on an accepted body (request or response).  Scoring bodies
/// are a few KB of LibSVM lines; anything near this limit is abuse or a
/// framing bug, and rejecting it keeps a malformed client from ballooning
/// server memory.
pub const MAX_BODY_BYTES: u64 = 16 * 1024 * 1024;
/// Request-correlation header: generated at the fleet edge, forwarded on
/// every router→backend hop, echoed in every response.  The id format and
/// span machinery live in [`crate::metrics::trace`].
pub const TRACE_HEADER: &str = "X-Trace-Id";
/// Upper bound on the head (request/status line + headers).
const MAX_HEAD_BYTES: usize = 64 * 1024;

/// One parsed request head + body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// `true` for `HTTP/1.0` (implies close unless keep-alive requested).
    pub http10: bool,
    /// Lower-cased header names, trimmed values, in arrival order.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }

    /// The wire value of [`TRACE_HEADER`], if the client sent one.
    pub fn trace_id(&self) -> Option<&str> {
        self.header(TRACE_HEADER)
    }

    /// Whether the client asked (or defaulted) to keep the connection open.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(str::to_ascii_lowercase) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => !self.http10, // HTTP/1.1 default: keep-alive
        }
    }
}

/// One parsed response head + body (the load-generator side).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }

    /// The echoed [`TRACE_HEADER`] value, if the server sent one back.
    pub fn trace_id(&self) -> Option<&str> {
        self.header(TRACE_HEADER)
    }

    pub fn body_text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    let name = name.to_ascii_lowercase();
    headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
}

/// Read one CRLF (or bare-LF) line, enforcing the head budget.
fn read_line<R: BufRead>(r: &mut R, budget: &mut usize) -> Result<Option<String>> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Ok(None); // clean EOF
    }
    *budget = budget.checked_sub(n).ok_or_else(|| {
        Error::InvalidArg("http head exceeds size limit".into())
    })?;
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(Some(line))
}

/// Headers + optional Content-Length body, shared by both directions.
fn read_headers_and_body<R: BufRead>(
    r: &mut R,
    budget: &mut usize,
) -> Result<(Vec<(String, String)>, Vec<u8>)> {
    let mut headers = Vec::new();
    loop {
        let line = read_line(r, budget)?
            .ok_or_else(|| Error::InvalidArg("http: eof inside headers".into()))?;
        if line.is_empty() {
            break;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| Error::InvalidArg(format!("http: bad header line {line:?}")))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let len: u64 = match header(&headers, "content-length") {
        None => 0,
        Some(v) => v
            .parse()
            .map_err(|_| Error::InvalidArg(format!("http: bad content-length {v:?}")))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(Error::InvalidArg(format!(
            "http: body of {len} bytes exceeds the {MAX_BODY_BYTES} limit"
        )));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    Ok((headers, body))
}

/// Read one request.  `Ok(None)` on clean EOF before any bytes (the client
/// closed a keep-alive connection between requests).
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<Request>> {
    let mut budget = MAX_HEAD_BYTES;
    let Some(line) = read_line(r, &mut budget)? else {
        return Ok(None);
    };
    let mut parts = line.split_ascii_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
        _ => return Err(Error::InvalidArg(format!("http: bad request line {line:?}"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(Error::InvalidArg(format!("http: unsupported version {version:?}")));
    }
    let http10 = version == "HTTP/1.0";
    let (headers, body) = read_headers_and_body(r, &mut budget)?;
    Ok(Some(Request { method, path, http10, headers, body }))
}

/// Read one response (load-generator side).
pub fn read_response<R: BufRead>(r: &mut R) -> Result<Response> {
    let mut budget = MAX_HEAD_BYTES;
    let line = read_line(r, &mut budget)?
        .ok_or_else(|| Error::InvalidArg("http: eof before status line".into()))?;
    // "HTTP/1.1 200 OK"
    let mut parts = line.split_ascii_whitespace();
    let (version, status) = match (parts.next(), parts.next()) {
        (Some(v), Some(s)) => (v, s),
        _ => return Err(Error::InvalidArg(format!("http: bad status line {line:?}"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(Error::InvalidArg(format!("http: unsupported version {version:?}")));
    }
    let status: u16 = status
        .parse()
        .map_err(|_| Error::InvalidArg(format!("http: bad status code in {line:?}")))?;
    let (headers, body) = read_headers_and_body(r, &mut budget)?;
    Ok(Response { status, headers, body })
}

/// Write one response with automatic `Content-Length` framing.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
    write!(w, "Content-Type: text/plain; charset=utf-8\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "\r\n")?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Write one `POST` request with a text body (load-generator side).
pub fn write_post<W: Write>(w: &mut W, path: &str, body: &[u8]) -> Result<()> {
    write_post_with(w, path, &[], body)
}

/// [`write_post`] plus extra headers (the router forwards `X-Top-K` and
/// the loadgen `/similar` mode sets it).
pub fn write_post_with<W: Write>(
    w: &mut W,
    path: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    write!(w, "POST {path} HTTP/1.1\r\n")?;
    write!(w, "Host: bbit-mh\r\n")?;
    write!(w, "Content-Type: text/plain; charset=utf-8\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "Connection: keep-alive\r\n\r\n")?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Write one `GET` request (load-generator / probe side).
pub fn write_get<W: Write>(w: &mut W, path: &str) -> Result<()> {
    write!(w, "GET {path} HTTP/1.1\r\nHost: bbit-mh\r\nConnection: keep-alive\r\n\r\n")?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn request_roundtrip_with_body() {
        let mut wire = Vec::new();
        write_post(&mut wire, "/score", b"+1 3:1 9:1\n").unwrap();
        let req = read_request(&mut BufReader::new(&wire[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/score");
        assert!(!req.http10);
        assert!(req.keep_alive());
        assert_eq!(req.body, b"+1 3:1 9:1\n");
        assert_eq!(req.header("content-length").unwrap(), "11");
    }

    #[test]
    fn response_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 503, "Service Unavailable", &[("Retry-After", "1".into())], b"shed\n")
            .unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(resp.status, 503);
        assert_eq!(resp.header("retry-after").unwrap(), "1");
        assert_eq!(resp.body, b"shed\n");
    }

    #[test]
    fn trace_header_surfaces_on_both_sides() {
        let mut wire = Vec::new();
        write_post_with(&mut wire, "/score", &[(TRACE_HEADER, "00c0ffee".into())], b"x\n")
            .unwrap();
        let req = read_request(&mut BufReader::new(&wire[..])).unwrap().unwrap();
        assert_eq!(req.trace_id(), Some("00c0ffee"));
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "OK", &[(TRACE_HEADER, "00c0ffee".into())], b"ok\n")
            .unwrap();
        let resp = read_response(&mut BufReader::new(&wire[..])).unwrap();
        assert_eq!(resp.trace_id(), Some("00c0ffee"));
    }

    #[test]
    fn keep_alive_defaults_follow_the_version() {
        let wire = b"GET / HTTP/1.1\r\n\r\n";
        let req = read_request(&mut BufReader::new(&wire[..])).unwrap().unwrap();
        assert!(req.keep_alive());
        let wire = b"GET / HTTP/1.0\r\n\r\n";
        let req = read_request(&mut BufReader::new(&wire[..])).unwrap().unwrap();
        assert!(!req.keep_alive());
        let wire = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = read_request(&mut BufReader::new(&wire[..])).unwrap().unwrap();
        assert!(!req.keep_alive());
    }

    #[test]
    fn clean_eof_is_none_and_garbage_is_an_error() {
        assert!(read_request(&mut BufReader::new(&b""[..])).unwrap().is_none());
        assert!(read_request(&mut BufReader::new(&b"not http at all\r\n\r\n"[..])).is_err());
        assert!(read_request(&mut BufReader::new(&b"GET / SPDY/9\r\n\r\n"[..])).is_err());
    }

    #[test]
    fn oversized_body_is_rejected_before_allocation() {
        let wire = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(read_request(&mut BufReader::new(wire.as_bytes())).is_err());
    }

    #[test]
    fn truncated_body_is_an_error() {
        let wire = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort";
        assert!(read_request(&mut BufReader::new(&wire[..])).is_err());
    }
}
