//! k-fold cross-validation over hashed data — the workflow the paper's
//! preprocessing-amortization argument is about (Sections 1 and 6: "a
//! learning task may need to re-use the same (hashed) dataset to perform
//! many cross-validations and parameter tuning").
//!
//! Folds are materialized once from a [`BbitDataset`] (row copies are
//! word-aligned memcpys) and every (C, fold) job runs through the
//! coordinator's scheduler, so a full CV grid costs one hashing pass plus
//! cheap trainings.

use crate::coordinator::scheduler::{Scheduler, SolverKind, TrainJob};
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::util::stats;
use crate::util::Rng;
use crate::{Error, Result};

/// Result of one C value across folds.
#[derive(Clone, Debug)]
pub struct CvPoint {
    pub c: f64,
    pub fold_accuracies: Vec<f64>,
    pub mean_accuracy: f64,
    pub std_accuracy: f64,
}

/// Cross-validation report: every grid point plus the winner.
#[derive(Clone, Debug)]
pub struct CvReport {
    pub points: Vec<CvPoint>,
    pub best_c: f64,
}

/// Split rows into `folds` deterministic shuffled folds.
fn fold_assignments(n: usize, folds: usize, seed: u64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    Rng::new(seed).shuffle(&mut order);
    let mut fold_of = vec![0usize; n];
    for (pos, &row) in order.iter().enumerate() {
        fold_of[row] = pos % folds;
    }
    fold_of
}

fn subset(data: &BbitDataset, rows: &[usize]) -> BbitDataset {
    let mut pc = PackedCodes::zeroed(data.codes.b, data.codes.k, rows.len());
    let mut labels = Vec::with_capacity(rows.len());
    for (dst, &src) in rows.iter().enumerate() {
        pc.copy_row_from(dst, &data.codes, src);
        labels.push(data.labels[src]);
    }
    BbitDataset::new(pc, labels)
}

/// Run `folds`-fold CV for `solver` over `c_grid`; `threads` parallelizes
/// the (C × fold) job matrix.
pub fn cross_validate(
    data: &BbitDataset,
    solver: SolverKind,
    c_grid: &[f64],
    folds: usize,
    seed: u64,
    threads: usize,
) -> Result<CvReport> {
    if folds < 2 || data.len() < folds {
        return Err(Error::InvalidArg(format!(
            "need >= 2 folds and n >= folds (n={}, folds={folds})",
            data.len()
        )));
    }
    if c_grid.is_empty() {
        return Err(Error::InvalidArg("empty C grid".into()));
    }
    let fold_of = fold_assignments(data.len(), folds, seed);
    // materialize train/val pairs once, reuse across the whole C grid
    let mut pairs = Vec::with_capacity(folds);
    for f in 0..folds {
        let (mut tr_rows, mut va_rows) = (Vec::new(), Vec::new());
        for (row, &fo) in fold_of.iter().enumerate() {
            if fo == f {
                va_rows.push(row);
            } else {
                tr_rows.push(row);
            }
        }
        pairs.push((subset(data, &tr_rows), subset(data, &va_rows)));
    }

    let sched = Scheduler::new(threads);
    let mut points = Vec::with_capacity(c_grid.len());
    for &c in c_grid {
        let mut accs = Vec::with_capacity(folds);
        for (tr, va) in &pairs {
            let out = sched.run_grid(
                tr,
                va,
                &[TrainJob { tag: String::new(), solver, c }],
            )?;
            accs.push(out[0].test_accuracy);
        }
        points.push(CvPoint {
            c,
            mean_accuracy: stats::mean(&accs),
            std_accuracy: stats::stddev(&accs),
            fold_accuracies: accs,
        });
    }
    let best_c = points
        .iter()
        .max_by(|a, b| a.mean_accuracy.partial_cmp(&b.mean_accuracy).unwrap())
        .unwrap()
        .c;
    Ok(CvReport { points, best_c })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn learnable(n: usize, seed: u64) -> BbitDataset {
        let (b, k) = (4u32, 16usize);
        let mut rng = Rng::new(seed);
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let row: Vec<u16> = (0..k)
                .map(|_| {
                    if pos {
                        rng.below(8) as u16
                    } else {
                        8 + rng.below(8) as u16
                    }
                })
                .collect();
            pc.push_row(&row).unwrap();
            labels.push(if pos { 1 } else { -1 });
        }
        BbitDataset::new(pc, labels)
    }

    #[test]
    fn folds_partition_rows() {
        let f = fold_assignments(103, 5, 7);
        assert_eq!(f.len(), 103);
        let mut counts = [0usize; 5];
        for &x in &f {
            counts[x] += 1;
        }
        // balanced within 1
        assert!(counts.iter().all(|&c| (20..=21).contains(&c)), "{counts:?}");
    }

    #[test]
    fn cv_finds_a_reasonable_c_and_is_deterministic() {
        let data = learnable(300, 3);
        let grid = [0.0001, 0.01, 1.0];
        let a = cross_validate(&data, SolverKind::SvmDcd, &grid, 4, 11, 2).unwrap();
        let b = cross_validate(&data, SolverKind::SvmDcd, &grid, 4, 11, 1).unwrap();
        assert_eq!(a.best_c, b.best_c);
        assert_eq!(a.points.len(), 3);
        // separable codes: the larger Cs must dominate the tiny one
        let acc_of = |r: &CvReport, c: f64| {
            r.points.iter().find(|p| p.c == c).unwrap().mean_accuracy
        };
        assert!(acc_of(&a, 1.0) >= acc_of(&a, 0.0001));
        assert!(acc_of(&a, a.best_c) > 0.9);
        for p in &a.points {
            assert_eq!(p.fold_accuracies.len(), 4);
        }
    }

    #[test]
    fn cv_rejects_degenerate_inputs() {
        let data = learnable(10, 5);
        assert!(cross_validate(&data, SolverKind::SvmDcd, &[1.0], 1, 0, 1).is_err());
        assert!(cross_validate(&data, SolverKind::SvmDcd, &[], 3, 0, 1).is_err());
        assert!(cross_validate(&data, SolverKind::SvmDcd, &[1.0], 11, 0, 1).is_err());
    }
}
