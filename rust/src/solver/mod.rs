//! The linear-learning substrate (the paper's LIBLINEAR dependency,
//! reimplemented): L2-regularized linear SVM via dual coordinate descent
//! (Hsieh et al., ICML'08 — LIBLINEAR's `-s 1`/`-s 3` solvers) and
//! L2-regularized logistic regression via Newton-CG (the TRON family,
//! LIBLINEAR's `-s 0`), plus an SGD solver matching the semantics of the
//! AOT'd PJRT train artifacts.
//!
//! All solvers are generic over [`FeatureMatrix`], so the same code trains
//! on raw CSR data, VW-hashed real-valued data, and implicit b-bit
//! expanded data (Section 3) without materializing the 2^b·k vectors.
//! The SGD solver additionally has a streaming form ([`SgdStream`],
//! `train_sgd_stream`, `train_from_cache`) that consumes hashed chunks
//! from the pipeline or the on-disk cache in O(dim + batch) memory — the
//! out-of-core path for corpora that never fit in RAM.

pub mod cv;
pub mod dcd_svm;
pub mod linear;
pub mod lr_newton;
pub mod model_io;
pub mod sgd;

pub use cv::{cross_validate, CvReport};
pub use dcd_svm::{train_svm, SvmConfig, SvmLoss};
pub use linear::{accuracy, FeatureMatrix, LinearModel, TrainStats};
pub use lr_newton::{train_lr, LrConfig};
pub use model_io::SavedModel;
pub use sgd::{
    eval_from_cache, train_from_cache, train_from_cache_holdout, train_sgd, train_sgd_stream,
    CacheEval, HoldoutReport, SgdConfig, SgdLoss, SgdStream,
};
