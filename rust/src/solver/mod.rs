//! The linear-learning substrate (the paper's LIBLINEAR dependency,
//! reimplemented): L2-regularized linear SVM via dual coordinate descent
//! (Hsieh et al., ICML'08 — LIBLINEAR's `-s 1`/`-s 3` solvers) and
//! L2-regularized logistic regression via Newton-CG (the TRON family,
//! LIBLINEAR's `-s 0`), plus an SGD solver matching the semantics of the
//! AOT'd PJRT train artifacts.
//!
//! All solvers are generic over [`FeatureMatrix`], so the same code trains
//! on raw CSR data, VW-hashed real-valued data, and implicit b-bit
//! expanded data (Section 3) without materializing the 2^b·k vectors.
//! The SGD solver additionally has a streaming form ([`SgdStream`],
//! `train_sgd_stream`, `train_from_cache`) that consumes hashed chunks
//! from the pipeline or the on-disk cache in O(dim + batch) memory — the
//! out-of-core path for corpora that never fit in RAM.  Cache replay
//! scales with cores: `eval_from_cache_threads` shards the chunk index
//! with a merge reduce (thread-count-invariant results),
//! `train_from_cache_holdout_threads` decodes through the in-order reader
//! pool (bit-for-bit exact), and `train_from_cache_threads` runs per-shard
//! SGD synchronized by iterate averaging at epoch boundaries.

pub mod cv;
pub mod dcd_svm;
pub mod linear;
pub mod lr_newton;
pub mod model_io;
pub mod sgd;

pub use cv::{cross_validate, CvReport};
pub use dcd_svm::{train_svm, SvmConfig, SvmLoss};
pub use linear::{accuracy, FeatureMatrix, LinearModel, TrainStats};
pub use lr_newton::{train_lr, LrConfig};
pub use model_io::{OptState, SavedModel};
pub use sgd::{
    eval_from_cache, eval_from_cache_threads, train_from_cache, train_from_cache_checkpointed,
    train_from_cache_holdout, train_from_cache_holdout_threads, train_from_cache_threads,
    train_sgd, train_sgd_stream, CacheEval, HoldoutReport, SgdConfig, SgdLoss, SgdStream,
};
