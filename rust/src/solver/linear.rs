//! Linear-model core: the [`FeatureMatrix`] abstraction, trained-model
//! container, and evaluation.

use crate::data::dataset::SparseDataset;
use crate::encode::expansion::BbitDataset;
use crate::kernels;

/// xᵢ·w / w += alpha·xᵢ over one packed code row in the implicit 2^b×k
/// expansion (column j of code c lives at `(j << b) + c`).  Both the
/// [`FeatureMatrix`] impl for [`BbitDataset`] and the solver replay paths
/// (which score borrowed scratch buffers without a dataset wrapper) route
/// through [`crate::kernels`], so their f32 accumulation order is
/// structurally identical — the bit-for-bit replay-parity tests depend on
/// that.  Since PR 6 the shared kernel is the unrolled multi-accumulator
/// form (scalar reference twin selectable, see the kernels module docs).
pub(crate) use crate::kernels::{packed_axpy, packed_dot};

/// Row-access abstraction all solvers train against.
///
/// Implemented by raw/VW CSR data ([`SparseDataset`]) and by implicit
/// b-bit expanded data ([`BbitDataset`]) — the latter never materializes
/// its 2^b·k one-hot vectors; `dot`/`axpy` walk the k blocks directly.
pub trait FeatureMatrix: Sync {
    fn n(&self) -> usize;
    fn dim(&self) -> usize;
    /// Label in {−1.0, +1.0}.
    fn label(&self, i: usize) -> f32;
    /// xᵢ · w
    fn dot(&self, i: usize, w: &[f32]) -> f32;
    /// w += alpha · xᵢ
    fn axpy(&self, i: usize, alpha: f32, w: &mut [f32]);
    /// ‖xᵢ‖²
    fn norm_sq(&self, i: usize) -> f32;
    /// Hint that row `i` is about to be dotted against / scattered into
    /// `w`: implementations prefetch the weight cache lines that row
    /// gathers.  Purely a performance hint — correctness-neutral, and a
    /// no-op by default (and under forced-scalar kernel mode).
    #[inline]
    fn prefetch_row(&self, _i: usize, _w: &[f32]) {}
}

impl FeatureMatrix for SparseDataset {
    fn n(&self) -> usize {
        self.len()
    }

    fn dim(&self) -> usize {
        self.dim as usize
    }

    #[inline]
    fn label(&self, i: usize) -> f32 {
        self.labels[i] as f32
    }

    // dot / axpy / norm_sq all route through crate::kernels, so the
    // VW/RP valued rows follow one accumulation convention (the unrolled
    // lane kernels, or their scalar twins under forced-scalar mode) —
    // pre-PR-6 these mixed iterator `sum` and explicit loops.

    #[inline]
    fn dot(&self, i: usize, w: &[f32]) -> f32 {
        let (idx, vals) = self.row(i);
        match vals {
            None => kernels::dot_idx(idx, w),
            Some(vs) => kernels::dot_vals(idx, vs, w),
        }
    }

    #[inline]
    fn axpy(&self, i: usize, alpha: f32, w: &mut [f32]) {
        let (idx, vals) = self.row(i);
        match vals {
            None => kernels::axpy_idx(idx, alpha, w),
            Some(vs) => kernels::axpy_vals(idx, vs, alpha, w),
        }
    }

    #[inline]
    fn norm_sq(&self, i: usize) -> f32 {
        let (idx, vals) = self.row(i);
        match vals {
            None => idx.len() as f32,
            Some(vs) => kernels::sum_sq(vs),
        }
    }

    #[inline]
    fn prefetch_row(&self, i: usize, w: &[f32]) {
        // CSR rows already hold gather indices — prefetch them directly
        kernels::prefetch_weights(w, self.row(i).0);
    }
}

impl FeatureMatrix for BbitDataset {
    fn n(&self) -> usize {
        self.len()
    }

    fn dim(&self) -> usize {
        BbitDataset::dim(self)
    }

    #[inline]
    fn label(&self, i: usize) -> f32 {
        self.labels[i] as f32
    }

    #[inline]
    fn dot(&self, i: usize, w: &[f32]) -> f32 {
        packed_dot(&self.codes, i, w)
    }

    #[inline]
    fn axpy(&self, i: usize, alpha: f32, w: &mut [f32]) {
        packed_axpy(&self.codes, i, alpha, w)
    }

    #[inline]
    fn norm_sq(&self, _i: usize) -> f32 {
        // exactly k ones per expanded row (Section 3)
        self.codes.k as f32
    }

    #[inline]
    fn prefetch_row(&self, i: usize, w: &[f32]) {
        kernels::packed_prefetch(&self.codes, i, w);
    }
}

/// A trained linear model.
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub w: Vec<f32>,
}

impl LinearModel {
    pub fn zeros(dim: usize) -> Self {
        LinearModel { w: vec![0.0; dim] }
    }

    pub fn margin<F: FeatureMatrix>(&self, data: &F, i: usize) -> f32 {
        data.dot(i, &self.w)
    }

    pub fn predict<F: FeatureMatrix>(&self, data: &F, i: usize) -> i8 {
        if self.margin(data, i) >= 0.0 {
            1
        } else {
            -1
        }
    }
}

/// Classification accuracy of `model` on `data`.
pub fn accuracy<F: FeatureMatrix>(model: &LinearModel, data: &F) -> f64 {
    let n = data.n();
    if n == 0 {
        return 0.0;
    }
    let mut correct = 0usize;
    for i in 0..n {
        if i + 1 < n {
            data.prefetch_row(i + 1, &model.w);
        }
        if model.predict(data, i) as f32 == data.label(i) {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Common training telemetry every solver reports.
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    /// Outer iterations (or epochs) executed.
    pub iterations: usize,
    /// Final objective value (primal).
    pub objective: f64,
    /// Whether the stopping tolerance was reached (vs iteration cap).
    pub converged: bool,
    /// Wall-clock seconds spent in the solver.
    pub train_seconds: f64,
}

/// Primal objective 0.5‖w‖² + C·Σ loss(yᵢ·mᵢ) — shared by solvers/tests.
pub fn primal_objective<F: FeatureMatrix>(
    data: &F,
    w: &[f32],
    c: f64,
    loss: impl Fn(f64) -> f64,
) -> f64 {
    let reg: f64 = 0.5 * w.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
    let total: f64 = (0..data.n())
        .map(|i| loss(data.label(i) as f64 * data.dot(i, w) as f64))
        .sum();
    reg + c * total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Example;
    use crate::encode::packed::PackedCodes;

    fn csr() -> SparseDataset {
        SparseDataset::from_examples(
            8,
            &[
                Example::binary(1, vec![0, 1]),
                Example::binary(-1, vec![2, 3]),
            ],
        )
    }

    #[test]
    fn csr_dot_axpy_norm() {
        let ds = csr();
        let mut w = vec![0.0f32; 8];
        ds.axpy(0, 2.0, &mut w);
        assert_eq!(&w[..4], &[2.0, 2.0, 0.0, 0.0]);
        assert_eq!(ds.dot(0, &w), 4.0);
        assert_eq!(ds.dot(1, &w), 0.0);
        assert_eq!(ds.norm_sq(0), 2.0);
    }

    #[test]
    fn valued_rows() {
        let mut ds = SparseDataset::new(4);
        ds.push(&Example { label: 1, indices: vec![1, 3], values: Some(vec![0.5, -2.0]) });
        let mut w = vec![1.0f32; 4];
        assert_eq!(ds.dot(0, &w), 0.5 - 2.0);
        ds.axpy(0, 1.0, &mut w);
        assert_eq!(w, vec![1.0, 1.5, 1.0, -1.0]);
        assert_eq!(ds.norm_sq(0), 0.25 + 4.0);
    }

    #[test]
    fn bbit_matches_materialized_csr() {
        let mut pc = PackedCodes::new(4, 6);
        pc.push_row(&[0, 3, 7, 15, 2, 9]).unwrap();
        pc.push_row(&[1, 1, 1, 1, 1, 1]).unwrap();
        let bb = BbitDataset::new(pc, vec![1, -1]);
        let csr = bb.to_sparse_dataset();
        let mut w: Vec<f32> = (0..bb.dim()).map(|i| (i % 13) as f32 * 0.1).collect();
        for i in 0..2 {
            assert!((FeatureMatrix::dot(&bb, i, &w) - csr.dot(i, &w)).abs() < 1e-5);
            assert_eq!(FeatureMatrix::norm_sq(&bb, i), 6.0);
        }
        let mut w2 = w.clone();
        FeatureMatrix::axpy(&bb, 0, 0.5, &mut w);
        csr.axpy(0, 0.5, &mut w2);
        assert_eq!(w, w2);
    }

    #[test]
    fn accuracy_counts() {
        let ds = csr();
        let mut model = LinearModel::zeros(8);
        model.w[0] = 1.0; // predicts +1 for row 0, +1 (margin 0) for row 1
        assert_eq!(accuracy(&model, &ds), 0.5);
        model.w[2] = -1.0;
        model.w[3] = -1.0;
        assert_eq!(accuracy(&model, &ds), 1.0);
    }
}
