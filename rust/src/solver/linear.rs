//! Linear-model core: the [`FeatureMatrix`] abstraction, trained-model
//! container, and evaluation.

use crate::data::dataset::SparseDataset;
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;

/// xᵢ·w over one packed code row in the implicit 2^b×k expansion (column
/// j of code c lives at `(j << b) + c`).  The [`FeatureMatrix`] impl for
/// [`BbitDataset`] and the solver replay paths (which score borrowed
/// scratch buffers without a dataset wrapper) both call this, so their
/// f32 accumulation order is structurally identical — the bit-for-bit
/// replay-parity tests depend on that.
#[inline]
pub(crate) fn packed_dot(codes: &PackedCodes, i: usize, w: &[f32]) -> f32 {
    let b = codes.b as usize;
    let mut acc = 0.0;
    for j in 0..codes.k {
        acc += w[(j << b) + codes.get(i, j) as usize];
    }
    acc
}

/// w += alpha·xᵢ over one packed code row (update twin of [`packed_dot`]).
#[inline]
pub(crate) fn packed_axpy(codes: &PackedCodes, i: usize, alpha: f32, w: &mut [f32]) {
    let b = codes.b as usize;
    for j in 0..codes.k {
        w[(j << b) + codes.get(i, j) as usize] += alpha;
    }
}

/// Row-access abstraction all solvers train against.
///
/// Implemented by raw/VW CSR data ([`SparseDataset`]) and by implicit
/// b-bit expanded data ([`BbitDataset`]) — the latter never materializes
/// its 2^b·k one-hot vectors; `dot`/`axpy` walk the k blocks directly.
pub trait FeatureMatrix: Sync {
    fn n(&self) -> usize;
    fn dim(&self) -> usize;
    /// Label in {−1.0, +1.0}.
    fn label(&self, i: usize) -> f32;
    /// xᵢ · w
    fn dot(&self, i: usize, w: &[f32]) -> f32;
    /// w += alpha · xᵢ
    fn axpy(&self, i: usize, alpha: f32, w: &mut [f32]);
    /// ‖xᵢ‖²
    fn norm_sq(&self, i: usize) -> f32;
}

impl FeatureMatrix for SparseDataset {
    fn n(&self) -> usize {
        self.len()
    }

    fn dim(&self) -> usize {
        self.dim as usize
    }

    #[inline]
    fn label(&self, i: usize) -> f32 {
        self.labels[i] as f32
    }

    #[inline]
    fn dot(&self, i: usize, w: &[f32]) -> f32 {
        let (idx, vals) = self.row(i);
        match vals {
            None => idx.iter().map(|&t| w[t as usize]).sum(),
            Some(vs) => idx
                .iter()
                .zip(vs)
                .map(|(&t, &v)| w[t as usize] * v)
                .sum(),
        }
    }

    #[inline]
    fn axpy(&self, i: usize, alpha: f32, w: &mut [f32]) {
        let (idx, vals) = self.row(i);
        match vals {
            None => {
                for &t in idx {
                    w[t as usize] += alpha;
                }
            }
            Some(vs) => {
                for (&t, &v) in idx.iter().zip(vs) {
                    w[t as usize] += alpha * v;
                }
            }
        }
    }

    #[inline]
    fn norm_sq(&self, i: usize) -> f32 {
        let (idx, vals) = self.row(i);
        match vals {
            None => idx.len() as f32,
            Some(vs) => vs.iter().map(|v| v * v).sum(),
        }
    }
}

impl FeatureMatrix for BbitDataset {
    fn n(&self) -> usize {
        self.len()
    }

    fn dim(&self) -> usize {
        BbitDataset::dim(self)
    }

    #[inline]
    fn label(&self, i: usize) -> f32 {
        self.labels[i] as f32
    }

    #[inline]
    fn dot(&self, i: usize, w: &[f32]) -> f32 {
        packed_dot(&self.codes, i, w)
    }

    #[inline]
    fn axpy(&self, i: usize, alpha: f32, w: &mut [f32]) {
        packed_axpy(&self.codes, i, alpha, w)
    }

    #[inline]
    fn norm_sq(&self, _i: usize) -> f32 {
        // exactly k ones per expanded row (Section 3)
        self.codes.k as f32
    }
}

/// A trained linear model.
#[derive(Clone, Debug)]
pub struct LinearModel {
    pub w: Vec<f32>,
}

impl LinearModel {
    pub fn zeros(dim: usize) -> Self {
        LinearModel { w: vec![0.0; dim] }
    }

    pub fn margin<F: FeatureMatrix>(&self, data: &F, i: usize) -> f32 {
        data.dot(i, &self.w)
    }

    pub fn predict<F: FeatureMatrix>(&self, data: &F, i: usize) -> i8 {
        if self.margin(data, i) >= 0.0 {
            1
        } else {
            -1
        }
    }
}

/// Classification accuracy of `model` on `data`.
pub fn accuracy<F: FeatureMatrix>(model: &LinearModel, data: &F) -> f64 {
    if data.n() == 0 {
        return 0.0;
    }
    let correct = (0..data.n())
        .filter(|&i| model.predict(data, i) as f32 == data.label(i))
        .count();
    correct as f64 / data.n() as f64
}

/// Common training telemetry every solver reports.
#[derive(Clone, Debug, Default)]
pub struct TrainStats {
    /// Outer iterations (or epochs) executed.
    pub iterations: usize,
    /// Final objective value (primal).
    pub objective: f64,
    /// Whether the stopping tolerance was reached (vs iteration cap).
    pub converged: bool,
    /// Wall-clock seconds spent in the solver.
    pub train_seconds: f64,
}

/// Primal objective 0.5‖w‖² + C·Σ loss(yᵢ·mᵢ) — shared by solvers/tests.
pub fn primal_objective<F: FeatureMatrix>(
    data: &F,
    w: &[f32],
    c: f64,
    loss: impl Fn(f64) -> f64,
) -> f64 {
    let reg: f64 = 0.5 * w.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
    let total: f64 = (0..data.n())
        .map(|i| loss(data.label(i) as f64 * data.dot(i, w) as f64))
        .sum();
    reg + c * total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Example;
    use crate::encode::packed::PackedCodes;

    fn csr() -> SparseDataset {
        SparseDataset::from_examples(
            8,
            &[
                Example::binary(1, vec![0, 1]),
                Example::binary(-1, vec![2, 3]),
            ],
        )
    }

    #[test]
    fn csr_dot_axpy_norm() {
        let ds = csr();
        let mut w = vec![0.0f32; 8];
        ds.axpy(0, 2.0, &mut w);
        assert_eq!(&w[..4], &[2.0, 2.0, 0.0, 0.0]);
        assert_eq!(ds.dot(0, &w), 4.0);
        assert_eq!(ds.dot(1, &w), 0.0);
        assert_eq!(ds.norm_sq(0), 2.0);
    }

    #[test]
    fn valued_rows() {
        let mut ds = SparseDataset::new(4);
        ds.push(&Example { label: 1, indices: vec![1, 3], values: Some(vec![0.5, -2.0]) });
        let mut w = vec![1.0f32; 4];
        assert_eq!(ds.dot(0, &w), 0.5 - 2.0);
        ds.axpy(0, 1.0, &mut w);
        assert_eq!(w, vec![1.0, 1.5, 1.0, -1.0]);
        assert_eq!(ds.norm_sq(0), 0.25 + 4.0);
    }

    #[test]
    fn bbit_matches_materialized_csr() {
        let mut pc = PackedCodes::new(4, 6);
        pc.push_row(&[0, 3, 7, 15, 2, 9]).unwrap();
        pc.push_row(&[1, 1, 1, 1, 1, 1]).unwrap();
        let bb = BbitDataset::new(pc, vec![1, -1]);
        let csr = bb.to_sparse_dataset();
        let mut w: Vec<f32> = (0..bb.dim()).map(|i| (i % 13) as f32 * 0.1).collect();
        for i in 0..2 {
            assert!((FeatureMatrix::dot(&bb, i, &w) - csr.dot(i, &w)).abs() < 1e-5);
            assert_eq!(FeatureMatrix::norm_sq(&bb, i), 6.0);
        }
        let mut w2 = w.clone();
        FeatureMatrix::axpy(&bb, 0, 0.5, &mut w);
        csr.axpy(0, 0.5, &mut w2);
        assert_eq!(w, w2);
    }

    #[test]
    fn accuracy_counts() {
        let ds = csr();
        let mut model = LinearModel::zeros(8);
        model.w[0] = 1.0; // predicts +1 for row 0, +1 (margin 0) for row 1
        assert_eq!(accuracy(&model, &ds), 0.5);
        model.w[2] = -1.0;
        model.w[3] = -1.0;
        assert_eq!(accuracy(&model, &ds), 1.0);
    }
}
