//! SGD solver with Bottou's learning-rate schedule.
//!
//! Two roles: (a) the "online algorithm" the paper's loading-time argument
//! mentions (Section 1); (b) the *native twin* of the AOT'd PJRT train
//! artifacts — `train_chunk` in `python/compile/model.py` implements the
//! same update, so the cross-layer parity test drives both on identical
//! data and requires near-identical weights.
//!
//! Objective (per-example averaged):  λ/2 ‖w‖² + (1/n) Σ loss(yᵢ wᵀxᵢ),
//! with λ = 1/(C·n) mapping to the paper's C convention.  Minibatch step:
//!
//!   w ← (1 − η λ) w − η · (1/B) Σ_{i∈batch} ∂loss/∂m · xᵢ,
//!   η(t) = η₀ / (1 + t·λ·η₀).

use std::time::Instant;

use crate::solver::linear::{FeatureMatrix, LinearModel, TrainStats};

/// Loss selector matching the PJRT artifact pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SgdLoss {
    Logistic,
    SquaredHinge,
}

impl SgdLoss {
    /// dLoss/dMargin at (margin, label).
    #[inline]
    pub fn grad_coef(self, m: f32, y: f32) -> f32 {
        match self {
            SgdLoss::Logistic => -y / (1.0 + (y * m).exp()),
            SgdLoss::SquaredHinge => -2.0 * y * (1.0 - y * m).max(0.0),
        }
    }

    #[inline]
    pub fn loss(self, m: f64, y: f64) -> f64 {
        match self {
            SgdLoss::Logistic => {
                let ym = y * m;
                if ym > 0.0 {
                    (-ym).exp().ln_1p()
                } else {
                    -ym + ym.exp().ln_1p()
                }
            }
            SgdLoss::SquaredHinge => {
                let v = (1.0 - y * m).max(0.0);
                v * v
            }
        }
    }
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct SgdConfig {
    pub loss: SgdLoss,
    /// Initial learning rate η₀.
    pub lr0: f64,
    /// Regularization λ (use `lambda_from_c` to map from the paper's C).
    pub lambda: f64,
    pub epochs: usize,
    pub batch: usize,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { loss: SgdLoss::Logistic, lr0: 0.5, lambda: 1e-4, epochs: 10, batch: 256 }
    }
}

/// λ = 1/(C·n): the SVM/LR "C" convention to per-example λ.
pub fn lambda_from_c(c: f64, n: usize) -> f64 {
    1.0 / (c * n as f64)
}

/// Train by minibatch SGD.  Deterministic: fixed in-order minibatches, the
/// same order as the PJRT `train_chunk` artifact scans (no shuffling, so
/// the parity test can compare weights).
pub fn train_sgd<F: FeatureMatrix>(data: &F, cfg: &SgdConfig) -> (LinearModel, TrainStats) {
    let t0 = Instant::now();
    let n = data.n();
    let mut w = vec![0.0f32; data.dim()];
    let mut step = 0u64;
    let mut stats = TrainStats::default();
    let mut coefs: Vec<f32> = Vec::with_capacity(cfg.batch);
    for _ in 0..cfg.epochs {
        let mut i0 = 0;
        while i0 < n {
            let bsz = cfg.batch.min(n - i0);
            let lr = cfg.lr0 / (1.0 + step as f64 * cfg.lambda * cfg.lr0);
            // margins/grad coefficients first (batch semantics: all margins
            // computed against the pre-update w, matching the artifact)
            coefs.clear();
            for i in i0..i0 + bsz {
                let m = data.dot(i, &w);
                coefs.push(cfg.loss.grad_coef(m, data.label(i)));
            }
            // decay + accumulate
            let decay = (1.0 - lr * cfg.lambda) as f32;
            if decay != 1.0 {
                w.iter_mut().for_each(|x| *x *= decay);
            }
            let scale = (lr / bsz as f64) as f32;
            for (off, i) in (i0..i0 + bsz).enumerate() {
                let g = coefs[off];
                if g != 0.0 {
                    data.axpy(i, -scale * g, &mut w);
                }
            }
            step += 1;
            i0 += bsz;
        }
        stats.iterations += 1;
    }
    stats.converged = true;
    stats.objective = {
        let reg = 0.5
            * cfg.lambda
            * w.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
        let avg: f64 = (0..n)
            .map(|i| cfg.loss.loss(data.dot(i, &w) as f64, data.label(i) as f64))
            .sum::<f64>()
            / n as f64;
        reg + avg
    };
    stats.train_seconds = t0.elapsed().as_secs_f64();
    (LinearModel { w }, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Example, SparseDataset};
    use crate::solver::linear::accuracy;
    use crate::util::Rng;

    fn separable(n: usize, seed: u64) -> SparseDataset {
        let mut rng = Rng::new(seed);
        let mut examples = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let base = if pos { 0 } else { 16 };
            let feats: Vec<u32> =
                (0..6).map(|_| base + rng.below(16) as u32).collect();
            examples.push(Example::binary(if pos { 1 } else { -1 }, feats));
        }
        SparseDataset::from_examples(32, &examples)
    }

    #[test]
    fn learns_separable_data_both_losses() {
        let ds = separable(512, 51);
        for loss in [SgdLoss::Logistic, SgdLoss::SquaredHinge] {
            let cfg = SgdConfig { loss, epochs: 20, batch: 64, ..Default::default() };
            let (model, _) = train_sgd(&ds, &cfg);
            assert!(accuracy(&model, &ds) > 0.97, "{loss:?}");
        }
    }

    #[test]
    fn deterministic() {
        let ds = separable(128, 53);
        let cfg = SgdConfig::default();
        let (m1, _) = train_sgd(&ds, &cfg);
        let (m2, _) = train_sgd(&ds, &cfg);
        assert_eq!(m1.w, m2.w);
    }

    #[test]
    fn objective_decreases_over_epochs() {
        let ds = separable(256, 57);
        let short = train_sgd(&ds, &SgdConfig { epochs: 1, ..Default::default() });
        let long = train_sgd(&ds, &SgdConfig { epochs: 15, ..Default::default() });
        assert!(long.1.objective < short.1.objective);
    }

    #[test]
    fn grad_coefs_match_losses() {
        // logistic at m=0: -y/2; sqhinge at (m=0,y=1): -2
        assert!((SgdLoss::Logistic.grad_coef(0.0, 1.0) + 0.5).abs() < 1e-6);
        assert!((SgdLoss::SquaredHinge.grad_coef(0.0, 1.0) + 2.0).abs() < 1e-6);
        // no gradient beyond the margin for hinge
        assert_eq!(SgdLoss::SquaredHinge.grad_coef(2.0, 1.0), 0.0);
    }

    #[test]
    fn lambda_from_c_mapping() {
        assert!((lambda_from_c(1.0, 1000) - 1e-3).abs() < 1e-12);
        assert!((lambda_from_c(10.0, 100) - 1e-3).abs() < 1e-12);
    }
}
