//! SGD solver with Bottou's learning-rate schedule.
//!
//! Two roles: (a) the "online algorithm" the paper's loading-time argument
//! mentions (Section 1); (b) the *native twin* of the AOT'd PJRT train
//! artifacts — `train_chunk` in `python/compile/model.py` implements the
//! same update, so the cross-layer parity test drives both on identical
//! data and requires near-identical weights.
//!
//! Objective (per-example averaged):  λ/2 ‖w‖² + (1/n) Σ loss(yᵢ wᵀxᵢ),
//! with λ = 1/(C·n) mapping to the paper's C convention.  Minibatch step:
//!
//!   w ← (1 − η λ) w − η · (1/B) Σ_{i∈batch} ∂loss/∂m · xᵢ,
//!   η(t) = η₀ / (1 + t·λ·η₀).

use std::path::Path;
use std::time::Instant;

use crate::coordinator::replay::{load_index_or_warn, replay_cache_with};
use crate::coordinator::sharding::ShardPlan;
use crate::encode::cache::{CacheReader, ChunkIndex, IndexedCacheReader};
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::kernels::{self, RowGather};
use crate::solver::linear::{packed_dot, FeatureMatrix, LinearModel, TrainStats};
use crate::solver::model_io::SavedModel;
use crate::{Error, Result};

/// Loss selector matching the PJRT artifact pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SgdLoss {
    Logistic,
    SquaredHinge,
}

impl SgdLoss {
    /// dLoss/dMargin at (margin, label).
    #[inline]
    pub fn grad_coef(self, m: f32, y: f32) -> f32 {
        match self {
            SgdLoss::Logistic => -y / (1.0 + (y * m).exp()),
            SgdLoss::SquaredHinge => -2.0 * y * (1.0 - y * m).max(0.0),
        }
    }

    #[inline]
    pub fn loss(self, m: f64, y: f64) -> f64 {
        match self {
            SgdLoss::Logistic => {
                let ym = y * m;
                if ym > 0.0 {
                    (-ym).exp().ln_1p()
                } else {
                    -ym + ym.exp().ln_1p()
                }
            }
            SgdLoss::SquaredHinge => {
                let v = (1.0 - y * m).max(0.0);
                v * v
            }
        }
    }
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct SgdConfig {
    pub loss: SgdLoss,
    /// Initial learning rate η₀.
    pub lr0: f64,
    /// Regularization λ (use `lambda_from_c` to map from the paper's C).
    pub lambda: f64,
    pub epochs: usize,
    pub batch: usize,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { loss: SgdLoss::Logistic, lr0: 0.5, lambda: 1e-4, epochs: 10, batch: 256 }
    }
}

/// λ = 1/(C·n): the SVM/LR "C" convention to per-example λ.
pub fn lambda_from_c(c: f64, n: usize) -> f64 {
    1.0 / (c * n as f64)
}

/// Train by minibatch SGD.  Deterministic: fixed in-order minibatches, the
/// same order as the PJRT `train_chunk` artifact scans (no shuffling, so
/// the parity test can compare weights).
pub fn train_sgd<F: FeatureMatrix>(data: &F, cfg: &SgdConfig) -> (LinearModel, TrainStats) {
    let t0 = Instant::now();
    let n = data.n();
    let mut w = vec![0.0f32; data.dim()];
    let mut step = 0u64;
    let mut stats = TrainStats::default();
    let mut coefs: Vec<f32> = Vec::with_capacity(cfg.batch);
    for _ in 0..cfg.epochs {
        let mut i0 = 0;
        while i0 < n {
            let bsz = cfg.batch.min(n - i0);
            let lr = cfg.lr0 / (1.0 + step as f64 * cfg.lambda * cfg.lr0);
            // margins/grad coefficients first (batch semantics: all margins
            // computed against the pre-update w, matching the artifact)
            coefs.clear();
            for i in i0..i0 + bsz {
                if i + 1 < i0 + bsz {
                    data.prefetch_row(i + 1, &w);
                }
                let m = data.dot(i, &w);
                coefs.push(cfg.loss.grad_coef(m, data.label(i)));
            }
            // decay + accumulate
            let decay = (1.0 - lr * cfg.lambda) as f32;
            if decay != 1.0 {
                w.iter_mut().for_each(|x| *x *= decay);
            }
            let scale = (lr / bsz as f64) as f32;
            for (off, i) in (i0..i0 + bsz).enumerate() {
                if i + 1 < i0 + bsz {
                    data.prefetch_row(i + 1, &w);
                }
                let g = coefs[off];
                if g != 0.0 {
                    data.axpy(i, -scale * g, &mut w);
                }
            }
            step += 1;
            i0 += bsz;
        }
        stats.iterations += 1;
    }
    stats.converged = true;
    stats.objective = {
        let reg = 0.5
            * cfg.lambda
            * w.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
        let avg: f64 = (0..n)
            .map(|i| cfg.loss.loss(data.dot(i, &w) as f64, data.label(i) as f64))
            .sum::<f64>()
            / n as f64;
        reg + avg
    };
    stats.train_seconds = t0.elapsed().as_secs_f64();
    (LinearModel { w }, stats)
}

/// Streaming twin of [`train_sgd`] for b-bit chunk streams.
///
/// Holds the weight vector plus at most one minibatch of buffered rows —
/// memory is O(dim + batch·k), independent of corpus size.  Rows arrive in
/// chunks (from the pipeline's [`TrainSink`](crate::coordinator::sink) or
/// a cache replay); the trainer re-batches them into exactly the minibatch
/// sequence [`train_sgd`] would visit, so for the same row order, batch
/// size, and epoch count the final weights are identical to
/// materialize-then-`train_sgd` (the integration test asserts this).
///
/// One pass = `push_chunk`… then [`end_epoch`](Self::end_epoch) (which
/// flushes the final partial minibatch exactly like `train_sgd`'s tail
/// batch).  Multi-epoch training replays the stream and calls `end_epoch`
/// after each pass; the step counter (and thus the learning-rate schedule)
/// carries across epochs, as in `train_sgd`.
pub struct SgdStream {
    cfg: SgdConfig,
    b: u32,
    k: usize,
    w: Vec<f32>,
    step: u64,
    /// Partial minibatch (always < cfg.batch rows between calls).
    buf: BbitDataset,
    row_scratch: Vec<u16>,
    /// Double-buffered row decode + one-row-ahead weight prefetch for the
    /// minibatch inner loops (see [`crate::kernels::RowGather`]).
    gather: RowGather,
    coefs: Vec<f32>,
    rows_seen: u64,
    epochs_done: usize,
    loss_sum: f64,
    t0: Instant,
}

impl SgdStream {
    pub fn new(cfg: SgdConfig, b: u32, k: usize) -> Self {
        assert!(cfg.batch > 0, "batch must be positive");
        let dim = (1usize << b) * k;
        SgdStream {
            cfg,
            b,
            k,
            w: vec![0.0f32; dim],
            step: 0,
            buf: BbitDataset::new(PackedCodes::new(b, k), Vec::new()),
            row_scratch: vec![0u16; k],
            gather: RowGather::new(k),
            coefs: Vec::new(),
            rows_seen: 0,
            epochs_done: 0,
            loss_sum: 0.0,
            t0: Instant::now(),
        }
    }

    /// Expanded dimensionality 2^b · k of the weight vector.
    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Rows consumed so far (across all epochs).
    pub fn rows_seen(&self) -> u64 {
        self.rows_seen
    }

    /// Mean pre-update loss over every row seen so far — VW-style
    /// progressive validation (each row is scored before the model has
    /// trained on it within its minibatch).
    pub fn progressive_loss(&self) -> f64 {
        self.loss_sum / self.rows_seen.max(1) as f64
    }

    /// Feed one hashed chunk by value — the pipeline sink and the
    /// allocating cache iterator own their chunks.  Same semantics as
    /// [`push_chunk_ref`](Self::push_chunk_ref).
    pub fn push_chunk(&mut self, codes: PackedCodes, labels: Vec<i8>) -> Result<()> {
        self.push_chunk_ref(&codes, &labels)
    }

    /// Feed one hashed chunk by reference (the replay hot path: callers
    /// keep reusable scratch buffers and nothing is allocated per chunk);
    /// applies a minibatch update every time `cfg.batch` rows have
    /// accumulated.  A chunk that aligns with the minibatch boundary
    /// (empty buffer, exactly `batch` rows — the CLI default: pipeline
    /// chunk_size == SGD batch) is consumed in place with no per-row
    /// unpack/repack.
    pub fn push_chunk_ref(&mut self, codes: &PackedCodes, labels: &[i8]) -> Result<()> {
        if codes.b != self.b || codes.k != self.k {
            return Err(Error::InvalidArg(format!(
                "chunk geometry (b={}, k={}) does not match trainer (b={}, k={})",
                codes.b, codes.k, self.b, self.k
            )));
        }
        if codes.n != labels.len() {
            return Err(Error::InvalidArg(format!(
                "chunk has {} rows but {} labels",
                codes.n,
                labels.len()
            )));
        }
        if self.buf.is_empty() && codes.n == self.cfg.batch {
            // aligned fast path: one whole minibatch, zero copies
            Self::minibatch_step(
                &self.cfg,
                &mut self.w,
                &mut self.step,
                &mut self.rows_seen,
                &mut self.loss_sum,
                &mut self.coefs,
                &mut self.gather,
                codes,
                labels,
            );
            return Ok(());
        }
        for i in 0..codes.n {
            codes.row_into(i, &mut self.row_scratch);
            self.buf.codes.push_row(&self.row_scratch)?;
            self.buf.labels.push(labels[i]);
            if self.buf.len() == self.cfg.batch {
                self.apply_buffered_batch();
            }
        }
        Ok(())
    }

    /// End the current pass: flush the partial tail minibatch (identical
    /// to `train_sgd`'s final `min(batch, n - i0)` batch of an epoch).
    /// Emits a `train.epoch` trace point (epoch index, rows seen,
    /// progressive loss) when tracing is on — the training curve as an
    /// observable event stream, not just the final TrainStats.
    pub fn end_epoch(&mut self) {
        self.apply_buffered_batch();
        self.epochs_done += 1;
        crate::metrics::trace::point(
            "train.epoch",
            &[
                ("epoch", self.epochs_done as f64),
                ("rows", self.rows_seen as f64),
                ("loss", self.progressive_loss()),
            ],
        );
    }

    fn apply_buffered_batch(&mut self) {
        Self::minibatch_step(
            &self.cfg,
            &mut self.w,
            &mut self.step,
            &mut self.rows_seen,
            &mut self.loss_sum,
            &mut self.coefs,
            &mut self.gather,
            &self.buf.codes,
            &self.buf.labels,
        );
        self.buf.codes.clear();
        self.buf.labels.clear();
    }

    /// One `train_sgd` minibatch step over all rows of a packed chunk (an
    /// associated fn taking fields explicitly so callers can pass either
    /// the internal buffer or a borrowed whole chunk).  Rows are decoded
    /// once per loop through `gather`, which also prefetches the next
    /// row's weight lines while the current row computes.
    #[allow(clippy::too_many_arguments)]
    fn minibatch_step(
        cfg: &SgdConfig,
        w: &mut [f32],
        step: &mut u64,
        rows_seen: &mut u64,
        loss_sum: &mut f64,
        coefs: &mut Vec<f32>,
        gather: &mut RowGather,
        codes: &PackedCodes,
        labels: &[i8],
    ) {
        let bsz = codes.n;
        if bsz == 0 {
            return;
        }
        let lr = cfg.lr0 / (1.0 + *step as f64 * cfg.lambda * cfg.lr0);
        coefs.clear();
        gather.begin(codes, 0);
        for i in 0..bsz {
            if i + 1 < bsz {
                gather.stage(codes, i + 1, w);
            }
            let m = kernels::dot_idx(gather.indices(), w);
            let y = labels[i] as f32;
            coefs.push(cfg.loss.grad_coef(m, y));
            *loss_sum += cfg.loss.loss(m as f64, y as f64);
            if i + 1 < bsz {
                gather.advance(codes, i + 1);
            }
        }
        let decay = (1.0 - lr * cfg.lambda) as f32;
        if decay != 1.0 {
            w.iter_mut().for_each(|x| *x *= decay);
        }
        let scale = (lr / bsz as f64) as f32;
        gather.begin(codes, 0);
        for (i, &g) in coefs.iter().enumerate() {
            if i + 1 < bsz {
                gather.stage(codes, i + 1, w);
            }
            if g != 0.0 {
                kernels::axpy_idx(gather.indices(), -scale * g, w);
            }
            if i + 1 < bsz {
                gather.advance(codes, i + 1);
            }
        }
        *step += 1;
        *rows_seen += bsz as u64;
    }

    /// Read-only view of the current weights (mid-stream evaluation).
    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Overwrite the weight vector (same length) — the iterate-averaging
    /// synchronization point of parallel cache replay: per-shard trainers
    /// are reset to the averaged iterate at each epoch boundary while
    /// their step counters (and so the learning-rate schedule) carry on.
    pub fn set_weights(&mut self, w: &[f32]) -> Result<()> {
        if w.len() != self.w.len() {
            return Err(Error::InvalidArg(format!(
                "weight vector has {} entries, trainer expects {}",
                w.len(),
                self.w.len()
            )));
        }
        self.w.copy_from_slice(w);
        Ok(())
    }

    /// Total pre-update loss accumulated so far (numerator of
    /// [`progressive_loss`](Self::progressive_loss)) — lets an aggregator
    /// combine several shard trainers exactly.
    pub fn loss_sum(&self) -> f64 {
        self.loss_sum
    }

    /// Snapshot the optimizer state for a checkpoint (the weights travel
    /// separately as the model vector).  Only meaningful at an epoch
    /// boundary: the partial-minibatch buffer is *not* part of the
    /// snapshot, and [`train_from_cache_checkpointed`] only checkpoints
    /// after [`end_epoch`](Self::end_epoch), when the buffer is empty.
    pub fn opt_state(&self) -> crate::solver::model_io::OptState {
        crate::solver::model_io::OptState {
            step: self.step,
            rows_seen: self.rows_seen,
            epochs_done: self.epochs_done,
            loss_sum: self.loss_sum,
        }
    }

    /// Restore a snapshot taken by [`opt_state`](Self::opt_state);
    /// together with [`set_weights`](Self::set_weights) this resumes the
    /// schedule exactly where the checkpoint left it — step counter,
    /// learning rate, progressive loss, all bit-identical.
    pub fn restore_opt_state(&mut self, s: &crate::solver::model_io::OptState) {
        self.step = s.step;
        self.rows_seen = s.rows_seen;
        self.epochs_done = s.epochs_done;
        self.loss_sum = s.loss_sum;
    }

    /// Consume the trainer.  `TrainStats.objective` is the *progressive
    /// loss* (no second pass over data that may already be gone), not the
    /// batch objective `train_sgd` reports.
    pub fn finalize(self) -> (LinearModel, TrainStats) {
        let stats = TrainStats {
            iterations: self.epochs_done,
            objective: self.progressive_loss(),
            converged: true,
            train_seconds: self.t0.elapsed().as_secs_f64(),
        };
        (LinearModel { w: self.w }, stats)
    }
}

/// Single-pass hash-and-train: drain a chunk stream through [`SgdStream`].
/// `cfg.epochs` is ignored — a stream can only be seen once; replay a
/// cache via [`train_from_cache`] for multi-epoch training.
pub fn train_sgd_stream<I>(
    chunks: I,
    b: u32,
    k: usize,
    cfg: &SgdConfig,
) -> Result<(LinearModel, TrainStats)>
where
    I: Iterator<Item = Result<(PackedCodes, Vec<i8>)>>,
{
    let mut stream = SgdStream::new(cfg.clone(), b, k);
    for chunk in chunks {
        let (codes, labels) = chunk?;
        stream.push_chunk_ref(&codes, &labels)?;
    }
    stream.end_epoch();
    Ok(stream.finalize())
}

/// The packed (b, k) geometry a cache must expose for streaming SGD.
fn sgd_geometry(meta: &crate::encode::cache::CacheMeta) -> Result<(u32, usize)> {
    meta.spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!(
            "cache records a sparse-output encoder ({}); streaming SGD needs packed codes",
            meta.spec.scheme()
        ))
    })
}

/// Multi-epoch streaming training from an on-disk hashed cache: replays
/// the cache `cfg.epochs` times through one [`SgdStream`] — the fwumious
/// "train over the cache" scenario, in constant memory (and zero
/// allocation per record: one pair of scratch buffers serves the whole
/// run).  Works for any packed-code encoder scheme the cache header
/// records (b-bit minwise, OPH, ...).
pub fn train_from_cache<P: AsRef<Path>>(
    path: P,
    cfg: &SgdConfig,
) -> Result<(LinearModel, TrainStats)> {
    let meta = CacheReader::open(&path)?.meta();
    let (b, k) = sgd_geometry(&meta)?;
    let mut stream = SgdStream::new(cfg.clone(), b, k);
    let mut codes = PackedCodes::new(b, k);
    let mut labels: Vec<i8> = Vec::new();
    for _ in 0..cfg.epochs.max(1) {
        let mut reader = CacheReader::open(&path)?;
        while reader.next_chunk_into(&mut codes, &mut labels)? {
            stream.push_chunk_ref(&codes, &labels)?;
        }
        stream.end_epoch();
    }
    Ok(stream.finalize())
}

/// [`train_from_cache`] with crash-safe epoch checkpoints: after every
/// `every`-th epoch (and always after the last) the weights plus the full
/// optimizer state ([`crate::solver::OptState`]) are written atomically to
/// `checkpoint` as a v3 model file — which the serve tier can hot-load
/// directly, since a checkpoint *is* a valid model.  With `resume`, an
/// existing checkpoint is loaded, already-completed epochs are skipped,
/// and the run continues to **bit-identical** final weights vs. an
/// uninterrupted run (the schedule position, progressive loss and weights
/// all round-trip exactly; `tests/crash_recovery.rs` kills a training
/// subprocess mid-epoch to prove it).  A `resume` with no checkpoint on
/// disk is a fresh start, so one CLI invocation is idempotent across
/// crashes.  Restricted to the sequential replay path: iterate-averaged
/// multi-thread training has per-shard state this format does not carry.
pub fn train_from_cache_checkpointed<P: AsRef<Path>>(
    path: P,
    cfg: &SgdConfig,
    checkpoint: &Path,
    every: usize,
    resume: bool,
) -> Result<(LinearModel, TrainStats)> {
    let meta = CacheReader::open(&path)?.meta();
    let (b, k) = sgd_geometry(&meta)?;
    let mut stream = SgdStream::new(cfg.clone(), b, k);
    let mut start_epoch = 0usize;
    if resume && checkpoint.exists() {
        let saved = SavedModel::load(checkpoint)?;
        let opt = saved.opt.ok_or_else(|| {
            Error::InvalidArg(format!(
                "{} is a plain model, not a training checkpoint (no optimizer state)",
                checkpoint.display()
            ))
        })?;
        if saved.spec != meta.spec {
            return Err(Error::InvalidArg(format!(
                "checkpoint encoder spec {:?} does not match cache spec {:?}",
                saved.spec, meta.spec
            )));
        }
        stream.set_weights(&saved.model.w)?;
        stream.restore_opt_state(&opt);
        start_epoch = opt.epochs_done;
        eprintln!(
            "resuming from checkpoint {} (epoch {start_epoch}, {} rows seen)",
            checkpoint.display(),
            opt.rows_seen
        );
    } else if resume {
        eprintln!("note: checkpoint {} not found; starting fresh", checkpoint.display());
    }
    let epochs = cfg.epochs.max(1);
    let every = every.max(1);
    let mut codes = PackedCodes::new(b, k);
    let mut labels: Vec<i8> = Vec::new();
    for epoch in start_epoch..epochs {
        let mut reader = CacheReader::open(&path)?;
        while reader.next_chunk_into(&mut codes, &mut labels)? {
            stream.push_chunk_ref(&codes, &labels)?;
        }
        stream.end_epoch();
        let done = epoch + 1;
        if done % every == 0 || done == epochs {
            let mut snap =
                SavedModel::new(meta.spec, LinearModel { w: stream.weights().to_vec() })?;
            snap.opt = Some(stream.opt_state());
            snap.save(checkpoint)?;
        }
    }
    Ok(stream.finalize())
}

/// [`train_from_cache`] across a reader pool: each of `threads` workers
/// replays its contiguous shard of the chunk index through a local
/// [`SgdStream`], and the shards synchronize by **iterate averaging** at
/// every epoch boundary (each worker's weights are reset to the
/// rows-weighted average; step counters carry on, as in the sequential
/// schedule).  `threads <= 1` is exactly [`train_from_cache`]; `threads >
/// 1` trades bit-exactness for wall-clock — on separable data the
/// averaged iterate lands within tolerance of the sequential run (the
/// parallel-replay integration test pins this down).  Deterministic for a
/// fixed (cache, config, thread count): shard boundaries and the merge
/// order never depend on scheduling.  Falls back to the sequential path
/// (with a warning) when the cache has no usable chunk index.
pub fn train_from_cache_threads<P: AsRef<Path>>(
    path: P,
    cfg: &SgdConfig,
    threads: usize,
) -> Result<(LinearModel, TrainStats)> {
    if threads <= 1 {
        return train_from_cache(path, cfg);
    }
    let path = path.as_ref();
    let Some(index) = ChunkIndex::load(path)? else {
        eprintln!(
            "warning: cache {} has no chunk index (pre-v3 file or damaged footer); \
             training on one thread",
            path.display()
        );
        return train_from_cache(path, cfg);
    };
    let n_rec = index.entries.len();
    if n_rec == 0 {
        return train_from_cache(path, cfg); // empty cache: zero weights
    }
    let t0 = Instant::now();
    let meta = CacheReader::open(path)?.meta();
    let (b, k) = sgd_geometry(&meta)?;
    let dim = (1usize << b) * k;
    let starts = index.row_starts();
    let plan = ShardPlan::new(n_rec, n_rec.div_ceil(threads).max(1));

    /// Everything one shard worker owns across epochs.
    struct Shard {
        reader: IndexedCacheReader<std::fs::File>,
        stream: SgdStream,
        /// Record range [lo, hi) of the chunk index.
        lo: usize,
        hi: usize,
        /// Rows in the shard (the averaging weight).
        rows: u64,
        codes: PackedCodes,
        labels: Vec<i8>,
    }
    let mut shards = Vec::with_capacity(plan.n_chunks());
    for a in plan.iter() {
        let rows: u64 = index.entries[a.row0..a.row0 + a.rows]
            .iter()
            .map(|e| e.rows as u64)
            .sum();
        shards.push(Shard {
            reader: IndexedCacheReader::open(path)?,
            stream: SgdStream::new(cfg.clone(), b, k),
            lo: a.row0,
            hi: a.row0 + a.rows,
            rows,
            codes: PackedCodes::new(b, k),
            labels: Vec::new(),
        });
    }
    let total_rows: f64 = shards.iter().map(|s| s.rows as f64).sum();
    let mut avg = vec![0.0f32; dim];
    let mut acc = vec![0.0f64; dim];
    let epochs = cfg.epochs.max(1);
    for _ in 0..epochs {
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(shards.len());
            for shard in shards.iter_mut() {
                let entries = &index.entries;
                let starts = &starts;
                handles.push(scope.spawn(move || -> Result<()> {
                    for rec in shard.lo..shard.hi {
                        shard.reader.read_into(
                            &entries[rec],
                            starts[rec],
                            &mut shard.codes,
                            &mut shard.labels,
                        )?;
                        shard.stream.push_chunk_ref(&shard.codes, &shard.labels)?;
                    }
                    shard.stream.end_epoch();
                    Ok(())
                }));
            }
            for h in handles {
                h.join()
                    .map_err(|_| Error::Pipeline("replay SGD worker panicked".into()))??;
            }
            Ok(())
        })?;
        // rows-weighted iterate averaging (f64 accumulation, fixed shard
        // order → deterministic)
        acc.iter_mut().for_each(|a| *a = 0.0);
        for shard in &shards {
            let weight = shard.rows as f64 / total_rows;
            for (a, &w) in acc.iter_mut().zip(shard.stream.weights()) {
                *a += weight * w as f64;
            }
        }
        for (dst, &a) in avg.iter_mut().zip(&acc) {
            *dst = a as f32;
        }
        for shard in shards.iter_mut() {
            shard.stream.set_weights(&avg)?;
        }
    }
    let rows_seen: u64 = shards.iter().map(|s| s.stream.rows_seen()).sum();
    let loss_sum: f64 = shards.iter().map(|s| s.stream.loss_sum()).sum();
    let stats = TrainStats {
        iterations: epochs,
        objective: loss_sum / rows_seen.max(1) as f64,
        converged: true,
        train_seconds: t0.elapsed().as_secs_f64(),
    };
    Ok((LinearModel { w: avg }, stats))
}

/// Deterministic per-row holdout membership: a splitmix64 draw on the
/// global row index against the `frac` threshold.  Depending only on
/// (row index, salt) makes the split identical across epochs, reruns and
/// readers — the training pass and the evaluation pass agree on which
/// rows are held out without storing a mask anywhere.
fn holdout_row(row: u64, salt: u64, frac: f64) -> bool {
    let mut z = row.wrapping_add(salt).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    ((z >> 11) as f64 / (1u64 << 53) as f64) < frac
}

/// Held-out-split evaluation attached to a cache training run.
#[derive(Clone, Copy, Debug, Default)]
pub struct HoldoutReport {
    pub train_rows: u64,
    pub holdout_rows: u64,
    /// Accuracy of the final model on the held-out rows.
    pub accuracy: f64,
    /// Mean (unregularized) loss of the final model on the held-out rows.
    pub mean_loss: f64,
}

/// [`train_from_cache`] with a deterministic held-out split: a `frac`
/// fraction of rows (chosen by a salted per-row hash of the global row
/// index — see `holdout_row`) is excluded from every training epoch, then scored once
/// with the final weights — generalization measured against data the
/// model never touched, at the cost of one extra cache pass.
pub fn train_from_cache_holdout<P: AsRef<Path>>(
    path: P,
    cfg: &SgdConfig,
    frac: f64,
    salt: u64,
) -> Result<(LinearModel, TrainStats, HoldoutReport)> {
    train_from_cache_holdout_threads(path, cfg, frac, salt, 1)
}

/// [`train_from_cache_holdout`] with an N-thread replay pool.  Unlike the
/// iterate-averaged [`train_from_cache_threads`], this path parallelizes
/// only record *decode* (read + checksum + unpack): chunks re-emerge from
/// the pool strictly in record order into the single trainer, so the
/// result is **bit-for-bit identical for every thread count** — the split
/// membership, the weights, and the held-out numbers.  Use it when the
/// validation protocol must stay exact and decode is the bottleneck.
pub fn train_from_cache_holdout_threads<P: AsRef<Path>>(
    path: P,
    cfg: &SgdConfig,
    frac: f64,
    salt: u64,
    threads: usize,
) -> Result<(LinearModel, TrainStats, HoldoutReport)> {
    if frac <= 0.0 || frac >= 1.0 || frac.is_nan() {
        return Err(Error::InvalidArg(format!(
            "holdout fraction must be in (0, 1), got {frac}"
        )));
    }
    let path = path.as_ref();
    let meta = CacheReader::open(path)?.meta();
    let (b, k) = sgd_geometry(&meta)?;
    // the index (or its absence, warned once) is loaded up front and
    // reused by every training pass and the eval pass
    let index = if threads > 1 { load_index_or_warn(path)? } else { None };
    let mut stream = SgdStream::new(cfg.clone(), b, k);
    let mut row_buf = vec![0u16; k];
    // training-chunk scratch, reused across every record of every epoch
    let mut tr_codes = PackedCodes::new(b, k);
    let mut tr_labels: Vec<i8> = Vec::new();
    for _ in 0..cfg.epochs.max(1) {
        replay_cache_with(path, index.as_ref(), threads, |_rec, row0, codes, labels| {
            // filter held-out rows from the training chunk
            tr_codes.clear();
            tr_labels.clear();
            for i in 0..codes.n {
                if !holdout_row(row0 + i as u64, salt, frac) {
                    codes.row_into(i, &mut row_buf);
                    tr_codes.push_row(&row_buf)?;
                    tr_labels.push(labels[i]);
                }
            }
            if tr_codes.n > 0 {
                stream.push_chunk_ref(&tr_codes, &tr_labels)?;
            }
            Ok(())
        })?;
        stream.end_epoch();
    }
    let (model, stats) = stream.finalize();

    // one evaluation pass over the held-out rows with the final weights
    let (mut held, mut correct) = (0u64, 0u64);
    let mut loss_sum = 0.0f64;
    replay_cache_with(path, index.as_ref(), threads, |_rec, row0, codes, labels| {
        for i in 0..codes.n {
            if holdout_row(row0 + i as u64, salt, frac) {
                held += 1;
                // sparse membership makes row i+1 rarely the next scored
                // row, so this path keeps the stateless per-row kernel
                // (thread-local decode scratch, no lookahead prefetch)
                let m = packed_dot(codes, i, &model.w);
                let y = labels[i];
                loss_sum += cfg.loss.loss(m as f64, y as f64);
                if (m >= 0.0) == (y > 0) {
                    correct += 1;
                }
            }
        }
        Ok(())
    })?;
    let report = HoldoutReport {
        train_rows: meta.n - held,
        holdout_rows: held,
        accuracy: correct as f64 / held.max(1) as f64,
        mean_loss: loss_sum / held.max(1) as f64,
    };
    Ok((model, stats, report))
}

/// Evaluation of one model over one hashed cache.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheEval {
    pub rows: u64,
    pub accuracy: f64,
    /// Mean (unregularized) loss over all rows.
    pub mean_loss: f64,
}

/// (rows, correct, loss sum) of one record under `w` — the per-record
/// partial both eval paths fold in record order, so sequential and pooled
/// evaluation produce bit-identical sums.  `gather` decodes each row once
/// and prefetches one row ahead; results don't depend on it (every margin
/// is the same [`kernels::dot_idx`] over the same decoded indices), so
/// thread-count invariance is untouched.
fn eval_record(
    codes: &PackedCodes,
    labels: &[i8],
    w: &[f32],
    loss: SgdLoss,
    gather: &mut RowGather,
) -> (u64, u64, f64) {
    let (mut correct, mut loss_sum) = (0u64, 0.0f64);
    if codes.n == 0 {
        return (0, 0, 0.0);
    }
    gather.begin(codes, 0);
    for i in 0..codes.n {
        if i + 1 < codes.n {
            gather.stage(codes, i + 1, w);
        }
        let m = kernels::dot_idx(gather.indices(), w);
        let y = labels[i];
        loss_sum += loss.loss(m as f64, y as f64);
        if (m >= 0.0) == (y > 0) {
            correct += 1;
        }
        if i + 1 < codes.n {
            gather.advance(codes, i + 1);
        }
    }
    (codes.n as u64, correct, loss_sum)
}

/// Fold per-record partials (in record order) into the aggregate eval.
fn fold_eval(partials: impl Iterator<Item = (u64, u64, f64)>) -> CacheEval {
    let (mut rows, mut correct) = (0u64, 0u64);
    let mut loss_sum = 0.0f64;
    for (r, c, l) in partials {
        rows += r;
        correct += c;
        loss_sum += l;
    }
    CacheEval {
        rows,
        accuracy: correct as f64 / rows.max(1) as f64,
        mean_loss: loss_sum / rows.max(1) as f64,
    }
}

/// Score every row of a hashed cache with a saved model — the batch twin
/// of the serve path (`classify --model m --cache c`).  The cache header
/// and the model file both record their [`EncoderSpec`]; a mismatch
/// (different scheme, parameters *or* hash-family seed — codes from one
/// family are meaningless under another's weights) is a typed error, never
/// an out-of-bounds panic.
///
/// The f64 loss sum is grouped **per record** (see [`eval_record`]) so
/// that every thread count of [`eval_from_cache_threads`] folds in the
/// identical order and produces bitwise-equal results.  This is a
/// deliberate trade: vs. the pre-replay flat row-by-row accumulation the
/// grouping can shift `mean_loss` by an ulp (row counts and accuracy are
/// integer-exact either way — nothing visible at printed precision), in
/// exchange for sequential/pooled evaluation being exactly interchangeable.
pub fn eval_from_cache<P: AsRef<Path>>(
    path: P,
    saved: &SavedModel,
    loss: SgdLoss,
) -> Result<CacheEval> {
    eval_from_cache_threads(path, saved, loss, 1)
}

/// [`eval_from_cache`] fanned out across shards of the chunk index with a
/// merge reduce: each of `threads` workers scores a contiguous record
/// range into per-record partials, which are folded in record order —
/// scoring is embarrassingly parallel, and grouping sums per record makes
/// the result **identical for every thread count** (integer counts
/// exactly; the f64 loss sum by construction of the fold order).  Falls
/// back to the sequential scan (with a warning) when the cache has no
/// usable index.
pub fn eval_from_cache_threads<P: AsRef<Path>>(
    path: P,
    saved: &SavedModel,
    loss: SgdLoss,
    threads: usize,
) -> Result<CacheEval> {
    let path = path.as_ref();
    let meta = CacheReader::open(path)?.meta();
    if meta.spec != saved.spec {
        return Err(Error::InvalidArg(format!(
            "cache encoder spec {:?} does not match the model's {:?}",
            meta.spec, saved.spec
        )));
    }
    let (b, k) = sgd_geometry(&meta)?;
    let w = &saved.model.w;
    if threads > 1 {
        match ChunkIndex::load(path)? {
            Some(index) => {
                let n_rec = index.entries.len();
                let starts = index.row_starts();
                let mut partials = vec![(0u64, 0u64, 0.0f64); n_rec];
                let plan = ShardPlan::new(n_rec, n_rec.div_ceil(threads).max(1));
                let mut shards = Vec::with_capacity(plan.n_chunks());
                let mut rest = partials.as_mut_slice();
                for a in plan.iter() {
                    let (shard, tail) = std::mem::take(&mut rest).split_at_mut(a.rows);
                    rest = tail;
                    shards.push((a, shard));
                }
                std::thread::scope(|scope| -> Result<()> {
                    let mut handles = Vec::with_capacity(shards.len());
                    for (a, shard) in shards {
                        let entries = &index.entries;
                        let starts = &starts;
                        handles.push(scope.spawn(move || -> Result<()> {
                            let mut reader = IndexedCacheReader::open(path)?;
                            let mut codes = PackedCodes::new(b, k);
                            let mut labels: Vec<i8> = Vec::new();
                            let mut gather = RowGather::new(k);
                            for (off, rec) in (a.row0..a.row0 + a.rows).enumerate() {
                                reader.read_into(
                                    &entries[rec],
                                    starts[rec],
                                    &mut codes,
                                    &mut labels,
                                )?;
                                shard[off] =
                                    eval_record(&codes, &labels, w, loss, &mut gather);
                            }
                            Ok(())
                        }));
                    }
                    for h in handles {
                        h.join()
                            .map_err(|_| Error::Pipeline("cache eval worker panicked".into()))??;
                    }
                    Ok(())
                })?;
                return Ok(fold_eval(partials.into_iter()));
            }
            None => eprintln!(
                "warning: cache {} has no chunk index (pre-v3 file or damaged footer); \
                 evaluating on one thread",
                path.display()
            ),
        }
    }
    // sequential scan folding each record's partial as it streams by —
    // same per-record grouping and fold order as the pooled path (so the
    // results match bitwise), but O(1) memory like every other replay
    let mut reader = CacheReader::open(path)?;
    let mut codes = PackedCodes::new(b, k);
    let mut labels: Vec<i8> = Vec::new();
    let mut gather = RowGather::new(k);
    let (mut rows, mut correct) = (0u64, 0u64);
    let mut loss_sum = 0.0f64;
    while reader.next_chunk_into(&mut codes, &mut labels)? {
        let (r, c, l) = eval_record(&codes, &labels, w, loss, &mut gather);
        rows += r;
        correct += c;
        loss_sum += l;
    }
    Ok(CacheEval {
        rows,
        accuracy: correct as f64 / rows.max(1) as f64,
        mean_loss: loss_sum / rows.max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Example, SparseDataset};
    use crate::solver::linear::accuracy;
    use crate::util::Rng;

    fn separable(n: usize, seed: u64) -> SparseDataset {
        let mut rng = Rng::new(seed);
        let mut examples = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let base = if pos { 0 } else { 16 };
            let feats: Vec<u32> =
                (0..6).map(|_| base + rng.below(16) as u32).collect();
            examples.push(Example::binary(if pos { 1 } else { -1 }, feats));
        }
        SparseDataset::from_examples(32, &examples)
    }

    #[test]
    fn learns_separable_data_both_losses() {
        let ds = separable(512, 51);
        for loss in [SgdLoss::Logistic, SgdLoss::SquaredHinge] {
            let cfg = SgdConfig { loss, epochs: 20, batch: 64, ..Default::default() };
            let (model, _) = train_sgd(&ds, &cfg);
            assert!(accuracy(&model, &ds) > 0.97, "{loss:?}");
        }
    }

    #[test]
    fn deterministic() {
        let ds = separable(128, 53);
        let cfg = SgdConfig::default();
        let (m1, _) = train_sgd(&ds, &cfg);
        let (m2, _) = train_sgd(&ds, &cfg);
        assert_eq!(m1.w, m2.w);
    }

    #[test]
    fn objective_decreases_over_epochs() {
        let ds = separable(256, 57);
        let short = train_sgd(&ds, &SgdConfig { epochs: 1, ..Default::default() });
        let long = train_sgd(&ds, &SgdConfig { epochs: 15, ..Default::default() });
        assert!(long.1.objective < short.1.objective);
    }

    #[test]
    fn grad_coefs_match_losses() {
        // logistic at m=0: -y/2; sqhinge at (m=0,y=1): -2
        assert!((SgdLoss::Logistic.grad_coef(0.0, 1.0) + 0.5).abs() < 1e-6);
        assert!((SgdLoss::SquaredHinge.grad_coef(0.0, 1.0) + 2.0).abs() < 1e-6);
        // no gradient beyond the margin for hinge
        assert_eq!(SgdLoss::SquaredHinge.grad_coef(2.0, 1.0), 0.0);
    }

    #[test]
    fn lambda_from_c_mapping() {
        assert!((lambda_from_c(1.0, 1000) - 1e-3).abs() < 1e-12);
        assert!((lambda_from_c(10.0, 100) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn holdout_membership_is_deterministic_and_near_frac() {
        let frac = 0.2;
        let n = 20_000u64;
        let held: Vec<u64> = (0..n).filter(|&i| holdout_row(i, 0x5A17, frac)).collect();
        // deterministic: same inputs, same split
        let held2: Vec<u64> = (0..n).filter(|&i| holdout_row(i, 0x5A17, frac)).collect();
        assert_eq!(held, held2);
        // different salt, different split
        assert_ne!(held, (0..n).filter(|&i| holdout_row(i, 0x0DD, frac)).collect::<Vec<_>>());
        // the realized fraction concentrates around frac
        let realized = held.len() as f64 / n as f64;
        assert!((realized - frac).abs() < 0.02, "realized {realized}");
    }

    #[test]
    fn holdout_frac_bounds_are_typed_errors() {
        for frac in [0.0, 1.0, -0.5, 1.5, f64::NAN] {
            let err = train_from_cache_holdout("/nonexistent", &SgdConfig::default(), frac, 1);
            assert!(err.is_err(), "frac {frac} must be rejected before any IO");
        }
    }

    fn random_bbit(b: u32, k: usize, n: usize, seed: u64) -> BbitDataset {
        let mut rng = Rng::new(seed);
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for _ in 0..n {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
            labels.push(if rng.bool() { 1 } else { -1 });
        }
        BbitDataset::new(pc, labels)
    }

    /// Slice rows [lo, hi) of a BbitDataset into a standalone chunk.
    fn chunk_of(ds: &BbitDataset, lo: usize, hi: usize) -> (PackedCodes, Vec<i8>) {
        let mut pc = PackedCodes::new(ds.codes.b, ds.codes.k);
        let mut row = vec![0u16; ds.codes.k];
        for i in lo..hi {
            ds.codes.row_into(i, &mut row);
            pc.push_row(&row).unwrap();
        }
        (pc, ds.labels[lo..hi].to_vec())
    }

    #[test]
    fn stream_matches_batch_across_ragged_chunks_and_epochs() {
        let ds = random_bbit(4, 24, 157, 0xD1CE);
        // batch=32 does not divide 157 and chunk boundaries (13) never
        // align with minibatch boundaries — the re-batching must hide both
        let cfg = SgdConfig { epochs: 3, batch: 32, lambda: 1e-3, ..Default::default() };
        let (reference, _) = train_sgd(&ds, &cfg);
        let mut stream = SgdStream::new(cfg.clone(), 4, 24);
        for _ in 0..cfg.epochs {
            let mut lo = 0;
            while lo < ds.len() {
                let hi = (lo + 13).min(ds.len());
                let (pc, ls) = chunk_of(&ds, lo, hi);
                stream.push_chunk(pc, ls).unwrap();
                lo = hi;
            }
            stream.end_epoch();
        }
        assert_eq!(stream.rows_seen(), (ds.len() * cfg.epochs) as u64);
        let (model, stats) = stream.finalize();
        assert_eq!(stats.iterations, 3);
        let max_diff = model
            .w
            .iter()
            .zip(&reference.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "stream/batch weight divergence: {max_diff}");
    }

    #[test]
    fn train_sgd_stream_single_pass_matches_one_epoch() {
        let ds = random_bbit(8, 10, 90, 0xBEEF);
        let cfg = SgdConfig { epochs: 1, batch: 16, ..Default::default() };
        let (reference, _) = train_sgd(&ds, &cfg);
        let chunks: Vec<_> = (0..ds.len())
            .step_by(7)
            .map(|lo| Ok(chunk_of(&ds, lo, (lo + 7).min(ds.len()))))
            .collect();
        let (model, stats) = train_sgd_stream(chunks.into_iter(), 8, 10, &cfg).unwrap();
        assert!(stats.objective.is_finite());
        let max_diff = model
            .w
            .iter()
            .zip(&reference.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "divergence: {max_diff}");
    }

    #[test]
    fn checkpointed_cache_training_resumes_bit_identically() {
        use crate::encode::cache::CacheWriter;
        use crate::encode::encoder::EncoderSpec;
        let dir = std::env::temp_dir().join(format!("bbmh_ckpt_sgd_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cache = dir.join("train.cache");
        let ds = random_bbit(4, 12, 200, 0xC0FFEE);
        let spec = EncoderSpec::Bbit { b: 4, k: 12, d: 1 << 16, seed: 1 };
        let mut w = CacheWriter::create(&cache, &spec).unwrap();
        for lo in (0..ds.len()).step_by(16) {
            let (pc, ls) = chunk_of(&ds, lo, (lo + 16).min(ds.len()));
            w.write_chunk(&pc, &ls).unwrap();
        }
        w.finalize().unwrap();

        let full_cfg = SgdConfig { epochs: 6, batch: 32, lambda: 1e-3, ..Default::default() };
        let (reference, _) = train_from_cache(&cache, &full_cfg).unwrap();

        // checkpointing must not perturb an uninterrupted run
        let ck_a = dir.join("a.ckpt");
        let (m_a, _) =
            train_from_cache_checkpointed(&cache, &full_cfg, &ck_a, 2, false).unwrap();
        assert_eq!(m_a.w, reference.w);

        // "crash" after 3 epochs, then resume to 6: bit-identical weights
        let ck_b = dir.join("b.ckpt");
        let half_cfg = SgdConfig { epochs: 3, ..full_cfg.clone() };
        train_from_cache_checkpointed(&cache, &half_cfg, &ck_b, 1, false).unwrap();
        let mid = SavedModel::load(&ck_b).unwrap();
        assert_eq!(mid.opt.unwrap().epochs_done, 3);
        assert_ne!(mid.model.w, reference.w, "3 epochs must differ from 6");
        let (m_b, stats) =
            train_from_cache_checkpointed(&cache, &full_cfg, &ck_b, 2, true).unwrap();
        assert_eq!(m_b.w, reference.w, "resumed weights must be bit-identical");
        assert_eq!(stats.iterations, 6);
        let done = SavedModel::load(&ck_b).unwrap();
        assert_eq!(done.model.w, reference.w, "final checkpoint carries the finished weights");
        assert_eq!(done.opt.unwrap().epochs_done, 6);

        // resuming an already-finished run is a no-op with the same result
        let (m_c, _) =
            train_from_cache_checkpointed(&cache, &full_cfg, &ck_b, 2, true).unwrap();
        assert_eq!(m_c.w, reference.w);

        // a plain (v2) model is rejected as a resume source
        let plain = SavedModel::new(spec, reference.clone()).unwrap();
        plain.save(&ck_a).unwrap();
        assert!(train_from_cache_checkpointed(&cache, &full_cfg, &ck_a, 2, true).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn stream_rejects_geometry_mismatch() {
        let mut stream = SgdStream::new(SgdConfig::default(), 8, 16);
        let ds = random_bbit(8, 17, 4, 1);
        let (pc, ls) = chunk_of(&ds, 0, 4);
        assert!(stream.push_chunk(pc, ls).is_err());
        let ds = random_bbit(4, 16, 4, 2);
        let (pc, ls) = chunk_of(&ds, 0, 4);
        assert!(stream.push_chunk(pc, ls).is_err());
        let ds = random_bbit(8, 16, 4, 3);
        let (pc, _) = chunk_of(&ds, 0, 4);
        assert!(stream.push_chunk(pc, vec![1]).is_err());
    }

    #[test]
    fn aligned_chunks_take_the_zero_copy_path_and_still_match() {
        // chunk size == batch size: every chunk hits the in-place fast
        // path; weights must be identical to the batch reference anyway
        let ds = random_bbit(6, 12, 128, 0xA11);
        let cfg = SgdConfig { epochs: 2, batch: 32, ..Default::default() };
        let (reference, _) = train_sgd(&ds, &cfg);
        let mut stream = SgdStream::new(cfg.clone(), 6, 12);
        for _ in 0..cfg.epochs {
            for lo in (0..ds.len()).step_by(32) {
                let (pc, ls) = chunk_of(&ds, lo, lo + 32);
                stream.push_chunk(pc, ls).unwrap();
            }
            stream.end_epoch();
        }
        let (model, _) = stream.finalize();
        let max_diff = model
            .w
            .iter()
            .zip(&reference.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "fast-path divergence: {max_diff}");
    }
}
