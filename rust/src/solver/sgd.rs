//! SGD solver with Bottou's learning-rate schedule.
//!
//! Two roles: (a) the "online algorithm" the paper's loading-time argument
//! mentions (Section 1); (b) the *native twin* of the AOT'd PJRT train
//! artifacts — `train_chunk` in `python/compile/model.py` implements the
//! same update, so the cross-layer parity test drives both on identical
//! data and requires near-identical weights.
//!
//! Objective (per-example averaged):  λ/2 ‖w‖² + (1/n) Σ loss(yᵢ wᵀxᵢ),
//! with λ = 1/(C·n) mapping to the paper's C convention.  Minibatch step:
//!
//!   w ← (1 − η λ) w − η · (1/B) Σ_{i∈batch} ∂loss/∂m · xᵢ,
//!   η(t) = η₀ / (1 + t·λ·η₀).

use std::path::Path;
use std::time::Instant;

use crate::encode::cache::CacheReader;
use crate::encode::expansion::BbitDataset;
use crate::encode::packed::PackedCodes;
use crate::solver::linear::{FeatureMatrix, LinearModel, TrainStats};
use crate::solver::model_io::SavedModel;
use crate::{Error, Result};

/// Loss selector matching the PJRT artifact pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SgdLoss {
    Logistic,
    SquaredHinge,
}

impl SgdLoss {
    /// dLoss/dMargin at (margin, label).
    #[inline]
    pub fn grad_coef(self, m: f32, y: f32) -> f32 {
        match self {
            SgdLoss::Logistic => -y / (1.0 + (y * m).exp()),
            SgdLoss::SquaredHinge => -2.0 * y * (1.0 - y * m).max(0.0),
        }
    }

    #[inline]
    pub fn loss(self, m: f64, y: f64) -> f64 {
        match self {
            SgdLoss::Logistic => {
                let ym = y * m;
                if ym > 0.0 {
                    (-ym).exp().ln_1p()
                } else {
                    -ym + ym.exp().ln_1p()
                }
            }
            SgdLoss::SquaredHinge => {
                let v = (1.0 - y * m).max(0.0);
                v * v
            }
        }
    }
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct SgdConfig {
    pub loss: SgdLoss,
    /// Initial learning rate η₀.
    pub lr0: f64,
    /// Regularization λ (use `lambda_from_c` to map from the paper's C).
    pub lambda: f64,
    pub epochs: usize,
    pub batch: usize,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { loss: SgdLoss::Logistic, lr0: 0.5, lambda: 1e-4, epochs: 10, batch: 256 }
    }
}

/// λ = 1/(C·n): the SVM/LR "C" convention to per-example λ.
pub fn lambda_from_c(c: f64, n: usize) -> f64 {
    1.0 / (c * n as f64)
}

/// Train by minibatch SGD.  Deterministic: fixed in-order minibatches, the
/// same order as the PJRT `train_chunk` artifact scans (no shuffling, so
/// the parity test can compare weights).
pub fn train_sgd<F: FeatureMatrix>(data: &F, cfg: &SgdConfig) -> (LinearModel, TrainStats) {
    let t0 = Instant::now();
    let n = data.n();
    let mut w = vec![0.0f32; data.dim()];
    let mut step = 0u64;
    let mut stats = TrainStats::default();
    let mut coefs: Vec<f32> = Vec::with_capacity(cfg.batch);
    for _ in 0..cfg.epochs {
        let mut i0 = 0;
        while i0 < n {
            let bsz = cfg.batch.min(n - i0);
            let lr = cfg.lr0 / (1.0 + step as f64 * cfg.lambda * cfg.lr0);
            // margins/grad coefficients first (batch semantics: all margins
            // computed against the pre-update w, matching the artifact)
            coefs.clear();
            for i in i0..i0 + bsz {
                let m = data.dot(i, &w);
                coefs.push(cfg.loss.grad_coef(m, data.label(i)));
            }
            // decay + accumulate
            let decay = (1.0 - lr * cfg.lambda) as f32;
            if decay != 1.0 {
                w.iter_mut().for_each(|x| *x *= decay);
            }
            let scale = (lr / bsz as f64) as f32;
            for (off, i) in (i0..i0 + bsz).enumerate() {
                let g = coefs[off];
                if g != 0.0 {
                    data.axpy(i, -scale * g, &mut w);
                }
            }
            step += 1;
            i0 += bsz;
        }
        stats.iterations += 1;
    }
    stats.converged = true;
    stats.objective = {
        let reg = 0.5
            * cfg.lambda
            * w.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
        let avg: f64 = (0..n)
            .map(|i| cfg.loss.loss(data.dot(i, &w) as f64, data.label(i) as f64))
            .sum::<f64>()
            / n as f64;
        reg + avg
    };
    stats.train_seconds = t0.elapsed().as_secs_f64();
    (LinearModel { w }, stats)
}

/// Streaming twin of [`train_sgd`] for b-bit chunk streams.
///
/// Holds the weight vector plus at most one minibatch of buffered rows —
/// memory is O(dim + batch·k), independent of corpus size.  Rows arrive in
/// chunks (from the pipeline's [`TrainSink`](crate::coordinator::sink) or
/// a cache replay); the trainer re-batches them into exactly the minibatch
/// sequence [`train_sgd`] would visit, so for the same row order, batch
/// size, and epoch count the final weights are identical to
/// materialize-then-`train_sgd` (the integration test asserts this).
///
/// One pass = `push_chunk`… then [`end_epoch`](Self::end_epoch) (which
/// flushes the final partial minibatch exactly like `train_sgd`'s tail
/// batch).  Multi-epoch training replays the stream and calls `end_epoch`
/// after each pass; the step counter (and thus the learning-rate schedule)
/// carries across epochs, as in `train_sgd`.
pub struct SgdStream {
    cfg: SgdConfig,
    b: u32,
    k: usize,
    w: Vec<f32>,
    step: u64,
    /// Partial minibatch (always < cfg.batch rows between calls).
    buf: BbitDataset,
    row_scratch: Vec<u16>,
    coefs: Vec<f32>,
    rows_seen: u64,
    epochs_done: usize,
    loss_sum: f64,
    t0: Instant,
}

impl SgdStream {
    pub fn new(cfg: SgdConfig, b: u32, k: usize) -> Self {
        assert!(cfg.batch > 0, "batch must be positive");
        let dim = (1usize << b) * k;
        SgdStream {
            cfg,
            b,
            k,
            w: vec![0.0f32; dim],
            step: 0,
            buf: BbitDataset::new(PackedCodes::new(b, k), Vec::new()),
            row_scratch: vec![0u16; k],
            coefs: Vec::new(),
            rows_seen: 0,
            epochs_done: 0,
            loss_sum: 0.0,
            t0: Instant::now(),
        }
    }

    /// Expanded dimensionality 2^b · k of the weight vector.
    pub fn dim(&self) -> usize {
        self.w.len()
    }

    /// Rows consumed so far (across all epochs).
    pub fn rows_seen(&self) -> u64 {
        self.rows_seen
    }

    /// Mean pre-update loss over every row seen so far — VW-style
    /// progressive validation (each row is scored before the model has
    /// trained on it within its minibatch).
    pub fn progressive_loss(&self) -> f64 {
        self.loss_sum / self.rows_seen.max(1) as f64
    }

    /// Feed one hashed chunk (by value — the pipeline sink and the cache
    /// reader both own their chunks); applies a minibatch update every
    /// time `cfg.batch` rows have accumulated.  A chunk that aligns with
    /// the minibatch boundary (empty buffer, exactly `batch` rows — the
    /// CLI default: pipeline chunk_size == SGD batch) is consumed in place
    /// with no per-row unpack/repack.
    pub fn push_chunk(&mut self, codes: PackedCodes, labels: Vec<i8>) -> Result<()> {
        if codes.b != self.b || codes.k != self.k {
            return Err(Error::InvalidArg(format!(
                "chunk geometry (b={}, k={}) does not match trainer (b={}, k={})",
                codes.b, codes.k, self.b, self.k
            )));
        }
        if codes.n != labels.len() {
            return Err(Error::InvalidArg(format!(
                "chunk has {} rows but {} labels",
                codes.n,
                labels.len()
            )));
        }
        if self.buf.is_empty() && codes.n == self.cfg.batch {
            // aligned fast path: one whole minibatch, zero copies
            let chunk = BbitDataset::new(codes, labels);
            Self::minibatch_step(
                &self.cfg,
                &mut self.w,
                &mut self.step,
                &mut self.rows_seen,
                &mut self.loss_sum,
                &mut self.coefs,
                &chunk,
            );
            return Ok(());
        }
        for i in 0..codes.n {
            codes.row_into(i, &mut self.row_scratch);
            self.buf.codes.push_row(&self.row_scratch)?;
            self.buf.labels.push(labels[i]);
            if self.buf.len() == self.cfg.batch {
                self.apply_buffered_batch();
            }
        }
        Ok(())
    }

    /// End the current pass: flush the partial tail minibatch (identical
    /// to `train_sgd`'s final `min(batch, n - i0)` batch of an epoch).
    pub fn end_epoch(&mut self) {
        self.apply_buffered_batch();
        self.epochs_done += 1;
    }

    fn apply_buffered_batch(&mut self) {
        Self::minibatch_step(
            &self.cfg,
            &mut self.w,
            &mut self.step,
            &mut self.rows_seen,
            &mut self.loss_sum,
            &mut self.coefs,
            &self.buf,
        );
        self.buf.codes.clear();
        self.buf.labels.clear();
    }

    /// One `train_sgd` minibatch step over all rows of `data` (an
    /// associated fn taking fields explicitly so callers can pass either
    /// the internal buffer or a borrowed whole chunk).
    #[allow(clippy::too_many_arguments)]
    fn minibatch_step(
        cfg: &SgdConfig,
        w: &mut [f32],
        step: &mut u64,
        rows_seen: &mut u64,
        loss_sum: &mut f64,
        coefs: &mut Vec<f32>,
        data: &BbitDataset,
    ) {
        let bsz = data.len();
        if bsz == 0 {
            return;
        }
        let lr = cfg.lr0 / (1.0 + *step as f64 * cfg.lambda * cfg.lr0);
        coefs.clear();
        for i in 0..bsz {
            let m = data.dot(i, w);
            let y = data.labels[i] as f32;
            coefs.push(cfg.loss.grad_coef(m, y));
            *loss_sum += cfg.loss.loss(m as f64, y as f64);
        }
        let decay = (1.0 - lr * cfg.lambda) as f32;
        if decay != 1.0 {
            w.iter_mut().for_each(|x| *x *= decay);
        }
        let scale = (lr / bsz as f64) as f32;
        for (i, &g) in coefs.iter().enumerate() {
            if g != 0.0 {
                data.axpy(i, -scale * g, w);
            }
        }
        *step += 1;
        *rows_seen += bsz as u64;
    }

    /// Read-only view of the current weights (mid-stream evaluation).
    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    /// Consume the trainer.  `TrainStats.objective` is the *progressive
    /// loss* (no second pass over data that may already be gone), not the
    /// batch objective `train_sgd` reports.
    pub fn finalize(self) -> (LinearModel, TrainStats) {
        let stats = TrainStats {
            iterations: self.epochs_done,
            objective: self.progressive_loss(),
            converged: true,
            train_seconds: self.t0.elapsed().as_secs_f64(),
        };
        (LinearModel { w: self.w }, stats)
    }
}

/// Single-pass hash-and-train: drain a chunk stream through [`SgdStream`].
/// `cfg.epochs` is ignored — a stream can only be seen once; replay a
/// cache via [`train_from_cache`] for multi-epoch training.
pub fn train_sgd_stream<I>(
    chunks: I,
    b: u32,
    k: usize,
    cfg: &SgdConfig,
) -> Result<(LinearModel, TrainStats)>
where
    I: Iterator<Item = Result<(PackedCodes, Vec<i8>)>>,
{
    let mut stream = SgdStream::new(cfg.clone(), b, k);
    for chunk in chunks {
        let (codes, labels) = chunk?;
        stream.push_chunk(codes, labels)?;
    }
    stream.end_epoch();
    Ok(stream.finalize())
}

/// Multi-epoch streaming training from an on-disk hashed cache: replays
/// the cache `cfg.epochs` times through one [`SgdStream`] — the fwumious
/// "train over the cache" scenario, in constant memory.  Works for any
/// packed-code encoder scheme the cache header records (b-bit minwise,
/// OPH, ...).
pub fn train_from_cache<P: AsRef<Path>>(path: P, cfg: &SgdConfig) -> Result<(LinearModel, TrainStats)> {
    let meta = CacheReader::open(&path)?.meta();
    let (b, k) = meta.spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!(
            "cache records a sparse-output encoder ({}); streaming SGD needs packed codes",
            meta.spec.scheme()
        ))
    })?;
    let mut stream = SgdStream::new(cfg.clone(), b, k);
    for _ in 0..cfg.epochs.max(1) {
        let mut reader = CacheReader::open(&path)?;
        while let Some((codes, labels)) = reader.next_chunk()? {
            stream.push_chunk(codes, labels)?;
        }
        stream.end_epoch();
    }
    Ok(stream.finalize())
}

/// Deterministic per-row holdout membership: a splitmix64 draw on the
/// global row index against the `frac` threshold.  Depending only on
/// (row index, salt) makes the split identical across epochs, reruns and
/// readers — the training pass and the evaluation pass agree on which
/// rows are held out without storing a mask anywhere.
fn holdout_row(row: u64, salt: u64, frac: f64) -> bool {
    let mut z = row.wrapping_add(salt).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    ((z >> 11) as f64 / (1u64 << 53) as f64) < frac
}

/// Held-out-split evaluation attached to a cache training run.
#[derive(Clone, Copy, Debug, Default)]
pub struct HoldoutReport {
    pub train_rows: u64,
    pub holdout_rows: u64,
    /// Accuracy of the final model on the held-out rows.
    pub accuracy: f64,
    /// Mean (unregularized) loss of the final model on the held-out rows.
    pub mean_loss: f64,
}

/// [`train_from_cache`] with a deterministic held-out split: a `frac`
/// fraction of rows (chosen by a salted per-row hash of the global row
/// index — see `holdout_row`) is excluded from every training epoch, then scored once
/// with the final weights — generalization measured against data the
/// model never touched, at the cost of one extra cache pass.
pub fn train_from_cache_holdout<P: AsRef<Path>>(
    path: P,
    cfg: &SgdConfig,
    frac: f64,
    salt: u64,
) -> Result<(LinearModel, TrainStats, HoldoutReport)> {
    if frac <= 0.0 || frac >= 1.0 || frac.is_nan() {
        return Err(Error::InvalidArg(format!(
            "holdout fraction must be in (0, 1), got {frac}"
        )));
    }
    let meta = CacheReader::open(&path)?.meta();
    let (b, k) = meta.spec.packed_geometry().ok_or_else(|| {
        Error::InvalidArg(format!(
            "cache records a sparse-output encoder ({}); streaming SGD needs packed codes",
            meta.spec.scheme()
        ))
    })?;
    let mut stream = SgdStream::new(cfg.clone(), b, k);
    let mut row_buf = vec![0u16; k];
    for _ in 0..cfg.epochs.max(1) {
        let mut reader = CacheReader::open(&path)?;
        let mut row0 = 0u64;
        while let Some((codes, labels)) = reader.next_chunk()? {
            // filter held-out rows from the training chunk
            let mut tr_codes = PackedCodes::new(b, k);
            let mut tr_labels = Vec::new();
            for i in 0..codes.n {
                if !holdout_row(row0 + i as u64, salt, frac) {
                    codes.row_into(i, &mut row_buf);
                    tr_codes.push_row(&row_buf)?;
                    tr_labels.push(labels[i]);
                }
            }
            row0 += codes.n as u64;
            if tr_codes.n > 0 {
                stream.push_chunk(tr_codes, tr_labels)?;
            }
        }
        stream.end_epoch();
    }
    let (model, stats) = stream.finalize();

    // one evaluation pass over the held-out rows with the final weights
    let mut reader = CacheReader::open(&path)?;
    let mut row0 = 0u64;
    let (mut held, mut correct) = (0u64, 0u64);
    let mut loss_sum = 0.0f64;
    while let Some((codes, labels)) = reader.next_chunk()? {
        let n = codes.n;
        let ds = BbitDataset::new(codes, labels);
        for i in 0..n {
            if holdout_row(row0 + i as u64, salt, frac) {
                held += 1;
                let m = ds.dot(i, &model.w);
                let y = ds.labels[i];
                loss_sum += cfg.loss.loss(m as f64, y as f64);
                if (m >= 0.0) == (y > 0) {
                    correct += 1;
                }
            }
        }
        row0 += n as u64;
    }
    let report = HoldoutReport {
        train_rows: meta.n - held,
        holdout_rows: held,
        accuracy: correct as f64 / held.max(1) as f64,
        mean_loss: loss_sum / held.max(1) as f64,
    };
    Ok((model, stats, report))
}

/// Evaluation of one model over one hashed cache.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheEval {
    pub rows: u64,
    pub accuracy: f64,
    /// Mean (unregularized) loss over all rows.
    pub mean_loss: f64,
}

/// Score every row of a hashed cache with a saved model — the batch twin
/// of the serve path (`classify --model m --cache c`).  The cache header
/// and the model file both record their [`EncoderSpec`]; a mismatch
/// (different scheme, parameters *or* hash-family seed — codes from one
/// family are meaningless under another's weights) is a typed error, never
/// an out-of-bounds panic.
pub fn eval_from_cache<P: AsRef<Path>>(
    path: P,
    saved: &SavedModel,
    loss: SgdLoss,
) -> Result<CacheEval> {
    let mut reader = CacheReader::open(&path)?;
    let meta = reader.meta();
    if meta.spec != saved.spec {
        return Err(Error::InvalidArg(format!(
            "cache encoder spec {:?} does not match the model's {:?}",
            meta.spec, saved.spec
        )));
    }
    let w = &saved.model.w;
    let (mut rows, mut correct) = (0u64, 0u64);
    let mut loss_sum = 0.0f64;
    while let Some((codes, labels)) = reader.next_chunk()? {
        let n = codes.n;
        let ds = BbitDataset::new(codes, labels);
        for i in 0..n {
            rows += 1;
            let m = ds.dot(i, w);
            let y = ds.labels[i];
            loss_sum += loss.loss(m as f64, y as f64);
            if (m >= 0.0) == (y > 0) {
                correct += 1;
            }
        }
    }
    Ok(CacheEval {
        rows,
        accuracy: correct as f64 / rows.max(1) as f64,
        mean_loss: loss_sum / rows.max(1) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Example, SparseDataset};
    use crate::solver::linear::accuracy;
    use crate::util::Rng;

    fn separable(n: usize, seed: u64) -> SparseDataset {
        let mut rng = Rng::new(seed);
        let mut examples = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let base = if pos { 0 } else { 16 };
            let feats: Vec<u32> =
                (0..6).map(|_| base + rng.below(16) as u32).collect();
            examples.push(Example::binary(if pos { 1 } else { -1 }, feats));
        }
        SparseDataset::from_examples(32, &examples)
    }

    #[test]
    fn learns_separable_data_both_losses() {
        let ds = separable(512, 51);
        for loss in [SgdLoss::Logistic, SgdLoss::SquaredHinge] {
            let cfg = SgdConfig { loss, epochs: 20, batch: 64, ..Default::default() };
            let (model, _) = train_sgd(&ds, &cfg);
            assert!(accuracy(&model, &ds) > 0.97, "{loss:?}");
        }
    }

    #[test]
    fn deterministic() {
        let ds = separable(128, 53);
        let cfg = SgdConfig::default();
        let (m1, _) = train_sgd(&ds, &cfg);
        let (m2, _) = train_sgd(&ds, &cfg);
        assert_eq!(m1.w, m2.w);
    }

    #[test]
    fn objective_decreases_over_epochs() {
        let ds = separable(256, 57);
        let short = train_sgd(&ds, &SgdConfig { epochs: 1, ..Default::default() });
        let long = train_sgd(&ds, &SgdConfig { epochs: 15, ..Default::default() });
        assert!(long.1.objective < short.1.objective);
    }

    #[test]
    fn grad_coefs_match_losses() {
        // logistic at m=0: -y/2; sqhinge at (m=0,y=1): -2
        assert!((SgdLoss::Logistic.grad_coef(0.0, 1.0) + 0.5).abs() < 1e-6);
        assert!((SgdLoss::SquaredHinge.grad_coef(0.0, 1.0) + 2.0).abs() < 1e-6);
        // no gradient beyond the margin for hinge
        assert_eq!(SgdLoss::SquaredHinge.grad_coef(2.0, 1.0), 0.0);
    }

    #[test]
    fn lambda_from_c_mapping() {
        assert!((lambda_from_c(1.0, 1000) - 1e-3).abs() < 1e-12);
        assert!((lambda_from_c(10.0, 100) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn holdout_membership_is_deterministic_and_near_frac() {
        let frac = 0.2;
        let n = 20_000u64;
        let held: Vec<u64> = (0..n).filter(|&i| holdout_row(i, 0x5A17, frac)).collect();
        // deterministic: same inputs, same split
        let held2: Vec<u64> = (0..n).filter(|&i| holdout_row(i, 0x5A17, frac)).collect();
        assert_eq!(held, held2);
        // different salt, different split
        assert_ne!(held, (0..n).filter(|&i| holdout_row(i, 0x0DD, frac)).collect::<Vec<_>>());
        // the realized fraction concentrates around frac
        let realized = held.len() as f64 / n as f64;
        assert!((realized - frac).abs() < 0.02, "realized {realized}");
    }

    #[test]
    fn holdout_frac_bounds_are_typed_errors() {
        for frac in [0.0, 1.0, -0.5, 1.5, f64::NAN] {
            let err = train_from_cache_holdout("/nonexistent", &SgdConfig::default(), frac, 1);
            assert!(err.is_err(), "frac {frac} must be rejected before any IO");
        }
    }

    fn random_bbit(b: u32, k: usize, n: usize, seed: u64) -> BbitDataset {
        let mut rng = Rng::new(seed);
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for _ in 0..n {
            let row: Vec<u16> = (0..k).map(|_| rng.below(1 << b) as u16).collect();
            pc.push_row(&row).unwrap();
            labels.push(if rng.bool() { 1 } else { -1 });
        }
        BbitDataset::new(pc, labels)
    }

    /// Slice rows [lo, hi) of a BbitDataset into a standalone chunk.
    fn chunk_of(ds: &BbitDataset, lo: usize, hi: usize) -> (PackedCodes, Vec<i8>) {
        let mut pc = PackedCodes::new(ds.codes.b, ds.codes.k);
        let mut row = vec![0u16; ds.codes.k];
        for i in lo..hi {
            ds.codes.row_into(i, &mut row);
            pc.push_row(&row).unwrap();
        }
        (pc, ds.labels[lo..hi].to_vec())
    }

    #[test]
    fn stream_matches_batch_across_ragged_chunks_and_epochs() {
        let ds = random_bbit(4, 24, 157, 0xD1CE);
        // batch=32 does not divide 157 and chunk boundaries (13) never
        // align with minibatch boundaries — the re-batching must hide both
        let cfg = SgdConfig { epochs: 3, batch: 32, lambda: 1e-3, ..Default::default() };
        let (reference, _) = train_sgd(&ds, &cfg);
        let mut stream = SgdStream::new(cfg.clone(), 4, 24);
        for _ in 0..cfg.epochs {
            let mut lo = 0;
            while lo < ds.len() {
                let hi = (lo + 13).min(ds.len());
                let (pc, ls) = chunk_of(&ds, lo, hi);
                stream.push_chunk(pc, ls).unwrap();
                lo = hi;
            }
            stream.end_epoch();
        }
        assert_eq!(stream.rows_seen(), (ds.len() * cfg.epochs) as u64);
        let (model, stats) = stream.finalize();
        assert_eq!(stats.iterations, 3);
        let max_diff = model
            .w
            .iter()
            .zip(&reference.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "stream/batch weight divergence: {max_diff}");
    }

    #[test]
    fn train_sgd_stream_single_pass_matches_one_epoch() {
        let ds = random_bbit(8, 10, 90, 0xBEEF);
        let cfg = SgdConfig { epochs: 1, batch: 16, ..Default::default() };
        let (reference, _) = train_sgd(&ds, &cfg);
        let chunks: Vec<_> = (0..ds.len())
            .step_by(7)
            .map(|lo| Ok(chunk_of(&ds, lo, (lo + 7).min(ds.len()))))
            .collect();
        let (model, stats) = train_sgd_stream(chunks.into_iter(), 8, 10, &cfg).unwrap();
        assert!(stats.objective.is_finite());
        let max_diff = model
            .w
            .iter()
            .zip(&reference.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "divergence: {max_diff}");
    }

    #[test]
    fn stream_rejects_geometry_mismatch() {
        let mut stream = SgdStream::new(SgdConfig::default(), 8, 16);
        let ds = random_bbit(8, 17, 4, 1);
        let (pc, ls) = chunk_of(&ds, 0, 4);
        assert!(stream.push_chunk(pc, ls).is_err());
        let ds = random_bbit(4, 16, 4, 2);
        let (pc, ls) = chunk_of(&ds, 0, 4);
        assert!(stream.push_chunk(pc, ls).is_err());
        let ds = random_bbit(8, 16, 4, 3);
        let (pc, _) = chunk_of(&ds, 0, 4);
        assert!(stream.push_chunk(pc, vec![1]).is_err());
    }

    #[test]
    fn aligned_chunks_take_the_zero_copy_path_and_still_match() {
        // chunk size == batch size: every chunk hits the in-place fast
        // path; weights must be identical to the batch reference anyway
        let ds = random_bbit(6, 12, 128, 0xA11);
        let cfg = SgdConfig { epochs: 2, batch: 32, ..Default::default() };
        let (reference, _) = train_sgd(&ds, &cfg);
        let mut stream = SgdStream::new(cfg.clone(), 6, 12);
        for _ in 0..cfg.epochs {
            for lo in (0..ds.len()).step_by(32) {
                let (pc, ls) = chunk_of(&ds, lo, lo + 32);
                stream.push_chunk(pc, ls).unwrap();
            }
            stream.end_epoch();
        }
        let (model, _) = stream.finalize();
        let max_diff = model
            .w
            .iter()
            .zip(&reference.w)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "fast-path divergence: {max_diff}");
    }
}
