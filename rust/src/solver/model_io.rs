//! Trained-model persistence: weights + the hashing recipe needed to
//! classify raw documents later.
//!
//! Because every hash family in this crate derives deterministically from
//! a `u64` seed (DESIGN.md §5b), a model file only stores `(b, k, d,
//! seed)` plus the weight vector — the loader re-draws the identical
//! family and the `classify` CLI can score raw LibSVM documents without
//! any other state.  Text header + little-endian f32 weights.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::hashing::minwise::BbitMinHash;
use crate::solver::linear::LinearModel;
use crate::util::Rng;
use crate::{Error, Result};

/// Everything needed to classify a raw document.
#[derive(Clone, Debug)]
pub struct SavedModel {
    pub b: u32,
    pub k: usize,
    pub d: u64,
    pub seed: u64,
    pub model: LinearModel,
}

impl SavedModel {
    /// Re-draw the (deterministic) hash family this model was trained with.
    pub fn hasher(&self) -> BbitMinHash {
        BbitMinHash::draw(self.k, self.b, self.d, &mut Rng::new(self.seed))
    }

    /// Margin for one raw document (set of feature indices).
    pub fn margin(&self, set: &[u32], scratch: &mut ClassifyScratch) -> f32 {
        scratch.hasher.codes_into(set, &mut scratch.z, &mut scratch.codes);
        let bshift = self.b as usize;
        let mut acc = 0.0f32;
        for (j, &c) in scratch.codes.iter().enumerate() {
            acc += self.model.w[(j << bshift) + c as usize];
        }
        acc
    }

    pub fn scratch(&self) -> ClassifyScratch {
        ClassifyScratch {
            hasher: self.hasher(),
            z: vec![0u64; self.k],
            codes: vec![0u16; self.k],
        }
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        writeln!(w, "BBMH-MODEL v1")?;
        writeln!(w, "b {}", self.b)?;
        writeln!(w, "k {}", self.k)?;
        writeln!(w, "d {}", self.d)?;
        writeln!(w, "seed {}", self.seed)?;
        writeln!(w, "dim {}", self.model.w.len())?;
        writeln!(w, "weights")?;
        for x in &self.model.w {
            w.write_all(&x.to_le_bytes())?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        let mut r = BufReader::new(f);
        // read header lines until "weights"
        let mut header = String::new();
        let mut byte = [0u8; 1];
        loop {
            r.read_exact(&mut byte)?;
            header.push(byte[0] as char);
            if header.ends_with("weights\n") {
                break;
            }
            if header.len() > 4096 {
                return Err(Error::InvalidArg("model header too large".into()));
            }
        }
        let mut lines = header.lines();
        if lines.next() != Some("BBMH-MODEL v1") {
            return Err(Error::InvalidArg("bad model magic".into()));
        }
        let mut get = |key: &str| -> Result<u64> {
            let line = lines
                .next()
                .ok_or_else(|| Error::InvalidArg(format!("missing {key}")))?;
            let (k, v) = line
                .split_once(' ')
                .ok_or_else(|| Error::InvalidArg(format!("bad line {line:?}")))?;
            if k != key {
                return Err(Error::InvalidArg(format!("expected {key}, got {k}")));
            }
            v.parse()
                .map_err(|_| Error::InvalidArg(format!("bad {key} value {v:?}")))
        };
        let b = get("b")? as u32;
        let k = get("k")? as usize;
        let d = get("d")?;
        let seed = get("seed")?;
        let dim = get("dim")? as usize;
        if dim != (1usize << b) * k {
            return Err(Error::InvalidArg(format!(
                "dim {dim} inconsistent with 2^{b}·{k}"
            )));
        }
        let mut bytes = vec![0u8; dim * 4];
        r.read_exact(&mut bytes)?;
        let w: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(SavedModel { b, k, d, seed, model: LinearModel { w } })
    }
}

/// Reusable per-thread classification scratch (hash family + buffers).
pub struct ClassifyScratch {
    hasher: BbitMinHash,
    z: Vec<u64>,
    codes: Vec<u16>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{dataset_chunks, HashJob, Pipeline, PipelineConfig};
    use crate::data::gen::{CorpusConfig, CorpusGenerator};
    use crate::solver::dcd_svm::{train_svm, SvmConfig};
    use crate::solver::linear::accuracy;

    #[test]
    fn save_load_roundtrip_and_classify_consistency() {
        let corpus =
            CorpusGenerator::new(CorpusConfig::rcv1_like(400, 77)).generate();
        let (b, k, d, seed) = (8u32, 64usize, corpus.dim, 0x5EED1u64);
        let job = HashJob::Bbit { b, k, d, seed };
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 64, queue_depth: 2 });
        let (hashed, _) = pipe.run(dataset_chunks(&corpus, 64), &job).unwrap();
        let hashed = hashed.into_bbit().unwrap();
        let (model, _) = train_svm(&hashed, &SvmConfig::with_c(1.0));
        let acc_direct = accuracy(&model, &hashed);
        assert!(acc_direct > 0.9);

        let saved = SavedModel { b, k, d, seed, model };
        let dir = std::env::temp_dir().join(format!("bbmh_model_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bbmh");
        saved.save(&path).unwrap();
        let loaded = SavedModel::load(&path).unwrap();
        assert_eq!(loaded.b, b);
        assert_eq!(loaded.model.w, saved.model.w);

        // classifying raw documents must match the trained-path accuracy
        let mut scratch = loaded.scratch();
        let correct = (0..corpus.len())
            .filter(|&i| {
                let m = loaded.margin(corpus.row(i).0, &mut scratch);
                (m >= 0.0) == (corpus.labels[i] > 0)
            })
            .count();
        let acc_raw = correct as f64 / corpus.len() as f64;
        assert!((acc_raw - acc_direct).abs() < 1e-9, "{acc_raw} vs {acc_direct}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("bbmh_badmodel_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bbmh");
        std::fs::write(&path, b"NOT A MODEL\nweights\n").unwrap();
        assert!(SavedModel::load(&path).is_err());
        // truncated weights
        std::fs::write(
            &path,
            b"BBMH-MODEL v1\nb 4\nk 2\nd 1024\nseed 1\ndim 32\nweights\nxx",
        )
        .unwrap();
        assert!(SavedModel::load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
