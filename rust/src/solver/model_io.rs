//! Trained-model persistence: weights + the encoder spec needed to
//! classify raw documents later.
//!
//! Because every encoder in this crate derives deterministically from its
//! [`EncoderSpec`] (DESIGN.md §5b), a model file only stores the spec plus
//! the weight vector — the loader re-draws the identical family (once,
//! cached on the loaded model) and the `classify` CLI can score raw LibSVM
//! documents with any scheme without any other state.  Text header +
//! little-endian f32 weights.
//!
//! Format v2: `BBMH-MODEL v2`, an `encoder <scheme>` line, the scheme's
//! parameters as `key value` lines, `dim`, then weights.  v1 files (b-bit
//! only: `b/k/d/seed/dim`) are still readable.
//!
//! Format v3 (training checkpoints): v2 plus an [`OptState`] block —
//! `step`/`rows_seen`/`epochs_done`/`loss_sum_bits` lines between `dim`
//! and `weights` — everything [`SgdStream`](crate::solver::SgdStream)
//! needs to continue a killed run to bit-identical final weights.
//! `save` writes v3 exactly when [`SavedModel::opt`] is set, so plain
//! models keep the v2 format older readers understand.
//!
//! Every save commits through
//! [`atomic_file::write_atomic`](crate::util::atomic_file::write_atomic)
//! (tmp + fsync + rename): a model path never names a half-written file,
//! which is what makes the serve tier's hot reload safe against a crash
//! mid-checkpoint.

use std::fmt;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::encode::encoder::{EncodeScratch, EncoderSpec, FeatureEncoder};
use crate::solver::linear::LinearModel;
use crate::util::atomic_file;
use crate::{Error, Result};

/// Optimizer state carried by a v3 training checkpoint: the schedule
/// position ([`SgdStream`](crate::solver::SgdStream) step counter), the
/// progressive-loss accumulators, and how many epochs are already done.
/// `loss_sum` round-trips through its raw f64 bits so a resumed run's
/// progressive loss is bit-identical to an uninterrupted one.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OptState {
    /// Minibatch steps taken (drives the learning-rate schedule).
    pub step: u64,
    /// Rows consumed across all epochs.
    pub rows_seen: u64,
    /// Epochs fully completed — a resumed run restarts at this epoch.
    pub epochs_done: usize,
    /// Progressive-loss numerator (pre-update loss summed over all rows).
    pub loss_sum: f64,
}

/// Everything needed to classify a raw document: the encoder spec, the
/// weights, and the encoder itself — drawn **once** at construction/load
/// time and reused across every classify call (re-drawing the hash family
/// per call was the old hot-path bug).
pub struct SavedModel {
    pub spec: EncoderSpec,
    pub model: LinearModel,
    /// Optimizer state when this file is a training checkpoint (`None`
    /// for plain models; its presence selects the v3 on-disk format).
    pub opt: Option<OptState>,
    encoder: Box<dyn FeatureEncoder>,
}

impl SavedModel {
    /// Bind weights to an encoder spec (validates the dimensionality and
    /// draws the encoder once).
    pub fn new(spec: EncoderSpec, model: LinearModel) -> Result<Self> {
        spec.validate()?;
        if model.w.len() != spec.output_dim() {
            return Err(Error::InvalidArg(format!(
                "model has {} weights but {} encoder expands to {}",
                model.w.len(),
                spec.scheme(),
                spec.output_dim()
            )));
        }
        let encoder = spec.encoder()?;
        Ok(SavedModel { spec, model, opt: None, encoder })
    }

    /// The cached encoder this model classifies with.
    pub fn encoder(&self) -> &dyn FeatureEncoder {
        self.encoder.as_ref()
    }

    /// Margin for one raw document (set of feature indices).
    pub fn margin(&self, set: &[u32], scratch: &mut EncodeScratch) -> f32 {
        self.encoder.margin(set, &self.model.w, scratch)
    }

    /// Reusable per-thread classification scratch.
    pub fn scratch(&self) -> EncodeScratch {
        self.encoder.scratch()
    }

    /// Write the model file atomically (tmp + fsync + rename): readers —
    /// including a live server's hot-reload poller — only ever see the
    /// old complete file or the new complete file, never a torn one.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        atomic_file::write_atomic(path.as_ref(), |f| -> Result<()> {
            let mut w = BufWriter::new(f);
            writeln!(w, "BBMH-MODEL v{}", if self.opt.is_some() { 3 } else { 2 })?;
            // the spec's text form is owned by EncoderSpec, next to its
            // binary cache-header form — one place per serialization
            self.spec.write_text_fields(&mut w)?;
            writeln!(w, "dim {}", self.model.w.len())?;
            if let Some(opt) = &self.opt {
                writeln!(w, "step {}", opt.step)?;
                writeln!(w, "rows_seen {}", opt.rows_seen)?;
                writeln!(w, "epochs_done {}", opt.epochs_done)?;
                writeln!(w, "loss_sum_bits {}", opt.loss_sum.to_bits())?;
            }
            writeln!(w, "weights")?;
            for x in &self.model.w {
                w.write_all(&x.to_le_bytes())?;
            }
            w.flush()?;
            Ok(())
        })
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let f = std::fs::File::open(path)?;
        let mut r = BufReader::new(f);
        // read header lines until "weights"
        let mut header = String::new();
        let mut byte = [0u8; 1];
        loop {
            r.read_exact(&mut byte)?;
            header.push(byte[0] as char);
            if header.ends_with("weights\n") {
                break;
            }
            if header.len() > 4096 {
                return Err(Error::InvalidArg("model header too large".into()));
            }
        }
        let mut lines = header.lines();
        let version = match lines.next() {
            Some("BBMH-MODEL v1") => 1u32,
            Some("BBMH-MODEL v2") => 2u32,
            Some("BBMH-MODEL v3") => 3u32,
            _ => return Err(Error::InvalidArg("bad model magic".into())),
        };
        let mut next_kv = |key: &str| -> Result<String> {
            let line = lines
                .next()
                .ok_or_else(|| Error::InvalidArg(format!("missing {key}")))?;
            let (k, v) = line
                .split_once(' ')
                .ok_or_else(|| Error::InvalidArg(format!("bad line {line:?}")))?;
            if k != key {
                return Err(Error::InvalidArg(format!("expected {key}, got {k}")));
            }
            Ok(v.to_string())
        };
        fn num<T: std::str::FromStr>(v: &str, key: &str) -> Result<T> {
            v.parse()
                .map_err(|_| Error::InvalidArg(format!("bad {key} value {v:?}")))
        }
        let spec = if version == 1 {
            // legacy fixed-field header: always b-bit minwise
            EncoderSpec::Bbit {
                b: num(&next_kv("b")?, "b")?,
                k: num(&next_kv("k")?, "k")?,
                d: num(&next_kv("d")?, "d")?,
                seed: num(&next_kv("seed")?, "seed")?,
            }
        } else {
            EncoderSpec::read_text_fields(&mut next_kv)?
        };
        let dim: usize = num(&next_kv("dim")?, "dim")?;
        if dim != spec.output_dim() {
            return Err(Error::InvalidArg(format!(
                "dim {dim} inconsistent with {} encoder ({})",
                spec.scheme(),
                spec.output_dim()
            )));
        }
        let opt = if version == 3 {
            Some(OptState {
                step: num(&next_kv("step")?, "step")?,
                rows_seen: num(&next_kv("rows_seen")?, "rows_seen")?,
                epochs_done: num(&next_kv("epochs_done")?, "epochs_done")?,
                loss_sum: f64::from_bits(num(&next_kv("loss_sum_bits")?, "loss_sum_bits")?),
            })
        } else {
            None
        };
        let mut bytes = vec![0u8; dim * 4];
        r.read_exact(&mut bytes)?;
        let w: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let mut saved = SavedModel::new(spec, LinearModel { w })?;
        saved.opt = opt;
        Ok(saved)
    }
}

impl Clone for SavedModel {
    fn clone(&self) -> Self {
        // the encoder draw is deterministic in the spec, and `self` was
        // validated at construction — re-drawing cannot fail
        let mut clone = SavedModel::new(self.spec, self.model.clone())
            .expect("cloning a validated model cannot fail");
        clone.opt = self.opt;
        clone
    }
}

impl fmt::Debug for SavedModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SavedModel")
            .field("spec", &self.spec)
            .field("dim", &self.model.w.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{dataset_chunks, Pipeline, PipelineConfig};
    use crate::data::gen::{CorpusConfig, CorpusGenerator};
    use crate::solver::dcd_svm::{train_svm, SvmConfig};
    use crate::solver::linear::accuracy;

    #[test]
    fn save_load_roundtrip_and_classify_consistency() {
        let corpus =
            CorpusGenerator::new(CorpusConfig::rcv1_like(400, 77)).generate();
        let spec =
            EncoderSpec::Bbit { b: 8, k: 64, d: corpus.dim, seed: 0x5EED1 };
        let pipe = Pipeline::new(PipelineConfig { workers: 2, chunk_size: 64, queue_depth: 2 });
        let (hashed, _) = pipe.run(dataset_chunks(&corpus, 64), &spec).unwrap();
        let hashed = hashed.into_packed().unwrap();
        let (model, _) = train_svm(&hashed, &SvmConfig::with_c(1.0));
        let acc_direct = accuracy(&model, &hashed);
        assert!(acc_direct > 0.9);

        let saved = SavedModel::new(spec, model).unwrap();
        let dir = std::env::temp_dir().join(format!("bbmh_model_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.bbmh");
        saved.save(&path).unwrap();
        let loaded = SavedModel::load(&path).unwrap();
        assert_eq!(loaded.spec, spec);
        assert_eq!(loaded.model.w, saved.model.w);

        // classifying raw documents must match the trained-path accuracy
        let mut scratch = loaded.scratch();
        let correct = (0..corpus.len())
            .filter(|&i| {
                let m = loaded.margin(corpus.row(i).0, &mut scratch);
                (m >= 0.0) == (corpus.labels[i] > 0)
            })
            .count();
        let acc_raw = correct as f64 / corpus.len() as f64;
        assert!((acc_raw - acc_direct).abs() < 1e-9, "{acc_raw} vs {acc_direct}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn every_scheme_roundtrips_through_the_model_file() {
        let dir = std::env::temp_dir().join(format!("bbmh_specmodels_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let specs = [
            EncoderSpec::Bbit { b: 4, k: 10, d: 1 << 20, seed: 1 },
            EncoderSpec::Vw { bins: 40, seed: 2 },
            EncoderSpec::Rp { proj: 12, s: 3.0, seed: 3 },
            EncoderSpec::Oph { bins: 9, b: 5, seed: 4 },
        ];
        for (i, spec) in specs.iter().enumerate() {
            let w: Vec<f32> = (0..spec.output_dim()).map(|j| j as f32 * 0.25 - 1.0).collect();
            let saved = SavedModel::new(*spec, LinearModel { w }).unwrap();
            let path = dir.join(format!("m{i}.bbmh"));
            saved.save(&path).unwrap();
            let loaded = SavedModel::load(&path).unwrap();
            assert_eq!(loaded.spec, *spec, "{}", spec.scheme());
            assert_eq!(loaded.model.w, saved.model.w);
            // margins agree between the saved and loaded encoders
            let set: Vec<u32> = (0..30).map(|t| t * 17 % 1000).collect();
            let set = {
                let mut s = set;
                s.sort_unstable();
                s.dedup();
                s
            };
            let (mut s1, mut s2) = (saved.scratch(), loaded.scratch());
            assert_eq!(saved.margin(&set, &mut s1), loaded.margin(&set, &mut s2));
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn v1_model_files_still_load_as_bbit() {
        let dir = std::env::temp_dir().join(format!("bbmh_v1model_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.bbmh");
        let (b, k) = (4u32, 2usize);
        let dim = (1usize << b) * k;
        let mut bytes = format!("BBMH-MODEL v1\nb {b}\nk {k}\nd 1024\nseed 9\ndim {dim}\nweights\n")
            .into_bytes();
        for j in 0..dim {
            bytes.extend_from_slice(&(j as f32).to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let loaded = SavedModel::load(&path).unwrap();
        assert_eq!(loaded.spec, EncoderSpec::Bbit { b, k, d: 1024, seed: 9 });
        assert_eq!(loaded.model.w.len(), dim);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn v3_checkpoint_roundtrips_opt_state_bit_exactly() {
        let dir = std::env::temp_dir().join(format!("bbmh_v3model_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bbmh");
        let spec = EncoderSpec::Bbit { b: 4, k: 6, d: 1 << 12, seed: 7 };
        let w: Vec<f32> = (0..spec.output_dim()).map(|j| (j as f32).sin()).collect();
        let mut saved = SavedModel::new(spec, LinearModel { w }).unwrap();
        saved.opt = Some(OptState {
            step: 12345,
            rows_seen: 987654,
            epochs_done: 3,
            // a value with no short decimal form: bits must survive
            loss_sum: 0.1 + 0.2,
        });
        saved.save(&path).unwrap();
        assert!(
            std::fs::read(&path).unwrap().starts_with(b"BBMH-MODEL v3\n"),
            "opt state selects the v3 format"
        );
        assert!(!crate::util::atomic_file::tmp_path(&path).exists(), "save must not leave a tmp");
        let loaded = SavedModel::load(&path).unwrap();
        assert_eq!(loaded.opt, saved.opt);
        assert_eq!(
            loaded.opt.unwrap().loss_sum.to_bits(),
            (0.1f64 + 0.2).to_bits(),
            "loss_sum must round-trip bit-exactly"
        );
        assert_eq!(loaded.model.w, saved.model.w);
        assert_eq!(loaded.clone().opt, saved.opt, "clone keeps the checkpoint state");
        // a plain model (opt None) keeps writing the v2 format
        let plain = SavedModel::new(spec, saved.model.clone()).unwrap();
        plain.save(&path).unwrap();
        assert!(std::fs::read(&path).unwrap().starts_with(b"BBMH-MODEL v2\n"));
        assert_eq!(SavedModel::load(&path).unwrap().opt, None);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn mismatched_dim_is_rejected() {
        let spec = EncoderSpec::Vw { bins: 8, seed: 0 };
        assert!(SavedModel::new(spec, LinearModel { w: vec![0.0; 9] }).is_err());
    }

    #[test]
    fn load_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("bbmh_badmodel_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bbmh");
        std::fs::write(&path, b"NOT A MODEL\nweights\n").unwrap();
        assert!(SavedModel::load(&path).is_err());
        // truncated weights
        std::fs::write(
            &path,
            b"BBMH-MODEL v1\nb 4\nk 2\nd 1024\nseed 1\ndim 32\nweights\nxx",
        )
        .unwrap();
        assert!(SavedModel::load(&path).is_err());
        // unknown scheme
        std::fs::write(
            &path,
            b"BBMH-MODEL v2\nencoder simhash\nbins 4\nseed 1\ndim 4\nweights\n",
        )
        .unwrap();
        assert!(SavedModel::load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
