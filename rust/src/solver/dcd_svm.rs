//! Dual coordinate descent for L2-regularized linear SVM
//! (Hsieh, Chang, Lin, Keerthi & Sundararajan, ICML 2008 — the algorithm
//! behind LIBLINEAR's `-s 1` (L2-loss) and `-s 3` (L1-loss) solvers, which
//! the paper uses for Figures 1–2, 5, 7).
//!
//! Solves  min_w  ½‖w‖² + C Σᵢ loss(yᵢ wᵀxᵢ)  through the dual
//!
//!   min_α  ½ αᵀ Q̄ α − eᵀα,   0 ≤ αᵢ ≤ U,
//!   Q̄ = Q + D,  Qᵢⱼ = yᵢyⱼ xᵢᵀxⱼ,
//!
//! with (L1 hinge) U = C, Dᵢᵢ = 0 and (L2 squared hinge) U = ∞,
//! Dᵢᵢ = 1/(2C).  The primal vector w = Σ αᵢyᵢxᵢ is maintained
//! incrementally, so each coordinate update is O(nnz(xᵢ)).  Random
//! permutation each outer pass; projected-gradient stopping rule as in the
//! paper/LIBLINEAR (without the shrinking heuristic — our problem sizes
//! after hashing don't need it; an ablation bench measures the cost).

use std::time::Instant;

use crate::solver::linear::{FeatureMatrix, LinearModel, TrainStats};
use crate::util::Rng;

/// Hinge variant (paper Eq. 8 is L1; LIBLINEAR's default dual is L2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvmLoss {
    /// max(1 − y·m, 0): U = C, D = 0.
    L1Hinge,
    /// max(1 − y·m, 0)²: U = ∞, D = 1/(2C).
    L2Hinge,
}

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct SvmConfig {
    pub c: f64,
    pub loss: SvmLoss,
    /// Stop when the projected-gradient spread falls below this.
    pub eps: f64,
    pub max_iter: usize,
    pub seed: u64,
    /// LIBLINEAR's shrinking heuristic: temporarily drop bounded
    /// coordinates whose projected gradient exceeds the previous pass's
    /// extremes (Hsieh et al. §4).  Off by default — hashed problems are
    /// small; `bench_train` carries the ablation.
    pub shrinking: bool,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig {
            c: 1.0,
            loss: SvmLoss::L2Hinge,
            eps: 0.1,
            max_iter: 200,
            seed: 1,
            shrinking: false,
        }
    }
}

impl SvmConfig {
    pub fn with_c(c: f64) -> Self {
        SvmConfig { c, ..Default::default() }
    }
}

/// Train a linear SVM by dual coordinate descent.
pub fn train_svm<F: FeatureMatrix>(data: &F, cfg: &SvmConfig) -> (LinearModel, TrainStats) {
    let t0 = Instant::now();
    let n = data.n();
    let (u_bound, d_diag) = match cfg.loss {
        SvmLoss::L1Hinge => (cfg.c, 0.0),
        SvmLoss::L2Hinge => (f64::INFINITY, 1.0 / (2.0 * cfg.c)),
    };
    let mut w = vec![0.0f32; data.dim()];
    let mut alpha = vec![0.0f64; n];
    // Q̄ᵢᵢ = ‖xᵢ‖² + Dᵢᵢ, precomputed once
    let qbar_diag: Vec<f64> =
        (0..n).map(|i| data.norm_sq(i) as f64 + d_diag).collect();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(cfg.seed);
    // shrinking state: `active` prefix of `order` is still optimized;
    // previous pass's PG extremes gate the shrink test (Hsieh et al. §4)
    let mut active = n;
    let (mut prev_pg_max, mut prev_pg_min) = (f64::INFINITY, f64::NEG_INFINITY);

    let mut stats = TrainStats::default();
    let mut iter = 0;
    while iter < cfg.max_iter {
        rng.shuffle(&mut order[..active]);
        // projected-gradient extremes for the stopping rule
        let (mut pg_max, mut pg_min) = (f64::NEG_INFINITY, f64::INFINITY);
        let mut s = 0usize;
        while s < active {
            let i = order[s];
            if s + 1 < active {
                // one-row-ahead weight prefetch; a later shrink may swap
                // order[s+1] away, which just makes this a wasted hint
                data.prefetch_row(order[s + 1], &w);
            }
            let yi = data.label(i) as f64;
            let g = yi * data.dot(i, &w) as f64 - 1.0 + d_diag * alpha[i];
            // projected gradient (+ the shrink test at the bounds)
            let pg = if alpha[i] <= 0.0 {
                if cfg.shrinking && g > prev_pg_max.max(0.0) {
                    // bounded at 0 and strongly optimal → shrink out
                    active -= 1;
                    order.swap(s, active);
                    continue;
                }
                g.min(0.0)
            } else if alpha[i] >= u_bound {
                if cfg.shrinking && g < prev_pg_min.min(0.0) {
                    active -= 1;
                    order.swap(s, active);
                    continue;
                }
                g.max(0.0)
            } else {
                g
            };
            if pg != 0.0 {
                pg_max = pg_max.max(pg);
                pg_min = pg_min.min(pg);
                let old = alpha[i];
                let new = (old - g / qbar_diag[i]).clamp(0.0, u_bound);
                if new != old {
                    alpha[i] = new;
                    data.axpy(i, ((new - old) * yi) as f32, &mut w);
                }
            }
            s += 1;
        }
        iter += 1;
        stats.iterations = iter;
        let spread = if pg_max == f64::NEG_INFINITY {
            0.0
        } else {
            pg_max - pg_min
        };
        if spread <= cfg.eps {
            if active == n {
                stats.converged = true;
                break;
            }
            // converged on the shrunk set: restore everything and take one
            // verification pass over the full problem (LIBLINEAR's rule)
            active = n;
            prev_pg_max = f64::INFINITY;
            prev_pg_min = f64::NEG_INFINITY;
            continue;
        }
        prev_pg_max = if pg_max <= 0.0 { f64::INFINITY } else { pg_max };
        prev_pg_min = if pg_min >= 0.0 { f64::NEG_INFINITY } else { pg_min };
    }

    let c = cfg.c;
    stats.objective = match cfg.loss {
        SvmLoss::L1Hinge => crate::solver::linear::primal_objective(data, &w, c, |ym| {
            (1.0 - ym).max(0.0)
        }),
        SvmLoss::L2Hinge => crate::solver::linear::primal_objective(data, &w, c, |ym| {
            let v = (1.0 - ym).max(0.0);
            v * v
        }),
    };
    stats.train_seconds = t0.elapsed().as_secs_f64();
    (LinearModel { w }, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Example, SparseDataset};
    use crate::solver::linear::accuracy;
    use crate::util::Rng;

    fn separable(n: usize, seed: u64) -> SparseDataset {
        // positives use features [0, 10), negatives [10, 20)
        let mut rng = Rng::new(seed);
        let mut examples = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let base = if pos { 0 } else { 10 };
            let feats: Vec<u32> =
                (0..4).map(|_| base + rng.below(10) as u32).collect();
            examples.push(Example::binary(if pos { 1 } else { -1 }, feats));
        }
        SparseDataset::from_examples(20, &examples)
    }

    #[test]
    fn separable_data_reaches_full_accuracy() {
        let ds = separable(200, 5);
        for loss in [SvmLoss::L1Hinge, SvmLoss::L2Hinge] {
            let cfg = SvmConfig { loss, ..SvmConfig::with_c(1.0) };
            let (model, stats) = train_svm(&ds, &cfg);
            assert!(accuracy(&model, &ds) > 0.99, "{loss:?}");
            assert!(stats.converged, "{loss:?} iterations {}", stats.iterations);
        }
    }

    #[test]
    fn dual_feasibility_and_kkt() {
        // after convergence on L1 hinge, alphas must be within [0, C] and
        // complementary slackness approximately holds
        let ds = separable(100, 7);
        let c = 0.5;
        let cfg = SvmConfig {
            c,
            loss: SvmLoss::L1Hinge,
            eps: 1e-3,
            max_iter: 2000,
            seed: 3,
            ..Default::default()
        };
        let (model, _) = train_svm(&ds, &cfg);
        // margin violations imply the objective cannot be far from optimal:
        // re-train with much smaller eps and compare objectives
        let tight = SvmConfig { eps: 1e-6, max_iter: 5000, ..cfg };
        let (model2, s2) = train_svm(&ds, &tight);
        let obj1 = crate::solver::linear::primal_objective(&ds, &model.w, c, |ym| {
            (1.0 - ym).max(0.0)
        });
        let obj2 = crate::solver::linear::primal_objective(&ds, &model2.w, c, |ym| {
            (1.0 - ym).max(0.0)
        });
        assert!(obj1 >= obj2 - 1e-6);
        assert!((obj1 - obj2) / obj2.max(1e-9) < 0.05, "{obj1} vs {obj2}");
        assert!(s2.iterations >= 1);
    }

    #[test]
    fn objective_decreases_with_tighter_eps() {
        let ds = separable(150, 11);
        let loose = train_svm(&ds, &SvmConfig { eps: 1.0, ..Default::default() });
        let tight = train_svm(&ds, &SvmConfig { eps: 1e-5, max_iter: 3000, ..Default::default() });
        assert!(tight.1.objective <= loose.1.objective + 1e-9);
    }

    #[test]
    fn larger_c_fits_harder() {
        // flip some labels → not separable; larger C must reach lower
        // training error (or equal) at convergence
        let mut ds = separable(300, 13);
        let mut rng = Rng::new(17);
        for _ in 0..30 {
            let i = rng.below_usize(300);
            ds.labels[i] = -ds.labels[i];
        }
        let small = train_svm(&ds, &SvmConfig { eps: 1e-4, max_iter: 1000, ..SvmConfig::with_c(0.001) });
        let large = train_svm(&ds, &SvmConfig { eps: 1e-4, max_iter: 1000, ..SvmConfig::with_c(10.0) });
        assert!(accuracy(&large.0, &ds) >= accuracy(&small.0, &ds) - 0.01);
    }

    #[test]
    fn shrinking_matches_unshrunk_objective() {
        // shrinking is an optimization, not an approximation: at a tight
        // tolerance both variants must land on the same objective
        let mut ds = separable(400, 21);
        let mut rng = Rng::new(22);
        for _ in 0..40 {
            let i = rng.below_usize(400);
            ds.labels[i] = -ds.labels[i]; // noise → bounded alphas exist
        }
        for loss in [SvmLoss::L1Hinge, SvmLoss::L2Hinge] {
            let base = SvmConfig { c: 0.5, loss, eps: 1e-4, max_iter: 3000, ..Default::default() };
            let plain = train_svm(&ds, &base);
            let shrunk = train_svm(&ds, &SvmConfig { shrinking: true, ..base });
            let rel = (plain.1.objective - shrunk.1.objective).abs()
                / plain.1.objective.abs().max(1e-9);
            assert!(rel < 1e-3, "{loss:?}: {} vs {}", plain.1.objective, shrunk.1.objective);
            assert!(shrunk.1.converged);
        }
    }

    #[test]
    fn trains_on_bbit_data() {
        use crate::encode::expansion::BbitDataset;
        use crate::encode::packed::PackedCodes;
        // codes correlated with the label are learnable
        let mut rng = Rng::new(19);
        let (k, b, n) = (24, 4, 400);
        let mut pc = PackedCodes::new(b, k);
        let mut labels = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let row: Vec<u16> = (0..k)
                .map(|_| {
                    if pos {
                        rng.below(8) as u16
                    } else {
                        8 + rng.below(8) as u16
                    }
                })
                .collect();
            pc.push_row(&row).unwrap();
            labels.push(if pos { 1 } else { -1 });
        }
        let bb = BbitDataset::new(pc, labels);
        let (model, _) = train_svm(&bb, &SvmConfig::with_c(1.0));
        assert!(accuracy(&model, &bb) > 0.99);
    }
}
