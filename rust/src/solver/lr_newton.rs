//! Newton-CG for L2-regularized logistic regression (the TRON family —
//! LIBLINEAR's `-s 0` solver, used by the paper for Figures 3–4, 6).
//!
//! Solves  min_w  f(w) = ½‖w‖² + C Σᵢ log(1 + e^{−yᵢ wᵀxᵢ})  with exact
//! Newton directions from conjugate gradient on the Hessian system
//!
//!   ∇f  = w + C Σ (σᵢ − 1) yᵢ xᵢ,         σᵢ = 1/(1 + e^{−yᵢ wᵀxᵢ})
//!   ∇²f·v = v + C Σ σᵢ(1 − σᵢ) (xᵢᵀv) xᵢ
//!
//! followed by Armijo backtracking.  Hessian-vector products never form
//! the Hessian — each is two sweeps over the data, O(total nnz).

use std::time::Instant;

use crate::solver::linear::{FeatureMatrix, LinearModel, TrainStats};

/// Solver configuration.
#[derive(Clone, Debug)]
pub struct LrConfig {
    pub c: f64,
    /// Stop when ‖∇f‖ ≤ eps · ‖∇f(0)‖ (LIBLINEAR's relative rule).
    pub eps: f64,
    pub max_newton_iter: usize,
    pub max_cg_iter: usize,
}

impl Default for LrConfig {
    fn default() -> Self {
        LrConfig { c: 1.0, eps: 1e-2, max_newton_iter: 50, max_cg_iter: 30 }
    }
}

impl LrConfig {
    pub fn with_c(c: f64) -> Self {
        LrConfig { c, ..Default::default() }
    }
}

fn objective<F: FeatureMatrix>(data: &F, w: &[f32], margins: &[f64], c: f64) -> f64 {
    let reg = 0.5 * w.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
    let ll: f64 = (0..data.n())
        .map(|i| {
            let ym = data.label(i) as f64 * margins[i];
            // stable log(1+e^{-ym})
            if ym > 0.0 {
                (-ym).exp().ln_1p()
            } else {
                -ym + ym.exp().ln_1p()
            }
        })
        .sum();
    reg + c * ll
}

fn compute_margins<F: FeatureMatrix>(data: &F, w: &[f32], out: &mut [f64]) {
    for (i, m) in out.iter_mut().enumerate() {
        *m = data.dot(i, w) as f64;
    }
}

/// ∇f into `grad`; also fills `sigma[i] = σᵢ` for the Hessian products.
fn gradient<F: FeatureMatrix>(
    data: &F,
    w: &[f32],
    margins: &[f64],
    c: f64,
    grad: &mut [f32],
    sigma: &mut [f64],
) {
    grad.iter_mut().zip(w).for_each(|(g, &wi)| *g = wi);
    for i in 0..data.n() {
        let y = data.label(i) as f64;
        let s = 1.0 / (1.0 + (-y * margins[i]).exp());
        sigma[i] = s;
        let coef = c * (s - 1.0) * y;
        data.axpy(i, coef as f32, grad);
    }
}

/// Hessian-vector product Hv = v + C Σ σ(1−σ)(xᵀv)x into `out`.
fn hessian_vec<F: FeatureMatrix>(
    data: &F,
    v: &[f32],
    sigma: &[f64],
    c: f64,
    out: &mut [f32],
) {
    out.copy_from_slice(v);
    for i in 0..data.n() {
        let s = sigma[i];
        let dii = s * (1.0 - s);
        if dii <= 1e-300 {
            continue;
        }
        let xv = data.dot(i, v) as f64;
        data.axpy(i, (c * dii * xv) as f32, out);
    }
}

fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

/// Train logistic regression by Newton-CG.
pub fn train_lr<F: FeatureMatrix>(data: &F, cfg: &LrConfig) -> (LinearModel, TrainStats) {
    let t0 = Instant::now();
    let dim = data.dim();
    let n = data.n();
    let mut w = vec![0.0f32; dim];
    let mut margins = vec![0.0f64; n];
    let mut sigma = vec![0.0f64; n];
    let mut grad = vec![0.0f32; dim];
    let mut stats = TrainStats::default();

    compute_margins(data, &w, &mut margins);
    gradient(data, &w, &margins, cfg.c, &mut grad, &mut sigma);
    let g0_norm = dot64(&grad, &grad).sqrt();
    let tol = cfg.eps * g0_norm.max(1e-12);

    // CG scratch
    let mut dir = vec![0.0f32; dim];
    let mut r = vec![0.0f32; dim];
    let mut p = vec![0.0f32; dim];
    let mut hp = vec![0.0f32; dim];
    let mut w_new = vec![0.0f32; dim];
    let mut margins_new = vec![0.0f64; n];

    for iter in 0..cfg.max_newton_iter {
        stats.iterations = iter + 1;
        let gnorm = dot64(&grad, &grad).sqrt();
        if gnorm <= tol {
            stats.converged = true;
            break;
        }
        // --- CG: solve H d = −g ---
        dir.fill(0.0);
        r.iter_mut().zip(&grad).for_each(|(ri, &gi)| *ri = -gi);
        p.copy_from_slice(&r);
        let mut rsq = dot64(&r, &r);
        let cg_tol = (0.1f64 * rsq.sqrt()).max(1e-20);
        for _ in 0..cfg.max_cg_iter {
            hessian_vec(data, &p, &sigma, cfg.c, &mut hp);
            let php = dot64(&p, &hp);
            if php <= 0.0 {
                break; // should not happen: H ⪰ I
            }
            let alpha = rsq / php;
            for j in 0..dim {
                dir[j] += alpha as f32 * p[j];
                r[j] -= alpha as f32 * hp[j];
            }
            let rsq_new = dot64(&r, &r);
            if rsq_new.sqrt() <= cg_tol {
                break;
            }
            let beta = rsq_new / rsq;
            for j in 0..dim {
                p[j] = r[j] + beta as f32 * p[j];
            }
            rsq = rsq_new;
        }
        // --- Armijo backtracking on f along dir ---
        let f_old = objective(data, &w, &margins, cfg.c);
        let g_dot_d = dot64(&grad, &dir);
        let mut step = 1.0f64;
        let mut accepted = false;
        for _ in 0..30 {
            for j in 0..dim {
                w_new[j] = w[j] + (step * dir[j] as f64) as f32;
            }
            compute_margins(data, &mut w_new, &mut margins_new);
            let f_new = objective(data, &w_new, &margins_new, cfg.c);
            if f_new <= f_old + 1e-4 * step * g_dot_d {
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            break; // no descent possible within precision — done
        }
        std::mem::swap(&mut w, &mut w_new);
        std::mem::swap(&mut margins, &mut margins_new);
        gradient(data, &w, &margins, cfg.c, &mut grad, &mut sigma);
    }

    stats.objective = objective(data, &w, &margins, cfg.c);
    stats.train_seconds = t0.elapsed().as_secs_f64();
    (LinearModel { w }, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Example, SparseDataset};
    use crate::solver::linear::accuracy;
    use crate::util::Rng;

    fn separable(n: usize, seed: u64) -> SparseDataset {
        let mut rng = Rng::new(seed);
        let mut examples = Vec::new();
        for _ in 0..n {
            let pos = rng.bool();
            let base = if pos { 0 } else { 12 };
            let feats: Vec<u32> =
                (0..5).map(|_| base + rng.below(12) as u32).collect();
            examples.push(Example::binary(if pos { 1 } else { -1 }, feats));
        }
        SparseDataset::from_examples(24, &examples)
    }

    #[test]
    fn separable_reaches_high_accuracy_and_converges() {
        let ds = separable(300, 23);
        let (model, stats) = train_lr(&ds, &LrConfig::with_c(1.0));
        assert!(accuracy(&model, &ds) > 0.99);
        assert!(stats.converged, "{stats:?}");
        assert!(stats.objective.is_finite());
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let ds = separable(40, 29);
        let c = 0.7;
        let dim = 24;
        let mut rng = Rng::new(31);
        let w: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut margins = vec![0.0; 40];
        compute_margins(&ds, &w, &mut margins);
        let mut grad = vec![0.0f32; dim];
        let mut sigma = vec![0.0; 40];
        gradient(&ds, &w, &margins, c, &mut grad, &mut sigma);
        let eps = 1e-3f32;
        for j in [0usize, 5, 13, 23] {
            let mut wp = w.clone();
            wp[j] += eps;
            let mut mp = vec![0.0; 40];
            compute_margins(&ds, &wp, &mut mp);
            let fp = objective(&ds, &wp, &mp, c);
            let mut wm = w.clone();
            wm[j] -= eps;
            compute_margins(&ds, &wm, &mut mp);
            let fm = objective(&ds, &wm, &mp, c);
            let fd = (fp - fm) / (2.0 * eps as f64);
            assert!(
                (fd - grad[j] as f64).abs() < 1e-2 * (1.0 + fd.abs()),
                "j={j} fd={fd} grad={}",
                grad[j]
            );
        }
    }

    #[test]
    fn hessian_vec_matches_gradient_difference() {
        let ds = separable(30, 37);
        let c = 1.3;
        let dim = 24;
        let mut rng = Rng::new(41);
        let w: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 0.05).collect();
        let v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let mut margins = vec![0.0; 30];
        compute_margins(&ds, &w, &mut margins);
        let mut sigma = vec![0.0; 30];
        let mut g = vec![0.0f32; dim];
        gradient(&ds, &w, &margins, c, &mut g, &mut sigma);
        let mut hv = vec![0.0f32; dim];
        hessian_vec(&ds, &v, &sigma, c, &mut hv);
        // finite difference of the gradient along v
        let eps = 1e-3f32;
        let wp: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        compute_margins(&ds, &wp, &mut margins);
        let mut gp = vec![0.0f32; dim];
        gradient(&ds, &wp, &margins, c, &mut gp, &mut sigma);
        for j in 0..dim {
            let fd = (gp[j] - g[j]) / eps;
            assert!(
                (fd as f64 - hv[j] as f64).abs() < 0.05 * (1.0 + fd.abs() as f64),
                "j={j} fd={fd} hv={}",
                hv[j]
            );
        }
    }

    #[test]
    fn objective_below_zero_init() {
        let ds = separable(100, 43);
        let c = 1.0;
        let (model, stats) = train_lr(&ds, &LrConfig::with_c(c));
        let f0 = 100.0 * c * (2.0f64).ln(); // f(0) = C·n·log2
        assert!(stats.objective < f0);
        assert!(model.w.iter().any(|&x| x != 0.0));
    }
}
