//! Artifact manifest parser.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` describing every
//! lowered HLO module: file name, baked-in constants (p, d_space, b, k,
//! batch, …) and input/output dtypes+shapes.  Line-oriented records:
//!
//! ```text
//! artifact minhash_k200
//! file minhash_k200.hlo.txt
//! const k 200
//! input arg0 int32 256x1024
//! output int32 256x200
//! end
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Tensor dtype as named in the manifest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    I64,
    U64,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "float32" => DType::F32,
            "int32" => DType::I32,
            "uint32" => DType::U32,
            "int64" => DType::I64,
            "uint64" => DType::U64,
            other => return Err(Error::Manifest(format!("unknown dtype {other:?}"))),
        })
    }
}

/// A tensor specification (dtype + shape; empty shape = scalar).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(dtype: &str, dims: &str) -> Result<Self> {
        let dtype = DType::parse(dtype)?;
        let shape = if dims == "scalar" {
            Vec::new()
        } else {
            dims.split('x')
                .map(|d| {
                    d.parse::<usize>()
                        .map_err(|_| Error::Manifest(format!("bad dim {d:?}")))
                })
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { dtype, shape })
    }
}

/// One AOT'd artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub consts: BTreeMap<String, i64>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    /// Integer constant baked at lowering time (e.g. k, batch, d_space).
    pub fn konst(&self, key: &str) -> Result<i64> {
        self.consts
            .get(key)
            .copied()
            .ok_or_else(|| Error::Manifest(format!("{}: missing const {key}", self.name)))
    }
}

/// The parsed manifest: name → spec.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut m = Manifest { artifacts: BTreeMap::new(), dir: dir.to_path_buf() };
        let mut cur: Option<ArtifactSpec> = None;
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut toks = line.split_ascii_whitespace();
            let tag = toks.next().unwrap();
            let rest: Vec<&str> = toks.collect();
            let bad = |msg: &str| Error::Manifest(format!("line {}: {msg}", no + 1));
            match (tag, rest.as_slice()) {
                ("artifact", [name]) => {
                    if cur.is_some() {
                        return Err(bad("nested artifact record"));
                    }
                    cur = Some(ArtifactSpec {
                        name: name.to_string(),
                        file: PathBuf::new(),
                        consts: BTreeMap::new(),
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
                ("file", [f]) => {
                    cur.as_mut().ok_or_else(|| bad("file outside artifact"))?.file =
                        dir.join(f);
                }
                ("const", [key, val]) => {
                    let v: i64 =
                        val.parse().map_err(|_| bad(&format!("bad const {val:?}")))?;
                    cur.as_mut()
                        .ok_or_else(|| bad("const outside artifact"))?
                        .consts
                        .insert(key.to_string(), v);
                }
                ("input", [_name, dtype, dims]) => {
                    let spec = TensorSpec::parse(dtype, dims)?;
                    cur.as_mut().ok_or_else(|| bad("input outside artifact"))?.inputs.push(spec);
                }
                ("output", [dtype, dims]) => {
                    let spec = TensorSpec::parse(dtype, dims)?;
                    cur.as_mut().ok_or_else(|| bad("output outside artifact"))?.outputs.push(spec);
                }
                ("end", []) => {
                    let spec = cur.take().ok_or_else(|| bad("end without artifact"))?;
                    if spec.file.as_os_str().is_empty() {
                        return Err(bad("artifact missing file"));
                    }
                    m.artifacts.insert(spec.name.clone(), spec);
                }
                _ => return Err(bad(&format!("unrecognized line {line:?}"))),
            }
        }
        if cur.is_some() {
            return Err(Error::Manifest("unterminated artifact record".into()));
        }
        Ok(m)
    }

    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Manifest::parse(&text, dir)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown artifact {name:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact minhash_k200
file minhash_k200.hlo.txt
const p 2147483647
const k 200
input arg0 int32 256x1024
input arg1 int32 256x1024
input arg2 uint32 200
input arg3 uint32 200
output int32 256x200
end
artifact train_logistic_b8_k200
file train_logistic_b8_k200.hlo.txt
const b 8
input arg0 float32 51200
input arg3 float32 scalar
output float32 51200
output int32 scalar
end
";

    #[test]
    fn parses_records() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let mh = m.get("minhash_k200").unwrap();
        assert_eq!(mh.konst("k").unwrap(), 200);
        assert_eq!(mh.inputs.len(), 4);
        assert_eq!(mh.inputs[0].shape, vec![256, 1024]);
        assert_eq!(mh.inputs[2].dtype, DType::U32);
        assert_eq!(mh.outputs[0].elements(), 256 * 200);
        assert_eq!(mh.file, Path::new("/tmp/a/minhash_k200.hlo.txt"));
        let tr = m.get("train_logistic_b8_k200").unwrap();
        assert_eq!(tr.inputs[1].shape, Vec::<usize>::new()); // scalar
        assert_eq!(tr.inputs[1].elements(), 1);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("const x 1\n", Path::new(".")).is_err());
        assert!(Manifest::parse("artifact a\nend\n", Path::new(".")).is_err()); // no file
        assert!(Manifest::parse("artifact a\nfile f\n", Path::new(".")).is_err()); // no end
        assert!(Manifest::parse("artifact a\nfile f\ninput x badtype 2\nend\n", Path::new("."))
            .is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.get("nope").is_err());
        assert!(m.get("minhash_k200").unwrap().konst("zzz").is_err());
    }
}
