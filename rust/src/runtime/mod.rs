//! PJRT runtime: load `artifacts/*.hlo.txt`, compile on the CPU client,
//! execute from the coordinator's hot path.
//!
//! Pattern (from /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  Text is the interchange format
//! because jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects.
//!
//! [`PjrtRuntime`] caches compiled executables by artifact name; the
//! high-level engines ([`MinhashEngine`], [`VwEngine`], [`TrainEngine`])
//! wrap padding, literal construction and output unpacking for the three
//! artifact families (preprocess / train / predict).
//!
//! ## Worker-stage integration (device-batched preprocessing)
//!
//! The runtime also sits on the ingest hot path: `preprocess --device xla`
//! routes every pipeline worker's encode stage through a
//! [`DeviceEncoder`](crate::encode::device::DeviceEncoder).  The PJRT
//! client is not `Sync` (and is treated as not `Send`), so it never
//! crosses threads — the encoder owns one dedicated driver thread that
//! constructs the [`PjrtRuntime`] and its engine, and the workers feed it
//! pre-padded `[batch, nnz]` CSR slabs over a bounded channel.
//! [`MinhashEngine::minhash_padded`] / [`VwEngine::hash_padded`] are the
//! launch entry points for that path: the caller owns padding and
//! double-buffering, the engine owns literal construction and unpacking.
//! Every launch goes through [`HostInput`], which validates dtype/shape
//! against the manifest *before* any literal is built, so a geometry
//! mismatch fails as a typed [`Error::Runtime`] naming the artifact and
//! offending input instead of an opaque XLA abort.

pub mod manifest;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::encode::packed::PackedCodes;
use crate::hashing::universal::UniversalFamily;
use crate::runtime::manifest::{ArtifactSpec, DType, Manifest};
use crate::{Error, Result};

/// A host-side tensor handed to [`LoadedArtifact::execute`]: the raw data
/// plus the logical dims, so the launch can be validated against the
/// manifest's [`ArtifactSpec`] before any literal is built.  Rank-0
/// inputs use the `Scalar*` variants (XLA distinguishes a scalar from a
/// one-element vector).
pub enum HostInput<'a> {
    F32 { data: &'a [f32], dims: &'a [usize] },
    I32 { data: &'a [i32], dims: &'a [usize] },
    U32 { data: &'a [u32], dims: &'a [usize] },
    ScalarF32(f32),
    ScalarI32(i32),
}

impl HostInput<'_> {
    fn dtype(&self) -> DType {
        match self {
            HostInput::F32 { .. } | HostInput::ScalarF32(_) => DType::F32,
            HostInput::I32 { .. } | HostInput::ScalarI32(_) => DType::I32,
            HostInput::U32 { .. } => DType::U32,
        }
    }

    fn dims(&self) -> &[usize] {
        match self {
            HostInput::F32 { dims, .. }
            | HostInput::I32 { dims, .. }
            | HostInput::U32 { dims, .. } => dims,
            HostInput::ScalarF32(_) | HostInput::ScalarI32(_) => &[],
        }
    }

    fn len(&self) -> usize {
        match self {
            HostInput::F32 { data, .. } => data.len(),
            HostInput::I32 { data, .. } => data.len(),
            HostInput::U32 { data, .. } => data.len(),
            HostInput::ScalarF32(_) | HostInput::ScalarI32(_) => 1,
        }
    }

    fn is_scalar(&self) -> bool {
        matches!(self, HostInput::ScalarF32(_) | HostInput::ScalarI32(_))
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        fn shaped(lit: xla::Literal, dims: &[usize]) -> Result<xla::Literal> {
            if dims.len() <= 1 {
                return Ok(lit); // vec1 already carries rank-1 shape
            }
            let shape: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&shape)?)
        }
        match self {
            HostInput::F32 { data, dims } => shaped(xla::Literal::vec1(data), dims),
            HostInput::I32 { data, dims } => shaped(xla::Literal::vec1(data), dims),
            HostInput::U32 { data, dims } => shaped(xla::Literal::vec1(data), dims),
            HostInput::ScalarF32(v) => Ok(xla::Literal::scalar(*v)),
            HostInput::ScalarI32(v) => Ok(xla::Literal::scalar(*v)),
        }
    }
}

/// Manifest dtype names (`float32`, `int32`, …) for error messages.
fn dtype_str(d: DType) -> &'static str {
    match d {
        DType::F32 => "float32",
        DType::I32 => "int32",
        DType::U32 => "uint32",
        DType::I64 => "int64",
        DType::U64 => "uint64",
    }
}

/// Manifest shape notation (`256x1024`, `scalar`) for error messages.
fn dims_str(dims: &[usize]) -> String {
    if dims.is_empty() {
        return "scalar".to_string();
    }
    dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
}

/// Validate a launch against the manifest spec — input count, per-input
/// dtype, shape, and data length — so a geometry mismatch surfaces as a
/// typed error naming the artifact, the offending input index, and
/// expected-vs-got, instead of an opaque XLA error at launch time.
pub(crate) fn validate_inputs(spec: &ArtifactSpec, inputs: &[HostInput<'_>]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        return Err(Error::Runtime(format!(
            "{}: got {} inputs, artifact wants {}",
            spec.name,
            inputs.len(),
            spec.inputs.len()
        )));
    }
    for (i, (got, want)) in inputs.iter().zip(&spec.inputs).enumerate() {
        if got.dtype() != want.dtype {
            return Err(Error::Runtime(format!(
                "{}: input {i} dtype mismatch — artifact wants {} {}, got {} {}",
                spec.name,
                dtype_str(want.dtype),
                dims_str(&want.shape),
                dtype_str(got.dtype()),
                dims_str(got.dims()),
            )));
        }
        if want.shape.is_empty() && !got.is_scalar() {
            return Err(Error::Runtime(format!(
                "{}: input {i} is rank-0 — pass HostInput::ScalarF32/ScalarI32, \
                 got {} {}",
                spec.name,
                dtype_str(got.dtype()),
                dims_str(got.dims()),
            )));
        }
        if got.dims() != want.shape.as_slice() {
            return Err(Error::Runtime(format!(
                "{}: input {i} shape mismatch — artifact wants {}, got {}",
                spec.name,
                dims_str(&want.shape),
                dims_str(got.dims()),
            )));
        }
        let want_len = want.elements();
        if got.len() != want_len {
            return Err(Error::Runtime(format!(
                "{}: input {i} carries {} elements for shape {} ({} elements)",
                spec.name,
                got.len(),
                dims_str(&want.shape),
                want_len,
            )));
        }
    }
    Ok(())
}

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Validate `inputs` against the manifest spec ([`validate_inputs`]),
    /// build the literals, and execute; returns the flattened tuple
    /// outputs (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, inputs: &[HostInput<'_>]) -> Result<Vec<xla::Literal>> {
        validate_inputs(&self.spec, inputs)?;
        let lits = inputs
            .iter()
            .map(HostInput::to_literal)
            .collect::<Result<Vec<_>>>()?;
        self.execute_literals(&lits)
    }

    /// Execute pre-built positional literals (arity-checked only — the
    /// typed geometry validation lives in [`execute`], which callers
    /// should prefer).
    pub fn execute_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} inputs, artifact wants {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// Client + compiled-executable cache.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<LoadedArtifact>>>,
}

impl PjrtRuntime {
    /// CPU PJRT client over the artifact directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load+compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Arc<LoadedArtifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let loaded = Arc::new(LoadedArtifact { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }
}

/// Batched minwise hashing through the PJRT `minhash_*` artifact — the
/// paper's GPU-preprocessing path (Table 2, last column).
pub struct MinhashEngine {
    artifact: Arc<LoadedArtifact>,
    /// Documents per execute call.
    pub batch: usize,
    /// Padded nonzeros per document.
    pub nnz: usize,
    /// Number of hash functions k.
    pub k: usize,
    /// Rehash space D.
    pub d_space: u64,
}

impl MinhashEngine {
    /// `name` is `minhash_k200` / `minhash_k512` (see aot.py).
    pub fn new(rt: &PjrtRuntime, name: &str) -> Result<Self> {
        let artifact = rt.load(name)?;
        let spec = &artifact.spec;
        let (batch, k, nnz, d_space) = (
            spec.konst("batch")? as usize,
            spec.konst("k")? as usize,
            spec.konst("nnz")? as usize,
            spec.konst("d_space")? as u64,
        );
        Ok(MinhashEngine { artifact, batch, nnz, k, d_space })
    }

    /// Execute one pre-padded `[batch, nnz]` launch: `idx`/`mask` are the
    /// caller-owned padded slabs, `c1`/`c2` the family parameters.
    /// Returns the full row-major `[batch, k]` minwise output.  This is
    /// the device-encoder driver's entry point — the caller owns padding
    /// and double-buffering, so upload overlaps compute.
    pub fn minhash_padded(
        &self,
        idx: &[i32],
        mask: &[i32],
        c1: &[u32],
        c2: &[u32],
    ) -> Result<Vec<i32>> {
        let outputs = self.artifact.execute(&[
            HostInput::I32 { data: idx, dims: &[self.batch, self.nnz] },
            HostInput::I32 { data: mask, dims: &[self.batch, self.nnz] },
            HostInput::U32 { data: c1, dims: &[self.k] },
            HostInput::U32 { data: c2, dims: &[self.k] },
        ])?;
        Ok(outputs[0].to_vec()?)
    }

    /// Minwise-hash up to `batch` sets with the family's parameters; rows
    /// longer than the padded width are an error (callers chunk/fall back).
    /// Returns row-major `[rows, k]` minwise values.
    pub fn minhash_batch(
        &self,
        sets: &[&[u32]],
        family: &UniversalFamily,
    ) -> Result<Vec<u32>> {
        if sets.len() > self.batch {
            return Err(Error::InvalidArg(format!(
                "batch {} exceeds artifact batch {}",
                sets.len(),
                self.batch
            )));
        }
        if family.k() != self.k {
            return Err(Error::InvalidArg(format!(
                "family k={} != artifact k={}",
                family.k(),
                self.k
            )));
        }
        let mut idx = vec![0i32; self.batch * self.nnz];
        let mut mask = vec![0i32; self.batch * self.nnz];
        for (r, set) in sets.iter().enumerate() {
            if set.len() > self.nnz {
                return Err(Error::InvalidArg(format!(
                    "row {r} has {} nonzeros > padded {}",
                    set.len(),
                    self.nnz
                )));
            }
            let base = r * self.nnz;
            for (c, &t) in set.iter().enumerate() {
                idx[base + c] = t as i32;
                mask[base + c] = 1;
            }
        }
        let (c1, c2) = family.param_arrays();
        let z = self.minhash_padded(&idx, &mask, &c1, &c2)?;
        Ok(z[..sets.len() * self.k].iter().map(|&v| v as u32).collect())
    }

    /// Hash + b-bit truncate straight into a [`PackedCodes`] (rows appended).
    pub fn codes_batch(
        &self,
        sets: &[&[u32]],
        family: &UniversalFamily,
        b: u32,
        out: &mut PackedCodes,
    ) -> Result<()> {
        let z = self.minhash_batch(sets, family)?;
        let mask = (1u32 << b) - 1;
        let mut row = vec![0u16; self.k];
        for r in 0..sets.len() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = (z[r * self.k + j] & mask) as u16;
            }
            out.push_row(&row)?;
        }
        Ok(())
    }
}

/// Size-routing wrapper over two [`MinhashEngine`]s: documents are routed
/// to the smallest padded-nnz artifact they fit, each bucket flushing as a
/// full batch.  Padded work is wasted work — on corpora where most
/// documents are short this cuts the accelerated preprocessing cost by
/// roughly `nnz_large / nnz_small` (§Perf; the coordinator's answer to
/// the paper's "preprocessing is trivially parallelizable" at the batch
/// level).  Output codes are re-emitted in input order.
pub struct RoutedMinhash {
    /// Engines sorted by ascending padded nnz; a document routes to the
    /// first one it fits.
    tiers: Vec<MinhashEngine>,
}

impl RoutedMinhash {
    /// Build from artifact names (any count ≥ 1, any order; all must share
    /// k and d).  Convenience: [`new`] keeps the original two-tier call.
    pub fn from_names(rt: &PjrtRuntime, names: &[&str]) -> Result<Self> {
        if names.is_empty() {
            return Err(Error::InvalidArg("need at least one engine".into()));
        }
        let mut tiers = names
            .iter()
            .map(|n| MinhashEngine::new(rt, n))
            .collect::<Result<Vec<_>>>()?;
        tiers.sort_by_key(|e| e.nnz);
        let (k, d) = (tiers[0].k, tiers[0].d_space);
        if tiers.iter().any(|e| e.k != k || e.d_space != d) {
            return Err(Error::InvalidArg("routed engines must share k and d".into()));
        }
        if tiers.windows(2).any(|w| w[0].nnz == w[1].nnz) {
            return Err(Error::InvalidArg("duplicate nnz tier".into()));
        }
        Ok(RoutedMinhash { tiers })
    }

    pub fn new(rt: &PjrtRuntime, small_name: &str, large_name: &str) -> Result<Self> {
        Self::from_names(rt, &[small_name, large_name])
    }

    pub fn k(&self) -> usize {
        self.tiers[0].k
    }

    pub fn d_space(&self) -> u64 {
        self.tiers[0].d_space
    }

    /// Minwise-hash any number of sets, routing by size and batching per
    /// tier.  Returns row-major `[sets.len(), k]` minwise values in input
    /// order.
    pub fn minhash_all(
        &self,
        sets: &[&[u32]],
        family: &UniversalFamily,
    ) -> Result<Vec<u32>> {
        let k = self.k();
        let mut out = vec![0u32; sets.len() * k];
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); self.tiers.len()];
        'docs: for (pos, set) in sets.iter().enumerate() {
            for (tier, engine) in self.tiers.iter().enumerate() {
                if set.len() <= engine.nnz {
                    buckets[tier].push(pos);
                    continue 'docs;
                }
            }
            return Err(Error::InvalidArg(format!(
                "document {pos} has {} nonzeros > largest padded {}",
                set.len(),
                self.tiers.last().unwrap().nnz
            )));
        }
        for (tier, members) in buckets.iter().enumerate() {
            let engine = &self.tiers[tier];
            for batch in members.chunks(engine.batch) {
                let refs: Vec<&[u32]> = batch.iter().map(|&p| sets[p]).collect();
                let z = engine.minhash_batch(&refs, family)?;
                for (row, &pos) in batch.iter().enumerate() {
                    out[pos * k..(pos + 1) * k]
                        .copy_from_slice(&z[row * k..(row + 1) * k]);
                }
            }
        }
        Ok(out)
    }
}

/// VW hashing through the PJRT `vw_bins*` artifact.
pub struct VwEngine {
    artifact: Arc<LoadedArtifact>,
    pub batch: usize,
    pub nnz: usize,
    pub bins: usize,
}

impl VwEngine {
    pub fn new(rt: &PjrtRuntime, name: &str) -> Result<Self> {
        let artifact = rt.load(name)?;
        let spec = &artifact.spec;
        Ok(VwEngine {
            batch: spec.konst("batch")? as usize,
            nnz: spec.konst("nnz")? as usize,
            bins: spec.konst("bins")? as usize,
            artifact,
        })
    }

    /// Execute one pre-padded `[batch, nnz]` launch with the hasher's
    /// `(bin c1, bin c2, sign c1, sign c2)` parameters; returns the full
    /// row-major `[batch, bins]` dense output.  Device-encoder driver
    /// entry point, like [`MinhashEngine::minhash_padded`].
    pub fn hash_padded(&self, idx: &[i32], mask: &[i32], params: [u32; 4]) -> Result<Vec<f32>> {
        let outputs = self.artifact.execute(&[
            HostInput::I32 { data: idx, dims: &[self.batch, self.nnz] },
            HostInput::I32 { data: mask, dims: &[self.batch, self.nnz] },
            HostInput::U32 { data: &params, dims: &[4] },
        ])?;
        Ok(outputs[0].to_vec()?)
    }

    /// Returns row-major `[rows, bins]` hashed vectors.
    pub fn hash_batch(&self, sets: &[&[u32]], params: [u32; 4]) -> Result<Vec<f32>> {
        if sets.len() > self.batch {
            return Err(Error::InvalidArg("batch too large".into()));
        }
        let mut idx = vec![0i32; self.batch * self.nnz];
        let mut mask = vec![0i32; self.batch * self.nnz];
        for (r, set) in sets.iter().enumerate() {
            if set.len() > self.nnz {
                return Err(Error::InvalidArg(format!(
                    "row {r} has {} nonzeros > padded {}",
                    set.len(),
                    self.nnz
                )));
            }
            let base = r * self.nnz;
            for (c, &t) in set.iter().enumerate() {
                idx[base + c] = t as i32;
                mask[base + c] = 1;
            }
        }
        let v = self.hash_padded(&idx, &mask, params)?;
        Ok(v[..sets.len() * self.bins].to_vec())
    }
}

/// SGD training + prediction over b-bit codes through the PJRT
/// `train_{loss}_b*_k*` / `predict_b*_k*` artifacts.  A device-side scan
/// runs `chunk/batch` minibatch steps per execute call; python is not
/// involved.
pub struct TrainEngine {
    train: Arc<LoadedArtifact>,
    predict: Arc<LoadedArtifact>,
    /// Weight vector (host copy; ping-ponged through the artifact).
    pub w: Vec<f32>,
    pub b: u32,
    pub k: usize,
    pub chunk: usize,
    pub batch: usize,
    pub pred_n: usize,
    step: i32,
}

impl TrainEngine {
    pub fn new(rt: &PjrtRuntime, train_name: &str, predict_name: &str) -> Result<Self> {
        let train = rt.load(train_name)?;
        let predict = rt.load(predict_name)?;
        let spec = &train.spec;
        let dim = spec.konst("dim")? as usize;
        Ok(TrainEngine {
            b: spec.konst("b")? as u32,
            k: spec.konst("k")? as usize,
            chunk: spec.konst("chunk")? as usize,
            batch: spec.konst("batch")? as usize,
            pred_n: predict.spec.konst("n")? as usize,
            w: vec![0.0; dim],
            train,
            predict,
            step: 0,
        })
    }

    /// Run one chunk of SGD steps on row-major `[rows, k]` codes
    /// (`rows ≤ chunk`).  Short chunks are padded by wrapping rows, which
    /// keeps the decay schedule continuous — callers pass full chunks
    /// except possibly the last.
    pub fn train_chunk(
        &mut self,
        codes: &[i32],
        labels: &[f32],
        lr0: f32,
        lambda: f32,
    ) -> Result<()> {
        let rows = labels.len();
        if rows == 0 {
            return Ok(());
        }
        if codes.len() != rows * self.k {
            return Err(Error::InvalidArg("codes/labels shape mismatch".into()));
        }
        let mut c = vec![0i32; self.chunk * self.k];
        let mut y = vec![0f32; self.chunk];
        for r in 0..self.chunk {
            let src = r % rows;
            c[r * self.k..(r + 1) * self.k]
                .copy_from_slice(&codes[src * self.k..(src + 1) * self.k]);
            y[r] = labels[src];
        }
        let outputs = self.train.execute(&[
            HostInput::F32 { data: &self.w, dims: &[self.w.len()] },
            HostInput::I32 { data: &c, dims: &[self.chunk, self.k] },
            HostInput::F32 { data: &y, dims: &[self.chunk] },
            HostInput::ScalarF32(lr0),
            HostInput::ScalarF32(lambda),
            HostInput::ScalarI32(self.step),
        ])?;
        self.w = outputs[0].to_vec()?;
        self.step = outputs[1].to_vec::<i32>()?[0];
        Ok(())
    }

    /// Margins for row-major `[rows, k]` codes (internally batched to the
    /// predict artifact's row count).
    pub fn margins(&self, codes: &[i32]) -> Result<Vec<f32>> {
        let rows = codes.len() / self.k;
        let mut out = Vec::with_capacity(rows);
        let mut i0 = 0usize;
        while i0 < rows {
            let take = (rows - i0).min(self.pred_n);
            let mut c = vec![0i32; self.pred_n * self.k];
            c[..take * self.k]
                .copy_from_slice(&codes[i0 * self.k..(i0 + take) * self.k]);
            let outputs = self.predict.execute(&[
                HostInput::F32 { data: &self.w, dims: &[self.w.len()] },
                HostInput::I32 { data: &c, dims: &[self.pred_n, self.k] },
            ])?;
            let m: Vec<f32> = outputs[0].to_vec()?;
            out.extend_from_slice(&m[..take]);
            i0 += take;
        }
        Ok(out)
    }

    pub fn steps_done(&self) -> i32 {
        self.step
    }

    pub fn reset(&mut self) {
        self.w.fill(0.0);
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;
    use std::path::PathBuf;

    /// A hand-built spec: [2x3 int32, 4-vec uint32, scalar float32] —
    /// validation is pure host-side logic, no PJRT client needed.
    fn toy_spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "toy".to_string(),
            file: PathBuf::from("toy.hlo.txt"),
            consts: BTreeMap::new(),
            inputs: vec![
                TensorSpec { dtype: DType::I32, shape: vec![2, 3] },
                TensorSpec { dtype: DType::U32, shape: vec![4] },
                TensorSpec { dtype: DType::F32, shape: Vec::new() },
            ],
            outputs: Vec::new(),
        }
    }

    #[test]
    fn validate_accepts_matching_inputs() {
        let spec = toy_spec();
        let idx = [0i32; 6];
        let params = [0u32; 4];
        validate_inputs(
            &spec,
            &[
                HostInput::I32 { data: &idx, dims: &[2, 3] },
                HostInput::U32 { data: &params, dims: &[4] },
                HostInput::ScalarF32(1.5),
            ],
        )
        .unwrap();
    }

    #[test]
    fn validate_names_artifact_on_input_count_mismatch() {
        let spec = toy_spec();
        let err = validate_inputs(&spec, &[]).unwrap_err().to_string();
        assert!(err.contains("toy"), "{err}");
        assert!(err.contains("wants 3"), "{err}");
    }

    #[test]
    fn validate_names_offending_input_on_dtype_mismatch() {
        let spec = toy_spec();
        let wrong = [0.0f32; 6];
        let params = [0u32; 4];
        let err = validate_inputs(
            &spec,
            &[
                HostInput::F32 { data: &wrong, dims: &[2, 3] },
                HostInput::U32 { data: &params, dims: &[4] },
                HostInput::ScalarF32(0.0),
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("toy"), "{err}");
        assert!(err.contains("input 0"), "{err}");
        assert!(err.contains("int32"), "{err}");
        assert!(err.contains("float32"), "{err}");
    }

    #[test]
    fn validate_reports_expected_vs_got_shape() {
        let spec = toy_spec();
        let idx = [0i32; 6];
        let params = [0u32; 4];
        let err = validate_inputs(
            &spec,
            &[
                HostInput::I32 { data: &idx, dims: &[3, 2] },
                HostInput::U32 { data: &params, dims: &[4] },
                HostInput::ScalarF32(0.0),
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("input 0"), "{err}");
        assert!(err.contains("2x3"), "{err}");
        assert!(err.contains("3x2"), "{err}");
    }

    #[test]
    fn validate_rejects_vector_where_scalar_expected() {
        let spec = toy_spec();
        let idx = [0i32; 6];
        let params = [0u32; 4];
        let one = [0.0f32; 1];
        let err = validate_inputs(
            &spec,
            &[
                HostInput::I32 { data: &idx, dims: &[2, 3] },
                HostInput::U32 { data: &params, dims: &[4] },
                HostInput::F32 { data: &one, dims: &[] },
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("input 2"), "{err}");
        assert!(err.contains("Scalar"), "{err}");
    }

    #[test]
    fn validate_catches_data_length_vs_dims_mismatch() {
        let spec = toy_spec();
        let short = [0i32; 5]; // dims say 2x3 = 6
        let params = [0u32; 4];
        let err = validate_inputs(
            &spec,
            &[
                HostInput::I32 { data: &short, dims: &[2, 3] },
                HostInput::U32 { data: &params, dims: &[4] },
                HostInput::ScalarF32(0.0),
            ],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("input 0"), "{err}");
        assert!(err.contains("5 elements"), "{err}");
    }
}
