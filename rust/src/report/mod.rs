//! Table/figure rendering: fixed-width console tables and CSV export.
//! Every experiment harness prints through [`Table`] so EXPERIMENTS.md rows
//! are regenerated verbatim by `bbit-mh experiments <id>`.

use std::fmt::Write as _;
use std::fs::File;
use std::io::Write as _;
use std::path::Path;

use crate::Result;

/// A simple column-aligned table.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Raw row access (experiment harnesses derive summary tables from
    /// detail tables through this).
    pub fn rows_raw(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Render to a console string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, "{:<w$}  ", c, w = widths[i]);
            }
            let _ = writeln!(out, "{}", s.trim_end());
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total.min(120)));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Write as CSV (for plotting the paper's figures externally).
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format a float with a sensible number of digits for tables.
pub fn fnum(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.3}")
    } else {
        format!("{v:.5}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("a    bbbb"));
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("bbit_mh_test_csv");
        let path = dir.join("t.csv");
        let mut t = Table::new("x", &["c1", "c2"]);
        t.row(&["1".into(), "a".into()]);
        t.write_csv(&path).unwrap();
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content, "c1,c2\n1,a\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(123.456), "123.5");
        assert_eq!(fnum(1.23456), "1.235");
        assert_eq!(fnum(0.012345), "0.01235");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
