//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every layer of the coordinator.
#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("libsvm parse error at line {line}: {msg}")]
    LibsvmParse { line: usize, msg: String },

    #[error("config error: {0}")]
    Config(String),

    #[error("manifest error: {0}")]
    Manifest(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("pipeline error: {0}")]
    Pipeline(String),

    #[error("invalid argument: {0}")]
    InvalidArg(String),

    #[error("solver error: {0}")]
    Solver(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
